//! Deterministic DES trace emitter for the CI queue byte-diff.
//!
//! Runs a 2D (dp-dominated) sim, a fig9-scale pp > 1 pipeline sim, and a
//! checkpoint-restart goodput renewal on one of the two schedulers and
//! prints every result — full breakdowns, event counters, and the entire
//! goodput fault trace — with `{:.17e}` (round-trip exact for f64). CI
//! runs it twice and byte-diffs the outputs:
//!
//! ```sh
//! COMET_DES_QUEUE=heap     cargo run --release --example des_trace > a
//! COMET_DES_QUEUE=calendar cargo run --release --example des_trace > b
//! diff a b   # any byte of divergence fails the build
//! ```
//!
//! `heap` selects the retained `BinaryHeap` oracle queue, `calendar`
//! (the default) the production calendar queue; both drive the same
//! generic engine core, so the diff pins the scheduler swap end to end.

use comet::analytical::TrainingBreakdown;
use comet::config::presets;
use comet::model::inputs::{derive_inputs, EvalOptions, ModelInputs};
use comet::parallel::Strategy;
use comet::resilience::FaultModel;
use comet::sim::{
    simulate, simulate_goodput, simulate_goodput_oracle, simulate_oracle,
    FaultEventKind, SimResult,
};
use comet::workload::transformer::Transformer;

fn print_breakdown(tag: &str, b: &TrainingBreakdown) {
    println!("{tag}.fp_compute       {:.17e}", b.fp_compute);
    println!("{tag}.fp_exposed_comm  {:.17e}", b.fp_exposed_comm);
    println!("{tag}.ig_compute       {:.17e}", b.ig_compute);
    println!("{tag}.ig_exposed_comm  {:.17e}", b.ig_exposed_comm);
    println!("{tag}.wg_compute       {:.17e}", b.wg_compute);
    println!("{tag}.wg_exposed_comm  {:.17e}", b.wg_exposed_comm);
    println!("{tag}.bubble           {:.17e}", b.bubble);
    println!("{tag}.pp_exposed_comm  {:.17e}", b.pp_exposed_comm);
    println!("{tag}.total            {:.17e}", b.total());
}

fn print_result(tag: &str, r: &SimResult) {
    print_breakdown(tag, &r.breakdown);
    println!("{tag}.events           {}", r.stats.events);
    println!("{tag}.peak_events      {}", r.stats.peak_events);
    println!("{tag}.util_intra       {:.17e}", r.stats.util_intra);
    println!("{tag}.util_inter       {:.17e}", r.stats.util_inter);
}

fn main() -> comet::Result<()> {
    let queue = std::env::var("COMET_DES_QUEUE")
        .unwrap_or_else(|_| "calendar".to_string());
    let heap = match queue.as_str() {
        "heap" => true,
        "calendar" => false,
        other => {
            return Err(comet::Error::Config(format!(
                "COMET_DES_QUEUE: unknown queue '{other}' (heap|calendar)"
            )))
        }
    };
    // The queue name is deliberately NOT printed: the two outputs must
    // be byte-identical, including this header.
    println!("des_trace v1");

    let cluster = presets::dgx_a100_1024();
    let sim = |inp: &ModelInputs| {
        if heap {
            simulate_oracle(inp)
        } else {
            simulate(inp)
        }
    };

    // 2D dp-dominated config (Fig. 8a's optimum): the WG-overlap path
    // that actually exercises the event queue.
    let flat = derive_inputs(
        &Transformer::t1().build(&Strategy::new(8, 128)?)?,
        &cluster,
        &EvalOptions {
            ignore_capacity: true,
            ..Default::default()
        },
    )?;
    print_result("flat_mp8_dp128", &sim(&flat));

    // Fig. 9-scale pp > 1 pipeline config (1f1b, 8 microbatches).
    let pipe = derive_inputs(
        &Transformer::t1().build(&Strategy::new_3d(8, 32, 4)?)?,
        &cluster,
        &EvalOptions {
            ignore_capacity: true,
            microbatches: 8,
            ..Default::default()
        },
    )?;
    print_result("pipe_mp8_dp32_pp4", &sim(&pipe));

    // Goodput renewal with a converging geometry: MTBF ~ 200 steps,
    // restart 5 steps, 2k-step horizon — enough failures, checkpoints,
    // and restarts to exercise the whole trace machinery.
    let step = sim(&flat).breakdown.total();
    let n = cluster.n_nodes;
    let mut fault = FaultModel::none();
    fault.mtbf_node_hours = 200.0 * step * n as f64 / 3600.0;
    fault.restart_s = 5.0 * step;
    fault.straggler_frac = 0.02;
    fault.straggler_slowdown = 1.5;
    fault.seed = 7;
    let g = if heap {
        simulate_goodput_oracle(&flat, &fault, n, 2_000)
    } else {
        simulate_goodput(&flat, &fault, n, 2_000)
    };
    println!("goodput.ideal_step_s  {:.17e}", g.ideal_step_s);
    println!("goodput.step_s        {:.17e}", g.step_s);
    println!("goodput.efficiency    {:.17e}", g.efficiency);
    println!("goodput.wall_s        {:.17e}", g.wall_s);
    println!("goodput.failures      {}", g.failures);
    println!("goodput.checkpoints   {}", g.checkpoints);
    println!("goodput.truncated     {}", g.truncated);
    for (i, ev) in g.trace.iter().enumerate() {
        let kind = match ev.kind {
            FaultEventKind::Failure { node } => format!("failure node={node}"),
            FaultEventKind::Restart => "restart".to_string(),
            FaultEventKind::Checkpoint => "checkpoint".to_string(),
        };
        println!("goodput.trace[{i}]  {:.17e}  {kind}", ev.at_s);
    }
    Ok(())
}
