//! Transformer-1T parallelization-strategy study (paper SV-B1, Fig. 8):
//! full breakdown across the (MP, DP) sweep on all three backends, showing
//! that the closed form, the discrete-event simulator, and the AOT
//! artifact agree.
//!
//! ```sh
//! cargo run --release --example transformer_sweep
//! ```

use comet::config::presets;
use comet::coordinator::{sweep, Coordinator};
use comet::model::inputs::{derive_inputs, EvalOptions};
use comet::parallel::Strategy;
use comet::util::stats::rel_diff;
use comet::workload::transformer::Transformer;

fn main() -> comet::Result<()> {
    // Fig. 8a through the coordinator (native backend).
    let native = Coordinator::native();
    let f = sweep::fig8a(&native)?;
    println!("{}", f.to_table());
    println!(
        "optimal configuration: {}\n",
        f.argmin("Total_s").unwrap_or("?")
    );

    // Backend agreement on the full sweep.
    let des = Coordinator::des();
    let artifact = Coordinator::artifact().ok();
    let cluster = presets::dgx_a100_1024();
    let opts = EvalOptions {
        ignore_capacity: true,
        ..Default::default()
    };
    println!(
        "{:>14} {:>12} {:>12} {:>12} {:>10}",
        "strategy", "native_s", "des_s", "artifact_s", "max_delta"
    );
    for s in sweep::fig8_strategies() {
        let w = Transformer::t1().build(&s)?;
        let inputs = derive_inputs(&w, &cluster, &opts)?;
        let n = native.evaluate_inputs(std::slice::from_ref(&inputs))?[0]
            .total();
        let d = des.evaluate_inputs(std::slice::from_ref(&inputs))?[0].total();
        let a = match &artifact {
            Some(c) => {
                c.evaluate_inputs(std::slice::from_ref(&inputs))?[0].total()
            }
            None => f64::NAN,
        };
        let delta = rel_diff(n, d).max(if a.is_nan() { 0.0 } else { rel_diff(n, a) });
        println!(
            "{:>14} {:>12.3} {:>12.3} {:>12.3} {:>9.3}%",
            s.label(),
            n,
            d,
            a,
            delta * 100.0
        );
    }
    let _ = Strategy::new(8, 128); // keep the import obviously used
    Ok(())
}
