//! Drive the declarative scenario engine from code: run a built-in spec,
//! then build a custom study from a TOML string — no new Rust needed per
//! study.
//!
//! ```sh
//! cargo run --release --example scenario_run
//! ```

use comet::coordinator::Coordinator;
use comet::scenario::{registry, run, ScenarioSpec};

fn main() -> comet::Result<()> {
    let coord = Coordinator::native();

    // --- a built-in scenario (same engine as `comet scenario run`) ------
    let spec = registry::get("quickstart")?;
    println!("{}", run(&spec, &coord)?.to_table());

    // --- a custom study, declared inline --------------------------------
    // Does doubling the inter-pod fabric help a communication-bound
    // config more than a compute-bound one? Express it as data.
    let custom = ScenarioSpec::parse_str(
        r#"
name = "inter-pod-doubling"
title = "What does a 2x inter-pod fabric buy?"

[workload]
kind = "transformer"
preset = "transformer-1t"

[cluster]
preset = "baseline"

[study]
kind = "network-scaling"
strategies = ["MP64_DP16", "MP8_DP128"]
intra_factors = [1.0]
inter_factors = [1.0, 2.0]

[options]
infinite_memory = true
collective = "hierarchical"
"#,
    )?;
    println!("{}", run(&custom, &coord)?.to_table());

    let (hits, misses) = coord.cache_stats();
    println!("cache: {hits} hits / {misses} misses");
    Ok(())
}
