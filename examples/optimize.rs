//! Find the best (strategy, memory) co-design for GPT-3 175B on the
//! A100-like baseline cluster — without evaluating the whole grid.
//!
//! The branch-and-bound optimizer walks the strategy x expanded-memory
//! lattice best-first, pruning subtrees whose admissible lower bound
//! (compute-only roofline + exact blocking collectives) already loses to
//! the incumbent top-k. Same engine as `comet optimize` and the
//! `kind = "optimize"` scenarios; mirrors examples/scenario_run.rs.
//!
//! ```sh
//! cargo run --release --example optimize
//! ```

use comet::coordinator::Coordinator;
use comet::scenario::{optimizer_for, run_optimize, ScenarioSpec};

fn main() -> comet::Result<()> {
    // GPT-3 175B (Brown et al.): 96 stacks, d_model 12288, 96 heads,
    // seq 2048, expressed as overrides on the transformer workload.
    // MP is capped at 64 (it must divide the 96 attention heads' power-
    // of-two sweep ceiling).
    let spec = ScenarioSpec::parse_str(
        r#"
name = "optimize-gpt3"
title = "Best (strategy, memory) co-design for GPT-3 175B on 1024 A100s"

[workload]
kind = "transformer"
preset = "transformer-1t"
name = "gpt3-175b"
stacks = 96
d_model = 12288
heads = 96
seq = 2048
vocab = 50257

[cluster]
preset = "baseline"

[study]
kind = "optimize"
strategies = "pow2"
min_mp = 1
max_mp = 64
em_bandwidths_gbps = [250, 500, 1000, 2039]
top_k = 5
"#,
    )?;

    let coord = Coordinator::native();
    let (fig, out) = run_optimize(&spec, &coord)?;
    println!("{}", fig.to_table());

    let best = out.best().expect("feasible point");
    println!(
        "argmin: {} ({:.3} s/iter, footprint {:.0} GB)",
        best.label,
        best.total(),
        best.footprint / 1e9
    );
    println!(
        "search evaluated {}/{} lattice points ({} pruned by bound, {} \
         infeasible)",
        out.evaluated, out.total_points, out.pruned, out.infeasible
    );
    println!("\ncompute-vs-communication Pareto frontier:");
    for c in &out.frontier {
        println!(
            "  {:<28} compute {:.3} s  exposed comm {:.3} s",
            c.label,
            c.breakdown.compute(),
            c.breakdown.exposed_comm()
        );
    }

    // The exhaustive oracle agrees (and is what bench_optimizer compares
    // evaluated-point counts against).
    let exhaustive = optimizer_for(&spec, &coord)?.exhaustive()?;
    assert_eq!(exhaustive.best().unwrap().label, best.label);
    println!(
        "\nexhaustive enumeration of all {} points confirms the argmin",
        exhaustive.evaluated
    );
    Ok(())
}
