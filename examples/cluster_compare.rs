//! End-to-end driver (paper SV-D, Fig. 15): run the complete COMET
//! pipeline — workload decomposition, strategy search, footprint modeling,
//! cost-model evaluation through the AOT artifact — across all eleven
//! Table III clusters, and report the paper's headline metric: the best
//! GPU cluster's speedup over the A0 baseline (paper: ~7.7x on average,
//! C0 best).
//!
//! ```sh
//! cargo run --release --example cluster_compare
//! ```

use std::time::Instant;

use comet::config::presets;
use comet::coordinator::{sweep, Coordinator};
use comet::util::stats::geomean;

fn main() -> comet::Result<()> {
    // Full three-layer stack: the artifact backend executes the Pallas
    // kernels + JAX graph through PJRT; panics early if `make artifacts`
    // has not produced them (fall back with --no-artifact semantics via
    // Coordinator::auto in your own code).
    let coord = Coordinator::auto();
    println!("backend: {:?}", coord.backend());

    let t0 = Instant::now();
    let f = sweep::fig15(&coord)?;
    let elapsed = t0.elapsed();
    println!("{}", f.to_table());

    // Headline: best GPU cluster on (geometric) average across workloads.
    let mut best: Option<(String, f64)> = None;
    for c in presets::table3_all() {
        if !matches!(c.name.as_str(), "TPUv4" | "Dojo") {
            let d = f.cell(&c.name, "DLRM_x8").unwrap_or(f64::NAN);
            let t = f.cell(&c.name, "Transformer-1T").unwrap_or(f64::NAN);
            let avg = geomean(&[d, t]);
            if best.as_ref().map(|(_, b)| avg > *b).unwrap_or(true) {
                best = Some((c.name.clone(), avg));
            }
        }
    }
    let (name, avg) = best.unwrap();
    println!(
        "best GPU cluster on average: {name} at {avg:.1}x over A0 \
         (paper: C0 at ~7.7x)"
    );
    println!(
        "full 11-cluster x 2-workload comparison took {:.2} s \
         (paper SV-E: hours on a 24-core Xeon)",
        elapsed.as_secs_f64()
    );
    Ok(())
}
