//! Memory-expansion case study (paper SV-B2, Fig. 9 + Ex.1/Ex.2): what
//! capacity and bandwidth must a CXL-style expanded memory deliver to beat
//! the best local-memory-only configuration?
//!
//! ```sh
//! cargo run --release --example memory_expansion
//! ```

use comet::config::presets;
use comet::coordinator::{sweep, Coordinator};
use comet::parallel::{footprint_per_node, Strategy, ZeroStage};
use comet::util::units::{fmt_bytes, gb};
use comet::workload::transformer::Transformer;

fn main() -> comet::Result<()> {
    let coord = Coordinator::auto();
    let f = sweep::fig9(&coord)?;
    println!("{}", f.to_table());

    // --- Ex.1: what does MP8_DP128 need to beat the baseline? -----------
    let s = Strategy::new(8, 128)?;
    let w = Transformer::t1().build(&s)?;
    let fp = footprint_per_node(&w, &s, ZeroStage::OsG).total();
    let local = presets::dgx_a100_1024().node.local.capacity;
    println!("Ex.1: MP8_DP128 needs {} per node ({:.2}x the 80 GB local HBM).",
        fmt_bytes(fp), fp / local);

    // Find the minimum EM bandwidth column where MP8_DP128 speedup > 1.
    let mut min_bw = None;
    for col in &f.columns {
        if let Some(v) = f.cell("MP8_DP128", col) {
            if v > 1.0 {
                min_bw = Some(col.clone());
                break;
            }
        }
    }
    match min_bw {
        Some(bw) => println!(
            "      It outperforms MP64_DP16 once expanded memory delivers >= {bw}."
        ),
        None => println!("      No sweep point beats the baseline."),
    }

    // --- Ex.2: CXL sizing ------------------------------------------------
    let need = fp - local;
    println!(
        "Ex.2: a CXL device must provide ~{} of capacity at that bandwidth",
        fmt_bytes(need)
    );
    println!(
        "      ({} aggregate hybrid capacity, {:.2}x the baseline).",
        fmt_bytes(fp),
        fp / local
    );
    println!(
        "      Paper reference points: >= ~500 GB/s to ~{} (32 lanes of CXL 3.0).",
        fmt_bytes(gb(340.0) - gb(80.0))
    );
    Ok(())
}
