//! DLRM-1.2T study (paper SV-C, Fig. 13): cluster-size sensitivity and the
//! multi-instance memory-expansion trade-off.
//!
//! ```sh
//! cargo run --release --example dlrm_study
//! ```

use comet::coordinator::{sweep, Coordinator};
use comet::util::units::fmt_bytes;
use comet::workload::dlrm::Dlrm;

fn main() -> comet::Result<()> {
    let coord = Coordinator::auto();

    let d = Dlrm::dlrm_1_2t();
    println!(
        "DLRM-1.2T: {} tables x {}-wide embeddings, {} total params",
        d.tables,
        d.emb_dim,
        d.total_params()
    );
    for n in [64usize, 32, 16, 8] {
        println!(
            "  {:>3} nodes -> {:>9} per node",
            n,
            fmt_bytes(d.footprint_per_node(n))
        );
    }
    println!();

    // Fig. 13a: single-instance breakdown vs cluster size.
    println!("{}", sweep::fig13a(&coord)?.to_table());

    // Fig. 13b: 8-instance turnaround vs expanded-memory bandwidth.
    let f = sweep::fig13b(&coord)?;
    println!("{}", f.to_table());

    // Paper SV-C headline: a 200 GB expansion at 1.5 TB/s gives ~1.5x on
    // the 8-node packing.
    if let Some(v) = f.cell("8 nodes/instance", "1500GB/s") {
        println!("8-node packing at EM 1500 GB/s: {v:.2}x vs local-only waves");
    }
    Ok(())
}
