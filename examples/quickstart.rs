//! Quickstart: evaluate one training configuration and find the best
//! parallelization strategy for the baseline cluster.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use comet::config::presets;
use comet::coordinator::Coordinator;
use comet::model::inputs::{derive_inputs, EvalOptions};
use comet::parallel::{footprint_per_node, Strategy, ZeroStage};
use comet::util::units::{fmt_bytes, fmt_secs};
use comet::workload::transformer::Transformer;

fn main() -> comet::Result<()> {
    // The Table I baseline: 1024 A100 GPUs, 128 8-GPU pods.
    let cluster = presets::dgx_a100_1024();
    // Transformer-1T, the paper's flagship workload.
    let model = Transformer::t1();

    // `auto` uses the AOT-compiled artifact (L1 Pallas kernels + L2 JAX
    // graph via PJRT) when `make artifacts` has run, else the native f64
    // closed form.
    let coord = Coordinator::auto();
    println!("backend: {:?}\n", coord.backend());

    // --- single configuration ------------------------------------------
    let strategy = Strategy::new(8, 128)?;
    let workload = model.build(&strategy)?;
    let b = coord.evaluate(&workload, &cluster)?;
    println!("{} on {}:", workload.name, cluster.name);
    println!(
        "  FP: compute {} + exposed comm {}",
        fmt_secs(b.fp_compute),
        fmt_secs(b.fp_exposed_comm)
    );
    println!(
        "  IG: compute {} + exposed comm {}",
        fmt_secs(b.ig_compute),
        fmt_secs(b.ig_exposed_comm)
    );
    println!(
        "  WG: compute {} + exposed comm {}",
        fmt_secs(b.wg_compute),
        fmt_secs(b.wg_exposed_comm)
    );
    println!("  iteration: {}\n", fmt_secs(b.total()));

    // --- strategy sweep (the core COMET loop) ---------------------------
    let opts = EvalOptions {
        ignore_capacity: true, // paper Fig. 8a assumption
        ..Default::default()
    };
    let mut best: Option<(Strategy, f64)> = None;
    println!(
        "{:>14} {:>12} {:>14} {:>14}",
        "strategy", "total", "footprint", "feasible@80GB"
    );
    for s in Strategy::sweep_bounded(cluster.n_nodes, 1, 128)? {
        let w = model.build(&s)?;
        let inputs = derive_inputs(&w, &cluster, &opts)?;
        let t =
            coord.evaluate_inputs(std::slice::from_ref(&inputs))?[0].total();
        let fp = footprint_per_node(&w, &s, ZeroStage::OsG).total();
        println!(
            "{:>14} {:>12} {:>14} {:>14}",
            s.label(),
            fmt_secs(t),
            fmt_bytes(fp),
            if fp <= cluster.node.local.capacity {
                "yes"
            } else {
                "needs EM"
            },
        );
        if best.map(|(_, bt)| t < bt).unwrap_or(true) {
            best = Some((s, t));
        }
    }
    let (s, t) = best.unwrap();
    println!(
        "\nbest strategy: {} at {} per iteration",
        s.label(),
        fmt_secs(t)
    );
    println!("(paper Fig. 8a: MP8_DP128 is optimal, needing ~3.3x the A100's 80 GB)");
    Ok(())
}
