//! ZeRO-DP footprint study (paper SIV-B, Figs. 3 & 6): per-node memory as
//! a function of the (MP, DP) split and the ZeRO optimization stage.
//!
//! ```sh
//! cargo run --release --example zero_footprint
//! ```

use comet::coordinator::sweep;
use comet::parallel::{
    footprint_per_node, model_state_bytes, Strategy, ZeroStage,
};
use comet::util::units::fmt_bytes;
use comet::workload::transformer::Transformer;

fn main() -> comet::Result<()> {
    // Fig. 6 table.
    println!("{}", sweep::fig6().to_table());

    // Fig. 3's statement: halving MP (doubling DP) doubles the per-node
    // requirement AND the cluster-wide total.
    let psi = Transformer::t1().total_params();
    println!("Fig. 3 check (baseline, 1024 nodes):");
    for (mp, dp) in [(128usize, 8usize), (64, 16), (32, 32)] {
        let per_node = model_state_bytes(psi, mp, dp, ZeroStage::Baseline);
        println!(
            "  MP{mp:<4} DP{dp:<4}: {:>10} per node, {:>10} cluster-wide",
            fmt_bytes(per_node),
            fmt_bytes(per_node * 1024.0),
        );
    }

    // Full footprint decomposition for the paper's two key strategies.
    println!("\nfull footprint decomposition (ZeRO-2):");
    let t = Transformer::t1();
    for s in [Strategy::new(64, 16)?, Strategy::new(8, 128)?] {
        let w = t.build(&s)?;
        let fp = footprint_per_node(&w, &s, ZeroStage::OsG);
        println!(
            "  {:<12} model-states {:>10}  residual {:>9}  AWM {:>9}  total {:>10}",
            s.label(),
            fmt_bytes(fp.model_states),
            fmt_bytes(fp.residual),
            fmt_bytes(fp.awm),
            fmt_bytes(fp.total()),
        );
    }
    println!("\nZeRO-3 is flat across the sweep but costs 1.5x the DP communication");
    println!("volume (paper SIV-B) - stage {:?} multiplier: {}",
        ZeroStage::OsGP, ZeroStage::OsGP.comm_multiplier());
    Ok(())
}
