"""Kernel-vs-reference correctness: the CORE L1 signal.

The Pallas kernels (OI/per-step ring formulations) must agree with the
pure-jnp oracle (time-form / closed-form formulations) everywhere. Hypothesis
sweeps shapes, magnitudes, and degenerate corners.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import collective as kcoll
from compile.kernels import layout as ly
from compile.kernels import ref
from compile.kernels import roofline as kroof

RNG = np.random.default_rng(1234)


def mk_params(
    b,
    perf_peak=624e12,
    bw_lm=2039e9,
    bw_em=500e9,
    cap_lm=80e9,
    sram=40e6,
    footprint=60e9,
    bw_intra=300e9,
    bw_inter=31.25e9,
    lat=1e-6,
    overlap=1.0,
    em_frac=-1.0,
    coll_impl=0.0,
):
    p = np.zeros((b, ly.P), np.float32)
    p[:, ly.P_PERF_PEAK] = perf_peak
    p[:, ly.P_BW_LM] = bw_lm
    p[:, ly.P_BW_EM] = bw_em
    p[:, ly.P_CAP_LM] = cap_lm
    p[:, ly.P_SRAM] = sram
    p[:, ly.P_FOOTPRINT] = footprint
    p[:, ly.P_BW_INTRA] = bw_intra
    p[:, ly.P_BW_INTER] = bw_inter
    p[:, ly.P_LINK_LAT] = lat
    p[:, ly.P_OVERLAP_WG] = overlap
    p[:, ly.P_EM_FRAC] = em_frac
    p[:, ly.P_COLL_IMPL] = coll_impl
    return p


def rand_compute(b, l, rng=RNG, scale=1e12):
    c = rng.uniform(0.0, scale, (b, l, ly.CF)).astype(np.float32)
    # Realistic slot multiplicity (0 = padded slot .. 128 = stack count).
    c[:, :, ly.C_REPEAT] = rng.integers(0, 129, (b, l))
    return c


def rand_comm(b, l, rng=RNG, scale=1e9):
    m = rng.uniform(0.0, scale, (b, l, ly.MF)).astype(np.float32)
    m[:, :, ly.M_REPEAT] = rng.integers(0, 129, (b, l))
    for ct, ni, nx in (
        (ly.M_CTYPE_FP, ly.M_NINTRA_FP, ly.M_NINTER_FP),
        (ly.M_CTYPE_IG, ly.M_NINTRA_IG, ly.M_NINTER_IG),
        (ly.M_CTYPE_WG, ly.M_NINTRA_WG, ly.M_NINTER_WG),
    ):
        m[:, :, ct] = rng.integers(0, 5, (b, l))
        m[:, :, ni] = 2.0 ** rng.integers(0, 5, (b, l))
        m[:, :, nx] = 2.0 ** rng.integers(0, 6, (b, l))
    return m


class TestRooflineKernel:
    def test_matches_ref_basic(self):
        b, l = 8, 32
        c = rand_compute(b, l)
        p = mk_params(b)
        got = kroof.roofline_delays(jnp.array(c), jnp.array(p))
        want = ref.eval_phase_delays(jnp.array(c), jnp.array(p))
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-12)

    def test_zero_padding_rows_give_zero(self):
        b, l = 8, 16
        c = np.zeros((b, l, ly.CF), np.float32)
        p = mk_params(b)
        got = np.asarray(kroof.roofline_delays(jnp.array(c), jnp.array(p)))
        assert np.all(got == 0.0)

    def test_compute_bound_layer(self):
        # Huge flops, tiny traffic => delay == flops / perf_peak.
        b, l = 8, 1
        c = np.zeros((b, l, ly.CF), np.float32)
        c[:, :, ly.C_REPEAT] = 1.0
        c[:, :, ly.C_FLOPS_FP] = 1e15
        c[:, :, ly.C_U_FP] = 1e6
        c[:, :, ly.C_V_FP] = 1e6
        c[:, :, ly.C_W_FP] = 1e6
        p = mk_params(b, perf_peak=624e12)
        got = np.asarray(kroof.roofline_delays(jnp.array(c), jnp.array(p)))
        np.testing.assert_allclose(got[:, 0, 0], 1e15 / 624e12, rtol=1e-5)

    def test_memory_bound_layer(self):
        # Tiny flops, huge traffic => delay == traffic / bw_lm.
        b, l = 8, 1
        c = np.zeros((b, l, ly.CF), np.float32)
        c[:, :, ly.C_REPEAT] = 1.0
        c[:, :, ly.C_FLOPS_FP] = 1.0
        c[:, :, ly.C_U_FP] = 0.0
        c[:, :, ly.C_V_FP] = 0.0
        c[:, :, ly.C_W_FP] = 1e12
        p = mk_params(b, bw_lm=2039e9, footprint=1e9)  # fits in LM
        got = np.asarray(kroof.roofline_delays(jnp.array(c), jnp.array(p)))
        np.testing.assert_allclose(got[:, 0, 0], 1e12 / 2039e9, rtol=1e-5)

    def test_spill_slows_down(self):
        b, l = 8, 4
        c = rand_compute(b, l)
        p_fit = mk_params(b, footprint=50e9)
        p_spill = mk_params(b, footprint=400e9)
        d_fit = np.asarray(kroof.roofline_delays(jnp.array(c), jnp.array(p_fit)))
        d_spill = np.asarray(
            kroof.roofline_delays(jnp.array(c), jnp.array(p_spill))
        )
        assert np.all(d_spill >= d_fit - 1e-9)

    def test_em_frac_override(self):
        b, l = 8, 4
        c = rand_compute(b, l)
        # Full spill with bw_em == bw_lm behaves like no spill.
        p_a = mk_params(b, footprint=400e9, bw_em=2039e9, em_frac=1.0)
        p_b = mk_params(b, footprint=50e9, em_frac=0.0)
        d_a = np.asarray(kroof.roofline_delays(jnp.array(c), jnp.array(p_a)))
        d_b = np.asarray(kroof.roofline_delays(jnp.array(c), jnp.array(p_b)))
        np.testing.assert_allclose(d_a, d_b, rtol=1e-5)

    @settings(max_examples=30, deadline=None)
    @given(
        l=st.integers(1, 48),
        scale=st.sampled_from([1e3, 1e9, 1e12, 1e15]),
        footprint=st.floats(1e9, 1e12),
        sram=st.sampled_from([1e6, 40e6, 66e9]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_sweep(self, l, scale, footprint, sram, seed):
        rng = np.random.default_rng(seed)
        b = 8
        c = rand_compute(b, l, rng, scale)
        p = mk_params(b, footprint=footprint, sram=sram)
        got = kroof.roofline_delays(jnp.array(c), jnp.array(p))
        want = ref.eval_phase_delays(jnp.array(c), jnp.array(p))
        np.testing.assert_allclose(got, want, rtol=3e-5, atol=1e-12)


class TestCollectiveKernel:
    def test_matches_ref_basic(self):
        b, l = 8, 32
        m = rand_comm(b, l)
        p = mk_params(b)
        got = kcoll.collective_costs(jnp.array(m), jnp.array(p))
        want = ref.eval_phase_comms(jnp.array(m), jnp.array(p))
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-12)

    def test_singleton_group_free(self):
        b, l = 8, 4
        m = rand_comm(b, l)
        for ni, nx in (
            (ly.M_NINTRA_FP, ly.M_NINTER_FP),
            (ly.M_NINTRA_IG, ly.M_NINTER_IG),
            (ly.M_NINTRA_WG, ly.M_NINTER_WG),
        ):
            m[:, :, ni] = 1.0
            m[:, :, nx] = 1.0
        p = mk_params(b)
        got = np.asarray(kcoll.collective_costs(jnp.array(m), jnp.array(p)))
        assert np.all(got == 0.0)

    def test_flat_ring_allreduce_closed_form(self):
        # n_intra = 8, n_inter = 1: classic 2(n-1)/n * bytes / bw.
        b, l = 8, 1
        m = np.zeros((b, l, ly.MF), np.float32)
        m[:, :, ly.M_REPEAT] = 1.0
        m[:, :, ly.M_BYTES_FP] = 1e9
        m[:, :, ly.M_CTYPE_FP] = ly.CT_ALLREDUCE
        m[:, :, ly.M_NINTRA_FP] = 8.0
        m[:, :, ly.M_NINTER_FP] = 1.0
        p = mk_params(b, bw_intra=300e9, lat=0.0)
        got = np.asarray(kcoll.collective_costs(jnp.array(m), jnp.array(p)))
        want = 2.0 * 7.0 / 8.0 * 1e9 / 300e9
        np.testing.assert_allclose(got[:, 0, 0], want, rtol=1e-5)

    def test_hierarchical_beats_flat_on_slow_inter(self):
        """Hierarchical AR cost must be below a flat ring over the slow
        inter-pod links for a multi-pod group (the reason the paper uses
        hierarchical collectives)."""
        bytes_, n_intra, n_inter = 1e9, 8.0, 16.0
        bw_i, bw_x = 300e9, 31.25e9
        m = np.zeros((8, 1, ly.MF), np.float32)
        m[:, :, ly.M_REPEAT] = 1.0
        m[:, :, ly.M_BYTES_FP] = bytes_
        m[:, :, ly.M_CTYPE_FP] = ly.CT_ALLREDUCE
        m[:, :, ly.M_NINTRA_FP] = n_intra
        m[:, :, ly.M_NINTER_FP] = n_inter
        p_h = mk_params(8, bw_intra=bw_i, bw_inter=bw_x, lat=0.0, coll_impl=1.0)
        p_f = mk_params(8, bw_intra=bw_i, bw_inter=bw_x, lat=0.0, coll_impl=0.0)
        hier = np.asarray(kcoll.collective_costs(jnp.array(m), jnp.array(p_h)))
        flat = np.asarray(kcoll.collective_costs(jnp.array(m), jnp.array(p_f)))
        n = n_intra * n_inter
        want_flat = 2.0 * (n - 1.0) / n * bytes_ / bw_x
        np.testing.assert_allclose(flat[:, 0, 0], want_flat, rtol=1e-5)
        assert np.all(hier[:, 0, 0] < flat[:, 0, 0])

    @settings(max_examples=30, deadline=None)
    @given(
        l=st.integers(1, 48),
        scale=st.sampled_from([1e3, 1e6, 1e9, 1e11]),
        lat=st.sampled_from([0.0, 1e-7, 1e-6, 1e-5]),
        coll_impl=st.sampled_from([0.0, 1.0]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_sweep(self, l, scale, lat, coll_impl, seed):
        rng = np.random.default_rng(seed)
        b = 8
        m = rand_comm(b, l, rng, scale)
        p = mk_params(b, lat=lat, coll_impl=coll_impl)
        got = kcoll.collective_costs(jnp.array(m), jnp.array(p))
        want = ref.eval_phase_comms(jnp.array(m), jnp.array(p))
        np.testing.assert_allclose(got, want, rtol=3e-5, atol=1e-12)

    @settings(max_examples=20, deadline=None)
    @given(
        bytes_=st.floats(1e3, 1e12),
        n_intra=st.sampled_from([1.0, 2.0, 4.0, 8.0, 16.0]),
        n_inter=st.sampled_from([1.0, 2.0, 8.0, 64.0, 128.0]),
    )
    def test_allreduce_monotone_in_bytes(self, bytes_, n_intra, n_inter):
        m = np.zeros((8, 2, ly.MF), np.float32)
        for j, by in enumerate((bytes_, bytes_ * 2.0)):
            m[:, j, ly.M_REPEAT] = 1.0
            m[:, j, ly.M_BYTES_FP] = by
            m[:, j, ly.M_CTYPE_FP] = ly.CT_ALLREDUCE
            m[:, j, ly.M_NINTRA_FP] = n_intra
            m[:, j, ly.M_NINTER_FP] = n_inter
        p = mk_params(8)
        got = np.asarray(kcoll.collective_costs(jnp.array(m), jnp.array(p)))
        assert np.all(got[:, 1, 0] >= got[:, 0, 0])
