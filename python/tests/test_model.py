"""L2 graph tests: comet_batch_eval vs the oracle, shapes, exposure rule,
and qualitative cost-model behaviours the paper's case studies rely on."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import layout as ly
from compile.kernels import ref

from tests.test_kernel import mk_params, rand_comm, rand_compute


def run_model(c, m, p):
    return np.asarray(
        model.comet_batch_eval(jnp.array(c), jnp.array(m), jnp.array(p))[0]
    )


class TestBatchEval:
    def test_matches_ref(self):
        b, l = 8, 40
        c, m, p = rand_compute(b, l), rand_comm(b, l), mk_params(b)
        got = run_model(c, m, p)
        want = np.asarray(
            ref.eval_breakdown(jnp.array(c), jnp.array(m), jnp.array(p))
        )
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-12)

    def test_output_shape(self):
        b, l = 8, 16
        out = run_model(rand_compute(b, l), rand_comm(b, l), mk_params(b))
        assert out.shape == (b, ly.OUTF)

    def test_all_finite_nonnegative(self):
        b, l = 8, 64
        out = run_model(rand_compute(b, l), rand_comm(b, l), mk_params(b))
        assert np.all(np.isfinite(out))
        assert np.all(out >= 0.0)

    def test_padding_invariance(self):
        """Extending L with zero rows must not change the breakdown."""
        b, l = 8, 24
        c, m, p = rand_compute(b, l), rand_comm(b, l), mk_params(b)
        c2 = np.concatenate([c, np.zeros((b, 40, ly.CF), np.float32)], axis=1)
        m2 = np.concatenate([m, np.zeros((b, 40, ly.MF), np.float32)], axis=1)
        np.testing.assert_allclose(
            run_model(c, m, p), run_model(c2, m2, p), rtol=1e-6
        )

    def test_wg_overlap_rule(self):
        """With overlap on, exposed WG comm == max(0, comm - compute)."""
        b, l = 8, 8
        c, m = rand_compute(b, l), rand_comm(b, l)
        p_on = mk_params(b, overlap=1.0)
        p_off = mk_params(b, overlap=0.0)
        out_on = run_model(c, m, p_on)
        out_off = run_model(c, m, p_off)
        wg_c, wg_m = out_off[:, ly.O_WG_COMPUTE], out_off[:, ly.O_WG_EXPOSED]
        np.testing.assert_allclose(
            out_on[:, ly.O_WG_EXPOSED],
            np.maximum(wg_m - wg_c, 0.0),
            rtol=1e-5,
            atol=1e-12,
        )

    def test_faster_network_never_hurts(self):
        b, l = 8, 32
        c, m = rand_compute(b, l), rand_comm(b, l)
        slow = run_model(c, m, mk_params(b, bw_intra=150e9, bw_inter=15e9))
        fast = run_model(c, m, mk_params(b, bw_intra=600e9, bw_inter=125e9))
        for col in (ly.O_FP_EXPOSED, ly.O_IG_EXPOSED, ly.O_WG_EXPOSED):
            assert np.all(fast[:, col] <= slow[:, col] + 1e-9)

    def test_more_compute_never_hurts(self):
        b, l = 8, 32
        c, m = rand_compute(b, l), rand_comm(b, l)
        lo = run_model(c, m, mk_params(b, perf_peak=312e12))
        hi = run_model(c, m, mk_params(b, perf_peak=1248e12))
        for col in (ly.O_FP_COMPUTE, ly.O_IG_COMPUTE, ly.O_WG_COMPUTE):
            assert np.all(hi[:, col] <= lo[:, col] + 1e-9)

    @settings(max_examples=20, deadline=None)
    @given(
        l=st.integers(1, 64),
        seed=st.integers(0, 2**31 - 1),
        overlap=st.sampled_from([0.0, 1.0]),
        footprint=st.floats(1e9, 1e12),
    )
    def test_matches_ref_sweep(self, l, seed, overlap, footprint):
        rng = np.random.default_rng(seed)
        b = 8
        c = rand_compute(b, l, rng)
        m = rand_comm(b, l, rng)
        p = mk_params(b, overlap=overlap, footprint=footprint)
        got = run_model(c, m, p)
        want = np.asarray(
            ref.eval_breakdown(jnp.array(c), jnp.array(m), jnp.array(p))
        )
        np.testing.assert_allclose(got, want, rtol=3e-5, atol=1e-12)


class TestCostModelBehaviours:
    """Qualitative behaviours fig. 8-11 depend on."""

    def test_expanded_bandwidth_helps_spilled_config(self):
        b, l = 8, 16
        c = rand_compute(b, l)
        m = np.zeros((b, l, ly.MF), np.float32)
        out = {}
        for bw_em in (250e9, 500e9, 1000e9, 2039e9):
            p = mk_params(b, footprint=340e9, bw_em=bw_em)
            out[bw_em] = run_model(c, m, p)[:, ly.O_FP_COMPUTE]
        assert np.all(out[250e9] >= out[500e9])
        assert np.all(out[500e9] >= out[1000e9])
        assert np.all(out[1000e9] >= out[2039e9])

    def test_fit_in_lm_insensitive_to_em(self):
        b, l = 8, 16
        c = rand_compute(b, l)
        m = np.zeros((b, l, ly.MF), np.float32)
        a = run_model(c, m, mk_params(b, footprint=50e9, bw_em=250e9))
        bb = run_model(c, m, mk_params(b, footprint=50e9, bw_em=2000e9))
        np.testing.assert_allclose(a, bb, rtol=1e-6)
