"""AOT export tests: the HLO text artifacts must exist-or-regenerate, parse,
stay Mosaic-free (interpret=True contract), and execute to the same numbers
as the live jax graph when run through xla_client from the text."""

import json
import os
import tempfile

import numpy as np
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import layout as ly

from tests.test_kernel import mk_params, rand_comm, rand_compute


class TestExport:
    def test_export_writes_all_artifacts(self):
        with tempfile.TemporaryDirectory() as d:
            aot.export(d)
            for b in ly.BATCH_SIZES:
                path = os.path.join(d, f"comet_eval_b{b}.hlo.txt")
                assert os.path.exists(path)
                text = open(path).read()
                assert text.startswith("HloModule")
                # interpret=True contract: no TPU Mosaic custom-calls.
                assert "mosaic" not in text.lower()
            man = json.load(open(os.path.join(d, "manifest.json")))
            assert man["b"] == ly.B and man["l"] == ly.L
            assert man["cf"] == ly.CF and man["mf"] == ly.MF
            assert man["p"] == ly.P and man["outf"] == ly.OUTF

    def test_lowered_has_three_params(self):
        lowered = model.lower_batch_eval(8)
        text = aot.to_hlo_text(lowered)
        # ENTRY computation must take exactly the 3 ABI tensors, with the
        # exact shapes the Rust runtime will feed.
        entry = text[text.index("ENTRY ") :]
        assert entry.count("parameter(") == 3
        assert f"f32[8,{ly.L},{ly.CF}]" in text
        assert f"f32[8,{ly.L},{ly.MF}]" in text
        assert f"f32[8,{ly.P}]" in text
        # Output is a 1-tuple (return_tuple=True -> rust to_tuple1()).
        assert f"(f32[8,{ly.OUTF}]" in text

    def test_export_deterministic(self):
        """Exporting twice must produce byte-identical HLO text (the
        artifact cache in the Makefile depends on this)."""
        lowered_a = model.lower_batch_eval(8)
        lowered_b = model.lower_batch_eval(8)
        assert aot.to_hlo_text(lowered_a) == aot.to_hlo_text(lowered_b)

    def test_live_jax_matches_ref_on_export_geometry(self):
        """The exact (B, L) geometry that gets exported must agree with the
        oracle; the rust integration test then checks artifact == native."""
        from compile.kernels import ref

        b, l = 8, ly.L
        c, m, p = rand_compute(b, l), rand_comm(b, l), mk_params(b)
        got = np.asarray(
            model.comet_batch_eval(jnp.array(c), jnp.array(m), jnp.array(p))[0]
        )
        want = np.asarray(
            ref.eval_breakdown(jnp.array(c), jnp.array(m), jnp.array(p))
        )
        np.testing.assert_allclose(got, want, rtol=3e-5, atol=1e-12)


class TestCheckedInArtifacts:
    """If artifacts/ is already built (make artifacts), sanity-check it."""

    ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

    def test_manifest_matches_layout(self):
        mpath = os.path.join(self.ART, "manifest.json")
        if not os.path.exists(mpath):
            import pytest

            pytest.skip("artifacts not built")
        man = json.load(open(mpath))
        assert man["b"] == ly.B
        assert man["l"] == ly.L
        assert man["cf"] == ly.CF
        assert man["mf"] == ly.MF
        assert man["p"] == ly.P
        assert man["outf"] == ly.OUTF
        for b in ly.BATCH_SIZES:
            assert str(b) in man["artifacts"]
            assert os.path.exists(
                os.path.join(self.ART, man["artifacts"][str(b)])
            )
