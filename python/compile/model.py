"""L2: the COMET batched cost-model graph.

`comet_batch_eval` is the compute hot-spot of COMET's design-space
exploration: it evaluates the full analytical cost model (roofline compute
delays + hierarchical collective costs + overlap/exposure) for a batch of B
cluster configurations x L layer slots in one fused XLA computation.

It composes the two L1 Pallas kernels:
  * kernels.roofline.roofline_delays    - per-layer compute delays
  * kernels.collective.collective_costs - per-layer collective costs
and reduces them to the per-config [B, OUTF] iteration-time breakdown
(FP/IG/WG compute + exposed communication, seconds).

This module is build-time only: python/compile/aot.py lowers it once to HLO
text under artifacts/, and the Rust coordinator executes the artifact via
PJRT on the request path. Python never runs at exploration time.
"""

import jax
import jax.numpy as jnp

from .kernels import collective as kcoll
from .kernels import layout as ly
from .kernels import roofline as kroof


def comet_batch_eval(compute, comm, params):
    """Evaluate the COMET cost model for a batch of configurations.

    Args:
      compute: f32[B, L, CF] per-(config, layer) compute quantities.
      comm:    f32[B, L, MF] per-(config, layer) collective quantities.
      params:  f32[B, P]     per-config cluster parameters.

    Returns:
      1-tuple of f32[B, OUTF]: per-config (fp_compute, fp_exposed,
      ig_compute, ig_exposed, wg_compute, wg_exposed) in seconds.
      Exposure rule (paper SIII-C4): FP/IG collectives block the critical
      path; the WG data-parallel collective overlaps with WG compute and
      only the excess is exposed (toggled per-config by P_OVERLAP_WG).
    """
    delays = kroof.roofline_delays(compute, params)  # [B, L, 3]
    comms = kcoll.collective_costs(comm, params)  # [B, L, 3]

    fp_c = jnp.sum(delays[:, :, 0], axis=1)
    ig_c = jnp.sum(delays[:, :, 1], axis=1)
    wg_c = jnp.sum(delays[:, :, 2], axis=1)
    fp_m = jnp.sum(comms[:, :, 0], axis=1)
    ig_m = jnp.sum(comms[:, :, 1], axis=1)
    wg_m = jnp.sum(comms[:, :, 2], axis=1)

    overlap = params[:, ly.P_OVERLAP_WG] > 0.5
    wg_exposed = jnp.where(overlap, jnp.maximum(wg_m - wg_c, 0.0), wg_m)
    out = jnp.stack([fp_c, fp_m, ig_c, ig_m, wg_c, wg_exposed], axis=-1)
    return (out,)


def lower_batch_eval(b: int, l: int = ly.L):
    """jax.jit-lower comet_batch_eval for a fixed (b, l) geometry."""
    spec_c = jax.ShapeDtypeStruct((b, l, ly.CF), jnp.float32)
    spec_m = jax.ShapeDtypeStruct((b, l, ly.MF), jnp.float32)
    spec_p = jax.ShapeDtypeStruct((b, ly.P), jnp.float32)
    return jax.jit(comet_batch_eval).lower(spec_c, spec_m, spec_p)
