"""Shared ABI layout between the L2 JAX graph, the L1 Pallas kernels, the
pure-jnp reference oracle, and the Rust runtime.

The batched COMET cost-model evaluator operates on three dense f32 tensors:

  compute : [B, L, CF]  per-(config, layer) compute-side quantities
  comm    : [B, L, MF]  per-(config, layer) communication-side quantities
  params  : [B, P]      per-config cluster parameters

with B configs per batch and L layer slots (zero-padded past the real layer
count).  The same field order is mirrored in ``rust/src/model/batch.rs`` and
cross-checked at runtime through ``artifacts/manifest.json``.

All quantities are SI: FLOPs, bytes, bytes/s, seconds.
"""

# Batch geometry. One artifact is exported per batch size in BATCH_SIZES;
# L is shared. Layer slots beyond the real workload are zero-padded (zero
# flops/bytes contribute exactly zero delay by construction).
BATCH_SIZES = (8, 64)
B = 64
L = 192

# --- compute tensor fields ------------------------------------------------
# Per training phase (FP = forward pass, IG = input gradient,
# WG = weight gradient): FLOPs and the three GEMM operand sizes in bytes
# (U, V inputs; W output) used by the tiling traffic model (paper Eqn. 1/2,
# SIII-C2).  Non-GEMM layers encode U = V = 0 and W = bytes-touched so the
# traffic model degrades to streaming.
# C_REPEAT is the layer-slot multiplicity: Transformer models encode one
# stack's layers once with repeat = #stacks (operand sizes must stay
# per-instance for the ceil(U/S) tiling term to be correct).  Zero-padded
# slots have repeat = 0 and contribute nothing.
CF = 13
C_FLOPS_FP, C_U_FP, C_V_FP, C_W_FP = 0, 1, 2, 3
C_FLOPS_IG, C_U_IG, C_V_IG, C_W_IG = 4, 5, 6, 7
C_FLOPS_WG, C_U_WG, C_V_WG, C_W_WG = 8, 9, 10, 11
C_REPEAT = 12

# --- comm tensor fields -----------------------------------------------------
# Per phase: collective payload bytes, collective type, and the two-level
# group decomposition (participants sharing a pod x participant groups
# across pods).  n_intra * n_inter == total participants.
# M_REPEAT mirrors C_REPEAT (the collective kernel only sees this tensor).
MF = 13
M_BYTES_FP, M_CTYPE_FP, M_NINTRA_FP, M_NINTER_FP = 0, 1, 2, 3
M_BYTES_IG, M_CTYPE_IG, M_NINTRA_IG, M_NINTER_IG = 4, 5, 6, 7
M_BYTES_WG, M_CTYPE_WG, M_NINTRA_WG, M_NINTER_WG = 8, 9, 10, 11
M_REPEAT = 12

# Collective type codes.
CT_NONE = 0.0
CT_ALLREDUCE = 1.0
CT_ALLTOALL = 2.0
CT_ALLGATHER = 3.0
CT_REDUCESCATTER = 4.0

# --- params tensor fields ---------------------------------------------------
P = 12
P_PERF_PEAK = 0   # FLOP/s
P_BW_LM = 1       # local-memory bandwidth, B/s
P_BW_EM = 2       # expanded-memory bandwidth, B/s (0 => no expansion)
P_CAP_LM = 3      # local-memory capacity, bytes
P_SRAM = 4        # on-chip buffer size S, bytes (tiling model)
P_FOOTPRINT = 5   # per-node working footprint, bytes (spill model input)
P_BW_INTRA = 6    # intra-pod link bandwidth per node, B/s per direction
P_BW_INTER = 7    # inter-pod link bandwidth per node, B/s per direction
P_LINK_LAT = 8    # per-hop link latency, seconds
P_OVERLAP_WG = 9  # 1.0 => WG comm overlaps WG compute (paper default)
P_EM_FRAC = 10    # >=0 overrides derived EM traffic fraction; <0 => derive
P_COLL_IMPL = 11  # 0 = logical ring (Table I baseline), 1 = hierarchical
                  #     (BlueConnect/Themis, used by the SV-B4 network study)

# --- output -----------------------------------------------------------------
# out : [B, OUTF] per-config iteration breakdown, seconds.
OUTF = 6
O_FP_COMPUTE = 0
O_FP_EXPOSED = 1
O_IG_COMPUTE = 2
O_IG_EXPOSED = 3
O_WG_COMPUTE = 4
O_WG_EXPOSED = 5


def manifest() -> dict:
    """Layout description embedded in artifacts/manifest.json."""
    return {
        "batch_sizes": list(BATCH_SIZES),
        "b": B,
        "l": L,
        "cf": CF,
        "mf": MF,
        "p": P,
        "outf": OUTF,
        "ctype": {
            "none": CT_NONE,
            "allreduce": CT_ALLREDUCE,
            "alltoall": CT_ALLTOALL,
            "allgather": CT_ALLGATHER,
            "reducescatter": CT_REDUCESCATTER,
        },
    }
