"""L1 Pallas kernel: fused tiling-traffic + hybrid-bandwidth + roofline delay.

Computes, for a block of cluster configurations at a time, the per-layer
compute delay of all three training phases (FP / IG / WG) of the COMET cost
model (paper SIII-C1/C2 + Eqn. 3).

TPU-shaped design (see DESIGN.md SHardware-Adaptation):
  * the (config, layer) grid is blocked along the config dimension; each grid
    step streams one [BLK_B, L, CF] tile HBM->VMEM via BlockSpec;
  * all math is element-wise over the tile (VPU work; the cost model has no
    matmul, so the MXU is idle by construction);
  * per-config scalars ([BLK_B, P]) ride alongside the tile, playing the role
    scalar-prefetch operands would on real hardware;
  * VMEM footprint per step: BLK_B*L*(CF+3)*4B + BLK_B*P*4B ~ 0.5 MiB at
    BLK_B=8, L=192 - far below the ~16 MiB VMEM budget, leaving room for
    double buffering.

Must be lowered with interpret=True: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.

The math here deliberately uses the OI/perf_max formulation of the paper
(Eqn. 1/2) rather than ref.py's time-form max() identity, so the pytest
kernel-vs-ref comparison exercises two independent derivations.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import layout as ly

# Configs per grid step. 8 divides every exported batch size.
BLK_B = 8


def _phase_delay(flops, u, v, w, sram, perf_peak, bw_eff):
    """One phase's delay via the paper's OI formulation.

    perf_max = min(perf_peak, OI * bw_eff);  delay = flops / perf_max.
    Zero-flop slots (padding, pure-comm layers) produce exactly 0.0 but may
    still move bytes (traffic/bw term) - matching ref.py's time-form max().
    """
    psi1 = jnp.ceil(u / sram) * v + u
    psi2 = jnp.ceil(v / sram) * u + v
    traffic = jnp.maximum(jnp.minimum(psi1, psi2), u + v) + w

    safe_traffic = jnp.maximum(traffic, 1.0)
    oi = flops / safe_traffic
    perf_max = jnp.minimum(perf_peak, oi * bw_eff)
    compute_t = jnp.where(perf_max > 0.0, flops / jnp.maximum(perf_max, 1e-30), 0.0)
    # Pure data movement (flops == 0) still costs traffic / bw.
    move_t = traffic / bw_eff
    return jnp.maximum(compute_t, move_t)


def _roofline_kernel(compute_ref, params_ref, out_ref):
    """Pallas body: compute_ref [BLK_B, L, CF], params_ref [BLK_B, P],
    out_ref [BLK_B, L, 3]."""
    comp = compute_ref[...]
    prm = params_ref[...]

    perf_peak = jnp.maximum(prm[:, ly.P_PERF_PEAK], 1.0)[:, None]
    sram = jnp.maximum(prm[:, ly.P_SRAM], 1.0)[:, None]

    # Hybrid bandwidth (Eqn. 3) from the spill fraction.
    footprint = prm[:, ly.P_FOOTPRINT]
    cap_lm = prm[:, ly.P_CAP_LM]
    override = prm[:, ly.P_EM_FRAC]
    derived = jnp.clip(
        (footprint - cap_lm) / jnp.maximum(footprint, 1.0), 0.0, 1.0
    )
    frac_em = jnp.where(override >= 0.0, override, derived)
    bw_lm = jnp.maximum(prm[:, ly.P_BW_LM], 1.0)
    bw_em = jnp.maximum(prm[:, ly.P_BW_EM], 1.0)
    bw_hybrid = 1.0 / ((1.0 - frac_em) / bw_lm + frac_em / bw_em)
    bw_eff = jnp.where(frac_em <= 0.0, bw_lm, bw_hybrid)[:, None]

    repeat = comp[:, :, ly.C_REPEAT]
    for phase, (fl, u, v, w) in enumerate(
        (
            (ly.C_FLOPS_FP, ly.C_U_FP, ly.C_V_FP, ly.C_W_FP),
            (ly.C_FLOPS_IG, ly.C_U_IG, ly.C_V_IG, ly.C_W_IG),
            (ly.C_FLOPS_WG, ly.C_U_WG, ly.C_V_WG, ly.C_W_WG),
        )
    ):
        out_ref[:, :, phase] = repeat * _phase_delay(
            comp[:, :, fl],
            comp[:, :, u],
            comp[:, :, v],
            comp[:, :, w],
            sram,
            perf_peak,
            bw_eff,
        )


@functools.partial(jax.jit, static_argnames=())
def roofline_delays(compute, params):
    """Per-layer phase delays. compute [B, L, CF], params [B, P] -> [B, L, 3]."""
    b, l, _ = compute.shape
    assert b % BLK_B == 0, f"batch {b} must be a multiple of {BLK_B}"
    return pl.pallas_call(
        _roofline_kernel,
        grid=(b // BLK_B,),
        in_specs=[
            pl.BlockSpec((BLK_B, l, ly.CF), lambda i: (i, 0, 0)),
            pl.BlockSpec((BLK_B, ly.P), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLK_B, l, 3), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, l, 3), jnp.float32),
        interpret=True,
    )(compute, params)
