"""L1 Pallas kernel: hierarchical collective cost on a two-level topology.

Computes, per (config, layer, phase), the cost of the layer's communication
collective (all-reduce / all-to-all / all-gather / reduce-scatter) on the
two-level intra-pod / inter-pod network of the modeled cluster
(paper SIII-C3, "Hierarchical Collective" a la BlueConnect / Themis).

Same blocking scheme as roofline.py: grid over config blocks, one
[BLK_B, L, MF] tile in VMEM per step, all math element-wise (VPU).
interpret=True - see roofline.py.

The formulation composes the cost from per-level ring *step* terms
(steps x (chunk/bw + latency)) rather than ref.py's closed (n-1)/n forms;
both are algebraically identical, keeping the pytest comparison meaningful.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import layout as ly

BLK_B = 8


def _ring_pass(bytes_, n, bw, lat):
    """One ring pass (reduce-scatter or all-gather): n-1 steps of size/n."""
    steps = jnp.maximum(n - 1.0, 0.0)
    chunk = bytes_ / jnp.maximum(n, 1.0)
    return steps * (chunk / bw + lat)


def _cost(bytes_, ctype, n_intra, n_inter, bw_intra, bw_inter, lat, impl):
    n = jnp.maximum(n_intra * n_inter, 1.0)

    # Logical ring (impl 0): one flat ring, serialized by the slowest link
    # class it crosses.
    bw_flat = jnp.where(n_inter > 1.0, bw_inter, bw_intra)
    ar_flat = 2.0 * _ring_pass(bytes_, n, bw_flat, lat)
    half_flat = _ring_pass(bytes_, n, bw_flat, lat)

    # Hierarchical (impl 1): RS(intra) + AR(inter, bytes/n_intra) + AG(intra).
    shard = bytes_ / jnp.maximum(n_intra, 1.0)
    ar_hier = (
        _ring_pass(bytes_, n_intra, bw_intra, lat)
        + 2.0 * _ring_pass(shard, n_inter, bw_inter, lat)
        + _ring_pass(bytes_, n_intra, bw_intra, lat)
    )
    half_hier = _ring_pass(bytes_, n_intra, bw_intra, lat) + _ring_pass(
        shard, n_inter, bw_inter, lat
    )

    hier = impl > 0.5
    ar = jnp.where(hier, ar_hier, ar_flat)
    half = jnp.where(hier, half_hier, half_flat)

    # All-to-all: intra/inter portions concurrent on their own link classes.
    peers = jnp.maximum(n - 1.0, 1.0)
    f_intra = jnp.maximum(n_intra - 1.0, 0.0) / peers
    a2a = (
        jnp.maximum(
            bytes_ * f_intra / bw_intra,
            bytes_ * (1.0 - f_intra) / bw_inter,
        )
        + (n - 1.0) * lat
    )

    is_half = (ctype == ly.CT_ALLGATHER) | (ctype == ly.CT_REDUCESCATTER)
    cost = jnp.where(
        ctype == ly.CT_ALLREDUCE,
        ar,
        jnp.where(ctype == ly.CT_ALLTOALL, a2a, jnp.where(is_half, half, 0.0)),
    )
    return jnp.where((ctype <= 0.0) | (bytes_ <= 0.0) | (n <= 1.0), 0.0, cost)


def _collective_kernel(comm_ref, params_ref, out_ref):
    """Pallas body: comm_ref [BLK_B, L, MF], params_ref [BLK_B, P],
    out_ref [BLK_B, L, 3]."""
    cm = comm_ref[...]
    prm = params_ref[...]
    bw_intra = jnp.maximum(prm[:, ly.P_BW_INTRA], 1.0)[:, None]
    bw_inter = jnp.maximum(prm[:, ly.P_BW_INTER], 1.0)[:, None]
    lat = prm[:, ly.P_LINK_LAT][:, None]
    impl = prm[:, ly.P_COLL_IMPL][:, None]

    repeat = cm[:, :, ly.M_REPEAT]
    for phase, (by, ct, ni, nx) in enumerate(
        (
            (ly.M_BYTES_FP, ly.M_CTYPE_FP, ly.M_NINTRA_FP, ly.M_NINTER_FP),
            (ly.M_BYTES_IG, ly.M_CTYPE_IG, ly.M_NINTRA_IG, ly.M_NINTER_IG),
            (ly.M_BYTES_WG, ly.M_CTYPE_WG, ly.M_NINTRA_WG, ly.M_NINTER_WG),
        )
    ):
        out_ref[:, :, phase] = repeat * _cost(
            cm[:, :, by],
            cm[:, :, ct],
            cm[:, :, ni],
            cm[:, :, nx],
            bw_intra,
            bw_inter,
            lat,
            impl,
        )


@functools.partial(jax.jit, static_argnames=())
def collective_costs(comm, params):
    """Per-layer phase comm costs. comm [B, L, MF], params [B, P] -> [B, L, 3]."""
    b, l, _ = comm.shape
    assert b % BLK_B == 0, f"batch {b} must be a multiple of {BLK_B}"
    return pl.pallas_call(
        _collective_kernel,
        grid=(b // BLK_B,),
        in_specs=[
            pl.BlockSpec((BLK_B, l, ly.MF), lambda i: (i, 0, 0)),
            pl.BlockSpec((BLK_B, ly.P), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLK_B, l, 3), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, l, 3), jnp.float32),
        interpret=True,
    )(comm, params)
