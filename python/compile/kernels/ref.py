"""Pure-jnp reference oracle for the COMET cost-model kernels.

This is the ground truth the Pallas kernels (roofline.py, collective.py) are
validated against in python/tests, and the contract the native Rust evaluator
(rust/src/model/eval.rs) mirrors in f64.

Model summary (paper section references in parentheses):

* Memory traffic of a GEMM with operands U, V bytes and output W bytes on a
  node with on-chip buffer S bytes (SIII-C2):
      psi1 = ceil(U/S) * V + U        # tile U, stream V
      psi2 = ceil(V/S) * U + V        # tile V, stream U
      traffic = max(min(psi1, psi2), U + V) + W
  The max() clamp covers degenerate non-GEMM layers encoded with U = V = 0,
  where each operand is touched exactly once.

* Hybrid local+expanded memory bandwidth (Eqn. 3): the fraction of the
  footprint beyond local capacity spills to expanded memory and all traffic
  is split capacity-proportionally:
      frac_em   = clip((footprint - cap_lm) / footprint, 0, 1)
      bw_hybrid = 1 / ((1 - frac_em)/bw_lm + frac_em/bw_em)

* Roofline compute delay (SIII-C1, Eqn. 2), in time form:
      delay = max(flops / perf_peak, traffic / bw_hybrid)

* Collective costs on a two-level (intra-pod / inter-pod) topology with ring
  schedules at each level (SIII-C3; hierarchical collectives a la
  BlueConnect/Themis).  See collective_cost() below for the exact forms.

* Exposure (SIII-C4): FP/IG collectives are blocking (fully exposed); the WG
  data-parallel collective overlaps with WG compute, exposing only the excess.
"""

import jax.numpy as jnp

from . import layout as ly


def gemm_traffic(u, v, w, s):
    """Bytes moved between memory and the compute unit for one GEMM."""
    s = jnp.maximum(s, 1.0)
    psi1 = jnp.ceil(u / s) * v + u
    psi2 = jnp.ceil(v / s) * u + v
    return jnp.maximum(jnp.minimum(psi1, psi2), u + v) + w


def em_fraction(footprint, cap_lm, em_frac_override):
    """Fraction of memory traffic served by expanded memory."""
    derived = jnp.clip(
        (footprint - cap_lm) / jnp.maximum(footprint, 1.0), 0.0, 1.0
    )
    return jnp.where(em_frac_override >= 0.0, em_frac_override, derived)


def hybrid_bandwidth(bw_lm, bw_em, frac_em):
    """Eqn. 3 effective bandwidth; collapses to bw_lm when nothing spills."""
    bw_em_safe = jnp.maximum(bw_em, 1.0)
    inv = (1.0 - frac_em) / jnp.maximum(bw_lm, 1.0) + frac_em / bw_em_safe
    bw = 1.0 / inv
    # No expanded memory (bw_em == 0) but spilling demanded => starved:
    # modelled as a 1 B/s expanded-memory floor via bw_em_safe.
    return jnp.where(frac_em <= 0.0, bw_lm, bw)


def roofline_delay(flops, traffic, perf_peak, bw_eff):
    """Time-form roofline: max of compute-bound and memory-bound times."""
    return jnp.maximum(
        flops / jnp.maximum(perf_peak, 1.0),
        traffic / jnp.maximum(bw_eff, 1.0),
    )


def _ring_ar(bytes_, n, bw, lat):
    """Flat ring all-reduce over n peers at per-node link bandwidth bw."""
    n = jnp.maximum(n, 1.0)
    return 2.0 * (n - 1.0) / n * bytes_ / jnp.maximum(bw, 1.0) + 2.0 * (
        n - 1.0
    ) * lat


def _ring_half(bytes_, n, bw, lat):
    """Reduce-scatter or all-gather (one ring pass)."""
    n = jnp.maximum(n, 1.0)
    return (n - 1.0) / n * bytes_ / jnp.maximum(bw, 1.0) + (n - 1.0) * lat


def collective_cost(
    bytes_, ctype, n_intra, n_inter, bw_intra, bw_inter, lat, impl
):
    """Cost of one collective on the two-level topology.

    Two implementations (P_COLL_IMPL):

    ``impl == 0`` — logical ring (Table I baseline): one flat ring over all
    n participants; a ring crossing pods is serialized by the slower
    inter-pod links, so the effective bandwidth is bw_inter when
    n_inter > 1 and bw_intra otherwise.

    ``impl == 1`` — hierarchical (BlueConnect/Themis, SV-B4):
      1. intra-pod reduce-scatter of `bytes` at bw_intra
      2. inter-pod all-reduce of `bytes / n_intra` at bw_inter
      3. intra-pod all-gather of `bytes` at bw_intra
    Degenerate levels (n == 1) contribute zero, covering flat groups.

    All-to-all (either impl): every participant holds `bytes` split evenly
    across the n - 1 peers; intra- and inter-pod portions proceed
    concurrently on their own links, so cost is the max serialization time.

    All-gather / reduce-scatter: one ring pass (half of all-reduce).
    """
    n = jnp.maximum(n_intra * n_inter, 1.0)

    # Flat logical-ring bandwidth: bottlenecked by the slowest link crossed.
    bw_flat = jnp.where(n_inter > 1.0, bw_inter, bw_intra)
    ar_flat = _ring_ar(bytes_, n, bw_flat, lat)
    half_flat = _ring_half(bytes_, n, bw_flat, lat)

    # Hierarchical all-reduce.
    ar_hier = (
        _ring_half(bytes_, n_intra, bw_intra, lat)
        + _ring_ar(bytes_ / jnp.maximum(n_intra, 1.0), n_inter, bw_inter, lat)
        + _ring_half(bytes_, n_intra, bw_intra, lat)
    )
    half_hier = _ring_half(bytes_, n_intra, bw_intra, lat) + _ring_half(
        bytes_ / jnp.maximum(n_intra, 1.0), n_inter, bw_inter, lat
    )

    hier = impl > 0.5
    ar = jnp.where(hier, ar_hier, ar_flat)
    half = jnp.where(hier, half_hier, half_flat)

    # All-to-all: fraction of peers inside the pod vs outside.
    peers = jnp.maximum(n - 1.0, 1.0)
    f_intra = jnp.maximum(n_intra - 1.0, 0.0) / peers
    f_inter = 1.0 - f_intra
    a2a = (
        jnp.maximum(
            bytes_ * f_intra / jnp.maximum(bw_intra, 1.0),
            bytes_ * f_inter / jnp.maximum(bw_inter, 1.0),
        )
        + (n - 1.0) * lat
    )

    cost = jnp.where(
        ctype == ly.CT_ALLREDUCE,
        ar,
        jnp.where(
            ctype == ly.CT_ALLTOALL,
            a2a,
            jnp.where(
                (ctype == ly.CT_ALLGATHER) | (ctype == ly.CT_REDUCESCATTER),
                half,
                0.0,
            ),
        ),
    )
    # No collective, no payload, or singleton group => free.
    return jnp.where((ctype <= 0.0) | (bytes_ <= 0.0) | (n <= 1.0), 0.0, cost)


def eval_phase_delays(compute, params):
    """Per-layer roofline delays for the three phases.

    compute : [B, L, CF]; params : [B, P]  ->  [B, L, 3] seconds.
    """
    pp = params[:, ly.P_PERF_PEAK][:, None]
    sram = params[:, ly.P_SRAM][:, None]
    frac = em_fraction(
        params[:, ly.P_FOOTPRINT], params[:, ly.P_CAP_LM], params[:, ly.P_EM_FRAC]
    )
    bw = hybrid_bandwidth(params[:, ly.P_BW_LM], params[:, ly.P_BW_EM], frac)[
        :, None
    ]

    repeat = compute[:, :, ly.C_REPEAT]
    outs = []
    for fl, u, v, w in (
        (ly.C_FLOPS_FP, ly.C_U_FP, ly.C_V_FP, ly.C_W_FP),
        (ly.C_FLOPS_IG, ly.C_U_IG, ly.C_V_IG, ly.C_W_IG),
        (ly.C_FLOPS_WG, ly.C_U_WG, ly.C_V_WG, ly.C_W_WG),
    ):
        traffic = gemm_traffic(
            compute[:, :, u], compute[:, :, v], compute[:, :, w], sram
        )
        outs.append(
            repeat * roofline_delay(compute[:, :, fl], traffic, pp, bw)
        )
    return jnp.stack(outs, axis=-1)


def eval_phase_comms(comm, params):
    """Per-layer collective costs for the three phases.

    comm : [B, L, MF]; params : [B, P]  ->  [B, L, 3] seconds.
    """
    bwi = params[:, ly.P_BW_INTRA][:, None]
    bwx = params[:, ly.P_BW_INTER][:, None]
    lat = params[:, ly.P_LINK_LAT][:, None]
    impl = params[:, ly.P_COLL_IMPL][:, None]
    repeat = comm[:, :, ly.M_REPEAT]
    outs = []
    for by, ct, ni, nx in (
        (ly.M_BYTES_FP, ly.M_CTYPE_FP, ly.M_NINTRA_FP, ly.M_NINTER_FP),
        (ly.M_BYTES_IG, ly.M_CTYPE_IG, ly.M_NINTRA_IG, ly.M_NINTER_IG),
        (ly.M_BYTES_WG, ly.M_CTYPE_WG, ly.M_NINTRA_WG, ly.M_NINTER_WG),
    ):
        outs.append(
            repeat
            * collective_cost(
                comm[:, :, by],
                comm[:, :, ct],
                comm[:, :, ni],
                comm[:, :, nx],
                bwi,
                bwx,
                lat,
                impl,
            )
        )
    return jnp.stack(outs, axis=-1)


def eval_breakdown(compute, comm, params):
    """Full reference evaluator: [B, OUTF] iteration-time breakdown."""
    delays = eval_phase_delays(compute, params)  # [B, L, 3]
    comms = eval_phase_comms(comm, params)  # [B, L, 3]

    fp_c = jnp.sum(delays[:, :, 0], axis=1)
    ig_c = jnp.sum(delays[:, :, 1], axis=1)
    wg_c = jnp.sum(delays[:, :, 2], axis=1)
    fp_m = jnp.sum(comms[:, :, 0], axis=1)
    ig_m = jnp.sum(comms[:, :, 1], axis=1)
    wg_m = jnp.sum(comms[:, :, 2], axis=1)

    overlap = params[:, ly.P_OVERLAP_WG] > 0.5
    wg_exposed = jnp.where(overlap, jnp.maximum(wg_m - wg_c, 0.0), wg_m)
    return jnp.stack([fp_c, fp_m, ig_c, ig_m, wg_c, wg_exposed], axis=-1)
