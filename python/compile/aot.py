"""AOT export: lower the L2 COMET cost-model graph to HLO text artifacts.

Run once at build time (``make artifacts``):

    cd python && python -m compile.aot --out-dir ../artifacts

Emits one artifact per batch size in layout.BATCH_SIZES:
    artifacts/comet_eval_b{B}.hlo.txt
plus artifacts/manifest.json describing the tensor ABI (field order, shapes)
that rust/src/model/batch.rs cross-checks at load time.

HLO **text** is the interchange format, NOT a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
bundled xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly. Lowered with return_tuple=True
so the Rust side unwraps with `to_tuple1()`.
"""

import argparse
import json
import os

from jax._src.lib import xla_client as xc

from . import model
from .kernels import layout as ly


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = ly.manifest()
    manifest["artifacts"] = {}
    for b in ly.BATCH_SIZES:
        lowered = model.lower_batch_eval(b)
        text = to_hlo_text(lowered)
        name = f"comet_eval_b{b}.hlo.txt"
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][str(b)] = name
        print(f"wrote {path} ({len(text)} chars)")
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    export(args.out_dir)


if __name__ == "__main__":
    main()
