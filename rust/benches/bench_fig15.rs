//! Regenerates paper Fig. 15 (11-cluster comparison).
use comet::coordinator::{sweep, Coordinator};
use comet::util::bench::{black_box, Bencher};

fn main() {
    let coord = Coordinator::native();
    let f = sweep::fig15(&coord).unwrap();
    assert!(f.cell("C0", "Transformer-1T").unwrap() > f.cell("A0", "Transformer-1T").unwrap());
    println!("{}", f.to_table());

    let mut b = Bencher::new();
    b.bench("fig15/native_cold", || {
        let c = Coordinator::native();
        black_box(sweep::fig15(&c).unwrap());
    });
    if let Ok(ac) = Coordinator::artifact() {
        b.bench("fig15/artifact(pjrt)_cold_cache", || {
            black_box(sweep::fig15(&ac).unwrap());
        });
    }
    b.report("bench_fig15");
}
