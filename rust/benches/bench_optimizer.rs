//! Pruned-vs-exhaustive co-design search: times the branch-and-bound
//! optimizer against full-grid enumeration on both built-in optimize
//! scenarios and records the evaluated/pruned point counts alongside the
//! timings in `BENCH_dse.json` (see BENCHMARKS.md for the comparison
//! rule: search must evaluate <= 50% of the grid and return the
//! identical argmin — the counts recorded here are what the rule is
//! checked against over time). `pipeline-transformer` adds a 3D-lattice
//! point (PP x microbatch x schedule branches) so the trajectory records
//! how pruning scales with the pipeline axis.
//!
//! Thread scaling: the `optimize-transformer` search is additionally
//! timed at 1, 2, and the host's pool width in lanes
//! (`optimizer/..._search_t<N>`), after an untimed pass asserting the
//! outcomes are bit-identical across widths. BENCHMARKS.md's
//! thread-scaling rule compares the tN/t1 speedup across trajectory
//! points from the same machine.
use comet::coordinator::Coordinator;
use comet::scenario::{optimizer_for, registry};
use comet::util::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::new();
    for name in
        ["optimize-transformer", "optimize-dlrm", "pipeline-transformer"]
    {
        let spec = registry::get(name).unwrap();
        // Correctness pass (untimed): the pruned search must return the
        // exhaustive argmin.
        let coord = Coordinator::native();
        let opt = optimizer_for(&spec, &coord).unwrap();

        let search = opt.search().unwrap();
        let exhaustive = opt.exhaustive().unwrap();
        assert_eq!(
            search.best().unwrap().label,
            exhaustive.best().unwrap().label,
            "{name}: pruned search must return the exhaustive argmin"
        );
        println!(
            "{name}: argmin {} | search evaluated {}/{} ({} pruned, {} \
             infeasible) vs exhaustive {}",
            search.best().unwrap().label,
            search.evaluated,
            search.total_points,
            search.pruned,
            search.infeasible,
            exhaustive.evaluated,
        );

        // Timed runs build a fresh coordinator per iteration so every
        // leaf evaluation is real work, not a warm-cache lookup — the
        // pruned-vs-exhaustive wall-clock gap is the point of the bench.
        b.bench(&format!("optimizer/{name}_search"), || {
            let c = Coordinator::native();
            let o = optimizer_for(&spec, &c).unwrap();
            black_box(o.search().unwrap());
        });
        b.bench(&format!("optimizer/{name}_exhaustive"), || {
            let c = Coordinator::native();
            let o = optimizer_for(&spec, &c).unwrap();
            black_box(o.exhaustive().unwrap());
        });
        b.metric(
            &format!("optimizer/{name}_evaluated"),
            search.evaluated as f64,
        );
        b.metric(&format!("optimizer/{name}_pruned"), search.pruned as f64);
        b.metric(
            &format!("optimizer/{name}_infeasible"),
            search.infeasible as f64,
        );
        b.metric(
            &format!("optimizer/{name}_exhaustive_evaluated"),
            exhaustive.evaluated as f64,
        );
    }

    // ---- thread scaling: the same search at 1 / 2 / host lanes --------
    let spec = registry::get("optimize-transformer").unwrap();
    let host = Coordinator::native().threads();
    // Untimed exactness pass: the outcome must be bit-identical at every
    // lane count (the parallel driver's headline guarantee; one shared
    // checker with the in-tree tests).
    {
        let coord = Coordinator::native();
        let opt = optimizer_for(&spec, &coord).unwrap();
        let seq = opt.search_sequential().unwrap();
        for lanes in [2usize, host.max(2)] {
            let par = opt.search_parallel(lanes).unwrap();
            seq.assert_bit_identical(&par, &format!("t{lanes}"));
        }
    }
    // The coordinator (and its pool threads) is hoisted OUT of the timed
    // closure: the point is the search's scaling on a persistent pool,
    // not per-iteration thread spawn/join. The search's leaf fast path
    // does not consult the eval cache, so a reused coordinator stays
    // honest work; only the derive cache warms up (identically at every
    // width, during warmup).
    let mut widths = vec![1usize, 2];
    if !widths.contains(&host) {
        widths.push(host);
    }
    for t in widths {
        let c = Coordinator::native().with_threads(t);
        let o = optimizer_for(&spec, &c).unwrap();
        b.bench(&format!("optimizer/optimize-transformer_search_t{t}"), || {
            black_box(o.search().unwrap());
        });
    }
    b.metric("optimizer/thread_scaling_host_lanes", host as f64);

    // ---- checkpoint overhead: flush at every safe boundary ------------
    // `checkpoint_every = 0` writes the resumable state at every batch
    // boundary — the worst case for the crash-safety machinery. Compared
    // against `optimize-transformer_search_t<host>` (same hoisted
    // coordinator, no checkpointing) the gap is the pure serialization +
    // tmp-rename cost per boundary.
    {
        let c = Coordinator::native();
        let o = optimizer_for(&spec, &c).unwrap();
        let ck = std::env::temp_dir()
            .join(format!("comet-bench-ck-{}.json", std::process::id()));
        let exec = comet::optimizer::SearchExec::default()
            .with_checkpoint(ck.clone())
            .with_checkpoint_every(0.0);
        // Untimed exactness pass: checkpointing must not change the
        // outcome (counters included).
        let plain = o.search().unwrap();
        let with_ck = o.search_with(&exec).unwrap();
        plain.assert_bit_identical(&with_ck, "checkpoint-every-0");
        b.bench("optimizer/optimize-transformer_search_ckpt0", || {
            black_box(o.search_with(&exec).unwrap());
        });
        let bytes = std::fs::metadata(&ck).map(|m| m.len()).unwrap_or(0);
        b.metric("optimizer/checkpoint_bytes", bytes as f64);
        let _ = std::fs::remove_file(&ck);
    }

    b.report("bench_optimizer");

    // Trajectory point next to the repo-root BENCHMARKS.md (cargo bench
    // runs with rust/ as CWD), same file the DSE bench appends to.
    let path = std::env::var("COMET_BENCH_JSON")
        .unwrap_or_else(|_| "../BENCH_dse.json".to_string());
    let label = std::env::var("COMET_BENCH_LABEL")
        .unwrap_or_else(|_| "bench_optimizer".to_string());
    match b.append_json(&path, &label) {
        Ok(()) => println!("recorded trajectory point in {path}"),
        Err(e) => eprintln!("could not record {path}: {e}"),
    }
}
