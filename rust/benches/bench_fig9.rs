//! Regenerates paper Fig. 9 (memory-expansion heatmap). The paper reports
//! ~5 h for this figure on a 24-core Xeon (SV-E); COMET-rs regenerates it
//! in milliseconds.
use comet::coordinator::{sweep, Coordinator};
use comet::util::bench::{black_box, Bencher};

fn main() {
    let coord = Coordinator::native();
    let f = sweep::fig9(&coord).unwrap();
    // Crossover shape: MP8_DP128 loses at 250 GB/s, wins at 2039 GB/s.
    assert!(f.cell("MP8_DP128", "250GB/s").unwrap() < 1.0);
    assert!(f.cell("MP8_DP128", "2039GB/s").unwrap() > 1.0);
    println!("{}", f.to_table());

    let mut b = Bencher::new();
    b.bench("fig9/native_cold", || {
        let c = Coordinator::native();
        black_box(sweep::fig9(&c).unwrap());
    });
    if let Ok(ac) = Coordinator::artifact() {
        b.bench("fig9/artifact(pjrt)_cold_cache", || {
            black_box(sweep::fig9(&ac).unwrap());
        });
    }
    b.report("bench_fig9");
}
