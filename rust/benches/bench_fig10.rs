//! Regenerates paper Fig. 10 (compute-capability scaling).
use comet::coordinator::{sweep, Coordinator};
use comet::util::bench::{black_box, Bencher};

fn main() {
    let coord = Coordinator::native();
    let f = sweep::fig10(&coord).unwrap();
    // Halving compute slows down; doubling speeds up with diminishing
    // returns (paper: +50% / -25% at full bandwidth).
    let half = f.cell("compute x0.5", "EM@2039GB/s").unwrap();
    let base = f.cell("compute x1", "EM@2039GB/s").unwrap();
    let dbl = f.cell("compute x2", "EM@2039GB/s").unwrap();
    assert!(half > base && dbl < base);
    println!("{}", f.to_table());
    println!("x0.5: {:+.1}%  x2: {:+.1}%", (half / base - 1.0) * 100.0, (dbl / base - 1.0) * 100.0);

    let mut b = Bencher::new();
    b.bench("fig10/native_cold", || {
        let c = Coordinator::native();
        black_box(sweep::fig10(&c).unwrap());
    });
    b.report("bench_fig10");
}
