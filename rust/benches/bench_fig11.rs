//! Regenerates paper Fig. 11 (intra/inter-pod bandwidth scaling grid).
use comet::coordinator::{sweep, Coordinator};
use comet::util::bench::{black_box, Bencher};

fn main() {
    let coord = Coordinator::native();
    let f = sweep::fig11(&coord).unwrap();
    // MP64 is network-sensitive; MP8 is not.
    let mp64_half = f.cell("MP64_DP16 intra x0.5", "inter x0.5").unwrap();
    let mp8_half = f.cell("MP8_DP128 intra x0.5", "inter x0.5").unwrap();
    assert!(mp64_half < 0.85, "{mp64_half}");
    assert!(mp8_half > 0.80, "{mp8_half}");
    println!("{}", f.to_table());

    let mut b = Bencher::new();
    b.bench("fig11/native_cold", || {
        let c = Coordinator::native();
        black_box(sweep::fig11(&c).unwrap());
    });
    b.report("bench_fig11");
}
