//! Regenerates paper Fig. 6 (ZeRO footprint table) and times it.
use comet::coordinator::sweep;
use comet::util::bench::{black_box, Bencher};

fn main() {
    let f = sweep::fig6();
    assert_eq!(f.rows.len(), 11);
    // Shape: ZeRO-3 flat, baseline doubling per MP halving.
    let z3a = f.cell("MP1024_DP1", "zero-3").unwrap();
    let z3b = f.cell("MP1_DP1024", "zero-3").unwrap();
    assert!((z3a - z3b).abs() < 1e-6);
    println!("{}", f.to_table());

    let mut b = Bencher::new();
    b.bench("fig6/footprint_table", || {
        black_box(sweep::fig6());
    });
    b.report("bench_fig6");
}
