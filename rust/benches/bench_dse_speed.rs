//! The paper's SV-E speed claim: generating the Fig. 9 + Fig. 13b heatmaps
//! takes ~5 h + ~45 min on a 24-core Xeon. This bench times COMET-rs
//! regenerating EVERY figure, per backend, and appends one trajectory
//! point to `BENCH_dse.json` (see BENCHMARKS.md). Cold-cache runs build a
//! fresh `Coordinator` per iteration, so they measure the full pipeline:
//! pool spin-up, parallel `derive_inputs`, sharded-cache misses, backend
//! evaluation.
use std::time::Instant;

use comet::coordinator::{sweep, Coordinator};
use comet::util::bench::{black_box, Bencher};

fn main() {
    let t0 = Instant::now();
    let coord = Coordinator::native();
    let figs = sweep::all_figures(&coord).unwrap();
    println!(
        "all {} figures on the native backend: {:.3} s (paper: hours)",
        figs.len(),
        t0.elapsed().as_secs_f64()
    );

    let mut b = Bencher::new();
    b.bench("dse/all_figures_native_cold", || {
        let c = Coordinator::native();
        black_box(sweep::all_figures(&c).unwrap());
    });
    b.bench("dse/all_figures_native_warmcache", || {
        black_box(sweep::all_figures(&coord).unwrap());
    });
    b.bench("dse/all_figures_des_cold", || {
        let c = Coordinator::des();
        black_box(sweep::all_figures(&c).unwrap());
    });
    if let Ok(ac) = Coordinator::artifact() {
        b.bench("dse/all_figures_artifact_warmcache", || {
            black_box(sweep::all_figures(&ac).unwrap());
        });
    }
    // Cache efficacy of the persistent coordinator travels with the
    // trajectory point — the warm-cache timing is meaningless without it.
    let (hits, misses) = coord.cache_stats();
    b.metric("dse/warm_eval_cache_hits", hits as f64);
    b.metric("dse/warm_eval_cache_misses", misses as f64);
    let (dhits, dmisses) = coord.derive_cache_stats();
    b.metric("dse/warm_derive_cache_hits", dhits as f64);
    b.metric("dse/warm_decompositions", dmisses as f64);
    b.report("bench_dse_speed");

    // Trajectory point: `cargo bench` runs with the package root (rust/)
    // as CWD, so the default lands next to the repo-root BENCHMARKS.md.
    let path = std::env::var("COMET_BENCH_JSON")
        .unwrap_or_else(|_| "../BENCH_dse.json".to_string());
    let label = std::env::var("COMET_BENCH_LABEL")
        .unwrap_or_else(|_| "bench_dse_speed".to_string());
    match b.append_json(&path, &label) {
        Ok(()) => println!("recorded trajectory point in {path}"),
        Err(e) => eprintln!("could not record {path}: {e}"),
    }
}
