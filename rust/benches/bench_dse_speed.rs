//! The paper's SV-E speed claim: generating the Fig. 9 + Fig. 13b heatmaps
//! takes ~5 h + ~45 min on a 24-core Xeon. This bench times COMET-rs
//! regenerating EVERY figure, per backend.
use std::time::Instant;

use comet::coordinator::{sweep, Coordinator};
use comet::util::bench::{black_box, Bencher};

fn main() {
    let t0 = Instant::now();
    let coord = Coordinator::native();
    let figs = sweep::all_figures(&coord).unwrap();
    println!(
        "all {} figures on the native backend: {:.3} s (paper: hours)",
        figs.len(),
        t0.elapsed().as_secs_f64()
    );

    let mut b = Bencher::new();
    b.bench("dse/all_figures_native_cold", || {
        let c = Coordinator::native();
        black_box(sweep::all_figures(&c).unwrap());
    });
    b.bench("dse/all_figures_des_cold", || {
        let c = Coordinator::des();
        black_box(sweep::all_figures(&c).unwrap());
    });
    if let Ok(ac) = Coordinator::artifact() {
        b.bench("dse/all_figures_artifact_warmcache", || {
            black_box(sweep::all_figures(&ac).unwrap());
        });
    }
    b.report("bench_dse_speed");
}
