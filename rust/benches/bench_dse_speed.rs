//! The paper's SV-E speed claim: generating the Fig. 9 + Fig. 13b heatmaps
//! takes ~5 h + ~45 min on a 24-core Xeon. This bench times COMET-rs
//! regenerating EVERY figure, per backend, and appends one trajectory
//! point to `BENCH_dse.json` (see BENCHMARKS.md). Cold-cache runs build a
//! fresh `Coordinator` per iteration, so they measure the full pipeline:
//! pool spin-up, parallel `derive_inputs`, sharded-cache misses, backend
//! evaluation.
use std::time::Instant;

use comet::config::presets;
use comet::coordinator::{sweep, Coordinator};
use comet::model::inputs::{derive_inputs, EvalOptions};
use comet::parallel::Strategy;
use comet::sim::{simulate, simulate_with, SimScratch};
use comet::util::bench::{black_box, Bencher};
use comet::workload::transformer::Transformer;

fn main() {
    let t0 = Instant::now();
    let coord = Coordinator::native();
    let figs = sweep::all_figures(&coord).unwrap();
    println!(
        "all {} figures on the native backend: {:.3} s (paper: hours)",
        figs.len(),
        t0.elapsed().as_secs_f64()
    );

    let mut b = Bencher::new();
    b.bench("dse/all_figures_native_cold", || {
        let c = Coordinator::native();
        black_box(sweep::all_figures(&c).unwrap());
    });
    b.bench("dse/all_figures_native_warmcache", || {
        black_box(sweep::all_figures(&coord).unwrap());
    });
    b.bench("dse/all_figures_des_cold", || {
        let c = Coordinator::des();
        black_box(sweep::all_figures(&c).unwrap());
    });
    if let Ok(ac) = Coordinator::artifact() {
        b.bench("dse/all_figures_artifact_warmcache", || {
            black_box(sweep::all_figures(&ac).unwrap());
        });
    }
    // Cache efficacy of the persistent coordinator travels with the
    // trajectory point — the warm-cache timing is meaningless without it.
    let (hits, misses) = coord.cache_stats();
    b.metric("dse/warm_eval_cache_hits", hits as f64);
    b.metric("dse/warm_eval_cache_misses", misses as f64);
    let (dhits, dmisses) = coord.derive_cache_stats();
    b.metric("dse/warm_derive_cache_hits", dhits as f64);
    b.metric("dse/warm_decompositions", dmisses as f64);

    // DES raw-throughput metrics on the fig9-scale pp > 1 point (the
    // ≥5x events/sec acceptance target vs the pre-calendar-queue
    // baseline lives in BENCHMARKS.md).
    let cluster = presets::dgx_a100_1024();
    let pipe = derive_inputs(
        &Transformer::t1()
            .build(&Strategy::new_3d(8, 32, 4).unwrap())
            .unwrap(),
        &cluster,
        &EvalOptions {
            ignore_capacity: true,
            microbatches: 8,
            ..Default::default()
        },
    )
    .unwrap();
    let events = simulate(&pipe).stats.events;
    let mut scratch = SimScratch::new();
    let mean_s = b
        .bench("des/simulate_fig9_pp4_config", || {
            black_box(simulate_with(black_box(&pipe), &mut scratch));
        })
        .summary
        .mean;
    b.metric("des_events_per_sec", events as f64 / mean_s.max(1e-12));
    // Peak pending events come from a 2D (dp-dominated) sim: the pp > 1
    // path precomputes its event order and never queues.
    let flat = derive_inputs(
        &Transformer::t1()
            .build(&Strategy::new(8, 128).unwrap())
            .unwrap(),
        &cluster,
        &EvalOptions { ignore_capacity: true, ..Default::default() },
    )
    .unwrap();
    b.metric("des_peak_events", simulate(&flat).stats.peak_events as f64);
    b.report("bench_dse_speed");

    // Trajectory point: `cargo bench` runs with the package root (rust/)
    // as CWD, so the default lands next to the repo-root BENCHMARKS.md.
    let path = std::env::var("COMET_BENCH_JSON")
        .unwrap_or_else(|_| "../BENCH_dse.json".to_string());
    let label = std::env::var("COMET_BENCH_LABEL")
        .unwrap_or_else(|_| "bench_dse_speed".to_string());
    match b.append_json(&path, &label) {
        Ok(()) => println!("recorded trajectory point in {path}"),
        Err(e) => eprintln!("could not record {path}: {e}"),
    }
}
