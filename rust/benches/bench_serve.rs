//! Loopback throughput of `comet serve`: requests/sec for `POST /run`
//! on a **warm** shared coordinator (the daemon's steady state — derive
//! and eval caches hot) vs the **cold** full round trip (bind a fresh
//! server, run one request on empty caches, drain). The gap is the
//! entire value proposition of the daemon over one-shot CLI runs, so
//! both land in `BENCH_dse.json` as `serve_rps_{cold,warm}` side
//! metrics (see BENCHMARKS.md for the comparison rule).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;

use comet::coordinator::Coordinator;
use comet::scenario::{self, registry};
use comet::serve::{ServeConfig, Server};
use comet::util::bench::{black_box, Bencher};
use comet::util::cancel::CancelToken;

/// An in-process server on an ephemeral loopback port; dropping drains
/// it and joins the serving thread.
struct Running {
    addr: SocketAddr,
    shutdown: CancelToken,
    handle: Option<std::thread::JoinHandle<()>>,
}

fn start() -> Running {
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_concurrency: 1,
        ..ServeConfig::default()
    };
    let server =
        Arc::new(Server::bind(cfg, Coordinator::native()).expect("bind :0"));
    let addr = server.local_addr().expect("local addr");
    let shutdown = CancelToken::new();
    let tok = shutdown.clone();
    let handle = std::thread::spawn(move || {
        server.run(&tok).expect("serve run");
    });
    Running {
        addr,
        shutdown,
        handle: Some(handle),
    }
}

impl Drop for Running {
    fn drop(&mut self) {
        self.shutdown.cancel();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// One full `POST /run` exchange; returns the raw response.
fn post_run(addr: SocketAddr, request: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(request.as_bytes()).expect("send");
    let mut out = String::new();
    s.read_to_string(&mut out).expect("read response");
    out
}

fn main() {
    let spec = registry::get("quickstart").unwrap();
    let body = spec.to_json().to_string_pretty();
    let request = format!(
        "POST /run HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    );

    // Correctness pass (untimed): the served body must be byte-identical
    // to the library result — the same contract the socket tests pin.
    {
        let srv = start();
        let response = post_run(srv.addr, &request);
        assert!(
            response.starts_with("HTTP/1.1 200 OK\r\n"),
            "serve bench sanity: {response}"
        );
        let served = response.split("\r\n\r\n").nth(1).unwrap();
        let mut expect = scenario::run(&spec, &Coordinator::native())
            .unwrap()
            .to_json()
            .to_string_pretty();
        expect.push('\n');
        assert_eq!(served, expect, "served body must match the library run");
    }

    let mut b = Bencher::new();

    // Cold: the full daemon lifecycle per request — bind, serve one
    // request on empty caches, drain. Dominated by startup/drain, which
    // is the honest cost of *not* keeping the daemon alive.
    let cold = b
        .bench("serve/run_quickstart_cold", || {
            let srv = start();
            black_box(post_run(srv.addr, &request));
        })
        .summary
        .median;

    // Warm: the daemon's steady state — one long-lived server, caches
    // hot after the first request, each iteration one loopback exchange.
    let srv = start();
    let warmup = post_run(srv.addr, &request);
    assert!(warmup.starts_with("HTTP/1.1 200 OK\r\n"));
    let warm = b
        .bench("serve/run_quickstart_warm", || {
            black_box(post_run(srv.addr, &request));
        })
        .summary
        .median;
    drop(srv);

    b.metric("serve_rps_cold", 1.0 / cold);
    b.metric("serve_rps_warm", 1.0 / warm);

    b.report("bench_serve");

    let path = std::env::var("COMET_BENCH_JSON")
        .unwrap_or_else(|_| "../BENCH_dse.json".to_string());
    let label = std::env::var("COMET_BENCH_LABEL")
        .unwrap_or_else(|_| "bench_serve".to_string());
    match b.append_json(&path, &label) {
        Ok(()) => println!("recorded trajectory point in {path}"),
        Err(e) => eprintln!("could not record {path}: {e}"),
    }
}
