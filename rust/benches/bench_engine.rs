//! Microbenchmarks of the evaluation backends: closed-form, DES, and the
//! PJRT artifact, per single configuration and per 64-config batch.
use comet::analytical::evaluate;
use comet::config::presets;
use comet::model::batch::{pack, stack};
use comet::model::inputs::{derive_inputs, EvalOptions};
use comet::parallel::Strategy;
use comet::runtime::{BatchEvaluator, Runtime};
use comet::sim::{simulate, simulate_oracle, simulate_with, SimScratch};
use comet::util::bench::{black_box, Bencher};
use comet::workload::transformer::Transformer;

fn main() {
    let cluster = presets::dgx_a100_1024();
    let opts = EvalOptions { ignore_capacity: true, ..Default::default() };
    let inp = derive_inputs(
        &Transformer::t1()
            .build(&Strategy::new(8, 128).unwrap())
            .unwrap(),
        &cluster,
        &opts,
    )
    .unwrap();
    // Fig. 9-scale pipeline point (pp > 1): the --cross-check workload.
    let pipe = derive_inputs(
        &Transformer::t1()
            .build(&Strategy::new_3d(8, 32, 4).unwrap())
            .unwrap(),
        &cluster,
        &EvalOptions {
            ignore_capacity: true,
            microbatches: 8,
            ..Default::default()
        },
    )
    .unwrap();
    let batch: Vec<_> = (0..64).map(|_| inp.clone()).collect();

    let mut b = Bencher::new();
    b.bench("analytical/eval_1_config", || {
        black_box(evaluate(black_box(&inp)));
    });
    b.bench("des/simulate_1_config", || {
        black_box(simulate(black_box(&inp)));
    });
    // Retained heap-queue oracle (fresh scratch each run) — the baseline
    // the calendar-queue speedup in BENCHMARKS.md is measured against.
    b.bench("des/simulate_1_config_oracle_heap", || {
        black_box(simulate_oracle(black_box(&inp)));
    });
    let mut scratch = SimScratch::new();
    b.bench("des/simulate_1_config_reused_scratch", || {
        black_box(simulate_with(black_box(&inp), &mut scratch));
    });
    b.bench("des/simulate_fig9_pp4_config", || {
        black_box(simulate(black_box(&pipe)));
    });
    b.bench("abi/pack_1_config", || {
        black_box(pack(black_box(&inp)).unwrap());
    });
    let packed = pack(&inp).unwrap();
    let packed64: Vec<_> = (0..64).map(|_| packed.clone()).collect();
    b.bench("abi/stack_64_configs", || {
        black_box(stack(black_box(&packed64), 64).unwrap());
    });
    if let Ok(rt) = Runtime::load_default() {
        let ev = BatchEvaluator::new(&rt);
        b.bench("artifact/eval_64_configs(pjrt)", || {
            black_box(ev.evaluate(black_box(&batch)).unwrap());
        });
        b.bench("artifact/eval_1_config(pjrt)", || {
            black_box(ev.evaluate_one(black_box(&inp)).unwrap());
        });
    }
    b.report("bench_engine");
}
