//! Microbenchmarks of the evaluation backends: closed-form, DES, and the
//! PJRT artifact, per single configuration and per 64-config batch.
use comet::analytical::evaluate;
use comet::config::presets;
use comet::model::batch::{pack, stack};
use comet::model::inputs::{derive_inputs, EvalOptions};
use comet::parallel::Strategy;
use comet::runtime::{BatchEvaluator, Runtime};
use comet::sim::simulate;
use comet::util::bench::{black_box, Bencher};
use comet::workload::transformer::Transformer;

fn main() {
    let cluster = presets::dgx_a100_1024();
    let opts = EvalOptions { ignore_capacity: true, ..Default::default() };
    let inp = derive_inputs(
        &Transformer::t1()
            .build(&Strategy::new(8, 128).unwrap())
            .unwrap(),
        &cluster,
        &opts,
    )
    .unwrap();
    let batch: Vec<_> = (0..64).map(|_| inp.clone()).collect();

    let mut b = Bencher::new();
    b.bench("analytical/eval_1_config", || {
        black_box(evaluate(black_box(&inp)));
    });
    b.bench("des/simulate_1_config", || {
        black_box(simulate(black_box(&inp)));
    });
    b.bench("abi/pack_1_config", || {
        black_box(pack(black_box(&inp)).unwrap());
    });
    let packed = pack(&inp).unwrap();
    let packed64: Vec<_> = (0..64).map(|_| packed.clone()).collect();
    b.bench("abi/stack_64_configs", || {
        black_box(stack(black_box(&packed64), 64).unwrap());
    });
    if let Ok(rt) = Runtime::load_default() {
        let ev = BatchEvaluator::new(&rt);
        b.bench("artifact/eval_64_configs(pjrt)", || {
            black_box(ev.evaluate(black_box(&batch)).unwrap());
        });
        b.bench("artifact/eval_1_config(pjrt)", || {
            black_box(ev.evaluate_one(black_box(&inp)).unwrap());
        });
    }
    b.report("bench_engine");
}
