//! Regenerates paper Fig. 8a/8b (parallelization-strategy breakdown).
use comet::coordinator::{sweep, Coordinator};
use comet::util::bench::{black_box, Bencher};

fn main() {
    let coord = Coordinator::native();
    let f = sweep::fig8a(&coord).unwrap();
    assert_eq!(f.argmin("Total_s"), Some("MP8_DP128"));
    println!("{}", f.to_table());
    println!("{}", sweep::fig8b(&coord).unwrap().to_table());

    let mut b = Bencher::new();
    b.bench("fig8a/native", || {
        let c = Coordinator::native(); // cold cache each iteration
        black_box(sweep::fig8a(&c).unwrap());
    });
    if let Ok(ac) = Coordinator::artifact() {
        b.bench("fig8a/artifact(pjrt)", || {
            black_box(sweep::fig8a(&ac).unwrap());
        });
    }
    b.bench("fig8a/native_warm_cache", || {
        black_box(sweep::fig8a(&coord).unwrap());
    });
    b.report("bench_fig8");
}
