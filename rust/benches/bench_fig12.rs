//! Regenerates paper Fig. 12 (fixed-aggregate bandwidth rebalancing).
use comet::coordinator::{sweep, Coordinator};
use comet::util::bench::{black_box, Bencher};

fn main() {
    let coord = Coordinator::native();
    let f = sweep::fig12(&coord).unwrap();
    println!("{}", f.to_table());
    // The MP64 column's best ratio should sit in the paper's 1:4-1:8 band.
    let best = f
        .rows
        .iter()
        .max_by(|a, b| a.1[0].partial_cmp(&b.1[0]).unwrap())
        .unwrap();
    println!("best ratio for MP64_DP16: {} ({:.3}x)", best.0, best.1[0]);

    let mut b = Bencher::new();
    b.bench("fig12/native_cold", || {
        let c = Coordinator::native();
        black_box(sweep::fig12(&c).unwrap());
    });
    b.report("bench_fig12");
}
