//! Regenerates paper Fig. 13a/13b (DLRM studies). The paper reports ~45 min
//! for the Fig. 13b heatmap (SV-E).
use comet::coordinator::{sweep, Coordinator};
use comet::util::bench::{black_box, Bencher};

fn main() {
    let coord = Coordinator::native();
    let fa = sweep::fig13a(&coord).unwrap();
    let fb = sweep::fig13b(&coord).unwrap();
    // Sublinear growth with shrinking clusters.
    assert!(fa.cell("32 nodes", "Norm_to_64").unwrap() < 2.0);
    println!("{}", fa.to_table());
    println!("{}", fb.to_table());

    let mut b = Bencher::new();
    b.bench("fig13a/native_cold", || {
        let c = Coordinator::native();
        black_box(sweep::fig13a(&c).unwrap());
    });
    b.bench("fig13b/native_cold", || {
        let c = Coordinator::native();
        black_box(sweep::fig13b(&c).unwrap());
    });
    b.report("bench_fig13");
}
