//! ASCII-table renderer for figure data.

use super::FigureData;

/// Format one value: engineering-friendly fixed/precision switching.
fn fmt(v: f64) -> String {
    if v.is_nan() {
        "-".to_string()
    } else if v == 0.0 {
        "0".to_string()
    } else if v.abs() >= 1e5 || v.abs() < 1e-3 {
        format!("{v:.3e}")
    } else {
        format!("{v:.3}")
    }
}

/// Render the figure as a boxed ASCII table.
pub fn render(f: &FigureData) -> String {
    let mut header: Vec<String> = vec![f.row_label.clone()];
    header.extend(f.columns.iter().cloned());
    let mut grid: Vec<Vec<String>> = vec![header];
    for (label, vals) in &f.rows {
        let mut row = vec![label.clone()];
        row.extend(vals.iter().map(|v| fmt(*v)));
        grid.push(row);
    }
    let ncols = grid.iter().map(|r| r.len()).max().unwrap_or(0);
    let mut widths = vec![0usize; ncols];
    for row in &grid {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }

    let mut out = String::new();
    out.push_str(&format!("== {} ({}) ==\n", f.title, f.id));
    let sep: String = widths
        .iter()
        .map(|w| "-".repeat(w + 2))
        .collect::<Vec<_>>()
        .join("+");
    out.push_str(&format!("+{sep}+\n"));
    for (ri, row) in grid.iter().enumerate() {
        out.push('|');
        for (i, w) in widths.iter().enumerate() {
            let cell = row.get(i).map(String::as_str).unwrap_or("");
            if i == 0 {
                out.push_str(&format!(" {cell:<w$} |"));
            } else {
                out.push_str(&format!(" {cell:>w$} |"));
            }
        }
        out.push('\n');
        if ri == 0 {
            out.push_str(&format!("+{sep}+\n"));
        }
    }
    out.push_str(&format!("+{sep}+\n"));
    for n in &f.notes {
        out.push_str(&format!("  note: {n}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::tests::sample;
    use super::*;

    #[test]
    fn renders_all_cells() {
        let t = render(&sample());
        assert!(t.contains("r1"));
        assert!(t.contains("r2"));
        assert!(t.contains("2.000"));
        assert!(t.contains('-')); // NaN cell
        assert!(t.contains("note: normalized to r1/a"));
    }

    #[test]
    fn fmt_switches_notation() {
        assert_eq!(fmt(1.5), "1.500");
        assert_eq!(fmt(1.5e7), "1.500e7");
        assert_eq!(fmt(0.0001), "1.000e-4");
        assert_eq!(fmt(0.0), "0");
        assert_eq!(fmt(f64::NAN), "-");
    }

    #[test]
    fn columns_aligned() {
        let t = render(&sample());
        let lines: Vec<&str> = t.lines().filter(|l| l.starts_with('|')).collect();
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w), "{t}");
    }
}
