//! Reporting: structured figure data plus ASCII-table and CSV renderers.
//! Every paper figure/table driver (coordinator::sweep) returns a
//! [`FigureData`]; the CLI and benches render or persist it.

pub mod csv;
pub mod table;

/// One regenerated figure/table: a named grid of series.
#[derive(Debug, Clone, PartialEq)]
pub struct FigureData {
    /// Identifier ("fig8a", "fig9", ...).
    pub id: String,
    /// Human title.
    pub title: String,
    /// Name of the row dimension (e.g. "(MP, DP)").
    pub row_label: String,
    /// Column headers (e.g. bandwidth points or breakdown components).
    pub columns: Vec<String>,
    /// Rows: (label, one value per column). NaN = not applicable.
    pub rows: Vec<(String, Vec<f64>)>,
    /// Free-form notes (normalization baseline, units).
    pub notes: Vec<String>,
}

impl FigureData {
    /// Look up a cell by row and column label.
    pub fn cell(&self, row: &str, col: &str) -> Option<f64> {
        let ci = self.columns.iter().position(|c| c == col)?;
        let r = self.rows.iter().find(|(l, _)| l == row)?;
        r.1.get(ci).copied()
    }

    /// The row with the minimum value in `col`.
    pub fn argmin(&self, col: &str) -> Option<&str> {
        let ci = self.columns.iter().position(|c| c == col)?;
        self.rows
            .iter()
            .filter(|(_, v)| v[ci].is_finite())
            .min_by(|a, b| a.1[ci].partial_cmp(&b.1[ci]).unwrap())
            .map(|(l, _)| l.as_str())
    }

    /// Render as an ASCII table.
    pub fn to_table(&self) -> String {
        table::render(self)
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        csv::render(self)
    }

    /// Render as a JSON value (`comet scenario` output format "json").
    /// Non-finite cells become `null` — JSON has no NaN.
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::Value;
        let cell = |v: &f64| {
            if v.is_finite() {
                Value::Num(*v)
            } else {
                Value::Null
            }
        };
        crate::util::json::obj(vec![
            ("id", Value::Str(self.id.clone())),
            ("title", Value::Str(self.title.clone())),
            ("row_label", Value::Str(self.row_label.clone())),
            (
                "columns",
                Value::Arr(
                    self.columns
                        .iter()
                        .map(|c| Value::Str(c.clone()))
                        .collect(),
                ),
            ),
            (
                "rows",
                Value::Arr(
                    self.rows
                        .iter()
                        .map(|(label, vals)| {
                            crate::util::json::obj(vec![
                                ("label", Value::Str(label.clone())),
                                (
                                    "values",
                                    Value::Arr(vals.iter().map(cell).collect()),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "notes",
                Value::Arr(
                    self.notes
                        .iter()
                        .map(|n| Value::Str(n.clone()))
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn sample() -> FigureData {
        FigureData {
            id: "figX".into(),
            title: "Sample".into(),
            row_label: "cfg".into(),
            columns: vec!["a".into(), "b".into()],
            rows: vec![
                ("r1".into(), vec![1.0, 2.0]),
                ("r2".into(), vec![0.5, f64::NAN]),
            ],
            notes: vec!["normalized to r1/a".into()],
        }
    }

    #[test]
    fn cell_lookup() {
        let f = sample();
        assert_eq!(f.cell("r1", "b"), Some(2.0));
        assert_eq!(f.cell("r9", "b"), None);
        assert_eq!(f.cell("r1", "z"), None);
    }

    #[test]
    fn argmin_skips_nan() {
        let f = sample();
        assert_eq!(f.argmin("a"), Some("r2"));
        assert_eq!(f.argmin("b"), Some("r1"));
    }

    #[test]
    fn json_is_parseable_and_nan_becomes_null() {
        use crate::util::json;
        let f = sample();
        let v = json::parse(&f.to_json().to_string_pretty()).unwrap();
        assert_eq!(v.get("id").unwrap().as_str(), Some("figX"));
        let rows = v.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        // r2's second cell is NaN in the figure -> null in JSON.
        let r2 = rows[1].get("values").unwrap().as_arr().unwrap();
        assert_eq!(r2[1], json::Value::Null);
    }
}
