//! CSV renderer for figure data (plot-ready output under results/).

use super::FigureData;

/// Quote a CSV field if needed.
fn quote(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Render the figure as CSV (header row + one row per series).
pub fn render(f: &FigureData) -> String {
    let mut out = String::new();
    out.push_str(&quote(&f.row_label));
    for c in &f.columns {
        out.push(',');
        out.push_str(&quote(c));
    }
    out.push('\n');
    for (label, vals) in &f.rows {
        out.push_str(&quote(label));
        for v in vals {
            out.push(',');
            if v.is_nan() {
                // empty cell for N/A
            } else {
                out.push_str(&format!("{v}"));
            }
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::tests::sample;
    use super::*;

    #[test]
    fn renders_csv_grid() {
        let c = render(&sample());
        let lines: Vec<&str> = c.lines().collect();
        assert_eq!(lines[0], "cfg,a,b");
        assert_eq!(lines[1], "r1,1,2");
        assert_eq!(lines[2], "r2,0.5,"); // NaN -> empty
    }

    #[test]
    fn quoting() {
        assert_eq!(quote("plain"), "plain");
        assert_eq!(quote("a,b"), "\"a,b\"");
        assert_eq!(quote("say \"hi\""), "\"say \"\"hi\"\"\"");
    }
}
