//! Crate-wide error type.

use std::fmt;

/// Errors surfaced by the COMET toolchain.
#[derive(Debug)]
pub enum Error {
    /// Invalid cluster / strategy / workload configuration.
    Config(String),
    /// Artifact ABI mismatch between `artifacts/manifest.json` and this
    /// crate's compiled-in layout (see [`crate::model::batch`]).
    AbiMismatch(String),
    /// Artifact file missing or unreadable.
    Artifact(String),
    /// PJRT / XLA runtime failure.
    Runtime(String),
    /// JSON parse error (configs, manifest).
    Json(String),
    /// I/O error with path context.
    Io(String),
    /// Malformed user input (scenario TOML, trace files) with key/line
    /// context — a parse problem, not an invalid-but-well-formed config.
    Parse(String),
    /// A worker-pool job panicked; the panic was caught at the CLI
    /// boundary and converted into a clean error (the pool itself stays
    /// usable — `scheduler` re-raises with the job index).
    Worker(String),
    /// A specific worker-pool job failed (panicked or stalled) while the
    /// rest of the batch completed. Carries the job index so callers can
    /// retry or report precisely which unit of work died.
    Job {
        /// Index of the failed job within its batch.
        index: usize,
        /// Captured panic message / stall description.
        cause: String,
    },
    /// A run was cancelled cooperatively (SIGINT or an explicit
    /// [`crate::util::cancel::CancelToken`]); partial results may have
    /// been checkpointed or returned separately.
    Cancelled(String),
    /// A run exceeded its deadline and was stopped at a safe boundary;
    /// partial results may have been checkpointed or returned separately.
    Deadline(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::AbiMismatch(m) => write!(f, "artifact ABI mismatch: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Io(m) => write!(f, "io error: {m}"),
            Error::Parse(m) => write!(f, "toml parse error: {m}"),
            Error::Worker(m) => write!(f, "worker error: {m}"),
            Error::Job { index, cause } => {
                write!(f, "worker error: job {index} failed: {cause}")
            }
            Error::Cancelled(m) => write!(f, "cancelled: {m}"),
            Error::Deadline(m) => write!(f, "deadline exceeded: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e.to_string())
    }
}

impl Error {
    /// Convert a caught panic payload (e.g. a worker-pool re-raise,
    /// which panics with `worker pool job {i} panicked: ...`) into a
    /// displayable [`Error::Worker`] for the CLI boundary.
    pub fn from_panic(payload: Box<dyn std::any::Any + Send>) -> Error {
        let msg = payload
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_else(|| "panic with non-string payload".into());
        Error::Worker(msg)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Config("MP must divide N".into());
        assert!(e.to_string().contains("MP must divide N"));
        assert!(e.to_string().contains("config"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }

    #[test]
    fn parse_error_keeps_line_context() {
        let e = Error::Parse("bad value for 'top_k' (line 12)".into());
        let s = e.to_string();
        assert!(s.contains("toml parse error"), "{s}");
        assert!(s.contains("line 12"), "{s}");
    }

    #[test]
    fn job_cancel_and_deadline_display_with_context() {
        let e = Error::Job {
            index: 7,
            cause: "division by zero".into(),
        };
        let s = e.to_string();
        assert!(s.contains("worker error"), "{s}");
        assert!(s.contains("job 7"), "{s}");
        assert!(s.contains("division by zero"), "{s}");
        let s = Error::Cancelled("search".into()).to_string();
        assert!(s.contains("cancelled"), "{s}");
        let s = Error::Deadline("search after 5s".into()).to_string();
        assert!(s.contains("deadline exceeded"), "{s}");
    }

    #[test]
    fn panic_payloads_convert_to_worker_errors() {
        let caught = std::panic::catch_unwind(|| {
            panic!("worker pool job 3 panicked: boom");
        })
        .unwrap_err();
        let e = Error::from_panic(caught);
        let s = e.to_string();
        assert!(matches!(e, Error::Worker(_)));
        assert!(s.contains("worker error"), "{s}");
        assert!(s.contains("job 3"), "{s}");
        // `panic!` with a formatted message yields a `String` payload;
        // a literal yields `&'static str` — both must convert.
        let caught = std::panic::catch_unwind(|| panic!("plain literal"))
            .unwrap_err();
        assert!(Error::from_panic(caught).to_string().contains("literal"));
    }
}
