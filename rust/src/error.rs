//! Crate-wide error type.

use std::fmt;

/// Errors surfaced by the COMET toolchain.
#[derive(Debug)]
pub enum Error {
    /// Invalid cluster / strategy / workload configuration.
    Config(String),
    /// Artifact ABI mismatch between `artifacts/manifest.json` and this
    /// crate's compiled-in layout (see [`crate::model::batch`]).
    AbiMismatch(String),
    /// Artifact file missing or unreadable.
    Artifact(String),
    /// PJRT / XLA runtime failure.
    Runtime(String),
    /// JSON parse error (configs, manifest).
    Json(String),
    /// I/O error with path context.
    Io(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::AbiMismatch(m) => write!(f, "artifact ABI mismatch: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Io(m) => write!(f, "io error: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Config("MP must divide N".into());
        assert!(e.to_string().contains("MP must divide N"));
        assert!(e.to_string().contains("config"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
