//! The L3 design-space-exploration coordinator: backend selection (native
//! f64 / DES / AOT artifact via PJRT), a multi-threaded job scheduler, and
//! a result cache. This is COMET's "leader" — the CLI, the examples, and
//! the benches all drive sweeps through it.

mod cache;
mod scheduler;
pub mod sweep;

pub use cache::EvalCache;
pub use scheduler::Scheduler;

use crate::analytical::{evaluate as native_evaluate, TrainingBreakdown};
use crate::config::ClusterConfig;
use crate::error::Result;
use crate::model::inputs::{derive_inputs, EvalOptions, ModelInputs};
use crate::runtime::{BatchEvaluator, Runtime};
use crate::sim::simulate;
use crate::workload::Workload;

/// Which cost-model backend evaluates configurations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Closed-form f64 evaluation in-process (fast reference).
    Native,
    /// Discrete-event simulation (captures link contention).
    Des,
    /// The AOT-compiled artifact through PJRT (the L1/L2 layers on the
    /// request path — COMET's production configuration).
    Artifact,
}

/// The evaluation coordinator.
pub struct Coordinator {
    backend: Backend,
    runtime: Option<Runtime>,
    cache: EvalCache,
    /// Worker threads for native/DES fan-out.
    pub threads: usize,
}

impl std::fmt::Debug for Coordinator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Coordinator")
            .field("backend", &self.backend)
            .field("threads", &self.threads)
            .finish()
    }
}

fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

impl Coordinator {
    /// Native closed-form backend.
    pub fn native() -> Coordinator {
        Coordinator {
            backend: Backend::Native,
            runtime: None,
            cache: EvalCache::new(),
            threads: default_threads(),
        }
    }

    /// Discrete-event backend.
    pub fn des() -> Coordinator {
        Coordinator {
            backend: Backend::Des,
            runtime: None,
            cache: EvalCache::new(),
            threads: default_threads(),
        }
    }

    /// AOT-artifact backend (loads + compiles `artifacts/`).
    pub fn artifact() -> Result<Coordinator> {
        Ok(Coordinator {
            backend: Backend::Artifact,
            runtime: Some(Runtime::load_default()?),
            cache: EvalCache::new(),
            threads: default_threads(),
        })
    }

    /// Artifact if available, else native (with a stderr note).
    pub fn auto() -> Coordinator {
        match Self::artifact() {
            Ok(c) => c,
            Err(e) => {
                eprintln!("comet: artifact backend unavailable ({e}); using native");
                Self::native()
            }
        }
    }

    /// Active backend.
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// Evaluate one (workload, cluster) configuration.
    pub fn evaluate(
        &self,
        workload: &Workload,
        cluster: &ClusterConfig,
    ) -> Result<TrainingBreakdown> {
        self.evaluate_opts(workload, cluster, &EvalOptions::default())
    }

    /// Evaluate with explicit options.
    pub fn evaluate_opts(
        &self,
        workload: &Workload,
        cluster: &ClusterConfig,
        opts: &EvalOptions,
    ) -> Result<TrainingBreakdown> {
        let inputs = derive_inputs(workload, cluster, opts)?;
        Ok(self.evaluate_inputs(std::slice::from_ref(&inputs))?.remove(0))
    }

    /// Evaluate a batch of derived inputs (the sweep hot path).
    ///
    /// Results are cached by input fingerprint; cache hits skip the
    /// backend entirely.
    pub fn evaluate_inputs(
        &self,
        inputs: &[ModelInputs],
    ) -> Result<Vec<TrainingBreakdown>> {
        // Partition into hits and misses.
        let mut results: Vec<Option<TrainingBreakdown>> =
            inputs.iter().map(|i| self.cache.get(i)).collect();
        let miss_idx: Vec<usize> = results
            .iter()
            .enumerate()
            .filter_map(|(i, r)| r.is_none().then_some(i))
            .collect();
        if !miss_idx.is_empty() {
            let miss_inputs: Vec<&ModelInputs> =
                miss_idx.iter().map(|&i| &inputs[i]).collect();
            let computed = match self.backend {
                Backend::Artifact => {
                    let rt = self.runtime.as_ref().expect("artifact runtime");
                    let owned: Vec<ModelInputs> =
                        miss_inputs.iter().map(|i| (*i).clone()).collect();
                    BatchEvaluator::new(rt).evaluate(&owned)?
                }
                Backend::Native => Scheduler::new(self.threads)
                    .map(&miss_inputs, |inp| native_evaluate(inp)),
                Backend::Des => Scheduler::new(self.threads)
                    .map(&miss_inputs, |inp| simulate(inp).breakdown),
            };
            for (&i, b) in miss_idx.iter().zip(computed) {
                self.cache.put(&inputs[i], b);
                results[i] = Some(b);
            }
        }
        Ok(results.into_iter().map(|r| r.unwrap()).collect())
    }

    /// Cache statistics (hits, misses).
    pub fn cache_stats(&self) -> (u64, u64) {
        self.cache.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::parallel::Strategy;
    use crate::util::stats::rel_diff;
    use crate::workload::transformer::Transformer;

    fn job() -> (Workload, ClusterConfig) {
        (
            Transformer::t1().build(&Strategy::new(8, 128)).unwrap(),
            presets::dgx_a100_1024(),
        )
    }

    #[test]
    fn native_coordinator_evaluates() {
        let (w, c) = job();
        let b = Coordinator::native().evaluate(&w, &c).unwrap();
        assert!(b.total() > 0.0);
    }

    #[test]
    fn des_and_native_agree() {
        let (w, c) = job();
        let n = Coordinator::native().evaluate(&w, &c).unwrap();
        let d = Coordinator::des().evaluate(&w, &c).unwrap();
        assert!(rel_diff(n.total(), d.total()) < 0.05);
    }

    #[test]
    fn cache_hits_on_second_eval() {
        let (w, c) = job();
        let coord = Coordinator::native();
        coord.evaluate(&w, &c).unwrap();
        coord.evaluate(&w, &c).unwrap();
        let (hits, misses) = coord.cache_stats();
        assert_eq!(hits, 1);
        assert_eq!(misses, 1);
    }

    #[test]
    fn batch_order_preserved() {
        let c = presets::dgx_a100_1024();
        let coord = Coordinator::native();
        let opts = EvalOptions {
            ignore_capacity: true,
            ..Default::default()
        };
        let inputs: Vec<_> = Strategy::sweep_bounded(1024, 1, 128)
            .iter()
            .map(|s| {
                derive_inputs(
                    &Transformer::t1().build(s).unwrap(),
                    &c,
                    &opts,
                )
                .unwrap()
            })
            .collect();
        let batch = coord.evaluate_inputs(&inputs).unwrap();
        for (inp, got) in inputs.iter().zip(&batch) {
            let want = native_evaluate(inp);
            assert!(rel_diff(want.total(), got.total()) < 1e-12, "{}", inp.name);
        }
    }

    #[test]
    fn artifact_backend_matches_native_when_available() {
        let Ok(coord) = Coordinator::artifact() else {
            return;
        };
        let (w, c) = job();
        let a = coord.evaluate(&w, &c).unwrap();
        let n = Coordinator::native().evaluate(&w, &c).unwrap();
        assert!(rel_diff(a.total(), n.total()) < 1e-4);
    }
}
