//! The L3 design-space-exploration coordinator: backend selection (native
//! f64 / DES / AOT artifact via PJRT), a multi-threaded job scheduler, and
//! a result cache. This is COMET's "leader" — the CLI, the examples, and
//! the benches all drive sweeps through it.

mod cache;
mod scheduler;
pub mod sweep;

pub use cache::{DeriveCache, EvalCache};
pub use scheduler::WorkerPool;
pub use sweep::GridSweep;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::analytical::{evaluate as native_evaluate, TrainingBreakdown};
use crate::config::ClusterConfig;
use crate::error::Result;
use crate::model::inputs::{
    derive_inputs, resolve_inputs, EvalOptions, ModelInputs,
    WorkloadDecomposition,
};
use crate::runtime::{BatchEvaluator, Runtime};
use crate::sim::simulate;
use crate::util::cancel::RunControl;
use crate::workload::Workload;

/// Which cost-model backend evaluates configurations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Closed-form f64 evaluation in-process (fast reference).
    Native,
    /// Discrete-event simulation (captures link contention).
    Des,
    /// The AOT-compiled artifact through PJRT (the L1/L2 layers on the
    /// request path — COMET's production configuration).
    Artifact,
}

/// The evaluation coordinator. Owns a persistent [`WorkerPool`]: worker
/// threads are spawned once per coordinator and reused across every
/// [`Coordinator::evaluate_inputs`] call.
pub struct Coordinator {
    backend: Backend,
    runtime: Option<Runtime>,
    cache: EvalCache,
    derive: DeriveCache,
    pool: WorkerPool,
    /// Peak pending-event occupancy across every DES evaluation this
    /// coordinator has run (`SimStats::peak_events` max). Shared with
    /// the pool workers' `'static` job closures via `Arc`.
    des_peak: Arc<AtomicU64>,
}

/// One snapshot of the coordinator's lifetime counters — the structured
/// form of the `scenario run --verbose` stderr lines, and the substance
/// of the serve layer's `GET /stats` endpoint. All counters are
/// cumulative since the coordinator was built.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CoordinatorStats {
    /// Eval-cache hits across all shards.
    pub eval_hits: u64,
    /// Eval-cache misses across all shards (backend evaluations).
    pub eval_misses: u64,
    /// Derive-cache hits.
    pub derive_hits: u64,
    /// Derive-cache misses — each one is an actual workload
    /// decomposition.
    pub derive_misses: u64,
    /// Jobs submitted to the worker pool across every batch surface.
    pub jobs_run: u64,
    /// Workers respawned (panic recovery, watchdog, `heal`).
    pub workers_respawned: u64,
    /// Peak pending-event occupancy over every DES evaluation (0 when
    /// the DES backend never ran).
    pub des_peak_events: u64,
}

impl std::fmt::Debug for Coordinator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Coordinator")
            .field("backend", &self.backend)
            .field("threads", &self.pool.threads())
            .finish()
    }
}

/// Minimum watchdog budget for a deadline-supervised batch: even when
/// the run's deadline is (almost) spent, a healthy in-flight batch gets
/// this long to finish rather than being abandoned spuriously — the
/// boundary `control.check` right before the fan-out already rejected a
/// truly expired deadline.
const WATCHDOG_FLOOR: Duration = Duration::from_millis(250);

fn default_threads() -> usize {
    // COMET_THREADS bounds the pool on shared machines and makes
    // single-threaded bench runs reproducible without an API call.
    if let Ok(v) = std::env::var("COMET_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

impl Coordinator {
    /// Native closed-form backend.
    pub fn native() -> Coordinator {
        Coordinator {
            backend: Backend::Native,
            runtime: None,
            cache: EvalCache::new(),
            derive: DeriveCache::new(),
            pool: WorkerPool::new(default_threads()),
            des_peak: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Discrete-event backend.
    pub fn des() -> Coordinator {
        Coordinator {
            backend: Backend::Des,
            runtime: None,
            cache: EvalCache::new(),
            derive: DeriveCache::new(),
            pool: WorkerPool::new(default_threads()),
            des_peak: Arc::new(AtomicU64::new(0)),
        }
    }

    /// AOT-artifact backend (loads + compiles `artifacts/`).
    pub fn artifact() -> Result<Coordinator> {
        Ok(Coordinator {
            backend: Backend::Artifact,
            runtime: Some(Runtime::load_default()?),
            cache: EvalCache::new(),
            derive: DeriveCache::new(),
            pool: WorkerPool::new(default_threads()),
            des_peak: Arc::new(AtomicU64::new(0)),
        })
    }

    /// Artifact if available, else native (with a stderr note).
    pub fn auto() -> Coordinator {
        match Self::artifact() {
            Ok(c) => c,
            Err(e) => {
                eprintln!("comet: artifact backend unavailable ({e}); using native");
                Self::native()
            }
        }
    }

    /// Active backend.
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// Worker-pool width.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// The coordinator's persistent worker pool. Exposed so subsystems
    /// that batch their own work — the branch-and-bound optimizer fans
    /// speculative leaf evaluations out here — can borrow the pool via
    /// [`WorkerPool::scoped_map`] instead of spawning threads of their
    /// own.
    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    /// Rebuild the coordinator's pool with an explicit width (the old
    /// pool's workers are joined). `Coordinator::native().with_threads(1)`
    /// gives deterministic single-threaded evaluation.
    pub fn with_threads(mut self, threads: usize) -> Coordinator {
        self.pool = WorkerPool::new(threads);
        self
    }

    /// Evaluate one (workload, cluster) configuration.
    pub fn evaluate(
        &self,
        workload: &Workload,
        cluster: &ClusterConfig,
    ) -> Result<TrainingBreakdown> {
        self.evaluate_opts(workload, cluster, &EvalOptions::default())
    }

    /// Evaluate with explicit options.
    pub fn evaluate_opts(
        &self,
        workload: &Workload,
        cluster: &ClusterConfig,
        opts: &EvalOptions,
    ) -> Result<TrainingBreakdown> {
        let inputs = derive_inputs(workload, cluster, opts)?;
        Ok(self.evaluate_inputs(std::slice::from_ref(&inputs))?.remove(0))
    }

    /// Evaluate a batch of derived inputs (the sweep hot path).
    ///
    /// Results are cached by input fingerprint; cache hits skip the
    /// backend entirely. Each input is fingerprinted exactly once — the
    /// same key serves the lookup and, on a miss, the insert.
    pub fn evaluate_inputs(
        &self,
        inputs: &[ModelInputs],
    ) -> Result<Vec<TrainingBreakdown>> {
        self.evaluate_inputs_controlled(inputs, &RunControl::unbounded())
    }

    /// [`Coordinator::evaluate_inputs`] with a cooperative stop check at
    /// the batch boundary: a cancelled token or an exceeded deadline
    /// stops the batch *before* it fans out (a batch in flight always
    /// completes — that is the safe-boundary contract every checkpoint
    /// and partial-outcome guarantee builds on). A panicking evaluation
    /// job no longer poisons the pool: it surfaces as a structured
    /// [`crate::error::Error::Job`] with the in-batch job index while
    /// the rest of the batch completes and the worker respawns.
    pub fn evaluate_inputs_controlled(
        &self,
        inputs: &[ModelInputs],
        control: &RunControl,
    ) -> Result<Vec<TrainingBreakdown>> {
        control.check("batch evaluation")?;
        // Partition into hits and misses.
        let keys: Vec<u64> = inputs.iter().map(|i| i.fingerprint()).collect();
        let mut results: Vec<Option<TrainingBreakdown>> =
            keys.iter().map(|&k| self.cache.get_by_key(k)).collect();
        let miss_idx: Vec<usize> = results
            .iter()
            .enumerate()
            .filter_map(|(i, r)| r.is_none().then_some(i))
            .collect();
        if !miss_idx.is_empty() {
            // Dedup identical inputs within the batch: batched figure
            // drivers carry their normalization baselines alongside grid
            // points that often resolve to the same configuration, so
            // evaluate one representative per distinct fingerprint.
            let mut key_slot: std::collections::HashMap<u64, usize> =
                std::collections::HashMap::with_capacity(miss_idx.len());
            let mut reps: Vec<usize> = Vec::with_capacity(miss_idx.len());
            for &i in &miss_idx {
                key_slot.entry(keys[i]).or_insert_with(|| {
                    reps.push(i);
                    reps.len() - 1
                });
            }
            // One clone per distinct miss: the persistent pool's jobs must
            // own their data ('static). The copy is a few KB of layer
            // records vs a backend evaluation that traverses the same
            // records doing the actual math — noise next to the old
            // spawn-threads-per-batch design this replaced.
            let owned: Vec<ModelInputs> =
                reps.iter().map(|&i| inputs[i].clone()).collect();
            let computed = match self.backend {
                Backend::Artifact => {
                    let rt = self.runtime.as_ref().expect("artifact runtime");
                    BatchEvaluator::new(rt).evaluate(&owned)?
                }
                Backend::Native => {
                    self.pool_batch(owned, control, native_evaluate)?
                }
                // Each persistent pool worker reuses its own
                // thread-local SimScratch across jobs (schedulers,
                // slab, phase buffers), so a DES batch allocates only
                // on each worker's first job.
                Backend::Des => {
                    let peak = self.des_peak.clone();
                    self.pool_batch(owned, control, move |inp| {
                        let r = simulate(inp);
                        peak.fetch_max(r.stats.peak_events, Ordering::Relaxed);
                        r.breakdown
                    })?
                }
            };
            for (&i, b) in reps.iter().zip(&computed) {
                self.cache.put_by_key(keys[i], *b);
            }
            for &i in &miss_idx {
                results[i] = Some(computed[key_slot[&keys[i]]]);
            }
        }
        Ok(results.into_iter().map(|r| r.unwrap()).collect())
    }

    /// Backend batch fan-out with deadline-aware supervision: with no
    /// deadline armed, a plain structured-error map; with one armed, the
    /// pool's watchdog sized to the remaining budget (floored so a
    /// nearly-expired deadline still lets a healthy batch finish), so a
    /// stuck evaluation becomes [`crate::error::Error::Deadline`]
    /// instead of a hang. Both paths fill slots in job order — the
    /// result is byte-identical either way.
    fn pool_batch<T, R>(
        &self,
        owned: Vec<T>,
        control: &RunControl,
        f: impl Fn(&T) -> R + Send + Sync + 'static,
    ) -> Result<Vec<R>>
    where
        T: Send + Sync + 'static,
        R: Send + 'static,
    {
        match control.deadline_remaining() {
            Some(rem) => self.pool.try_map_watchdog(
                owned,
                usize::MAX,
                rem.max(WATCHDOG_FLOOR),
                f,
            ),
            None => self.pool.try_map(owned, f),
        }
    }

    /// Derive a batch of model inputs through the worker pool: the
    /// figure drivers enumerate their full (workload, cluster, options)
    /// grids up front and resolve them here concurrently.
    ///
    /// Two-stage: each **distinct** workload (by
    /// [`Workload::fingerprint`]) is decomposed exactly once through the
    /// coordinator's [`DeriveCache`] — a 1,000-point sweep over one
    /// transformer decomposes it once, not 1,000 times — and the per-point
    /// cluster/options resolution fans out over the pool.
    pub fn derive_batch(
        &self,
        specs: Vec<(Workload, ClusterConfig, EvalOptions)>,
    ) -> Result<Vec<ModelInputs>> {
        self.derive_batch_controlled(specs, &RunControl::unbounded())
    }

    /// [`Coordinator::derive_batch`] with a cooperative stop check
    /// between its two stages (same batch-boundary contract as
    /// [`Coordinator::evaluate_inputs_controlled`]).
    pub fn derive_batch_controlled(
        &self,
        specs: Vec<(Workload, ClusterConfig, EvalOptions)>,
        control: &RunControl,
    ) -> Result<Vec<ModelInputs>> {
        control.check("batch derivation")?;
        // Stage 1 (serial, cached): decomposition per distinct workload.
        let jobs: Vec<(Arc<WorkloadDecomposition>, ClusterConfig, EvalOptions)> =
            specs
                .into_iter()
                .map(|(w, c, o)| (self.derive.decomposition(&w), c, o))
                .collect();
        control.check("batch input resolution")?;
        // Stage 2 (parallel): bind every grid point to its cluster.
        self.pool
            .try_map(jobs, |(dec, c, o)| resolve_inputs(dec, c, o))?
            .into_iter()
            .collect()
    }

    /// The decomposition of a workload, through the coordinator's derive
    /// cache (the optimizer shares decompositions with the grid path
    /// this way).
    pub fn decomposition(
        &self,
        workload: &Workload,
    ) -> Arc<WorkloadDecomposition> {
        self.derive.decomposition(workload)
    }

    /// Cache statistics (hits, misses).
    pub fn cache_stats(&self) -> (u64, u64) {
        self.cache.stats()
    }

    /// Derive-cache statistics (hits, misses). Misses count actual
    /// workload decompositions.
    pub fn derive_cache_stats(&self) -> (u64, u64) {
        self.derive.stats()
    }

    /// One consistent-enough snapshot of every lifetime counter. Each
    /// counter is read atomically; the snapshot as a whole is not a
    /// transaction (a concurrent request may land between reads), which
    /// is fine for the monitoring surfaces this feeds — the
    /// `--verbose` stderr report and `GET /stats`.
    pub fn stats(&self) -> CoordinatorStats {
        let (eval_hits, eval_misses) = self.cache.stats();
        let (derive_hits, derive_misses) = self.derive.stats();
        CoordinatorStats {
            eval_hits,
            eval_misses,
            derive_hits,
            derive_misses,
            jobs_run: self.pool.jobs_run(),
            workers_respawned: self.pool.respawns() as u64,
            des_peak_events: self.des_peak.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::parallel::Strategy;
    use crate::util::stats::rel_diff;
    use crate::workload::transformer::Transformer;

    fn job() -> (Workload, ClusterConfig) {
        (
            Transformer::t1()
                .build(&Strategy::new(8, 128).unwrap())
                .unwrap(),
            presets::dgx_a100_1024(),
        )
    }

    #[test]
    fn native_coordinator_evaluates() {
        let (w, c) = job();
        let b = Coordinator::native().evaluate(&w, &c).unwrap();
        assert!(b.total() > 0.0);
    }

    #[test]
    fn des_and_native_agree() {
        let (w, c) = job();
        let n = Coordinator::native().evaluate(&w, &c).unwrap();
        let d = Coordinator::des().evaluate(&w, &c).unwrap();
        assert!(rel_diff(n.total(), d.total()) < 0.05);
    }

    #[test]
    fn cache_hits_on_second_eval() {
        let (w, c) = job();
        let coord = Coordinator::native();
        coord.evaluate(&w, &c).unwrap();
        coord.evaluate(&w, &c).unwrap();
        let (hits, misses) = coord.cache_stats();
        assert_eq!(hits, 1);
        assert_eq!(misses, 1);
    }

    #[test]
    fn batch_order_preserved() {
        let c = presets::dgx_a100_1024();
        let coord = Coordinator::native();
        let opts = EvalOptions {
            ignore_capacity: true,
            ..Default::default()
        };
        let inputs: Vec<_> = Strategy::sweep_bounded(1024, 1, 128)
            .unwrap()
            .iter()
            .map(|s| {
                derive_inputs(
                    &Transformer::t1().build(s).unwrap(),
                    &c,
                    &opts,
                )
                .unwrap()
            })
            .collect();
        let batch = coord.evaluate_inputs(&inputs).unwrap();
        for (inp, got) in inputs.iter().zip(&batch) {
            let want = native_evaluate(inp);
            assert!(rel_diff(want.total(), got.total()) < 1e-12, "{}", inp.name);
        }
    }

    #[test]
    fn des_batch_order_preserved() {
        let c = presets::dgx_a100_1024();
        let coord = Coordinator::des();
        let opts = EvalOptions {
            ignore_capacity: true,
            ..Default::default()
        };
        let inputs: Vec<_> = Strategy::sweep_bounded(1024, 2, 64)
            .unwrap()
            .iter()
            .map(|s| {
                derive_inputs(
                    &Transformer::t1().build(s).unwrap(),
                    &c,
                    &opts,
                )
                .unwrap()
            })
            .collect();
        let batch = coord.evaluate_inputs(&inputs).unwrap();
        for (inp, got) in inputs.iter().zip(&batch) {
            let want = crate::sim::simulate(inp).breakdown;
            assert!(
                rel_diff(want.total(), got.total()) < 1e-12,
                "{}",
                inp.name
            );
        }
    }

    #[test]
    fn derive_batch_decomposes_once_per_distinct_workload() {
        let coord = Coordinator::native();
        let (w, c) = job();
        // Ten grid points over the same workload (different options).
        let specs: Vec<_> = (0..10)
            .map(|i| {
                (
                    w.clone(),
                    c.clone(),
                    EvalOptions {
                        em_frac_override: Some(i as f64 / 100.0),
                        ..Default::default()
                    },
                )
            })
            .collect();
        let inputs = coord.derive_batch(specs).unwrap();
        assert_eq!(inputs.len(), 10);
        let (hits, misses) = coord.derive_cache_stats();
        assert_eq!(misses, 1, "one decomposition per distinct workload");
        assert_eq!(hits, 9);
        // A second batch with a new workload decomposes only the new one.
        let w2 = Transformer::t1()
            .build(&Strategy::new(16, 64).unwrap())
            .unwrap();
        coord
            .derive_batch(vec![
                (w2, c.clone(), EvalOptions::default()),
                (w.clone(), c.clone(), EvalOptions::default()),
            ])
            .unwrap();
        assert_eq!(coord.derive_cache_stats(), (10, 2));
    }

    #[test]
    fn derive_batch_matches_single_pass_derive() {
        let coord = Coordinator::native();
        let c = presets::dgx_a100_1024();
        let opts = EvalOptions::default();
        let specs: Vec<_> = Strategy::sweep_bounded(1024, 1, 128)
            .unwrap()
            .iter()
            .map(|s| {
                (
                    Transformer::t1().build(s).unwrap(),
                    c.clone(),
                    opts,
                )
            })
            .collect();
        let singles: Vec<_> = specs
            .iter()
            .map(|(w, c, o)| derive_inputs(w, c, o).unwrap())
            .collect();
        let batched = coord.derive_batch(specs).unwrap();
        assert_eq!(singles, batched);
    }

    #[test]
    fn with_threads_overrides_pool_width() {
        let coord = Coordinator::native().with_threads(2);
        assert_eq!(coord.threads(), 2);
        let (w, c) = job();
        assert!(coord.evaluate(&w, &c).unwrap().total() > 0.0);
    }

    #[test]
    fn pool_reused_across_calls_and_threads_reported() {
        let coord = Coordinator::native();
        assert!(coord.threads() >= 1);
        let (w, c) = job();
        // Many small calls against the same coordinator must all succeed
        // on the persistent pool (regression: spawn-per-call scheduler).
        for _ in 0..16 {
            coord.evaluate(&w, &c).unwrap();
        }
        let (hits, misses) = coord.cache_stats();
        assert_eq!(misses, 1);
        assert_eq!(hits, 15);
    }

    #[test]
    fn concurrent_evaluate_inputs() {
        use std::sync::Arc;
        let coord = Arc::new(Coordinator::native());
        let c = presets::dgx_a100_1024();
        let opts = EvalOptions {
            ignore_capacity: true,
            ..Default::default()
        };
        let inputs: Arc<Vec<_>> = Arc::new(
            Strategy::sweep_bounded(1024, 1, 128)
                .unwrap()
                .iter()
                .map(|s| {
                    derive_inputs(
                        &Transformer::t1().build(s).unwrap(),
                        &c,
                        &opts,
                    )
                    .unwrap()
                })
                .collect(),
        );
        let mut joins = Vec::new();
        for _ in 0..4 {
            let coord = coord.clone();
            let inputs = inputs.clone();
            joins.push(std::thread::spawn(move || {
                coord.evaluate_inputs(&inputs).unwrap()
            }));
        }
        let first = joins.remove(0).join().unwrap();
        for j in joins {
            assert_eq!(j.join().unwrap(), first);
        }
        let (hits, misses) = coord.cache_stats();
        // Every configuration is computed at least once; all four threads
        // account for every lookup.
        assert_eq!(hits + misses, 4 * inputs.len() as u64);
        assert!(misses >= inputs.len() as u64);
    }

    #[test]
    fn artifact_backend_matches_native_when_available() {
        let Ok(coord) = Coordinator::artifact() else {
            return;
        };
        let (w, c) = job();
        let a = coord.evaluate(&w, &c).unwrap();
        let n = Coordinator::native().evaluate(&w, &c).unwrap();
        assert!(rel_diff(a.total(), n.total()) < 1e-4);
    }

    #[test]
    fn controlled_batches_stop_at_boundaries() {
        use crate::util::cancel::RunControl;
        let coord = Coordinator::native();
        let (w, c) = job();
        let cancelled = RunControl::unbounded().cancel_after_polls(0);
        // Both batch entry points refuse to start under a tripped
        // control and report a structured cancel, not a panic.
        let err = coord
            .derive_batch_controlled(
                vec![(w.clone(), c.clone(), EvalOptions::default())],
                &cancelled,
            )
            .unwrap_err();
        assert!(matches!(err, crate::error::Error::Cancelled(_)), "{err}");
        let inputs = coord
            .derive_batch(vec![(w, c, EvalOptions::default())])
            .unwrap();
        let err = coord
            .evaluate_inputs_controlled(&inputs, &cancelled)
            .unwrap_err();
        assert!(matches!(err, crate::error::Error::Cancelled(_)), "{err}");
        // An unbounded control changes nothing: same results as the
        // plain entry points.
        let a = coord
            .evaluate_inputs_controlled(&inputs, &RunControl::unbounded())
            .unwrap();
        let b = coord.evaluate_inputs(&inputs).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn stats_snapshot_mirrors_individual_counters() {
        let (w, c) = job();
        let coord = Coordinator::native();
        coord.evaluate(&w, &c).unwrap();
        coord.evaluate(&w, &c).unwrap();
        let s = coord.stats();
        assert_eq!((s.eval_hits, s.eval_misses), coord.cache_stats());
        assert_eq!(
            (s.derive_hits, s.derive_misses),
            coord.derive_cache_stats()
        );
        assert_eq!(s.eval_hits, 1);
        assert_eq!(s.eval_misses, 1);
        assert!(s.jobs_run >= 1, "evaluations run through the pool: {s:?}");
        assert_eq!(s.workers_respawned, 0);
        assert_eq!(s.des_peak_events, 0, "native never touches the DES");
    }

    #[test]
    fn stats_track_des_peak_events() {
        let (w, c) = job();
        let coord = Coordinator::des();
        assert_eq!(coord.stats().des_peak_events, 0);
        coord.evaluate(&w, &c).unwrap();
        let s = coord.stats();
        // The dp-dominated MP8_DP128 shape queues events, so the DES
        // reports a nonzero occupancy peak.
        assert!(s.des_peak_events > 0, "{s:?}");
        // Monotone: a cache-hit re-evaluation cannot lower the peak.
        coord.evaluate(&w, &c).unwrap();
        assert_eq!(coord.stats().des_peak_events, s.des_peak_events);
    }
}
