//! Persistent channel-fed worker pool for CPU-bound evaluation jobs.
//!
//! The offline crate set has no rayon/tokio, so COMET ships its own pool.
//! Workers are spawned **once** (when the [`Coordinator`](super::Coordinator)
//! is built) and reused across every batch: each `map` call publishes one
//! shared batch descriptor to every worker, workers claim jobs through an
//! atomic cursor (dynamic load balancing at item granularity) and write
//! results into disjoint slots of a preallocated buffer — there is no
//! shared results mutex to contend on. The submitting thread participates
//! as a worker, so a pool of width `t` spawns `t - 1` background threads
//! and runs exactly `t` lanes — width 1 is strictly inline (deterministic
//! single-threaded execution) and small batches never pay a cross-thread
//! round-trip.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Type-erased batch handle the worker threads execute.
trait Task: Send + Sync {
    fn run_worker(&self);
}

/// One in-flight `map` call: jobs, the mapper, and per-job result slots.
struct Batch<T, R> {
    jobs: Vec<T>,
    f: Box<dyn Fn(&T) -> R + Send + Sync>,
    /// Next unclaimed job index.
    next: AtomicUsize,
    /// Disjoint per-job result slots. Each slot's lock is touched exactly
    /// twice (one write, one take) — never contended across jobs.
    slots: Vec<Mutex<Option<R>>>,
    /// Jobs not yet finished; the worker that drops this to zero signals
    /// `done`.
    remaining: AtomicUsize,
    /// First observed panic: (job index, payload message).
    panic: Mutex<Option<(usize, String)>>,
    done: Mutex<bool>,
    done_cv: Condvar,
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

impl<T: Send + Sync, R: Send> Batch<T, R> {
    fn new(jobs: Vec<T>, f: Box<dyn Fn(&T) -> R + Send + Sync>) -> Batch<T, R> {
        let n = jobs.len();
        Batch {
            jobs,
            f,
            next: AtomicUsize::new(0),
            slots: (0..n).map(|_| Mutex::new(None)).collect(),
            remaining: AtomicUsize::new(n),
            panic: Mutex::new(None),
            done: Mutex::new(false),
            done_cv: Condvar::new(),
        }
    }

    fn execute(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.jobs.len() {
                break;
            }
            match catch_unwind(AssertUnwindSafe(|| (self.f)(&self.jobs[i]))) {
                Ok(r) => *self.slots[i].lock().unwrap() = Some(r),
                Err(payload) => {
                    let mut p = self.panic.lock().unwrap();
                    if p.is_none() {
                        *p = Some((i, panic_message(payload.as_ref())));
                    }
                }
            }
            if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                let mut d = self.done.lock().unwrap();
                *d = true;
                self.done_cv.notify_all();
            }
        }
    }
}

impl<T: Send + Sync, R: Send> Task for Batch<T, R> {
    fn run_worker(&self) {
        self.execute()
    }
}

/// Persistent worker pool. Threads are spawned once and fed batches over
/// per-worker channels; dropping the pool shuts them down.
pub struct WorkerPool {
    senders: Vec<Sender<Arc<dyn Task>>>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
    /// Rotates which workers small batches notify, so concurrent
    /// submitters don't all pin their jobs behind the low-index workers.
    next_worker: AtomicUsize,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("threads", &self.threads)
            .finish()
    }
}

impl WorkerPool {
    /// A pool of total width `threads` (>= 1): `threads - 1` background
    /// workers plus the submitting thread.
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let mut senders = Vec::with_capacity(threads - 1);
        let mut handles = Vec::with_capacity(threads - 1);
        for i in 0..threads - 1 {
            let (tx, rx) = channel::<Arc<dyn Task>>();
            senders.push(tx);
            let handle = std::thread::Builder::new()
                .name(format!("comet-pool-{i}"))
                .spawn(move || {
                    while let Ok(task) = rx.recv() {
                        task.run_worker();
                    }
                })
                .expect("spawn pool worker");
            handles.push(handle);
        }
        WorkerPool {
            senders,
            handles,
            threads,
            next_worker: AtomicUsize::new(0),
        }
    }

    /// Total pool width (background workers + the submitting thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Map `f` over `jobs`, preserving order. Jobs run concurrently on
    /// the pool's background workers plus the calling thread; a width-1
    /// pool executes everything inline on the caller.
    ///
    /// # Panics
    ///
    /// If a job panics, the panic is re-raised on the calling thread with
    /// the failing job's index prepended to the payload message. The
    /// remaining jobs still run to completion first (no worker is lost —
    /// the pool stays usable afterwards).
    pub fn map<T, R>(
        &self,
        jobs: Vec<T>,
        f: impl Fn(&T) -> R + Send + Sync + 'static,
    ) -> Vec<R>
    where
        T: Send + Sync + 'static,
        R: Send + 'static,
    {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        let batch = Arc::new(Batch::new(jobs, Box::new(f)));
        // Fan out to at most n-1 workers (the submitter claims jobs too,
        // and a single-job batch never leaves the calling thread),
        // starting at a rotating offset so concurrent small batches
        // spread over different workers.
        let fanout = (n - 1).min(self.senders.len());
        if fanout > 0 {
            let start = self.next_worker.fetch_add(fanout, Ordering::Relaxed);
            for j in 0..fanout {
                let tx = &self.senders[(start + j) % self.senders.len()];
                let task: Arc<dyn Task> = batch.clone();
                let _ = tx.send(task);
            }
        }
        batch.execute();
        // All jobs claimed by now (the submitter's cursor ran past n), but
        // workers may still be finishing theirs.
        let mut done = batch.done.lock().unwrap();
        while !*done {
            done = batch.done_cv.wait(done).unwrap();
        }
        drop(done);
        if let Some((i, msg)) = batch.panic.lock().unwrap().take() {
            panic!("worker pool job {i} panicked: {msg}");
        }
        batch
            .slots
            .iter()
            .map(|s| s.lock().unwrap().take().expect("pool slot filled"))
            .collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the channels ends each worker's recv loop.
        self.senders.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let pool = WorkerPool::new(8);
        let jobs: Vec<u64> = (0..1000).collect();
        let out = pool.map(jobs.clone(), |x| x * 2);
        assert_eq!(out, jobs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_pool() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.map(vec![1, 2, 3], |x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn width_one_is_strictly_inline() {
        let pool = WorkerPool::new(1);
        let main_id = std::thread::current().id();
        let jobs: Vec<u32> = (0..16).collect();
        let ids = pool.map(jobs, move |_| std::thread::current().id());
        assert!(ids.iter().all(|&id| id == main_id));
    }

    #[test]
    fn empty_jobs() {
        let pool = WorkerPool::new(4);
        let out: Vec<u32> = pool.map(Vec::<u32>::new(), |x| *x);
        assert!(out.is_empty());
    }

    #[test]
    fn more_threads_than_jobs() {
        let pool = WorkerPool::new(64);
        assert_eq!(pool.map(vec![7u64], |x| x * x), vec![49]);
    }

    #[test]
    fn reused_across_batches() {
        let pool = WorkerPool::new(4);
        for round in 0..20u64 {
            let jobs: Vec<u64> = (0..37).collect();
            let out = pool.map(jobs, move |x| x + round);
            assert_eq!(out[36], 36 + round);
        }
    }

    #[test]
    fn actually_parallel() {
        // Multiple threads must participate for a slow job set.
        use std::collections::HashSet;
        use std::sync::Mutex;
        let pool = WorkerPool::new(4);
        let ids = Arc::new(Mutex::new(HashSet::new()));
        let ids2 = ids.clone();
        let jobs: Vec<u32> = (0..64).collect();
        pool.map(jobs, move |_| {
            std::thread::sleep(std::time::Duration::from_millis(2));
            ids2.lock().unwrap().insert(std::thread::current().id());
        });
        assert!(ids.lock().unwrap().len() > 1);
    }

    #[test]
    fn non_copy_results() {
        let pool = WorkerPool::new(2);
        let out = pool.map(vec!["a", "bb", "ccc"], |s| s.to_string());
        assert_eq!(out, vec!["a", "bb", "ccc"]);
    }

    #[test]
    fn panic_reports_job_index_and_pool_survives() {
        let pool = WorkerPool::new(4);
        let jobs: Vec<u32> = (0..8).collect();
        let err = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.map(jobs, |&x| {
                if x == 5 {
                    panic!("boom on five");
                }
                x
            })
        }))
        .unwrap_err();
        let msg = panic_message(err.as_ref());
        assert!(msg.contains("job 5"), "{msg}");
        assert!(msg.contains("boom on five"), "{msg}");
        // The pool remains fully usable after a panicking batch.
        assert_eq!(pool.map(vec![1u32, 2, 3], |x| x * 10), vec![10, 20, 30]);
    }

    #[test]
    fn concurrent_submitters() {
        let pool = Arc::new(WorkerPool::new(4));
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let p = pool.clone();
            joins.push(std::thread::spawn(move || {
                let jobs: Vec<u64> = (0..100).collect();
                p.map(jobs, move |x| x + t)
            }));
        }
        for (t, j) in joins.into_iter().enumerate() {
            let out = j.join().unwrap();
            assert_eq!(out[99], 99 + t as u64);
        }
    }
}
