//! Persistent channel-fed worker pool for CPU-bound evaluation jobs.
//!
//! The offline crate set has no rayon/tokio, so COMET ships its own pool.
//! Workers are spawned **once** (when the [`Coordinator`](super::Coordinator)
//! is built) and reused across every batch: each `map` call publishes one
//! shared batch descriptor to every worker, workers claim jobs through an
//! atomic cursor (dynamic load balancing at item granularity) and write
//! results into disjoint slots of a preallocated buffer — there is no
//! shared results mutex to contend on. The submitting thread participates
//! as a worker, so a pool of width `t` spawns `t - 1` background threads
//! and runs exactly `t` lanes — width 1 is strictly inline (deterministic
//! single-threaded execution) and small batches never pay a cross-thread
//! round-trip.
//!
//! Submission is **scoped**: [`WorkerPool::scoped_map`] accepts jobs and
//! closures that borrow the caller's stack (no `'static` bound), which is
//! what lets the branch-and-bound optimizer fan its per-batch leaf
//! evaluations out over the coordinator's pool while borrowing its
//! per-branch search state. [`WorkerPool::map`] is the owned-jobs
//! convenience wrapper the batched derive/evaluate paths use.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Type-erased batch handle the worker threads execute.
trait Task: Send + Sync {
    fn run_worker(&self);
}

/// One in-flight `scoped_map` call. The jobs and the mapper live in the
/// submitting call's scope and are held here as **raw pointers** plus an
/// owned length — never as references — so a worker that arrives after
/// the call returned only compares integers (`i >= n`) and touches no
/// expired borrow; the pointers are dereferenced exclusively for claimed
/// indices `i < n`, which can only happen while the submitting thread is
/// still blocked in `scoped_map` (it cannot return before every claimed
/// job completes).
struct Batch<T, R> {
    jobs: *const T,
    n: usize,
    f: *const (dyn Fn(&T) -> R + Send + Sync),
    /// Next unclaimed job index.
    next: AtomicUsize,
    /// Disjoint per-job result slots. Each slot's lock is touched exactly
    /// twice (one write, one take) — never contended across jobs.
    slots: Vec<Mutex<Option<R>>>,
    /// Jobs not yet finished; the worker that drops this to zero signals
    /// `done`.
    remaining: AtomicUsize,
    /// First observed panic: (job index, payload message).
    panic: Mutex<Option<(usize, String)>>,
    done: Mutex<bool>,
    done_cv: Condvar,
}

// SAFETY: the raw `jobs`/`f` pointers are dereferenced only for claimed
// indices `i < n`, i.e. while the submitting thread is blocked in
// `scoped_map` and the pointed-to jobs/closure are alive. Sharing them
// across worker threads hands out `&T` (needs `T: Sync`) and moves each
// `R` into a slot the submitter takes (needs `R: Send`); everything else
// in the struct is owned sync primitives.
unsafe impl<T: Sync, R: Send> Send for Batch<T, R> {}
unsafe impl<T: Sync, R: Send> Sync for Batch<T, R> {}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

impl<T: Sync, R: Send> Batch<T, R> {
    fn new(
        jobs: &[T],
        f: &(dyn Fn(&T) -> R + Send + Sync),
    ) -> Batch<T, R> {
        let n = jobs.len();
        Batch {
            jobs: jobs.as_ptr(),
            n,
            f,
            next: AtomicUsize::new(0),
            slots: (0..n).map(|_| Mutex::new(None)).collect(),
            remaining: AtomicUsize::new(n),
            panic: Mutex::new(None),
            done: Mutex::new(false),
            done_cv: Condvar::new(),
        }
    }

    fn execute(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n {
                // Owned integer comparison only: a worker that dequeues
                // this batch after completion (the submitter may already
                // have returned) reborrows nothing.
                break;
            }
            // SAFETY: `i < n` means the batch is still incomplete, so
            // the submitting thread is blocked in `scoped_map` and the
            // jobs slice and closure it lent are alive; `i` is claimed
            // by exactly one worker, and `&*jobs.add(i)` is a shared
            // borrow of a `Sync` value.
            let job = unsafe { &*self.jobs.add(i) };
            let f = unsafe { &*self.f };
            match catch_unwind(AssertUnwindSafe(|| f(job))) {
                Ok(r) => *self.slots[i].lock().unwrap() = Some(r),
                Err(payload) => {
                    let mut p = self.panic.lock().unwrap();
                    if p.is_none() {
                        *p = Some((i, panic_message(payload.as_ref())));
                    }
                }
            }
            if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                let mut d = self.done.lock().unwrap();
                *d = true;
                self.done_cv.notify_all();
            }
        }
    }
}

impl<T: Sync, R: Send> Task for Batch<T, R> {
    fn run_worker(&self) {
        self.execute()
    }
}

/// Persistent worker pool. Threads are spawned once and fed batches over
/// per-worker channels; dropping the pool shuts them down.
pub struct WorkerPool {
    senders: Vec<Sender<Arc<dyn Task>>>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
    /// Rotates which workers small batches notify, so concurrent
    /// submitters don't all pin their jobs behind the low-index workers.
    next_worker: AtomicUsize,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("threads", &self.threads)
            .finish()
    }
}

impl WorkerPool {
    /// A pool of total width `threads` (>= 1): `threads - 1` background
    /// workers plus the submitting thread.
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let mut senders = Vec::with_capacity(threads - 1);
        let mut handles = Vec::with_capacity(threads - 1);
        for i in 0..threads - 1 {
            let (tx, rx) = channel::<Arc<dyn Task>>();
            senders.push(tx);
            let handle = std::thread::Builder::new()
                .name(format!("comet-pool-{i}"))
                .spawn(move || {
                    while let Ok(task) = rx.recv() {
                        task.run_worker();
                    }
                })
                .expect("spawn pool worker");
            handles.push(handle);
        }
        WorkerPool {
            senders,
            handles,
            threads,
            next_worker: AtomicUsize::new(0),
        }
    }

    /// Total pool width (background workers + the submitting thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Map `f` over borrowed `jobs`, preserving order, **without**
    /// requiring `'static` jobs or closures: both may borrow the caller's
    /// stack (branch states, shared atomics, the optimizer itself). Jobs
    /// run concurrently on the pool's background workers plus the calling
    /// thread; a width-1 pool executes everything inline on the caller.
    ///
    /// The call does not return until every job has finished, which is
    /// what makes lending stack data to the persistent workers sound —
    /// see the `SAFETY` comment inside.
    ///
    /// # Panics
    ///
    /// If a job panics, the panic is re-raised on the calling thread with
    /// the failing job's index prepended to the payload message. The
    /// remaining jobs still run to completion first (no worker is lost —
    /// the pool stays usable afterwards).
    pub fn scoped_map<T, R>(
        &self,
        jobs: &[T],
        f: impl Fn(&T) -> R + Send + Sync,
    ) -> Vec<R>
    where
        T: Sync,
        R: Send,
    {
        self.scoped_map_bounded(jobs, usize::MAX, f)
    }

    /// [`WorkerPool::scoped_map`] with the evaluation concurrency capped
    /// at `lanes` total (the submitting thread counts as one): at most
    /// `lanes - 1` background workers are notified. This is how the
    /// optimizer's `threads` knob genuinely bounds CPU use instead of
    /// merely sizing its batches — `lanes >= ` the pool width is the
    /// uncapped behavior.
    pub fn scoped_map_bounded<T, R>(
        &self,
        jobs: &[T],
        lanes: usize,
        f: impl Fn(&T) -> R + Send + Sync,
    ) -> Vec<R>
    where
        T: Sync,
        R: Send,
    {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        let batch = Arc::new(Batch::new(jobs, &f));
        // Fan out to at most n-1 workers (the submitter claims jobs too,
        // and a single-job batch never leaves the calling thread),
        // bounded by the requested lanes, starting at a rotating offset
        // so concurrent small batches spread over different workers.
        let fanout = (n - 1)
            .min(self.senders.len())
            .min(lanes.saturating_sub(1));
        if fanout > 0 {
            // SAFETY: the workers' channel is typed `Arc<dyn Task>`
            // (`'static`), but this batch points into the caller's
            // scope, so its lifetime bound is erased here. Sound because:
            //  * This call blocks below until `remaining == 0`, i.e.
            //    until every job has been claimed AND finished; the
            //    cursor `next` only grows, so a worker arriving later
            //    can never obtain an index below `n` — `execute()` then
            //    only compares owned integers and dereferences nothing.
            //    The `jobs`/`f` raw pointers are therefore dereferenced
            //    exclusively while this frame (which owns `f` and
            //    borrows `jobs`) is still blocked here.
            //  * A worker that drops its `Arc` after this call returned
            //    drops only owned handshake state: raw pointers (no-op),
            //    `None` result slots (the caller takes every `Some`
            //    before returning, including on the panic path), and
            //    plain atomics — no drop glue can touch the expired
            //    scope.
            let task: Arc<dyn Task + '_> = batch.clone();
            // Raw-pointer cast that only widens the trait object's
            // lifetime bound (same principal trait, same vtable).
            let raw = Arc::into_raw(task) as *const (dyn Task + 'static);
            let task: Arc<dyn Task> = unsafe { Arc::from_raw(raw) };
            let start = self.next_worker.fetch_add(fanout, Ordering::Relaxed);
            for j in 0..fanout {
                let tx = &self.senders[(start + j) % self.senders.len()];
                let _ = tx.send(task.clone());
            }
        }
        batch.execute();
        // All jobs claimed by now (the submitter's cursor ran past n), but
        // workers may still be finishing theirs.
        let mut done = batch.done.lock().unwrap();
        while !*done {
            done = batch.done_cv.wait(done).unwrap();
        }
        drop(done);
        // Drain every slot *before* the panic check so that even on the
        // panic path no `R` is left for a worker's late `Arc` drop.
        let results: Vec<Option<R>> = batch
            .slots
            .iter()
            .map(|s| s.lock().unwrap().take())
            .collect();
        if let Some((i, msg)) = batch.panic.lock().unwrap().take() {
            drop(results);
            panic!("worker pool job {i} panicked: {msg}");
        }
        results
            .into_iter()
            .map(|r| r.expect("pool slot filled"))
            .collect()
    }

    /// Map `f` over owned `jobs`, preserving order (the batched
    /// derive/evaluate entry point). Delegates to
    /// [`WorkerPool::scoped_map`]; see there for the execution and panic
    /// semantics.
    pub fn map<T, R>(
        &self,
        jobs: Vec<T>,
        f: impl Fn(&T) -> R + Send + Sync,
    ) -> Vec<R>
    where
        T: Sync,
        R: Send,
    {
        self.scoped_map(&jobs, f)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the channels ends each worker's recv loop.
        self.senders.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let pool = WorkerPool::new(8);
        let jobs: Vec<u64> = (0..1000).collect();
        let out = pool.map(jobs.clone(), |x| x * 2);
        assert_eq!(out, jobs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_pool() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.map(vec![1, 2, 3], |x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn width_one_is_strictly_inline() {
        let pool = WorkerPool::new(1);
        let main_id = std::thread::current().id();
        let jobs: Vec<u32> = (0..16).collect();
        let ids = pool.map(jobs, move |_| std::thread::current().id());
        assert!(ids.iter().all(|&id| id == main_id));
    }

    #[test]
    fn empty_jobs() {
        let pool = WorkerPool::new(4);
        let out: Vec<u32> = pool.map(Vec::<u32>::new(), |x| *x);
        assert!(out.is_empty());
    }

    #[test]
    fn more_threads_than_jobs() {
        let pool = WorkerPool::new(64);
        assert_eq!(pool.map(vec![7u64], |x| x * x), vec![49]);
    }

    #[test]
    fn reused_across_batches() {
        let pool = WorkerPool::new(4);
        for round in 0..20u64 {
            let jobs: Vec<u64> = (0..37).collect();
            let out = pool.map(jobs, move |x| x + round);
            assert_eq!(out[36], 36 + round);
        }
    }

    #[test]
    fn actually_parallel() {
        // Multiple threads must participate for a slow job set.
        use std::collections::HashSet;
        use std::sync::Mutex;
        let pool = WorkerPool::new(4);
        let ids = Arc::new(Mutex::new(HashSet::new()));
        let ids2 = ids.clone();
        let jobs: Vec<u32> = (0..64).collect();
        pool.map(jobs, move |_| {
            std::thread::sleep(std::time::Duration::from_millis(2));
            ids2.lock().unwrap().insert(std::thread::current().id());
        });
        assert!(ids.lock().unwrap().len() > 1);
    }

    #[test]
    fn non_copy_results() {
        let pool = WorkerPool::new(2);
        let out = pool.map(vec!["a", "bb", "ccc"], |s| s.to_string());
        assert_eq!(out, vec!["a", "bb", "ccc"]);
    }

    #[test]
    fn scoped_map_borrows_caller_state() {
        // The whole point of scoped_map: jobs AND closure borrow the
        // caller's stack — no 'static, no Arc plumbing.
        let pool = WorkerPool::new(4);
        let table: Vec<u64> = (0..100).map(|i| i * i).collect();
        let jobs: Vec<usize> = (0..100).collect();
        let out = pool.scoped_map(&jobs, |&i| table[i] + 1);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, table[i] + 1);
        }
    }

    #[test]
    fn scoped_map_shares_atomics_across_lanes() {
        use std::sync::atomic::AtomicU64;
        let pool = WorkerPool::new(4);
        let sum = AtomicU64::new(0);
        let jobs: Vec<u64> = (0..256).collect();
        let out = pool.scoped_map(&jobs, |&x| {
            sum.fetch_add(x, Ordering::Relaxed);
            x
        });
        assert_eq!(out.len(), 256);
        assert_eq!(sum.load(Ordering::Relaxed), 255 * 256 / 2);
    }

    #[test]
    fn bounded_lanes_cap_worker_fanout() {
        use std::collections::HashSet;
        let pool = WorkerPool::new(8);
        let ids = Mutex::new(HashSet::new());
        let jobs: Vec<u32> = (0..64).collect();
        pool.scoped_map_bounded(&jobs, 2, |_| {
            std::thread::sleep(std::time::Duration::from_millis(1));
            ids.lock().unwrap().insert(std::thread::current().id());
        });
        assert!(ids.lock().unwrap().len() <= 2, "lanes=2 must cap fan-out");
        // lanes = 1 stays strictly on the submitting thread.
        let main_id = std::thread::current().id();
        let only = pool
            .scoped_map_bounded(&jobs, 1, |_| std::thread::current().id());
        assert!(only.iter().all(|&id| id == main_id));
    }

    #[test]
    fn panic_reports_job_index_and_pool_survives() {
        let pool = WorkerPool::new(4);
        let jobs: Vec<u32> = (0..8).collect();
        let err = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.map(jobs, |&x| {
                if x == 5 {
                    panic!("boom on five");
                }
                x
            })
        }))
        .unwrap_err();
        let msg = panic_message(err.as_ref());
        assert!(msg.contains("job 5"), "{msg}");
        assert!(msg.contains("boom on five"), "{msg}");
        // The pool remains fully usable after a panicking batch.
        assert_eq!(pool.map(vec![1u32, 2, 3], |x| x * 10), vec![10, 20, 30]);
    }

    #[test]
    fn concurrent_submitters() {
        let pool = Arc::new(WorkerPool::new(4));
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let p = pool.clone();
            joins.push(std::thread::spawn(move || {
                let jobs: Vec<u64> = (0..100).collect();
                p.map(jobs, move |x| x + t)
            }));
        }
        for (t, j) in joins.into_iter().enumerate() {
            let out = j.join().unwrap();
            assert_eq!(out[99], 99 + t as u64);
        }
    }
}
