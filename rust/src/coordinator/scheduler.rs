//! Work-stealing-free but effective fan-out scheduler over std threads
//! (the offline crate set has no rayon/tokio): static round-robin
//! partitioning of independent evaluation jobs. DSE jobs are uniform
//! enough that static partitioning is within noise of work stealing.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Thread-pool-style mapper for CPU-bound evaluation jobs.
#[derive(Debug, Clone, Copy)]
pub struct Scheduler {
    threads: usize,
}

impl Scheduler {
    /// A scheduler with `threads` workers (>= 1).
    pub fn new(threads: usize) -> Scheduler {
        Scheduler {
            threads: threads.max(1),
        }
    }

    /// Map `f` over `jobs`, preserving order. `f` runs concurrently on up
    /// to `threads` workers via an atomic work index (dynamic load
    /// balancing at item granularity).
    pub fn map<T: Sync, R: Send>(
        &self,
        jobs: &[T],
        f: impl Fn(&T) -> R + Sync,
    ) -> Vec<R> {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        let workers = self.threads.min(n);
        if workers == 1 {
            return jobs.iter().map(f).collect();
        }

        let next = AtomicUsize::new(0);
        let results: std::sync::Mutex<Vec<Option<R>>> =
            std::sync::Mutex::new((0..n).map(|_| None).collect());

        std::thread::scope(|scope| {
            for _ in 0..workers {
                let next = &next;
                let f = &f;
                let results = &results;
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = f(&jobs[i]);
                    results.lock().unwrap()[i] = Some(r);
                });
            }
        });
        results
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("slot filled"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let jobs: Vec<u64> = (0..1000).collect();
        let out = Scheduler::new(8).map(&jobs, |x| x * 2);
        assert_eq!(out, jobs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let jobs = vec![1, 2, 3];
        assert_eq!(Scheduler::new(1).map(&jobs, |x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_jobs() {
        let jobs: Vec<u32> = vec![];
        assert!(Scheduler::new(4).map(&jobs, |x| *x).is_empty());
    }

    #[test]
    fn more_threads_than_jobs() {
        let jobs = vec![7];
        assert_eq!(Scheduler::new(64).map(&jobs, |x| x * x), vec![49]);
    }

    #[test]
    fn actually_parallel() {
        // All workers must participate for a slow job set.
        use std::collections::HashSet;
        use std::sync::Mutex;
        let ids = Mutex::new(HashSet::new());
        let jobs: Vec<u32> = (0..64).collect();
        Scheduler::new(4).map(&jobs, |_| {
            std::thread::sleep(std::time::Duration::from_millis(2));
            ids.lock().unwrap().insert(std::thread::current().id());
        });
        assert!(ids.lock().unwrap().len() > 1);
    }

    #[test]
    fn non_copy_results() {
        let jobs = vec!["a", "bb", "ccc"];
        let out = Scheduler::new(2).map(&jobs, |s| s.to_string());
        assert_eq!(out, vec!["a", "bb", "ccc"]);
    }
}
