//! Persistent channel-fed worker pool for CPU-bound evaluation jobs.
//!
//! The offline crate set has no rayon/tokio, so COMET ships its own pool.
//! Workers are spawned **once** (when the [`Coordinator`](super::Coordinator)
//! is built) and reused across every batch: each `map` call publishes one
//! shared batch descriptor to every worker, workers claim jobs through an
//! atomic cursor (dynamic load balancing at item granularity) and write
//! results into disjoint slots of a preallocated buffer — there is no
//! shared results mutex to contend on. The submitting thread participates
//! as a worker, so a pool of width `t` spawns `t - 1` background threads
//! and runs exactly `t` lanes — width 1 is strictly inline (deterministic
//! single-threaded execution) and small batches never pay a cross-thread
//! round-trip.
//!
//! Submission is **scoped**: [`WorkerPool::scoped_map`] accepts jobs and
//! closures that borrow the caller's stack (no `'static` bound), which is
//! what lets the branch-and-bound optimizer fan its per-batch leaf
//! evaluations out over the coordinator's pool while borrowing its
//! per-branch search state. [`WorkerPool::map`] is the owned-jobs
//! convenience wrapper the batched derive/evaluate paths use.
//!
//! **Fault isolation**: a panicking job never poisons the pool. Every
//! panic is caught inside the worker loop and recorded per job index;
//! the batch always runs to completion and the pool stays reusable. Two
//! reporting surfaces exist: the legacy [`WorkerPool::scoped_map`]
//! re-raises the first (lowest-index) panic on the caller, while the
//! `try_*` variants return a structured
//! [`Error::Job`](crate::error::Error::Job) — optionally after retrying
//! the failed indices once with a short backoff
//! ([`WorkerPool::try_scoped_map_retry`]). For jobs that may *stall*
//! rather than panic, [`WorkerPool::try_map_watchdog`] runs an owned
//! (`'static`) batch under a timeout: a stuck batch is abandoned (the
//! leaked batch keeps its jobs alive for the stalled worker), the
//! targeted workers are respawned to restore pool width, and the caller
//! gets [`Error::Deadline`](crate::error::Error::Deadline) instead of a
//! hang. Scoped batches cannot be abandoned — the submitter *must*
//! block until `remaining == 0` for the lent borrows to stay sound —
//! which is why the watchdog exists only on the owned path.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::error::{Error, Result};

/// Type-erased batch handle the worker threads execute.
trait Task: Send + Sync {
    fn run_worker(&self);
}

/// Pause before re-running failed indices in
/// [`WorkerPool::try_scoped_map_retry`] — long enough for a transient
/// resource squeeze to clear, short enough to be invisible next to any
/// real batch.
const RETRY_BACKOFF: Duration = Duration::from_millis(10);

/// One in-flight `scoped_map` call. The jobs and the mapper live in the
/// submitting call's scope and are held here as **raw pointers** plus an
/// owned length — never as references — so a worker that arrives after
/// the call returned only compares integers (`i >= n`) and touches no
/// expired borrow; the pointers are dereferenced exclusively for claimed
/// indices `i < n`, which can only happen while the submitting thread is
/// still blocked in `scoped_map` (it cannot return before every claimed
/// job completes).
struct Batch<T, R> {
    jobs: *const T,
    n: usize,
    f: *const (dyn Fn(&T) -> R + Send + Sync),
    /// Next unclaimed job index.
    next: AtomicUsize,
    /// Disjoint per-job result slots. Each slot's lock is touched exactly
    /// twice (one write, one take) — never contended across jobs.
    slots: Vec<Mutex<Option<R>>>,
    /// Jobs not yet finished; the worker that drops this to zero signals
    /// `done`.
    remaining: AtomicUsize,
    /// Every observed panic: (job index, payload message). Collected in
    /// completion order; callers sort by index for determinism.
    failures: Mutex<Vec<(usize, String)>>,
    done: Mutex<bool>,
    done_cv: Condvar,
}

// SAFETY: the raw `jobs`/`f` pointers are dereferenced only for claimed
// indices `i < n`, i.e. while the submitting thread is blocked in
// `scoped_map` and the pointed-to jobs/closure are alive. Sharing them
// across worker threads hands out `&T` (needs `T: Sync`) and moves each
// `R` into a slot the submitter takes (needs `R: Send`); everything else
// in the struct is owned sync primitives.
unsafe impl<T: Sync, R: Send> Send for Batch<T, R> {}
unsafe impl<T: Sync, R: Send> Sync for Batch<T, R> {}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

impl<T: Sync, R: Send> Batch<T, R> {
    fn new(
        jobs: &[T],
        f: &(dyn Fn(&T) -> R + Send + Sync),
    ) -> Batch<T, R> {
        let n = jobs.len();
        Batch {
            jobs: jobs.as_ptr(),
            n,
            f,
            next: AtomicUsize::new(0),
            slots: (0..n).map(|_| Mutex::new(None)).collect(),
            remaining: AtomicUsize::new(n),
            failures: Mutex::new(Vec::new()),
            done: Mutex::new(false),
            done_cv: Condvar::new(),
        }
    }

    fn execute(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n {
                // Owned integer comparison only: a worker that dequeues
                // this batch after completion (the submitter may already
                // have returned) reborrows nothing.
                break;
            }
            // SAFETY: `i < n` means the batch is still incomplete, so
            // the submitting thread is blocked in `scoped_map` and the
            // jobs slice and closure it lent are alive; `i` is claimed
            // by exactly one worker, and `&*jobs.add(i)` is a shared
            // borrow of a `Sync` value.
            let job = unsafe { &*self.jobs.add(i) };
            let f = unsafe { &*self.f };
            match catch_unwind(AssertUnwindSafe(|| f(job))) {
                Ok(r) => *self.slots[i].lock().unwrap() = Some(r),
                Err(payload) => self
                    .failures
                    .lock()
                    .unwrap()
                    .push((i, panic_message(payload.as_ref()))),
            }
            if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                let mut d = self.done.lock().unwrap();
                *d = true;
                self.done_cv.notify_all();
            }
        }
    }
}

impl<T: Sync, R: Send> Task for Batch<T, R> {
    fn run_worker(&self) {
        self.execute()
    }
}

/// Spawn one background worker thread: drains its channel until the
/// sender side is dropped (pool drop or respawn), executing each batch
/// with every per-job panic caught inside [`Batch::execute`].
fn spawn_worker(idx: usize) -> (Sender<Arc<dyn Task>>, JoinHandle<()>) {
    let (tx, rx) = channel::<Arc<dyn Task>>();
    let handle = std::thread::Builder::new()
        .name(format!("comet-pool-{idx}"))
        .spawn(move || {
            while let Ok(task) = rx.recv() {
                task.run_worker();
            }
        })
        .expect("spawn pool worker");
    (tx, handle)
}

/// One background worker: its feed channel plus its join handle.
/// Wrapped in a `Mutex` on the pool so a worker can be **respawned**
/// under `&self` (watchdog recovery, [`WorkerPool::heal`]) — replacing
/// the sender ends the old thread's `recv` loop once it finishes its
/// current task, and a fresh thread takes over the slot.
struct WorkerSlot {
    sender: Option<Sender<Arc<dyn Task>>>,
    handle: Option<JoinHandle<()>>,
    /// Bumped on every respawn (observable via [`WorkerPool::respawns`]).
    generation: usize,
}

/// Persistent worker pool. Threads are spawned once and fed batches over
/// per-worker channels; dropping the pool shuts them down.
pub struct WorkerPool {
    workers: Vec<Mutex<WorkerSlot>>,
    threads: usize,
    /// Rotates which workers small batches notify, so concurrent
    /// submitters don't all pin their jobs behind the low-index workers.
    next_worker: AtomicUsize,
    /// Total workers respawned over the pool's lifetime.
    respawned: AtomicUsize,
    /// Total jobs submitted over the pool's lifetime (every batch
    /// surface counts its batch size on entry).
    jobs_run: AtomicU64,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("threads", &self.threads)
            .finish()
    }
}

impl WorkerPool {
    /// A pool of total width `threads` (>= 1): `threads - 1` background
    /// workers plus the submitting thread.
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let mut workers = Vec::with_capacity(threads - 1);
        for i in 0..threads - 1 {
            let (sender, handle) = spawn_worker(i);
            workers.push(Mutex::new(WorkerSlot {
                sender: Some(sender),
                handle: Some(handle),
                generation: 0,
            }));
        }
        WorkerPool {
            workers,
            threads,
            next_worker: AtomicUsize::new(0),
            respawned: AtomicUsize::new(0),
            jobs_run: AtomicU64::new(0),
        }
    }

    /// Total pool width (background workers + the submitting thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Workers respawned over the pool's lifetime (watchdog recovery or
    /// [`WorkerPool::heal`]).
    pub fn respawns(&self) -> usize {
        self.respawned.load(Ordering::Relaxed)
    }

    /// Jobs submitted over the pool's lifetime, across every batch
    /// surface (scoped, owned, and watchdog paths). Feeds
    /// `Coordinator::stats()` and the serve layer's `/stats` endpoint.
    pub fn jobs_run(&self) -> u64 {
        self.jobs_run.load(Ordering::Relaxed)
    }

    /// Replace worker `idx` with a fresh thread. The old thread's sender
    /// is dropped, so it exits its `recv` loop as soon as it finishes
    /// whatever it is doing (a stalled thread dies when its stuck job
    /// finally returns); its handle is detached rather than joined so
    /// recovery never blocks on the very stall it is recovering from.
    fn respawn_worker(&self, idx: usize) {
        let mut slot = self.workers[idx].lock().unwrap();
        let (sender, handle) = spawn_worker(idx);
        slot.sender = Some(sender);
        drop(slot.handle.take()); // detach the old thread
        slot.handle = Some(handle);
        slot.generation += 1;
        self.respawned.fetch_add(1, Ordering::Relaxed);
    }

    /// Defensive sweep: respawn any background worker whose thread has
    /// terminated (a caught panic never kills a worker, but a foreign
    /// exception or exotic unwind could). Returns how many were revived.
    pub fn heal(&self) -> usize {
        let mut revived = 0;
        for idx in 0..self.workers.len() {
            let finished = {
                let slot = self.workers[idx].lock().unwrap();
                slot.handle.as_ref().map(|h| h.is_finished()).unwrap_or(true)
            };
            if finished {
                self.respawn_worker(idx);
                revived += 1;
            }
        }
        revived
    }

    /// Send `task` to worker `idx` (no-op if its sender is missing).
    fn send_to(&self, idx: usize, task: Arc<dyn Task>) {
        let slot = self.workers[idx].lock().unwrap();
        if let Some(tx) = &slot.sender {
            let _ = tx.send(task);
        }
    }

    /// Map `f` over borrowed `jobs`, preserving order, **without**
    /// requiring `'static` jobs or closures: both may borrow the caller's
    /// stack (branch states, shared atomics, the optimizer itself). Jobs
    /// run concurrently on the pool's background workers plus the calling
    /// thread; a width-1 pool executes everything inline on the caller.
    ///
    /// The call does not return until every job has finished, which is
    /// what makes lending stack data to the persistent workers sound —
    /// see the `SAFETY` comment inside.
    ///
    /// # Panics
    ///
    /// If a job panics, the panic is re-raised on the calling thread with
    /// the failing job's index prepended to the payload message. The
    /// remaining jobs still run to completion first (no worker is lost —
    /// the pool stays usable afterwards).
    pub fn scoped_map<T, R>(
        &self,
        jobs: &[T],
        f: impl Fn(&T) -> R + Send + Sync,
    ) -> Vec<R>
    where
        T: Sync,
        R: Send,
    {
        self.scoped_map_bounded(jobs, usize::MAX, f)
    }

    /// [`WorkerPool::scoped_map`] with the evaluation concurrency capped
    /// at `lanes` total (the submitting thread counts as one): at most
    /// `lanes - 1` background workers are notified. This is how the
    /// optimizer's `threads` knob genuinely bounds CPU use instead of
    /// merely sizing its batches — `lanes >= ` the pool width is the
    /// uncapped behavior.
    pub fn scoped_map_bounded<T, R>(
        &self,
        jobs: &[T],
        lanes: usize,
        f: impl Fn(&T) -> R + Send + Sync,
    ) -> Vec<R>
    where
        T: Sync,
        R: Send,
    {
        let (results, failures) = self.scoped_run_bounded(jobs, lanes, &f);
        if let Some((i, msg)) = failures.into_iter().min_by_key(|(i, _)| *i) {
            drop(results);
            panic!("worker pool job {i} panicked: {msg}");
        }
        results
            .into_iter()
            .map(|r| r.expect("pool slot filled"))
            .collect()
    }

    /// Shared engine for every scoped surface: runs the batch to
    /// completion and returns the per-slot results plus every captured
    /// per-job panic (unsorted), leaving policy — re-raise, structured
    /// error, retry — to the wrappers.
    fn scoped_run_bounded<T, R>(
        &self,
        jobs: &[T],
        lanes: usize,
        f: &(dyn Fn(&T) -> R + Send + Sync),
    ) -> (Vec<Option<R>>, Vec<(usize, String)>)
    where
        T: Sync,
        R: Send,
    {
        let n = jobs.len();
        if n == 0 {
            return (Vec::new(), Vec::new());
        }
        self.jobs_run.fetch_add(n as u64, Ordering::Relaxed);
        let batch = Arc::new(Batch::new(jobs, f));
        // Fan out to at most n-1 workers (the submitter claims jobs too,
        // and a single-job batch never leaves the calling thread),
        // bounded by the requested lanes, starting at a rotating offset
        // so concurrent small batches spread over different workers.
        let fanout = (n - 1)
            .min(self.workers.len())
            .min(lanes.saturating_sub(1));
        if fanout > 0 {
            // SAFETY: the workers' channel is typed `Arc<dyn Task>`
            // (`'static`), but this batch points into the caller's
            // scope, so its lifetime bound is erased here. Sound because:
            //  * This call blocks below until `remaining == 0`, i.e.
            //    until every job has been claimed AND finished; the
            //    cursor `next` only grows, so a worker arriving later
            //    can never obtain an index below `n` — `execute()` then
            //    only compares owned integers and dereferences nothing.
            //    The `jobs`/`f` raw pointers are therefore dereferenced
            //    exclusively while this frame (which owns `f` and
            //    borrows `jobs`) is still blocked here.
            //  * A worker that drops its `Arc` after this call returned
            //    drops only owned handshake state: raw pointers (no-op),
            //    `None` result slots (the caller takes every `Some`
            //    before returning, including on the panic path), and
            //    plain atomics — no drop glue can touch the expired
            //    scope.
            let task: Arc<dyn Task + '_> = batch.clone();
            // Raw-pointer cast that only widens the trait object's
            // lifetime bound (same principal trait, same vtable).
            let raw = Arc::into_raw(task) as *const (dyn Task + 'static);
            let task: Arc<dyn Task> = unsafe { Arc::from_raw(raw) };
            let start = self.next_worker.fetch_add(fanout, Ordering::Relaxed);
            for j in 0..fanout {
                self.send_to((start + j) % self.workers.len(), task.clone());
            }
        }
        batch.execute();
        // All jobs claimed by now (the submitter's cursor ran past n), but
        // workers may still be finishing theirs.
        let mut done = batch.done.lock().unwrap();
        while !*done {
            done = batch.done_cv.wait(done).unwrap();
        }
        drop(done);
        // Drain every slot *before* handing out the failures so that
        // even on the panic path no `R` is left for a worker's late
        // `Arc` drop.
        let results: Vec<Option<R>> = batch
            .slots
            .iter()
            .map(|s| s.lock().unwrap().take())
            .collect();
        let failures = std::mem::take(&mut *batch.failures.lock().unwrap());
        (results, failures)
    }

    /// [`WorkerPool::scoped_map_bounded`] with structured failure
    /// reporting: a panicking job yields
    /// [`Error::Job`]`{ index, cause }` (lowest failing index when
    /// several jobs panic) instead of re-raising on the caller. The
    /// batch still runs to completion and the pool stays reusable.
    pub fn try_scoped_map_bounded<T, R>(
        &self,
        jobs: &[T],
        lanes: usize,
        f: impl Fn(&T) -> R + Send + Sync,
    ) -> Result<Vec<R>>
    where
        T: Sync,
        R: Send,
    {
        self.try_scoped_map_retry(jobs, lanes, false, f)
    }

    /// [`WorkerPool::try_scoped_map_bounded`] for jobs flagged
    /// retryable: after the batch completes, every failed index is
    /// re-run **once** inline on the caller following a short backoff
    /// (transient failures — artifact I/O hiccups, OOM-kill races —
    /// get a second chance; deterministic panics fail again and
    /// surface as [`Error::Job`]).
    pub fn try_scoped_map_retry<T, R>(
        &self,
        jobs: &[T],
        lanes: usize,
        retry_once: bool,
        f: impl Fn(&T) -> R + Send + Sync,
    ) -> Result<Vec<R>>
    where
        T: Sync,
        R: Send,
    {
        let (mut results, mut failures) =
            self.scoped_run_bounded(jobs, lanes, &f);
        failures.sort_by_key(|(i, _)| *i);
        if !failures.is_empty() && retry_once {
            std::thread::sleep(RETRY_BACKOFF);
            let mut still = Vec::new();
            for (i, _) in failures {
                match catch_unwind(AssertUnwindSafe(|| f(&jobs[i]))) {
                    Ok(r) => results[i] = Some(r),
                    Err(p) => still.push((i, panic_message(p.as_ref()))),
                }
            }
            failures = still;
        }
        if let Some((index, cause)) = failures.into_iter().next() {
            drop(results);
            return Err(Error::Job { index, cause });
        }
        Ok(results
            .into_iter()
            .map(|r| r.expect("pool slot filled"))
            .collect())
    }

    /// Map `f` over owned `jobs`, preserving order (the batched
    /// derive/evaluate entry point). Delegates to
    /// [`WorkerPool::scoped_map`]; see there for the execution and panic
    /// semantics.
    pub fn map<T, R>(
        &self,
        jobs: Vec<T>,
        f: impl Fn(&T) -> R + Send + Sync,
    ) -> Vec<R>
    where
        T: Sync,
        R: Send,
    {
        self.scoped_map(&jobs, f)
    }

    /// [`WorkerPool::map`] with structured failure reporting
    /// ([`Error::Job`] instead of a re-raised panic).
    pub fn try_map<T, R>(
        &self,
        jobs: Vec<T>,
        f: impl Fn(&T) -> R + Send + Sync,
    ) -> Result<Vec<R>>
    where
        T: Sync,
        R: Send,
    {
        self.try_scoped_map_bounded(&jobs, usize::MAX, f)
    }

    /// Run an **owned** (`'static`) batch under a watchdog: if the batch
    /// has not completed within `timeout`, it is abandoned — the still-
    /// running batch keeps its own jobs and closure alive (it is
    /// `Arc`-shared, no borrowed state), the workers it was fanned out
    /// to are respawned so the pool regains full width, and the caller
    /// gets [`Error::Deadline`] naming the first incomplete job instead
    /// of hanging forever. Panicking jobs inside the timeout surface as
    /// [`Error::Job`], exactly like the `try_*` scoped surfaces.
    ///
    /// The submitting thread does **not** claim jobs here (it has to
    /// stay free to time out), so the batch runs entirely on background
    /// workers; a width-1 pool spawns one temporary thread for it.
    pub fn try_map_watchdog<T, R>(
        &self,
        jobs: Vec<T>,
        lanes: usize,
        timeout: Duration,
        f: impl Fn(&T) -> R + Send + Sync + 'static,
    ) -> Result<Vec<R>>
    where
        T: Send + Sync + 'static,
        R: Send + 'static,
    {
        let n = jobs.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        self.jobs_run.fetch_add(n as u64, Ordering::Relaxed);
        let batch = Arc::new(OwnedBatch {
            jobs,
            f: Box::new(f),
            next: AtomicUsize::new(0),
            slots: (0..n).map(|_| Mutex::new(None)).collect(),
            remaining: AtomicUsize::new(n),
            failures: Mutex::new(Vec::new()),
            done: Mutex::new(false),
            done_cv: Condvar::new(),
        });
        let fanout = n.min(self.workers.len()).min(lanes.max(1));
        let start = self.next_worker.fetch_add(fanout, Ordering::Relaxed);
        let mut targets = Vec::with_capacity(fanout.max(1));
        if fanout > 0 {
            let task: Arc<dyn Task> = batch.clone();
            for j in 0..fanout {
                let idx = (start + j) % self.workers.len();
                targets.push(idx);
                self.send_to(idx, task.clone());
            }
        } else {
            // No background workers (width-1 pool): one temporary
            // detached thread runs the batch so the caller can still
            // time out.
            let task = batch.clone();
            std::thread::Builder::new()
                .name("comet-pool-tmp".into())
                .spawn(move || task.execute())
                .expect("spawn temp pool worker");
        }
        let done = batch.done.lock().unwrap();
        let (done, wait) = batch
            .done_cv
            .wait_timeout_while(done, timeout, |d| !*d)
            .unwrap();
        if wait.timed_out() && !*done {
            drop(done);
            let claimed = batch.next.load(Ordering::Relaxed).min(n);
            let failed: Vec<usize> = batch
                .failures
                .lock()
                .unwrap()
                .iter()
                .map(|(i, _)| *i)
                .collect();
            let stuck = (0..n)
                .find(|&i| {
                    let unclaimed = i >= claimed;
                    let unfinished = batch.slots[i].lock().unwrap().is_none()
                        && !failed.contains(&i);
                    unclaimed || unfinished
                })
                .unwrap_or(0);
            // Restore pool width: the stalled workers' replacements take
            // over their slots; the old threads die once their stuck
            // jobs return (the leaked Arc keeps the batch alive for
            // them).
            for idx in targets {
                self.respawn_worker(idx);
            }
            return Err(Error::Deadline(format!(
                "worker batch stalled: job {stuck} incomplete after \
                 {:.1}s (watchdog); {} worker(s) respawned",
                timeout.as_secs_f64(),
                fanout.max(1)
            )));
        }
        drop(done);
        let results: Vec<Option<R>> = batch
            .slots
            .iter()
            .map(|s| s.lock().unwrap().take())
            .collect();
        let mut failures =
            std::mem::take(&mut *batch.failures.lock().unwrap());
        failures.sort_by_key(|(i, _)| *i);
        if let Some((index, cause)) = failures.into_iter().next() {
            return Err(Error::Job { index, cause });
        }
        Ok(results
            .into_iter()
            .map(|r| r.expect("pool slot filled"))
            .collect())
    }
}

/// An owned, `'static` batch for the watchdog path: unlike [`Batch`],
/// everything lives inside the `Arc`, so abandoning it on timeout is
/// plain reference counting — the stalled worker's clone keeps the jobs
/// and closure alive until it finally returns.
struct OwnedBatch<T, R> {
    jobs: Vec<T>,
    f: Box<dyn Fn(&T) -> R + Send + Sync>,
    next: AtomicUsize,
    slots: Vec<Mutex<Option<R>>>,
    remaining: AtomicUsize,
    failures: Mutex<Vec<(usize, String)>>,
    done: Mutex<bool>,
    done_cv: Condvar,
}

impl<T: Send + Sync + 'static, R: Send + 'static> OwnedBatch<T, R> {
    fn execute(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.jobs.len() {
                break;
            }
            match catch_unwind(AssertUnwindSafe(|| (self.f)(&self.jobs[i]))) {
                Ok(r) => *self.slots[i].lock().unwrap() = Some(r),
                Err(payload) => self
                    .failures
                    .lock()
                    .unwrap()
                    .push((i, panic_message(payload.as_ref()))),
            }
            if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                let mut d = self.done.lock().unwrap();
                *d = true;
                self.done_cv.notify_all();
            }
        }
    }
}

impl<T: Send + Sync + 'static, R: Send + 'static> Task for OwnedBatch<T, R> {
    fn run_worker(&self) {
        self.execute()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the channels ends each worker's recv loop; join only
        // the current generation (stalled predecessors were detached).
        let mut handles = Vec::new();
        for slot in &mut self.workers {
            let slot = slot.get_mut().unwrap();
            drop(slot.sender.take());
            if let Some(h) = slot.handle.take() {
                handles.push(h);
            }
        }
        for h in handles {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let pool = WorkerPool::new(8);
        let jobs: Vec<u64> = (0..1000).collect();
        let out = pool.map(jobs.clone(), |x| x * 2);
        assert_eq!(out, jobs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_pool() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.map(vec![1, 2, 3], |x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn width_one_is_strictly_inline() {
        let pool = WorkerPool::new(1);
        let main_id = std::thread::current().id();
        let jobs: Vec<u32> = (0..16).collect();
        let ids = pool.map(jobs, move |_| std::thread::current().id());
        assert!(ids.iter().all(|&id| id == main_id));
    }

    #[test]
    fn empty_jobs() {
        let pool = WorkerPool::new(4);
        let out: Vec<u32> = pool.map(Vec::<u32>::new(), |x| *x);
        assert!(out.is_empty());
    }

    #[test]
    fn jobs_run_counts_every_batch_surface() {
        let pool = WorkerPool::new(2);
        assert_eq!(pool.jobs_run(), 0);
        pool.map((0..10u64).collect(), |x| x + 1);
        assert_eq!(pool.jobs_run(), 10);
        pool.try_map_watchdog(
            (0..5u32).collect(),
            2,
            Duration::from_secs(30),
            |x| x + 1,
        )
        .unwrap();
        assert_eq!(pool.jobs_run(), 15);
        // Empty batches don't count.
        let _: Vec<u32> = pool.map(Vec::new(), |x| *x);
        assert_eq!(pool.jobs_run(), 15);
    }

    #[test]
    fn more_threads_than_jobs() {
        let pool = WorkerPool::new(64);
        assert_eq!(pool.map(vec![7u64], |x| x * x), vec![49]);
    }

    #[test]
    fn reused_across_batches() {
        let pool = WorkerPool::new(4);
        for round in 0..20u64 {
            let jobs: Vec<u64> = (0..37).collect();
            let out = pool.map(jobs, move |x| x + round);
            assert_eq!(out[36], 36 + round);
        }
    }

    #[test]
    fn actually_parallel() {
        // Multiple threads must participate for a slow job set.
        use std::collections::HashSet;
        use std::sync::Mutex;
        let pool = WorkerPool::new(4);
        let ids = Arc::new(Mutex::new(HashSet::new()));
        let ids2 = ids.clone();
        let jobs: Vec<u32> = (0..64).collect();
        pool.map(jobs, move |_| {
            std::thread::sleep(std::time::Duration::from_millis(2));
            ids2.lock().unwrap().insert(std::thread::current().id());
        });
        assert!(ids.lock().unwrap().len() > 1);
    }

    #[test]
    fn non_copy_results() {
        let pool = WorkerPool::new(2);
        let out = pool.map(vec!["a", "bb", "ccc"], |s| s.to_string());
        assert_eq!(out, vec!["a", "bb", "ccc"]);
    }

    #[test]
    fn scoped_map_borrows_caller_state() {
        // The whole point of scoped_map: jobs AND closure borrow the
        // caller's stack — no 'static, no Arc plumbing.
        let pool = WorkerPool::new(4);
        let table: Vec<u64> = (0..100).map(|i| i * i).collect();
        let jobs: Vec<usize> = (0..100).collect();
        let out = pool.scoped_map(&jobs, |&i| table[i] + 1);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, table[i] + 1);
        }
    }

    #[test]
    fn scoped_map_shares_atomics_across_lanes() {
        use std::sync::atomic::AtomicU64;
        let pool = WorkerPool::new(4);
        let sum = AtomicU64::new(0);
        let jobs: Vec<u64> = (0..256).collect();
        let out = pool.scoped_map(&jobs, |&x| {
            sum.fetch_add(x, Ordering::Relaxed);
            x
        });
        assert_eq!(out.len(), 256);
        assert_eq!(sum.load(Ordering::Relaxed), 255 * 256 / 2);
    }

    #[test]
    fn bounded_lanes_cap_worker_fanout() {
        use std::collections::HashSet;
        let pool = WorkerPool::new(8);
        let ids = Mutex::new(HashSet::new());
        let jobs: Vec<u32> = (0..64).collect();
        pool.scoped_map_bounded(&jobs, 2, |_| {
            std::thread::sleep(std::time::Duration::from_millis(1));
            ids.lock().unwrap().insert(std::thread::current().id());
        });
        assert!(ids.lock().unwrap().len() <= 2, "lanes=2 must cap fan-out");
        // lanes = 1 stays strictly on the submitting thread.
        let main_id = std::thread::current().id();
        let only = pool
            .scoped_map_bounded(&jobs, 1, |_| std::thread::current().id());
        assert!(only.iter().all(|&id| id == main_id));
    }

    #[test]
    fn panic_reports_job_index_and_pool_survives() {
        let pool = WorkerPool::new(4);
        let jobs: Vec<u32> = (0..8).collect();
        let err = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.map(jobs, |&x| {
                if x == 5 {
                    panic!("boom on five");
                }
                x
            })
        }))
        .unwrap_err();
        let msg = panic_message(err.as_ref());
        assert!(msg.contains("job 5"), "{msg}");
        assert!(msg.contains("boom on five"), "{msg}");
        // The pool remains fully usable after a panicking batch.
        assert_eq!(pool.map(vec![1u32, 2, 3], |x| x * 10), vec![10, 20, 30]);
    }

    #[test]
    fn try_map_reports_structured_job_error_and_pool_survives() {
        let pool = WorkerPool::new(4);
        let jobs: Vec<u32> = (0..8).collect();
        let err = pool
            .try_map(jobs, |&x| {
                if x == 3 {
                    panic!("bad leaf");
                }
                x * 2
            })
            .unwrap_err();
        match err {
            Error::Job { index, cause } => {
                assert_eq!(index, 3);
                assert!(cause.contains("bad leaf"), "{cause}");
            }
            other => panic!("expected Error::Job, got {other}"),
        }
        // Structured failure, same isolation guarantee: reusable pool.
        assert_eq!(
            pool.try_map(vec![1u32, 2], |x| x + 1).unwrap(),
            vec![2, 3]
        );
    }

    #[test]
    fn try_map_reports_lowest_failing_index() {
        let pool = WorkerPool::new(4);
        let jobs: Vec<u32> = (0..16).collect();
        let err = pool
            .try_map(jobs, |&x| {
                if x % 5 == 2 {
                    panic!("boom {x}");
                }
                x
            })
            .unwrap_err();
        match err {
            Error::Job { index, .. } => assert_eq!(index, 2),
            other => panic!("expected Error::Job, got {other}"),
        }
    }

    #[test]
    fn retry_once_recovers_transient_failures() {
        use std::collections::HashSet;
        let pool = WorkerPool::new(4);
        let jobs: Vec<u32> = (0..8).collect();
        // Fails the FIRST attempt for job 6, succeeds on retry.
        let seen = Mutex::new(HashSet::new());
        let out = pool
            .try_scoped_map_retry(&jobs, usize::MAX, true, |&x| {
                if x == 6 && seen.lock().unwrap().insert(x) {
                    panic!("transient");
                }
                x * 10
            })
            .unwrap();
        assert_eq!(out[6], 60);
        assert_eq!(out.len(), 8);
        // A deterministic panic still fails after the retry.
        let err = pool
            .try_scoped_map_retry(&jobs, usize::MAX, true, |&x| {
                if x == 1 {
                    panic!("permanent");
                }
                x
            })
            .unwrap_err();
        assert!(matches!(err, Error::Job { index: 1, .. }), "{err}");
    }

    #[test]
    fn watchdog_times_out_stuck_batch_and_pool_recovers() {
        let pool = WorkerPool::new(4);
        let jobs: Vec<u32> = (0..4).collect();
        let err = pool
            .try_map_watchdog(
                jobs,
                usize::MAX,
                Duration::from_millis(40),
                |&x| {
                    if x == 2 {
                        std::thread::sleep(Duration::from_millis(400));
                    }
                    x
                },
            )
            .unwrap_err();
        assert!(matches!(err, Error::Deadline(_)), "{err}");
        assert!(err.to_string().contains("stalled"), "{err}");
        assert!(pool.respawns() > 0, "stalled workers must be respawned");
        // The pool is immediately usable at full width again.
        assert_eq!(pool.map(vec![1u32, 2, 3], |x| x * 2), vec![2, 4, 6]);
        // Give the stalled job time to finish so the detached thread
        // exits before the test process tears down allocator state.
        std::thread::sleep(Duration::from_millis(450));
    }

    #[test]
    fn watchdog_passes_through_fast_batches_and_panics() {
        let pool = WorkerPool::new(4);
        let out = pool
            .try_map_watchdog(
                (0..32u32).collect(),
                usize::MAX,
                Duration::from_secs(10),
                |&x| x + 1,
            )
            .unwrap();
        assert_eq!(out[31], 32);
        let err = pool
            .try_map_watchdog(
                (0..8u32).collect(),
                usize::MAX,
                Duration::from_secs(10),
                |&x| {
                    if x == 4 {
                        panic!("inside watchdog");
                    }
                    x
                },
            )
            .unwrap_err();
        assert!(matches!(err, Error::Job { index: 4, .. }), "{err}");
    }

    #[test]
    fn watchdog_works_on_width_one_pool() {
        // No background workers: the watchdog path spawns a temp thread
        // so even a width-1 pool cannot hang the caller.
        let pool = WorkerPool::new(1);
        let out = pool
            .try_map_watchdog(
                vec![1u32, 2, 3],
                usize::MAX,
                Duration::from_secs(10),
                |&x| x * 3,
            )
            .unwrap();
        assert_eq!(out, vec![3, 6, 9]);
        let err = pool
            .try_map_watchdog(
                vec![0u32],
                usize::MAX,
                Duration::from_millis(30),
                |_| std::thread::sleep(Duration::from_millis(300)),
            )
            .unwrap_err();
        assert!(matches!(err, Error::Deadline(_)), "{err}");
        std::thread::sleep(Duration::from_millis(350));
    }

    #[test]
    fn heal_is_a_noop_on_a_healthy_pool() {
        let pool = WorkerPool::new(4);
        pool.map((0..8u32).collect(), |&x| x);
        assert_eq!(pool.heal(), 0);
        assert_eq!(pool.respawns(), 0);
    }

    #[test]
    fn concurrent_submitters() {
        let pool = Arc::new(WorkerPool::new(4));
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let p = pool.clone();
            joins.push(std::thread::spawn(move || {
                let jobs: Vec<u64> = (0..100).collect();
                p.map(jobs, move |x| x + t)
            }));
        }
        for (t, j) in joins.into_iter().enumerate() {
            let out = j.join().unwrap();
            assert_eq!(out[99], 99 + t as u64);
        }
    }
}
