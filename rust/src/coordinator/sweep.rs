//! Figure/table drivers: one function per artifact of the paper's
//! evaluation section (SIV-B Fig. 6, SV-B Figs. 8-12, SV-C Fig. 13,
//! SV-D Fig. 15). Each returns a [`FigureData`] that the CLI renders and
//! `rust/benches/` regenerate; EXPERIMENTS.md records paper-vs-measured.

use crate::config::{presets, ClusterConfig};
use crate::error::Result;
use crate::model::inputs::{derive_inputs, EvalOptions, ModelInputs};
use crate::network::CollectiveImpl;
use crate::parallel::{footprint_per_node, model_state_bytes, Strategy, ZeroStage};
use crate::report::FigureData;
use crate::util::units::gb;
use crate::workload::dlrm::Dlrm;
use crate::workload::transformer::Transformer;

use super::Coordinator;

/// The (MP, DP) sweep used throughout SV-B: power-of-two splits of the
/// 1024-node baseline, bounded by the Transformer's 160 attention heads
/// (MP <= 128).
pub fn fig8_strategies() -> Vec<Strategy> {
    Strategy::sweep_bounded(1024, 1, 128)
}

fn t1_inputs(
    s: &Strategy,
    cluster: &ClusterConfig,
    opts: &EvalOptions,
) -> Result<ModelInputs> {
    derive_inputs(&Transformer::t1().build(s)?, cluster, opts)
}

/// Fig. 6: per-node memory footprint of Transformer-1T on 1024 nodes as a
/// function of MP degree, for each ZeRO-DP stage. Pure footprint model (no
/// simulation).
pub fn fig6() -> FigureData {
    let t = Transformer::t1();
    let psi = t.total_params();
    let mut rows = Vec::new();
    for s in Strategy::sweep(1024) {
        let vals: Vec<f64> = ZeroStage::ALL
            .iter()
            .map(|&st| model_state_bytes(psi, s.mp, s.dp, st) / gb(1.0))
            .collect();
        rows.push((s.label(), vals));
    }
    FigureData {
        id: "fig6".into(),
        title: "Per-node model-state footprint, Transformer-1T, 1024 nodes"
            .into(),
        row_label: "(MP, DP)".into(),
        columns: ZeroStage::ALL.iter().map(|s| s.label().to_string()).collect(),
        rows,
        notes: vec![
            "GB per node; mixed-precision Adam (16 B/param baseline)".into(),
        ],
    }
}

/// Fig. 8a: training-time breakdown + per-node footprint across the
/// (MP, DP) sweep, assuming infinite capacity at baseline local bandwidth.
pub fn fig8a(coord: &Coordinator) -> Result<FigureData> {
    let cluster = presets::dgx_a100_1024();
    let opts = EvalOptions {
        ignore_capacity: true,
        ..Default::default()
    };
    let strategies = fig8_strategies();
    let inputs: Vec<ModelInputs> = strategies
        .iter()
        .map(|s| t1_inputs(s, &cluster, &opts))
        .collect::<Result<_>>()?;
    let evals = coord.evaluate_inputs(&inputs)?;

    let best = evals
        .iter()
        .map(|b| b.total())
        .fold(f64::INFINITY, f64::min);
    let mut rows = Vec::new();
    for (s, b) in strategies.iter().zip(&evals) {
        let w = Transformer::t1().build(s)?;
        let fp =
            footprint_per_node(&w, s, ZeroStage::OsG).total() / gb(1.0);
        rows.push((
            s.label(),
            vec![
                b.fp_compute,
                b.fp_exposed_comm,
                b.ig_compute,
                b.ig_exposed_comm,
                b.wg_compute,
                b.wg_exposed_comm,
                b.total(),
                b.total() / best,
                fp,
            ],
        ));
    }
    Ok(FigureData {
        id: "fig8a".into(),
        title: "Transformer-1T runtime breakdown vs (MP, DP)".into(),
        row_label: "(MP, DP)".into(),
        columns: [
            "FP_Compute",
            "FP_Exp_Comm",
            "IG_Compute",
            "IG_Exp_Comm",
            "WG_Compute",
            "WG_Exp_Comm",
            "Total_s",
            "Norm_to_best",
            "Footprint_GB",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect(),
        rows,
        notes: vec![
            "infinite per-node capacity at 2039 GB/s (paper SV-B1)".into(),
            "logical-ring collectives (Table I)".into(),
        ],
    })
}

/// Fig. 8b: compute vs exposed-communication share per strategy.
pub fn fig8b(coord: &Coordinator) -> Result<FigureData> {
    let f = fig8a(coord)?;
    let rows = f
        .rows
        .iter()
        .map(|(label, v)| {
            let compute = v[0] + v[2] + v[4];
            let comm = v[1] + v[3] + v[5];
            let total = compute + comm;
            (label.clone(), vec![compute / total, comm / total])
        })
        .collect();
    Ok(FigureData {
        id: "fig8b".into(),
        title: "Compute vs exposed communication share".into(),
        row_label: "(MP, DP)".into(),
        columns: vec!["Compute_frac".into(), "Exp_Comm_frac".into()],
        rows,
        notes: vec!["fractions of total iteration time".into()],
    })
}

/// Expanded-memory bandwidth sweep columns shared by figs. 9/10/13b, GB/s.
pub const EM_BW_SWEEP: [f64; 7] =
    [250.0, 500.0, 750.0, 1000.0, 1250.0, 1500.0, 2039.0];

/// Fig. 9: speedup heatmap over (strategy x expanded-memory bandwidth),
/// normalized to MP64_DP16 — the best configuration feasible without
/// memory expansion.
pub fn fig9(coord: &Coordinator) -> Result<FigureData> {
    let base_cluster = presets::dgx_a100_1024();
    let opts = EvalOptions::default();

    // Baseline: MP64_DP16 on local memory only.
    let baseline = coord
        .evaluate_inputs(&[t1_inputs(
            &Strategy::new(64, 16),
            &base_cluster,
            &opts,
        )?])?[0]
        .total();

    // Rows: MP128 .. MP2 (paper omits configs that perform strictly worse
    // than the baseline's flank; MP > 128 is unbuildable at 160 heads).
    let strategies: Vec<Strategy> = Strategy::sweep_bounded(1024, 2, 128);
    let mut jobs = Vec::new();
    for s in &strategies {
        let w = Transformer::t1().build(s)?;
        let fp = footprint_per_node(&w, s, ZeroStage::OsG).total();
        for &bw in &EM_BW_SWEEP {
            // Expansion sized to the spill (paper: capacity is the row's
            // requirement; bandwidth is the column).
            let need = (fp - base_cluster.node.local.capacity).max(0.0);
            let cluster = if need > 0.0 {
                base_cluster
                    .with_node(base_cluster.node.with_expanded(need, gb(bw)))
            } else {
                base_cluster.clone()
            };
            jobs.push(derive_inputs(&w, &cluster, &opts)?);
        }
    }
    let evals = coord.evaluate_inputs(&jobs)?;
    let mut rows = Vec::new();
    for (i, s) in strategies.iter().enumerate() {
        let vals: Vec<f64> = (0..EM_BW_SWEEP.len())
            .map(|j| baseline / evals[i * EM_BW_SWEEP.len() + j].total())
            .collect();
        rows.push((s.label(), vals));
    }
    Ok(FigureData {
        id: "fig9".into(),
        title: "Speedup vs expanded-memory bandwidth (Transformer-1T)".into(),
        row_label: "(MP, DP)".into(),
        columns: EM_BW_SWEEP.iter().map(|b| format!("{b:.0}GB/s")).collect(),
        rows,
        notes: vec![
            "speedup over MP64_DP16 on local memory (>1 = memory expansion wins)"
                .into(),
            "EM capacity per row = footprint - 80 GB".into(),
        ],
    })
}

/// Fig. 10: per-node compute-capability scaling at MP8_DP128, for several
/// expanded-memory bandwidths.
pub fn fig10(coord: &Coordinator) -> Result<FigureData> {
    let base_cluster = presets::dgx_a100_1024();
    let s = Strategy::new(8, 128);
    let w = Transformer::t1().build(&s)?;
    let fp = footprint_per_node(&w, &s, ZeroStage::OsG).total();
    let need = (fp - base_cluster.node.local.capacity).max(0.0);
    let opts = EvalOptions::default();
    let scales = [0.25, 0.5, 1.0, 2.0, 4.0, 8.0];
    let bws = [500.0, 1000.0, 1500.0, 2039.0];

    let mut jobs = Vec::new();
    for &sc in &scales {
        for &bw in &bws {
            let node = base_cluster
                .node
                .scale_compute(sc)
                .with_expanded(need, gb(bw));
            jobs.push(derive_inputs(&w, &base_cluster.with_node(node), &opts)?);
        }
    }
    let evals = coord.evaluate_inputs(&jobs)?;
    // Normalize to scale=1 at the highest EM bandwidth.
    let base_idx = scales.iter().position(|&x| x == 1.0).unwrap() * bws.len()
        + (bws.len() - 1);
    let baseline = evals[base_idx].total();
    let rows = scales
        .iter()
        .enumerate()
        .map(|(i, sc)| {
            (
                format!("compute x{sc}"),
                (0..bws.len())
                    .map(|j| evals[i * bws.len() + j].total() / baseline)
                    .collect(),
            )
        })
        .collect();
    Ok(FigureData {
        id: "fig10".into(),
        title: "Compute-capability scaling at MP8_DP128".into(),
        row_label: "node compute".into(),
        columns: bws.iter().map(|b| format!("EM@{b:.0}GB/s")).collect(),
        rows,
        notes: vec![
            "runtime normalized to baseline A100 (x1) at EM 2039 GB/s".into(),
        ],
    })
}

/// Fig. 11: intra-/inter-pod bandwidth scaling grid for the
/// communication-bound (MP64_DP16) and compute-bound (MP8_DP128) configs.
/// Hierarchical collectives, as in the paper's network study.
pub fn fig11(coord: &Coordinator) -> Result<FigureData> {
    let base_cluster = presets::dgx_a100_1024();
    let opts = EvalOptions {
        ignore_capacity: true,
        collective_impl: CollectiveImpl::Hierarchical,
        ..Default::default()
    };
    let factors = [0.5, 1.0, 2.0, 4.0];
    let configs = [Strategy::new(64, 16), Strategy::new(8, 128)];

    let mut rows = Vec::new();
    for s in &configs {
        let w = Transformer::t1().build(s)?;
        let base = coord
            .evaluate_inputs(&[derive_inputs(&w, &base_cluster, &opts)?])?[0]
            .total();
        for &fi in &factors {
            let mut jobs = Vec::new();
            for &fx in &factors {
                let cluster = base_cluster.scale_network(fi, fx);
                jobs.push(derive_inputs(&w, &cluster, &opts)?);
            }
            let evals = coord.evaluate_inputs(&jobs)?;
            rows.push((
                format!("{} intra x{fi}", s.label()),
                evals.iter().map(|b| base / b.total()).collect(),
            ));
        }
    }
    Ok(FigureData {
        id: "fig11".into(),
        title: "Network bandwidth scaling (speedup over baseline)".into(),
        row_label: "config / intra factor".into(),
        columns: factors.iter().map(|f| format!("inter x{f}")).collect(),
        rows,
        notes: vec![
            "hierarchical collectives; baseline 300/31.25 GB/s".into(),
            "infinite-capacity memory (network isolated)".into(),
        ],
    })
}

/// Fig. 12: rebalancing a fixed aggregate per-node bandwidth between
/// intra- and inter-pod links.
pub fn fig12(coord: &Coordinator) -> Result<FigureData> {
    let base_cluster = presets::dgx_a100_1024();
    let opts = EvalOptions {
        ignore_capacity: true,
        collective_impl: CollectiveImpl::Hierarchical,
        ..Default::default()
    };
    let ratios = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 8.0, 9.6, 12.0, 16.0, 24.0];
    let configs = [Strategy::new(64, 16), Strategy::new(8, 128)];

    // Baseline: the stock 1:9.6 split.
    let mut baselines = Vec::new();
    for s in &configs {
        let w = Transformer::t1().build(s)?;
        baselines.push(
            coord
                .evaluate_inputs(&[derive_inputs(&w, &base_cluster, &opts)?])?
                [0]
                .total(),
        );
    }

    let mut rows = Vec::new();
    for &r in &ratios {
        let cluster = base_cluster.rebalance_network(r)?;
        let mut vals = Vec::new();
        for (s, base) in configs.iter().zip(&baselines) {
            let w = Transformer::t1().build(s)?;
            let t = coord
                .evaluate_inputs(&[derive_inputs(&w, &cluster, &opts)?])?[0]
                .total();
            vals.push(base / t);
        }
        rows.push((format!("1:{r}"), vals));
    }
    Ok(FigureData {
        id: "fig12".into(),
        title: "Fixed-aggregate inter:intra bandwidth rebalancing".into(),
        row_label: "inter:intra ratio".into(),
        columns: configs.iter().map(|s| s.label()).collect(),
        rows,
        notes: vec![
            "aggregate 331.25 GB/s per node; speedup vs stock 1:9.6".into(),
        ],
    })
}

/// Fig. 13a: DLRM-1.2T breakdown + footprint vs cluster size.
pub fn fig13a(coord: &Coordinator) -> Result<FigureData> {
    let d = Dlrm::dlrm_1_2t();
    let mut rows = Vec::new();
    let mut base_total = f64::NAN;
    for &n in &[64usize, 32, 16, 8] {
        let w = d.build(n)?;
        // Paper normalizes to a 2 TB/s memory system: expanded memory
        // sized to the spill at 2 TB/s. DLRM's footprint is its embedding
        // shard (not the generic transformer ZeRO formula).
        let fp = d.footprint_per_node(n);
        let opts = EvalOptions {
            footprint_override: Some(fp),
            ..Default::default()
        };
        let mut cluster = presets::dgx_a100_64().with_n_nodes(n);
        let need = (fp - cluster.node.local.capacity).max(0.0);
        if need > 0.0 {
            cluster.node = cluster.node.with_expanded(need, 2e12);
        }
        let b = coord.evaluate_inputs(&[derive_inputs(&w, &cluster, &opts)?])?[0];
        if n == 64 {
            base_total = b.total();
        }
        rows.push((
            format!("{n} nodes"),
            vec![
                b.fp_compute,
                b.fp_exposed_comm,
                b.ig_compute,
                b.ig_exposed_comm,
                b.wg_compute,
                b.wg_exposed_comm,
                b.total(),
                b.total() / base_total,
                fp / gb(1.0),
            ],
        ));
    }
    Ok(FigureData {
        id: "fig13a".into(),
        title: "DLRM-1.2T breakdown vs cluster size".into(),
        row_label: "cluster".into(),
        columns: [
            "FP_Compute",
            "FP_Exp_Comm",
            "IG_Compute",
            "IG_Exp_Comm",
            "WG_Compute",
            "WG_Exp_Comm",
            "Total_s",
            "Norm_to_64",
            "Footprint_GB",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect(),
        rows,
        notes: vec!["expanded memory at 2 TB/s where the shard spills".into()],
    })
}

/// Fig. 13b: turnaround of training 8 DLRMs on 64 GPUs vs expanded-memory
/// bandwidth, for different nodes-per-instance packings.
pub fn fig13b(coord: &Coordinator) -> Result<FigureData> {
    let d = Dlrm::dlrm_1_2t();
    let total_nodes = 64usize;
    let instances = 8.0;

    // Baseline: 8 sequential waves of 64-node instances on local memory.
    let w64 = d.build(64)?;
    let base = coord
        .evaluate_inputs(&[derive_inputs(
            &w64,
            &presets::dgx_a100_64(),
            &EvalOptions {
                footprint_override: Some(d.footprint_per_node(64)),
                ..Default::default()
            },
        )?])?[0]
        .total()
        * instances;

    let mut rows = Vec::new();
    for &n in &[32usize, 16, 8] {
        let w = d.build(n)?;
        let fp = d.footprint_per_node(n);
        let opts = EvalOptions {
            footprint_override: Some(fp),
            ..Default::default()
        };
        let waves =
            (instances * n as f64 / total_nodes as f64).max(1.0).ceil();
        let vals: Vec<f64> = EM_BW_SWEEP
            .iter()
            .map(|&bw| {
                let mut cluster = presets::dgx_a100_64().with_n_nodes(n);
                let need = (fp - cluster.node.local.capacity).max(0.0);
                cluster.node = cluster.node.with_expanded(need, gb(bw));
                let t = coord
                    .evaluate_inputs(&[derive_inputs(&w, &cluster, &opts)
                        .unwrap()])
                    .unwrap()[0]
                    .total();
                base / (t * waves)
            })
            .collect();
        rows.push((format!("{n} nodes/instance"), vals));
    }
    Ok(FigureData {
        id: "fig13b".into(),
        title: "8-DLRM turnaround vs expanded-memory bandwidth".into(),
        row_label: "packing".into(),
        columns: EM_BW_SWEEP.iter().map(|b| format!("{b:.0}GB/s")).collect(),
        rows,
        notes: vec![
            "speedup over 8 sequential waves of 64-node instances on local memory"
                .into(),
        ],
    })
}

/// Best feasible Transformer-1T strategy on a cluster (capacity-aware) and
/// its iteration time.
fn best_transformer_time(
    coord: &Coordinator,
    cluster: &ClusterConfig,
) -> Result<f64> {
    let t = Transformer::t1();
    let opts = EvalOptions::default();
    let max_mp = 128.min(cluster.n_nodes);
    let mut jobs = Vec::new();
    for s in Strategy::sweep_bounded(cluster.n_nodes, 1, max_mp) {
        let w = t.build(&s)?;
        let fp = footprint_per_node(&w, &s, ZeroStage::OsG).total();
        // Infeasible if the footprint exceeds total (local + expanded)
        // capacity per node.
        if fp > cluster.node.total_capacity() {
            continue;
        }
        jobs.push(derive_inputs(&w, cluster, &opts)?);
    }
    if jobs.is_empty() {
        return Ok(f64::NAN);
    }
    let evals = coord.evaluate_inputs(&jobs)?;
    Ok(evals
        .iter()
        .map(|b| b.total())
        .fold(f64::INFINITY, f64::min))
}

/// DLRM nodes-per-instance for fig. 15, per the paper: GPU clusters use
/// 64 / 16 / 8 nodes for memory systems 0 / 1 / 2; TPU/Dojo use the
/// smallest power-of-two whose shard fits per-node capacity.
fn dlrm_nodes_per_instance(cluster: &ClusterConfig, d: &Dlrm) -> usize {
    match cluster.name.as_str() {
        "A0" | "B0" | "C0" => 64,
        "A1" | "B1" | "C1" => 16,
        "A2" | "B2" | "C2" => 8,
        _ => {
            let mut n = 1usize;
            while n < cluster.n_nodes
                && d.footprint_per_node(n) > cluster.node.total_capacity()
            {
                n *= 2;
            }
            n
        }
    }
}

/// Fig. 15: eleven-cluster comparison (Table III) on DLRM and
/// Transformer-1T, speedups normalized to cluster A0.
pub fn fig15(coord: &Coordinator) -> Result<FigureData> {
    let d = Dlrm::dlrm_1_2t();
    let clusters = presets::table3_all();
    let instances = 8.0;

    let mut dlrm_times = Vec::new();
    let mut tf_times = Vec::new();
    for cluster in &clusters {
        // DLRM: 8 instances, waves over a 64-node partition for GPU
        // clusters (SV-C setup) or the full fabric for TPU/Dojo.
        let pool = cluster.n_nodes.min(64);
        let n_i = dlrm_nodes_per_instance(cluster, &d).min(pool);
        let waves = (instances * n_i as f64 / pool as f64).max(1.0).ceil();
        let sub = cluster.with_n_nodes(n_i);
        let w = d.build(n_i)?;
        let opts = EvalOptions {
            footprint_override: Some(d.footprint_per_node(n_i)),
            ..Default::default()
        };
        let t = coord
            .evaluate_inputs(&[derive_inputs(&w, &sub, &opts)?])?[0]
            .total();
        dlrm_times.push(t * waves);

        tf_times.push(best_transformer_time(coord, cluster)?);
    }

    let rows = clusters
        .iter()
        .enumerate()
        .map(|(i, c)| {
            (
                c.name.clone(),
                vec![
                    dlrm_times[0] / dlrm_times[i],
                    tf_times[0] / tf_times[i],
                ],
            )
        })
        .collect();
    Ok(FigureData {
        id: "fig15".into(),
        title: "Cluster comparison (speedup vs A0)".into(),
        row_label: "cluster".into(),
        columns: vec!["DLRM_x8".into(), "Transformer-1T".into()],
        rows,
        notes: vec![
            "DLRM: 8 instances on a 64-node partition (TPU/Dojo: native packing)"
                .into(),
            "Transformer: best feasible (MP, DP) per cluster".into(),
        ],
    })
}

/// Ablation (DESIGN.md S6): how much of Fig. 8's shape is due to the
/// collective implementation? Reruns the strategy sweep under Table I's
/// logical ring vs the hierarchical (BlueConnect/Themis) collectives.
/// Shows the paper's left flank collapsing when pods are bridged
/// hierarchically — i.e. MP8's dominance is a *topology-awareness*
/// artifact, one of the design insights the methodology surfaces.
pub fn ablation_collectives(coord: &Coordinator) -> Result<FigureData> {
    let cluster = presets::dgx_a100_1024();
    let strategies = fig8_strategies();
    let mut rows = Vec::new();
    for s in &strategies {
        let w = Transformer::t1().build(s)?;
        let mut vals = Vec::new();
        for impl_ in [CollectiveImpl::LogicalRing, CollectiveImpl::Hierarchical]
        {
            let opts = EvalOptions {
                ignore_capacity: true,
                collective_impl: impl_,
                ..Default::default()
            };
            let inp = derive_inputs(&w, &cluster, &opts)?;
            vals.push(
                coord.evaluate_inputs(std::slice::from_ref(&inp))?[0].total(),
            );
        }
        vals.push(vals[0] / vals[1]); // ring / hierarchical
        rows.push((s.label(), vals));
    }
    Ok(FigureData {
        id: "ablation-collectives".into(),
        title: "Ablation: logical-ring vs hierarchical collectives".into(),
        row_label: "(MP, DP)".into(),
        columns: vec![
            "ring_total_s".into(),
            "hier_total_s".into(),
            "ring/hier".into(),
        ],
        rows,
        notes: vec![
            "Transformer-1T, infinite-capacity memory; Fig. 8 sweep".into(),
        ],
    })
}

/// Ablation: ZeRO stage choice. Per-node footprint AND iteration time for
/// the Fig. 8 sweep under each ZeRO stage (stage 3 pays its 1.5x DP
/// communication-volume penalty on the WG reduce-scatter).
pub fn ablation_zero(coord: &Coordinator) -> Result<FigureData> {
    let cluster = presets::dgx_a100_1024();
    let mut rows = Vec::new();
    for s in [Strategy::new(64, 16), Strategy::new(8, 128)] {
        let base = Transformer::t1().build(&s)?;
        for stage in ZeroStage::ALL {
            let mut w = base.clone();
            // Stage 3's extra parameter all-gather: scale the DP-scope
            // collective payloads by the stage's volume multiplier.
            for l in &mut w.layers {
                if l.comm_wg.scope == crate::workload::CommScope::Dp {
                    l.comm_wg.bytes *= stage.comm_multiplier();
                }
            }
            let opts = EvalOptions {
                zero_stage: stage,
                ignore_capacity: true,
                ..Default::default()
            };
            let fp = footprint_per_node(&w, &s, stage).total() / gb(1.0);
            let inp = derive_inputs(&w, &cluster, &opts)?;
            let b = coord.evaluate_inputs(std::slice::from_ref(&inp))?[0];
            rows.push((
                format!("{} {}", s.label(), stage.label()),
                vec![fp, b.total(), b.wg_exposed_comm],
            ));
        }
    }
    Ok(FigureData {
        id: "ablation-zero".into(),
        title: "Ablation: ZeRO stage (footprint vs comm overhead)".into(),
        row_label: "config".into(),
        columns: vec![
            "Footprint_GB".into(),
            "Total_s".into(),
            "WG_Exp_Comm_s".into(),
        ],
        rows,
        notes: vec!["stage-3 DP payloads scaled by 1.5x (ZeRO paper)".into()],
    })
}

/// All figures in paper order.
pub fn all_figures(coord: &Coordinator) -> Result<Vec<FigureData>> {
    Ok(vec![
        fig6(),
        fig8a(coord)?,
        fig8b(coord)?,
        fig9(coord)?,
        fig10(coord)?,
        fig11(coord)?,
        fig12(coord)?,
        fig13a(coord)?,
        fig13b(coord)?,
        fig15(coord)?,
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coord() -> Coordinator {
        Coordinator::native()
    }

    #[test]
    fn fig6_zero3_flat_and_baseline_steep() {
        let f = fig6();
        let z3_hi = f.cell("MP1024_DP1", "zero-3").unwrap();
        let z3_lo = f.cell("MP1_DP1024", "zero-3").unwrap();
        assert!((z3_hi - z3_lo).abs() < 1e-6);
        let b_hi = f.cell("MP1024_DP1", "baseline").unwrap();
        let b_lo = f.cell("MP1_DP1024", "baseline").unwrap();
        assert!((b_lo / b_hi - 1024.0).abs() < 1.0);
    }

    #[test]
    fn fig8a_best_is_mp8() {
        let f = fig8a(&coord()).unwrap();
        assert_eq!(f.argmin("Total_s"), Some("MP8_DP128"));
        // Footprint at MP8 is ~3.3x the 80 GB local capacity.
        let fp = f.cell("MP8_DP128", "Footprint_GB").unwrap();
        assert!((250.0..330.0).contains(&fp), "{fp}");
    }

    #[test]
    fn fig9_crossover_exists() {
        let f = fig9(&coord()).unwrap();
        // MP8_DP128 must lose at 250 GB/s and win at some higher bandwidth
        // (the paper's Ex.1: >= ~500 GB/s makes expansion worthwhile).
        let lo = f.cell("MP8_DP128", "250GB/s").unwrap();
        let hi = f.cell("MP8_DP128", "2039GB/s").unwrap();
        assert!(lo < 1.0, "{lo}");
        assert!(hi > 1.0, "{hi}");
    }

    #[test]
    fn fig13a_sublinear() {
        let f = fig13a(&coord()).unwrap();
        let n32 = f.cell("32 nodes", "Norm_to_64").unwrap();
        let n16 = f.cell("16 nodes", "Norm_to_64").unwrap();
        assert!(n32 < 2.0, "{n32}");
        assert!(n16 < 4.0, "{n16}");
        assert!(n32 > 1.0 && n16 > n32);
    }

    #[test]
    fn fig15_c0_beats_a0() {
        let f = fig15(&coord()).unwrap();
        let c0 = f.cell("C0", "Transformer-1T").unwrap();
        assert!(c0 > 2.0, "C0 speedup {c0}");
        let a0 = f.cell("A0", "Transformer-1T").unwrap();
        assert!((a0 - 1.0).abs() < 1e-9);
    }
}
