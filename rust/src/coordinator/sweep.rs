//! Figure/table drivers: one function per artifact of the paper's
//! evaluation section (SIV-B Fig. 6, SV-B Figs. 8-12, SV-C Fig. 13,
//! SV-D Fig. 15). Each returns a [`FigureData`] that the CLI renders and
//! `rust/benches/` regenerate; EXPERIMENTS.md records paper-vs-measured.
//!
//! Every driver follows the same batched shape: build the figure's full
//! (workload, cluster, options) grid up front, resolve the grid to model
//! inputs concurrently through the coordinator's worker pool
//! ([`Coordinator::derive_batch`]), and make **exactly one**
//! [`Coordinator::evaluate_inputs`] call — normalization baselines ride in
//! the same batch as the sweep points. [`GridSweep`] packages the common
//! strategy x bandwidth x capacity x collective-impl cross-product so new
//! case studies get the batched path for free.
//!
//! Every figure here is also expressible as a declarative spec — see
//! [`crate::scenario`] and the checked-in `scenarios/*.toml`. These
//! hand-written drivers are retained as the **equivalence oracle**: the
//! scenario engine's built-in specs are pinned to them cell-for-cell by
//! `tests/scenario_roundtrip.rs`, so either path is authoritative and new
//! studies should be written as scenario files, not new drivers.

use std::ops::Range;

use crate::config::{presets, ClusterConfig};
use crate::error::Result;
use crate::model::inputs::EvalOptions;
use crate::network::CollectiveImpl;
use crate::parallel::{
    footprint_per_node, model_state_bytes, pipeline_footprint_per_node,
    Strategy, ZeroStage,
};
use crate::report::FigureData;
use crate::util::units::gb;
use crate::workload::dlrm::Dlrm;
use crate::workload::transformer::Transformer;
use crate::workload::Workload;

use super::Coordinator;

/// One evaluation job of a figure grid, as consumed by
/// [`Coordinator::derive_batch`].
pub type SweepSpec = (Workload, ClusterConfig, EvalOptions);

/// A cross-product sweep over the paper's four cluster-design axes:
/// parallelization strategy, expanded-memory bandwidth, expanded-memory
/// capacity, and collective implementation. Axes default to a single
/// "baseline" point, so a driver only names the dimensions it sweeps.
#[derive(Debug, Clone)]
pub struct GridSweep {
    strategies: Vec<Strategy>,
    /// Expanded-memory bandwidths, bytes/s. `None` = local memory only.
    em_bandwidths: Vec<Option<f64>>,
    /// Expanded-memory capacities, bytes. `None` = sized to the spill.
    em_capacities: Vec<Option<f64>>,
    collective_impls: Vec<CollectiveImpl>,
}

/// One resolved point of a [`GridSweep`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GridPoint {
    /// Parallelization strategy of this point.
    pub strategy: Strategy,
    /// Expanded-memory bandwidth, bytes/s (`None` = local memory only).
    pub em_bandwidth: Option<f64>,
    /// Expanded-memory capacity, bytes (`None` = sized to the spill).
    pub em_capacity: Option<f64>,
    /// Collective implementation of this point.
    pub collective_impl: CollectiveImpl,
}

impl GridSweep {
    /// A sweep over `strategies` with every other axis at its baseline:
    /// local memory only, spill-sized capacity, logical-ring collectives.
    pub fn new(strategies: Vec<Strategy>) -> GridSweep {
        GridSweep {
            strategies,
            em_bandwidths: vec![None],
            em_capacities: vec![None],
            collective_impls: vec![CollectiveImpl::LogicalRing],
        }
    }

    /// Sweep expanded-memory bandwidth (bytes/s).
    pub fn em_bandwidths(mut self, bws: &[f64]) -> GridSweep {
        self.em_bandwidths = bws.iter().map(|&b| Some(b)).collect();
        self
    }

    /// Sweep expanded-memory capacity (bytes) instead of sizing it to the
    /// spill.
    pub fn em_capacities(mut self, caps: &[f64]) -> GridSweep {
        self.em_capacities = caps.iter().map(|&c| Some(c)).collect();
        self
    }

    /// Sweep collective implementations.
    pub fn collective_impls(mut self, impls: &[CollectiveImpl]) -> GridSweep {
        self.collective_impls = impls.to_vec();
        self
    }

    /// Number of grid points (full cross-product).
    pub fn len(&self) -> usize {
        self.strategies.len()
            * self.em_bandwidths.len()
            * self.em_capacities.len()
            * self.collective_impls.len()
    }

    /// Whether the grid is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enumerate the cross-product, row-major: strategy outermost, then
    /// bandwidth, then capacity, then collective implementation. The same
    /// order [`GridSweep::specs`] emits jobs in.
    pub fn points(&self) -> Vec<GridPoint> {
        let mut out = Vec::with_capacity(self.len());
        for &strategy in &self.strategies {
            for &em_bandwidth in &self.em_bandwidths {
                for &em_capacity in &self.em_capacities {
                    for &collective_impl in &self.collective_impls {
                        out.push(GridPoint {
                            strategy,
                            em_bandwidth,
                            em_capacity,
                            collective_impl,
                        });
                    }
                }
            }
        }
        out
    }

    /// Resolve the grid into evaluation jobs against a base cluster:
    /// `build` constructs the workload per strategy, expanded memory is
    /// attached when the point names a bandwidth (capacity from the point,
    /// or sized to the strategy's spill over local capacity), and the
    /// point's collective implementation overrides `opts`.
    pub fn specs<F>(
        &self,
        base: &ClusterConfig,
        opts: &EvalOptions,
        build: F,
    ) -> Result<Vec<SweepSpec>>
    where
        F: Fn(&Strategy) -> Result<Workload>,
    {
        // Capacity is an attribute of the expanded memory: sweeping it
        // without any bandwidth point would silently collapse every
        // capacity point onto the base cluster.
        if self.em_capacities.iter().any(|c| c.is_some())
            && self.em_bandwidths.iter().all(|b| b.is_none())
        {
            return Err(crate::error::Error::Config(
                "GridSweep sweeps em_capacities without em_bandwidths; \
                 expanded-memory capacity needs a bandwidth axis"
                    .into(),
            ));
        }
        let mut out = Vec::with_capacity(self.len());
        for s in &self.strategies {
            let w = build(s)?;
            // Pipeline-aware footprint (identical to footprint_per_node
            // on the pp = 1 slice) so 3D strategies size their expanded
            // memory to the worst stage's spill.
            let fp = pipeline_footprint_per_node(
                &w,
                opts.zero_stage,
                opts.pipe_schedule,
                opts.microbatches,
            );
            let spill = (fp - base.node.local.capacity).max(0.0);
            for &bw in &self.em_bandwidths {
                for &cap in &self.em_capacities {
                    for &ci in &self.collective_impls {
                        let o = EvalOptions {
                            collective_impl: ci,
                            ..*opts
                        };
                        let cluster = match bw {
                            Some(b) => {
                                let need = cap.unwrap_or(spill);
                                if need > 0.0 {
                                    base.with_node(
                                        base.node.with_expanded(need, b),
                                    )
                                } else {
                                    base.clone()
                                }
                            }
                            None => base.clone(),
                        };
                        out.push((w.clone(), cluster, o));
                    }
                }
            }
        }
        Ok(out)
    }
}

/// The (MP, DP) sweep used throughout SV-B: power-of-two splits of the
/// 1024-node baseline, bounded by the Transformer's 160 attention heads
/// (MP <= 128).
pub fn fig8_strategies() -> Vec<Strategy> {
    Strategy::sweep_bounded(1024, 1, 128)
        .expect("1024 is a power of two")
}

/// Fig. 6: per-node memory footprint of Transformer-1T on 1024 nodes as a
/// function of MP degree, for each ZeRO-DP stage. Pure footprint model (no
/// simulation).
pub fn fig6() -> FigureData {
    let t = Transformer::t1();
    let psi = t.total_params();
    let mut rows = Vec::new();
    for s in Strategy::sweep(1024).expect("1024 is a power of two") {
        let vals: Vec<f64> = ZeroStage::ALL
            .iter()
            .map(|&st| model_state_bytes(psi, s.mp, s.dp, st) / gb(1.0))
            .collect();
        rows.push((s.label(), vals));
    }
    FigureData {
        id: "fig6".into(),
        title: "Per-node model-state footprint, Transformer-1T, 1024 nodes"
            .into(),
        row_label: "(MP, DP)".into(),
        columns: ZeroStage::ALL.iter().map(|s| s.label().to_string()).collect(),
        rows,
        notes: vec![
            "GB per node; mixed-precision Adam (16 B/param baseline)".into(),
        ],
    }
}

/// Fig. 8a: training-time breakdown + per-node footprint across the
/// (MP, DP) sweep, assuming infinite capacity at baseline local bandwidth.
pub fn fig8a(coord: &Coordinator) -> Result<FigureData> {
    let cluster = presets::dgx_a100_1024();
    let opts = EvalOptions {
        ignore_capacity: true,
        ..Default::default()
    };
    let strategies = fig8_strategies();
    let mut footprints = Vec::with_capacity(strategies.len());
    let mut specs: Vec<SweepSpec> = Vec::with_capacity(strategies.len());
    for s in &strategies {
        let w = Transformer::t1().build(s)?;
        footprints
            .push(footprint_per_node(&w, s, ZeroStage::OsG).total() / gb(1.0));
        specs.push((w, cluster.clone(), opts));
    }
    let inputs = coord.derive_batch(specs)?;
    let evals = coord.evaluate_inputs(&inputs)?;

    let best = evals
        .iter()
        .map(|b| b.total())
        .fold(f64::INFINITY, f64::min);
    let mut rows = Vec::new();
    for ((s, b), fp) in strategies.iter().zip(&evals).zip(&footprints) {
        rows.push((
            s.label(),
            vec![
                b.fp_compute,
                b.fp_exposed_comm,
                b.ig_compute,
                b.ig_exposed_comm,
                b.wg_compute,
                b.wg_exposed_comm,
                b.total(),
                b.total() / best,
                *fp,
            ],
        ));
    }
    Ok(FigureData {
        id: "fig8a".into(),
        title: "Transformer-1T runtime breakdown vs (MP, DP)".into(),
        row_label: "(MP, DP)".into(),
        columns: [
            "FP_Compute",
            "FP_Exp_Comm",
            "IG_Compute",
            "IG_Exp_Comm",
            "WG_Compute",
            "WG_Exp_Comm",
            "Total_s",
            "Norm_to_best",
            "Footprint_GB",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect(),
        rows,
        notes: vec![
            "infinite per-node capacity at 2039 GB/s (paper SV-B1)".into(),
            "logical-ring collectives (Table I)".into(),
        ],
    })
}

/// Fig. 8b: compute vs exposed-communication share per strategy.
pub fn fig8b(coord: &Coordinator) -> Result<FigureData> {
    let f = fig8a(coord)?;
    let rows = f
        .rows
        .iter()
        .map(|(label, v)| {
            let compute = v[0] + v[2] + v[4];
            let comm = v[1] + v[3] + v[5];
            let total = compute + comm;
            (label.clone(), vec![compute / total, comm / total])
        })
        .collect();
    Ok(FigureData {
        id: "fig8b".into(),
        title: "Compute vs exposed communication share".into(),
        row_label: "(MP, DP)".into(),
        columns: vec!["Compute_frac".into(), "Exp_Comm_frac".into()],
        rows,
        notes: vec!["fractions of total iteration time".into()],
    })
}

/// Expanded-memory bandwidth sweep columns shared by figs. 9/10/13b, GB/s.
pub const EM_BW_SWEEP: [f64; 7] =
    [250.0, 500.0, 750.0, 1000.0, 1250.0, 1500.0, 2039.0];

/// Fig. 9: speedup heatmap over (strategy x expanded-memory bandwidth),
/// normalized to MP64_DP16 — the best configuration feasible without
/// memory expansion. The baseline rides in the same batch as the grid.
pub fn fig9(coord: &Coordinator) -> Result<FigureData> {
    let base_cluster = presets::dgx_a100_1024();
    let opts = EvalOptions::default();

    // Rows: MP128 .. MP2 (paper omits configs that perform strictly worse
    // than the baseline's flank; MP > 128 is unbuildable at 160 heads).
    // Columns: the shared EM bandwidth sweep, expansion sized to each
    // row's spill.
    let strategies = Strategy::sweep_bounded(1024, 2, 128)?;
    let grid = GridSweep::new(strategies.clone())
        .em_bandwidths(&EM_BW_SWEEP.map(gb));

    // Job 0: MP64_DP16 on local memory only (the normalization baseline).
    let mut specs: Vec<SweepSpec> = vec![(
        Transformer::t1().build(&Strategy::new(64, 16)?)?,
        base_cluster.clone(),
        opts,
    )];
    specs.extend(grid.specs(&base_cluster, &opts, |s| {
        Transformer::t1().build(s)
    })?);

    let inputs = coord.derive_batch(specs)?;
    let evals = coord.evaluate_inputs(&inputs)?;
    let baseline = evals[0].total();
    let width = EM_BW_SWEEP.len();
    let mut rows = Vec::new();
    for (i, s) in strategies.iter().enumerate() {
        let vals: Vec<f64> = (0..width)
            .map(|j| baseline / evals[1 + i * width + j].total())
            .collect();
        rows.push((s.label(), vals));
    }
    Ok(FigureData {
        id: "fig9".into(),
        title: "Speedup vs expanded-memory bandwidth (Transformer-1T)".into(),
        row_label: "(MP, DP)".into(),
        columns: EM_BW_SWEEP.iter().map(|b| format!("{b:.0}GB/s")).collect(),
        rows,
        notes: vec![
            "speedup over MP64_DP16 on local memory (>1 = memory expansion wins)"
                .into(),
            "EM capacity per row = footprint - 80 GB".into(),
        ],
    })
}

/// Fig. 10: per-node compute-capability scaling at MP8_DP128, for several
/// expanded-memory bandwidths.
pub fn fig10(coord: &Coordinator) -> Result<FigureData> {
    let base_cluster = presets::dgx_a100_1024();
    let s = Strategy::new(8, 128)?;
    let w = Transformer::t1().build(&s)?;
    let fp = footprint_per_node(&w, &s, ZeroStage::OsG).total();
    let need = (fp - base_cluster.node.local.capacity).max(0.0);
    let opts = EvalOptions::default();
    let scales = [0.25, 0.5, 1.0, 2.0, 4.0, 8.0];
    let bws = [500.0, 1000.0, 1500.0, 2039.0];

    let mut specs: Vec<SweepSpec> =
        Vec::with_capacity(scales.len() * bws.len());
    for &sc in &scales {
        for &bw in &bws {
            let node = base_cluster
                .node
                .scale_compute(sc)
                .with_expanded(need, gb(bw));
            specs.push((w.clone(), base_cluster.with_node(node), opts));
        }
    }
    let inputs = coord.derive_batch(specs)?;
    let evals = coord.evaluate_inputs(&inputs)?;
    // Normalize to scale=1 at the highest EM bandwidth.
    let base_idx = scales.iter().position(|&x| x == 1.0).unwrap() * bws.len()
        + (bws.len() - 1);
    let baseline = evals[base_idx].total();
    let rows = scales
        .iter()
        .enumerate()
        .map(|(i, sc)| {
            (
                format!("compute x{sc}"),
                (0..bws.len())
                    .map(|j| evals[i * bws.len() + j].total() / baseline)
                    .collect(),
            )
        })
        .collect();
    Ok(FigureData {
        id: "fig10".into(),
        title: "Compute-capability scaling at MP8_DP128".into(),
        row_label: "node compute".into(),
        columns: bws.iter().map(|b| format!("EM@{b:.0}GB/s")).collect(),
        rows,
        notes: vec![
            "runtime normalized to baseline A100 (x1) at EM 2039 GB/s".into(),
        ],
    })
}

/// Fig. 11: intra-/inter-pod bandwidth scaling grid for the
/// communication-bound (MP64_DP16) and compute-bound (MP8_DP128) configs.
/// Hierarchical collectives, as in the paper's network study.
pub fn fig11(coord: &Coordinator) -> Result<FigureData> {
    let base_cluster = presets::dgx_a100_1024();
    let opts = EvalOptions {
        ignore_capacity: true,
        collective_impl: CollectiveImpl::Hierarchical,
        ..Default::default()
    };
    let factors = [0.5, 1.0, 2.0, 4.0];
    let configs = [Strategy::new(64, 16)?, Strategy::new(8, 128)?];

    // Per config: one baseline job + the full factor x factor grid.
    let block = 1 + factors.len() * factors.len();
    let mut specs: Vec<SweepSpec> =
        Vec::with_capacity(configs.len() * block);
    for s in &configs {
        let w = Transformer::t1().build(s)?;
        specs.push((w.clone(), base_cluster.clone(), opts));
        for &fi in &factors {
            for &fx in &factors {
                specs.push((
                    w.clone(),
                    base_cluster.scale_network(fi, fx),
                    opts,
                ));
            }
        }
    }
    let inputs = coord.derive_batch(specs)?;
    let evals = coord.evaluate_inputs(&inputs)?;

    let mut rows = Vec::new();
    for (ci, s) in configs.iter().enumerate() {
        let base = evals[ci * block].total();
        for (i, fi) in factors.iter().enumerate() {
            rows.push((
                format!("{} intra x{fi}", s.label()),
                (0..factors.len())
                    .map(|j| {
                        base / evals[ci * block + 1 + i * factors.len() + j]
                            .total()
                    })
                    .collect(),
            ));
        }
    }
    Ok(FigureData {
        id: "fig11".into(),
        title: "Network bandwidth scaling (speedup over baseline)".into(),
        row_label: "config / intra factor".into(),
        columns: factors.iter().map(|f| format!("inter x{f}")).collect(),
        rows,
        notes: vec![
            "hierarchical collectives; baseline 300/31.25 GB/s".into(),
            "infinite-capacity memory (network isolated)".into(),
        ],
    })
}

/// Fig. 12: rebalancing a fixed aggregate per-node bandwidth between
/// intra- and inter-pod links.
pub fn fig12(coord: &Coordinator) -> Result<FigureData> {
    let base_cluster = presets::dgx_a100_1024();
    let opts = EvalOptions {
        ignore_capacity: true,
        collective_impl: CollectiveImpl::Hierarchical,
        ..Default::default()
    };
    let ratios = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 8.0, 9.6, 12.0, 16.0, 24.0];
    let configs = [Strategy::new(64, 16)?, Strategy::new(8, 128)?];
    let nc = configs.len();

    // Jobs 0..nc: the stock 1:9.6 baselines; then ratio-major grid.
    let mut specs: Vec<SweepSpec> =
        Vec::with_capacity(nc * (1 + ratios.len()));
    for s in &configs {
        specs.push((Transformer::t1().build(s)?, base_cluster.clone(), opts));
    }
    for &r in &ratios {
        let cluster = base_cluster.rebalance_network(r)?;
        for s in &configs {
            specs.push((Transformer::t1().build(s)?, cluster.clone(), opts));
        }
    }
    let inputs = coord.derive_batch(specs)?;
    let evals = coord.evaluate_inputs(&inputs)?;

    let mut rows = Vec::new();
    for (ri, r) in ratios.iter().enumerate() {
        let vals: Vec<f64> = (0..nc)
            .map(|ci| evals[ci].total() / evals[nc + ri * nc + ci].total())
            .collect();
        rows.push((format!("1:{r}"), vals));
    }
    Ok(FigureData {
        id: "fig12".into(),
        title: "Fixed-aggregate inter:intra bandwidth rebalancing".into(),
        row_label: "inter:intra ratio".into(),
        columns: configs.iter().map(|s| s.label()).collect(),
        rows,
        notes: vec![
            "aggregate 331.25 GB/s per node; speedup vs stock 1:9.6".into(),
        ],
    })
}

/// Fig. 13a: DLRM-1.2T breakdown + footprint vs cluster size.
pub fn fig13a(coord: &Coordinator) -> Result<FigureData> {
    let d = Dlrm::dlrm_1_2t();
    let sizes = [64usize, 32, 16, 8];
    let mut footprints = Vec::with_capacity(sizes.len());
    let mut specs: Vec<SweepSpec> = Vec::with_capacity(sizes.len());
    for &n in &sizes {
        let w = d.build(n)?;
        // Paper normalizes to a 2 TB/s memory system: expanded memory
        // sized to the spill at 2 TB/s. DLRM's footprint is its embedding
        // shard (not the generic transformer ZeRO formula).
        let fp = d.footprint_per_node(n);
        let opts = EvalOptions {
            footprint_override: Some(fp),
            ..Default::default()
        };
        let mut cluster = presets::dgx_a100_64().with_n_nodes(n);
        let need = (fp - cluster.node.local.capacity).max(0.0);
        if need > 0.0 {
            cluster.node = cluster.node.with_expanded(need, 2e12);
        }
        footprints.push(fp);
        specs.push((w, cluster, opts));
    }
    let inputs = coord.derive_batch(specs)?;
    let evals = coord.evaluate_inputs(&inputs)?;

    let base_total = evals[0].total();
    let mut rows = Vec::new();
    for ((&n, b), fp) in sizes.iter().zip(&evals).zip(&footprints) {
        rows.push((
            format!("{n} nodes"),
            vec![
                b.fp_compute,
                b.fp_exposed_comm,
                b.ig_compute,
                b.ig_exposed_comm,
                b.wg_compute,
                b.wg_exposed_comm,
                b.total(),
                b.total() / base_total,
                fp / gb(1.0),
            ],
        ));
    }
    Ok(FigureData {
        id: "fig13a".into(),
        title: "DLRM-1.2T breakdown vs cluster size".into(),
        row_label: "cluster".into(),
        columns: [
            "FP_Compute",
            "FP_Exp_Comm",
            "IG_Compute",
            "IG_Exp_Comm",
            "WG_Compute",
            "WG_Exp_Comm",
            "Total_s",
            "Norm_to_64",
            "Footprint_GB",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect(),
        rows,
        notes: vec!["expanded memory at 2 TB/s where the shard spills".into()],
    })
}

/// Fig. 13b: turnaround of training 8 DLRMs on 64 GPUs vs expanded-memory
/// bandwidth, for different nodes-per-instance packings.
pub fn fig13b(coord: &Coordinator) -> Result<FigureData> {
    let d = Dlrm::dlrm_1_2t();
    let total_nodes = 64usize;
    let instances = 8.0;
    let packings = [32usize, 16, 8];
    let width = EM_BW_SWEEP.len();

    // Job 0: 8 sequential waves of 64-node instances on local memory.
    let mut specs: Vec<SweepSpec> =
        Vec::with_capacity(1 + packings.len() * width);
    specs.push((
        d.build(64)?,
        presets::dgx_a100_64(),
        EvalOptions {
            footprint_override: Some(d.footprint_per_node(64)),
            ..Default::default()
        },
    ));
    for &n in &packings {
        let w = d.build(n)?;
        let fp = d.footprint_per_node(n);
        let opts = EvalOptions {
            footprint_override: Some(fp),
            ..Default::default()
        };
        for &bw in &EM_BW_SWEEP {
            let mut cluster = presets::dgx_a100_64().with_n_nodes(n);
            let need = (fp - cluster.node.local.capacity).max(0.0);
            cluster.node = cluster.node.with_expanded(need, gb(bw));
            specs.push((w.clone(), cluster, opts));
        }
    }
    let inputs = coord.derive_batch(specs)?;
    let evals = coord.evaluate_inputs(&inputs)?;

    let base = evals[0].total() * instances;
    let mut rows = Vec::new();
    for (pi, &n) in packings.iter().enumerate() {
        let waves =
            (instances * n as f64 / total_nodes as f64).max(1.0).ceil();
        let vals: Vec<f64> = (0..width)
            .map(|j| base / (evals[1 + pi * width + j].total() * waves))
            .collect();
        rows.push((format!("{n} nodes/instance"), vals));
    }
    Ok(FigureData {
        id: "fig13b".into(),
        title: "8-DLRM turnaround vs expanded-memory bandwidth".into(),
        row_label: "packing".into(),
        columns: EM_BW_SWEEP.iter().map(|b| format!("{b:.0}GB/s")).collect(),
        rows,
        notes: vec![
            "speedup over 8 sequential waves of 64-node instances on local memory"
                .into(),
        ],
    })
}

/// DLRM nodes-per-instance for fig. 15, per the paper: GPU clusters use
/// 64 / 16 / 8 nodes for memory systems 0 / 1 / 2; TPU/Dojo use the
/// smallest power-of-two whose shard fits per-node capacity. Shared with
/// the scenario engine's cluster-compare study.
pub(crate) fn dlrm_nodes_per_instance(
    cluster: &ClusterConfig,
    d: &Dlrm,
) -> usize {
    match cluster.name.as_str() {
        "A0" | "B0" | "C0" => 64,
        "A1" | "B1" | "C1" => 16,
        "A2" | "B2" | "C2" => 8,
        _ => {
            let mut n = 1usize;
            while n < cluster.n_nodes
                && d.footprint_per_node(n) > cluster.node.total_capacity()
            {
                n *= 2;
            }
            n
        }
    }
}

/// Per-cluster job layout inside fig. 15's single batch.
struct Fig15Plan {
    dlrm_idx: usize,
    waves: f64,
    /// Transformer candidate jobs (feasible strategies; may be empty).
    tf: Range<usize>,
}

/// Fig. 15: eleven-cluster comparison (Table III) on DLRM and
/// Transformer-1T, speedups normalized to cluster A0. All clusters' DLRM
/// packings AND every cluster's feasible Transformer strategies are
/// evaluated in one batch.
pub fn fig15(coord: &Coordinator) -> Result<FigureData> {
    let d = Dlrm::dlrm_1_2t();
    let clusters = presets::table3_all();
    let instances = 8.0;

    let mut specs: Vec<SweepSpec> = Vec::new();
    let mut plans = Vec::with_capacity(clusters.len());
    for cluster in &clusters {
        // DLRM: 8 instances, waves over a 64-node partition for GPU
        // clusters (SV-C setup) or the full fabric for TPU/Dojo.
        let pool = cluster.n_nodes.min(64);
        let n_i = dlrm_nodes_per_instance(cluster, &d).min(pool);
        let waves = (instances * n_i as f64 / pool as f64).max(1.0).ceil();
        let sub = cluster.with_n_nodes(n_i);
        let w = d.build(n_i)?;
        let opts = EvalOptions {
            footprint_override: Some(d.footprint_per_node(n_i)),
            ..Default::default()
        };
        let dlrm_idx = specs.len();
        specs.push((w, sub, opts));

        // Transformer: every capacity-feasible (MP, DP) split.
        let topts = EvalOptions::default();
        let tf_start = specs.len();
        let max_mp = 128.min(cluster.n_nodes);
        for s in Strategy::sweep_bounded(cluster.n_nodes, 1, max_mp)? {
            let w = Transformer::t1().build(&s)?;
            let fp = footprint_per_node(&w, &s, ZeroStage::OsG).total();
            // Infeasible if the footprint exceeds total (local + expanded)
            // capacity per node.
            if fp > cluster.node.total_capacity() {
                continue;
            }
            specs.push((w, cluster.clone(), topts));
        }
        plans.push(Fig15Plan {
            dlrm_idx,
            waves,
            tf: tf_start..specs.len(),
        });
    }

    let inputs = coord.derive_batch(specs)?;
    let evals = coord.evaluate_inputs(&inputs)?;

    let dlrm_times: Vec<f64> = plans
        .iter()
        .map(|p| evals[p.dlrm_idx].total() * p.waves)
        .collect();
    let tf_times: Vec<f64> = plans
        .iter()
        .map(|p| {
            if p.tf.is_empty() {
                f64::NAN
            } else {
                evals[p.tf.clone()]
                    .iter()
                    .map(|b| b.total())
                    .fold(f64::INFINITY, f64::min)
            }
        })
        .collect();

    let rows = clusters
        .iter()
        .enumerate()
        .map(|(i, c)| {
            (
                c.name.clone(),
                vec![
                    dlrm_times[0] / dlrm_times[i],
                    tf_times[0] / tf_times[i],
                ],
            )
        })
        .collect();
    Ok(FigureData {
        id: "fig15".into(),
        title: "Cluster comparison (speedup vs A0)".into(),
        row_label: "cluster".into(),
        columns: vec!["DLRM_x8".into(), "Transformer-1T".into()],
        rows,
        notes: vec![
            "DLRM: 8 instances on a 64-node partition (TPU/Dojo: native packing)"
                .into(),
            "Transformer: best feasible (MP, DP) per cluster".into(),
        ],
    })
}

/// Ablation (DESIGN.md S6): how much of Fig. 8's shape is due to the
/// collective implementation? Reruns the strategy sweep under Table I's
/// logical ring vs the hierarchical (BlueConnect/Themis) collectives.
/// Shows the paper's left flank collapsing when pods are bridged
/// hierarchically — i.e. MP8's dominance is a *topology-awareness*
/// artifact, one of the design insights the methodology surfaces.
pub fn ablation_collectives(coord: &Coordinator) -> Result<FigureData> {
    let cluster = presets::dgx_a100_1024();
    let strategies = fig8_strategies();
    let impls = [CollectiveImpl::LogicalRing, CollectiveImpl::Hierarchical];
    let grid = GridSweep::new(strategies.clone()).collective_impls(&impls);
    let opts = EvalOptions {
        ignore_capacity: true,
        ..Default::default()
    };
    let specs =
        grid.specs(&cluster, &opts, |s| Transformer::t1().build(s))?;
    let inputs = coord.derive_batch(specs)?;
    let evals = coord.evaluate_inputs(&inputs)?;

    let mut rows = Vec::new();
    for (i, s) in strategies.iter().enumerate() {
        let ring = evals[i * impls.len()].total();
        let hier = evals[i * impls.len() + 1].total();
        rows.push((s.label(), vec![ring, hier, ring / hier]));
    }
    Ok(FigureData {
        id: "ablation-collectives".into(),
        title: "Ablation: logical-ring vs hierarchical collectives".into(),
        row_label: "(MP, DP)".into(),
        columns: vec![
            "ring_total_s".into(),
            "hier_total_s".into(),
            "ring/hier".into(),
        ],
        rows,
        notes: vec![
            "Transformer-1T, infinite-capacity memory; Fig. 8 sweep".into(),
        ],
    })
}

/// Ablation: ZeRO stage choice. Per-node footprint AND iteration time for
/// the Fig. 8 sweep under each ZeRO stage (stage 3 pays its 1.5x DP
/// communication-volume penalty on the WG reduce-scatter).
pub fn ablation_zero(coord: &Coordinator) -> Result<FigureData> {
    let cluster = presets::dgx_a100_1024();
    let mut labels = Vec::new();
    let mut footprints = Vec::new();
    let mut specs: Vec<SweepSpec> = Vec::new();
    for s in [Strategy::new(64, 16)?, Strategy::new(8, 128)?] {
        let base = Transformer::t1().build(&s)?;
        for stage in ZeroStage::ALL {
            let mut w = base.clone();
            // Stage 3's extra parameter all-gather: scale the DP-scope
            // collective payloads by the stage's volume multiplier.
            for l in &mut w.layers {
                if l.comm_wg.scope == crate::workload::CommScope::Dp {
                    l.comm_wg.bytes *= stage.comm_multiplier();
                }
            }
            let opts = EvalOptions {
                zero_stage: stage,
                ignore_capacity: true,
                ..Default::default()
            };
            labels.push(format!("{} {}", s.label(), stage.label()));
            footprints
                .push(footprint_per_node(&w, &s, stage).total() / gb(1.0));
            specs.push((w, cluster.clone(), opts));
        }
    }
    let inputs = coord.derive_batch(specs)?;
    let evals = coord.evaluate_inputs(&inputs)?;

    let rows = labels
        .into_iter()
        .zip(footprints)
        .zip(&evals)
        .map(|((label, fp), b)| {
            (label, vec![fp, b.total(), b.wg_exposed_comm])
        })
        .collect();
    Ok(FigureData {
        id: "ablation-zero".into(),
        title: "Ablation: ZeRO stage (footprint vs comm overhead)".into(),
        row_label: "config".into(),
        columns: vec![
            "Footprint_GB".into(),
            "Total_s".into(),
            "WG_Exp_Comm_s".into(),
        ],
        rows,
        notes: vec!["stage-3 DP payloads scaled by 1.5x (ZeRO paper)".into()],
    })
}

/// All figures in paper order.
pub fn all_figures(coord: &Coordinator) -> Result<Vec<FigureData>> {
    Ok(vec![
        fig6(),
        fig8a(coord)?,
        fig8b(coord)?,
        fig9(coord)?,
        fig10(coord)?,
        fig11(coord)?,
        fig12(coord)?,
        fig13a(coord)?,
        fig13b(coord)?,
        fig15(coord)?,
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coord() -> Coordinator {
        Coordinator::native()
    }

    #[test]
    fn fig6_zero3_flat_and_baseline_steep() {
        let f = fig6();
        let z3_hi = f.cell("MP1024_DP1", "zero-3").unwrap();
        let z3_lo = f.cell("MP1_DP1024", "zero-3").unwrap();
        assert!((z3_hi - z3_lo).abs() < 1e-6);
        let b_hi = f.cell("MP1024_DP1", "baseline").unwrap();
        let b_lo = f.cell("MP1_DP1024", "baseline").unwrap();
        assert!((b_lo / b_hi - 1024.0).abs() < 1.0);
    }

    #[test]
    fn fig8a_best_is_mp8() {
        let f = fig8a(&coord()).unwrap();
        assert_eq!(f.argmin("Total_s"), Some("MP8_DP128"));
        // Footprint at MP8 is ~3.3x the 80 GB local capacity.
        let fp = f.cell("MP8_DP128", "Footprint_GB").unwrap();
        assert!((250.0..330.0).contains(&fp), "{fp}");
    }

    #[test]
    fn fig9_crossover_exists() {
        let f = fig9(&coord()).unwrap();
        // MP8_DP128 must lose at 250 GB/s and win at some higher bandwidth
        // (the paper's Ex.1: >= ~500 GB/s makes expansion worthwhile).
        let lo = f.cell("MP8_DP128", "250GB/s").unwrap();
        let hi = f.cell("MP8_DP128", "2039GB/s").unwrap();
        assert!(lo < 1.0, "{lo}");
        assert!(hi > 1.0, "{hi}");
    }

    #[test]
    fn fig13a_sublinear() {
        let f = fig13a(&coord()).unwrap();
        let n32 = f.cell("32 nodes", "Norm_to_64").unwrap();
        let n16 = f.cell("16 nodes", "Norm_to_64").unwrap();
        assert!(n32 < 2.0, "{n32}");
        assert!(n16 < 4.0, "{n16}");
        assert!(n32 > 1.0 && n16 > n32);
    }

    #[test]
    fn fig15_c0_beats_a0() {
        let f = fig15(&coord()).unwrap();
        let c0 = f.cell("C0", "Transformer-1T").unwrap();
        assert!(c0 > 2.0, "C0 speedup {c0}");
        let a0 = f.cell("A0", "Transformer-1T").unwrap();
        assert!((a0 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn grid_sweep_cross_product_size() {
        let grid = GridSweep::new(Strategy::sweep_bounded(1024, 2, 128).unwrap())
            .em_bandwidths(&EM_BW_SWEEP.map(gb));
        // 7 strategies (MP128..MP2) x 7 bandwidths x 1 capacity x 1 impl.
        assert_eq!(grid.len(), 7 * EM_BW_SWEEP.len());
        assert_eq!(grid.points().len(), grid.len());
        assert!(!grid.is_empty());

        let grid = GridSweep::new(Strategy::sweep(64).unwrap())
            .em_bandwidths(&[gb(500.0), gb(1000.0)])
            .em_capacities(&[gb(100.0), gb(200.0), gb(400.0)])
            .collective_impls(&[
                CollectiveImpl::LogicalRing,
                CollectiveImpl::Hierarchical,
            ]);
        assert_eq!(grid.len(), 7 * 2 * 3 * 2);
        assert_eq!(grid.points().len(), grid.len());
        assert!(GridSweep::new(Vec::new()).is_empty());
    }

    #[test]
    fn grid_sweep_rejects_capacity_without_bandwidth() {
        let err = GridSweep::new(vec![Strategy::new(8, 8).unwrap()])
            .em_capacities(&[gb(100.0)])
            .specs(
                &presets::dgx_a100_1024(),
                &EvalOptions::default(),
                |s| Transformer::t1().build(s),
            );
        assert!(err.is_err());
    }

    #[test]
    fn grid_sweep_points_row_major() {
        let grid = GridSweep::new(vec![
            Strategy::new(8, 8).unwrap(),
            Strategy::new(4, 16).unwrap(),
        ])
        .em_bandwidths(&[1e9, 2e9])
        .collective_impls(&[
            CollectiveImpl::LogicalRing,
            CollectiveImpl::Hierarchical,
        ]);
        let pts = grid.points();
        assert_eq!(pts.len(), 2 * 2 * 2);
        // Strategy outermost, then bandwidth, then impl innermost.
        assert_eq!(pts[0].strategy, Strategy::new(8, 8).unwrap());
        assert_eq!(pts[0].em_bandwidth, Some(1e9));
        assert_eq!(pts[0].collective_impl, CollectiveImpl::LogicalRing);
        assert_eq!(pts[1].collective_impl, CollectiveImpl::Hierarchical);
        assert_eq!(pts[2].em_bandwidth, Some(2e9));
        assert_eq!(pts[4].strategy, Strategy::new(4, 16).unwrap());
    }

    #[test]
    fn grid_sweep_specs_match_points() {
        let cluster = presets::dgx_a100_1024();
        let grid = GridSweep::new(Strategy::sweep_bounded(1024, 8, 64).unwrap())
            .em_bandwidths(&EM_BW_SWEEP.map(gb));
        let specs = grid
            .specs(&cluster, &EvalOptions::default(), |s| {
                Transformer::t1().build(s)
            })
            .unwrap();
        assert_eq!(specs.len(), grid.len());
        // Spilling strategies get expanded memory at the point's bandwidth;
        // fitting ones keep the base node.
        for (spec, pt) in specs.iter().zip(grid.points()) {
            let w = &spec.0;
            assert_eq!(w.mp, pt.strategy.mp);
            let fp = footprint_per_node(w, &pt.strategy, ZeroStage::OsG)
                .total();
            let spills = fp > cluster.node.local.capacity;
            if spills {
                assert_eq!(
                    spec.1.node.expanded.bandwidth,
                    pt.em_bandwidth.unwrap()
                );
            } else {
                assert_eq!(spec.1.node, cluster.node);
            }
        }
    }
}
