//! Evaluation cache: memoizes cost-model results by input fingerprint.
//! DSE sweeps revisit identical configurations constantly (normalization
//! baselines, shared sweep corners), so this is a real throughput lever.
//!
//! The map is sharded N ways by fingerprint so concurrent sweep threads
//! stop serializing on a single lock, and the fingerprint is computed
//! **once** per input by the coordinator ([`ModelInputs::fingerprint`])
//! and passed through [`EvalCache::get_by_key`] / [`EvalCache::put_by_key`]
//! — the old `get` + `put` pair hashed every miss twice.
//!
//! [`DeriveCache`] is the stage-1 companion: it memoizes workload
//! decompositions (the cluster-independent half of the two-stage derive)
//! by [`Workload::fingerprint`], so grid sweeps decompose each distinct
//! workload once instead of once per grid point.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::analytical::TrainingBreakdown;
use crate::model::inputs::{decompose, ModelInputs, WorkloadDecomposition};
use crate::workload::Workload;

/// Shard count: enough to make lock collisions rare at typical host core
/// counts, small enough that `len()`/`clear()` stay cheap. Power of two so
/// shard selection is a mask.
const N_SHARDS: usize = 16;

/// Thread-safe sharded memoization table.
#[derive(Debug)]
pub struct EvalCache {
    shards: Vec<Mutex<HashMap<u64, TrainingBreakdown>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Default for EvalCache {
    fn default() -> Self {
        EvalCache::new()
    }
}

impl EvalCache {
    /// Empty cache.
    pub fn new() -> EvalCache {
        EvalCache {
            shards: (0..N_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    fn shard(&self, key: u64) -> &Mutex<HashMap<u64, TrainingBreakdown>> {
        // FNV-1a's multiply only propagates entropy upward, so the low
        // bits are its worst-mixed; fold the high halves down before
        // masking to keep the shards balanced.
        let folded = key ^ (key >> 32) ^ (key >> 16);
        &self.shards[(folded as usize) & (N_SHARDS - 1)]
    }

    /// Look up by a precomputed fingerprint, counting a hit or miss.
    pub fn get_by_key(&self, key: u64) -> Option<TrainingBreakdown> {
        let hit = self.shard(key).lock().unwrap().get(&key).copied();
        match hit {
            Some(b) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(b)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Store by a precomputed fingerprint.
    pub fn put_by_key(&self, key: u64, b: TrainingBreakdown) {
        self.shard(key).lock().unwrap().insert(key, b);
    }

    /// Look up a previously evaluated configuration (hashes `inputs`).
    ///
    /// Convenience for one-off callers; the sweep hot path fingerprints
    /// once and uses [`EvalCache::get_by_key`] / [`EvalCache::put_by_key`]
    /// so a miss never hashes twice.
    pub fn get(&self, inputs: &ModelInputs) -> Option<TrainingBreakdown> {
        self.get_by_key(inputs.fingerprint())
    }

    /// Store a result (hashes `inputs`); see [`EvalCache::get`].
    pub fn put(&self, inputs: &ModelInputs, b: TrainingBreakdown) {
        self.put_by_key(inputs.fingerprint(), b);
    }

    /// (hits, misses) counters.
    pub fn stats(&self) -> (u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }

    /// Entries stored across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Whether the cache is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Stage-1 derive cache: memoizes [`WorkloadDecomposition`]s by
/// [`Workload::fingerprint`], so a sweep that evaluates one workload
/// across many (cluster, options) grid points decomposes it exactly once.
/// The miss counter doubles as the decomposition-call counter the
/// two-stage derive tests assert on.
#[derive(Debug, Default)]
pub struct DeriveCache {
    map: Mutex<HashMap<u64, Arc<WorkloadDecomposition>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl DeriveCache {
    /// Empty cache.
    pub fn new() -> DeriveCache {
        DeriveCache::default()
    }

    /// The decomposition of `workload`, computed on first sight and shared
    /// (via `Arc`) afterwards. Decomposition happens under the map lock —
    /// it is cheap (one pass over the layer list) and holding the lock
    /// guarantees each distinct workload is decomposed exactly once even
    /// under concurrent batches.
    pub fn decomposition(&self, workload: &Workload) -> Arc<WorkloadDecomposition> {
        let key = workload.fingerprint();
        let mut map = self.map.lock().unwrap();
        if let Some(dec) = map.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return dec.clone();
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let dec = Arc::new(decompose(workload));
        map.insert(key, dec.clone());
        dec
    }

    /// (hits, misses) counters. `misses` is the number of decompositions
    /// actually performed.
    pub fn stats(&self) -> (u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }

    /// Distinct workloads decomposed so far.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    /// Whether the cache is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::model::inputs::{derive_inputs, EvalOptions};
    use crate::parallel::Strategy;
    use crate::workload::transformer::Transformer;

    fn inputs(mp: usize, dp: usize) -> ModelInputs {
        derive_inputs(
            &Transformer::t1()
                .build(&Strategy::new(mp, dp).unwrap())
                .unwrap(),
            &presets::dgx_a100_1024(),
            &EvalOptions::default(),
        )
        .unwrap()
    }

    #[test]
    fn roundtrip() {
        let cache = EvalCache::new();
        let inp = inputs(8, 128);
        assert!(cache.get(&inp).is_none());
        let b = TrainingBreakdown {
            fp_compute: 1.0,
            ..Default::default()
        };
        cache.put(&inp, b);
        assert_eq!(cache.get(&inp), Some(b));
        assert_eq!(cache.stats(), (1, 1));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn keyed_roundtrip_matches_input_roundtrip() {
        let cache = EvalCache::new();
        let inp = inputs(8, 128);
        let key = inp.fingerprint();
        assert!(cache.get_by_key(key).is_none());
        let b = TrainingBreakdown {
            ig_compute: 2.0,
            ..Default::default()
        };
        cache.put_by_key(key, b);
        // The inputs-based accessor sees what the keyed one stored.
        assert_eq!(cache.get(&inp), Some(b));
        assert_eq!(cache.stats(), (1, 1));
    }

    #[test]
    fn different_configs_different_keys() {
        assert_ne!(inputs(8, 128).fingerprint(), inputs(16, 64).fingerprint());
    }

    #[test]
    fn identical_configs_same_key() {
        assert_eq!(inputs(8, 128).fingerprint(), inputs(8, 128).fingerprint());
    }

    #[test]
    fn option_fields_affect_key() {
        let a = derive_inputs(
            &Transformer::t1()
                .build(&Strategy::new(8, 128).unwrap())
                .unwrap(),
            &presets::dgx_a100_1024(),
            &EvalOptions::default(),
        )
        .unwrap();
        let b = derive_inputs(
            &Transformer::t1()
                .build(&Strategy::new(8, 128).unwrap())
                .unwrap(),
            &presets::dgx_a100_1024(),
            &EvalOptions {
                ignore_capacity: true,
                ..Default::default()
            },
        )
        .unwrap();
        assert_ne!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn shards_cover_key_space() {
        // Synthetic keys spread across every shard and survive roundtrips.
        let cache = EvalCache::new();
        let b = TrainingBreakdown::default();
        for k in 0..(N_SHARDS as u64 * 8) {
            cache.put_by_key(k.wrapping_mul(0x9e3779b97f4a7c15), b);
        }
        assert_eq!(cache.len(), N_SHARDS * 8);
        for k in 0..(N_SHARDS as u64 * 8) {
            assert!(cache
                .get_by_key(k.wrapping_mul(0x9e3779b97f4a7c15))
                .is_some());
        }
        let (hits, misses) = cache.stats();
        assert_eq!(hits, N_SHARDS as u64 * 8);
        assert_eq!(misses, 0);
    }

    #[test]
    fn derive_cache_decomposes_once_per_distinct_workload() {
        let cache = DeriveCache::new();
        let w8 = Transformer::t1()
            .build(&Strategy::new(8, 128).unwrap())
            .unwrap();
        let w16 = Transformer::t1()
            .build(&Strategy::new(16, 64).unwrap())
            .unwrap();
        let a = cache.decomposition(&w8);
        let b = cache.decomposition(&w8);
        assert!(Arc::ptr_eq(&a, &b));
        let c = cache.decomposition(&w16);
        assert_eq!(c.mp, 16);
        assert_eq!(cache.stats(), (1, 2));
        assert_eq!(cache.len(), 2);
        assert!(!cache.is_empty());
    }

    #[test]
    fn concurrent_access_preserves_accounting() {
        use std::sync::Arc;
        let cache = Arc::new(EvalCache::new());
        let threads = 8u64;
        let per = 200u64;
        let mut joins = Vec::new();
        for t in 0..threads {
            let c = cache.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..per {
                    // Every thread misses its own keys once, stores them,
                    // then hits them once.
                    let key = (t << 32) | i;
                    assert!(c.get_by_key(key).is_none());
                    c.put_by_key(key, TrainingBreakdown::default());
                    assert!(c.get_by_key(key).is_some());
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let (hits, misses) = cache.stats();
        assert_eq!(hits, threads * per);
        assert_eq!(misses, threads * per);
        assert_eq!(cache.len(), (threads * per) as usize);
    }
}
