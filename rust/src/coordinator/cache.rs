//! Evaluation cache: memoizes cost-model results by input fingerprint.
//! DSE sweeps revisit identical configurations constantly (normalization
//! baselines, shared sweep corners), so this is a real throughput lever.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::analytical::TrainingBreakdown;
use crate::model::inputs::ModelInputs;

/// Thread-safe memoization table.
#[derive(Debug, Default)]
pub struct EvalCache {
    map: Mutex<HashMap<u64, TrainingBreakdown>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl EvalCache {
    /// Empty cache.
    pub fn new() -> EvalCache {
        EvalCache::default()
    }

    /// Look up a previously evaluated configuration.
    pub fn get(&self, inputs: &ModelInputs) -> Option<TrainingBreakdown> {
        let key = fingerprint(inputs);
        let hit = self.map.lock().unwrap().get(&key).copied();
        match hit {
            Some(b) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(b)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Store a result.
    pub fn put(&self, inputs: &ModelInputs, b: TrainingBreakdown) {
        self.map.lock().unwrap().insert(fingerprint(inputs), b);
    }

    /// (hits, misses) counters.
    pub fn stats(&self) -> (u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }

    /// Entries stored.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    /// Whether the cache is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// FNV-1a over the full numeric content of the inputs. Collisions across
/// *different* configurations are astronomically unlikely (64-bit) and
/// would only perturb a figure, not corrupt state.
fn fingerprint(inputs: &ModelInputs) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut eat = |x: f64| {
        for b in x.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    let p = &inputs.params;
    for v in [
        p.perf_peak,
        p.bw_lm,
        p.bw_em,
        p.cap_lm,
        p.sram,
        p.footprint,
        p.bw_intra,
        p.bw_inter,
        p.link_latency,
        if p.overlap_wg { 1.0 } else { 0.0 },
        p.em_frac_override.unwrap_or(-1.0),
        p.collective_impl.code(),
    ] {
        eat(v);
    }
    for l in &inputs.layers {
        eat(l.repeat);
        for q in &l.q {
            eat(q.flops);
            eat(q.u);
            eat(q.v);
            eat(q.w);
        }
        for c in &l.comm {
            eat(c.collective.code());
            eat(c.bytes);
            eat(c.n_intra as f64);
            eat(c.n_inter as f64);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::model::inputs::{derive_inputs, EvalOptions};
    use crate::parallel::Strategy;
    use crate::workload::transformer::Transformer;

    fn inputs(mp: usize, dp: usize) -> ModelInputs {
        derive_inputs(
            &Transformer::t1().build(&Strategy::new(mp, dp)).unwrap(),
            &presets::dgx_a100_1024(),
            &EvalOptions::default(),
        )
        .unwrap()
    }

    #[test]
    fn roundtrip() {
        let cache = EvalCache::new();
        let inp = inputs(8, 128);
        assert!(cache.get(&inp).is_none());
        let b = TrainingBreakdown {
            fp_compute: 1.0,
            ..Default::default()
        };
        cache.put(&inp, b);
        assert_eq!(cache.get(&inp), Some(b));
        assert_eq!(cache.stats(), (1, 1));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn different_configs_different_keys() {
        assert_ne!(
            super::fingerprint(&inputs(8, 128)),
            super::fingerprint(&inputs(16, 64))
        );
    }

    #[test]
    fn identical_configs_same_key() {
        assert_eq!(
            super::fingerprint(&inputs(8, 128)),
            super::fingerprint(&inputs(8, 128))
        );
    }

    #[test]
    fn option_fields_affect_key() {
        let a = derive_inputs(
            &Transformer::t1().build(&Strategy::new(8, 128)).unwrap(),
            &presets::dgx_a100_1024(),
            &EvalOptions::default(),
        )
        .unwrap();
        let b = derive_inputs(
            &Transformer::t1().build(&Strategy::new(8, 128)).unwrap(),
            &presets::dgx_a100_1024(),
            &EvalOptions {
                ignore_capacity: true,
                ..Default::default()
            },
        )
        .unwrap();
        assert_ne!(super::fingerprint(&a), super::fingerprint(&b));
    }
}
