//! Support substrates built from scratch for the offline environment:
//! SI-unit helpers, a minimal JSON parser/serializer (config + manifest I/O),
//! a deterministic PRNG (property tests, workload jitter), descriptive
//! statistics, and the micro-benchmark harness used by `cargo bench`.

pub mod bench;
pub mod cancel;
pub mod json;
pub mod prng;
pub mod stats;
pub mod units;
