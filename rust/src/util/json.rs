//! Minimal JSON parser + serializer.
//!
//! Built from scratch because the offline build environment vendors no
//! `serde_json`. Supports the full JSON grammar needed by COMET's I/O:
//! `artifacts/manifest.json`, cluster/workload config files, and result
//! emission for the figure drivers.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A JSON value. Objects preserve key order via `BTreeMap` (deterministic
/// output; COMET configs never rely on duplicate keys).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// JSON `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (f64; integers render without a fraction).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Value>),
    /// An object (key order = `BTreeMap` order; deterministic output).
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Fetch `key` from an object.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Numeric accessor.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer accessor (lossy past 2^53, which COMET never needs).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    /// String accessor.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array accessor.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience: build an object from key/value pairs.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convenience: numeric array.
pub fn num_arr(xs: &[f64]) -> Value {
    Value::Arr(xs.iter().map(|x| Value::Num(*x)).collect())
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(Error::Json(format!(
            "trailing characters at offset {}",
            p.pos
        )));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json(format!("{msg} at offset {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            let combined = 0x10000
                                + ((cp - 0xD800) << 10)
                                + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(|| self.err("bad unicode escape"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Re-decode multi-byte UTF-8 from the raw slice.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let end = start + len;
                    let chunk = self
                        .bytes
                        .get(start..end)
                        .ok_or_else(|| self.err("truncated utf-8"))?;
                    let st = std::str::from_utf8(chunk)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(st);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b >= 0xF0 {
        4
    } else if b >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let v = parse(r#"{"b": 64, "l": 192, "artifacts": {"8": "a.hlo.txt"}}"#)
            .unwrap();
        assert_eq!(v.get("b").unwrap().as_usize(), Some(64));
        assert_eq!(
            v.get("artifacts").unwrap().get("8").unwrap().as_str(),
            Some("a.hlo.txt")
        );
    }

    #[test]
    fn parses_numbers() {
        for (s, want) in [
            ("0", 0.0),
            ("-12", -12.0),
            ("3.25", 3.25),
            ("6.25e9", 6.25e9),
            ("1E-3", 1e-3),
            ("-2.5e+2", -250.0),
        ] {
            assert_eq!(parse(s).unwrap().as_f64(), Some(want), "{s}");
        }
    }

    #[test]
    fn parses_strings_with_escapes() {
        let v = parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\ndA"));
    }

    #[test]
    fn parses_unicode_and_surrogates() {
        assert_eq!(parse(r#""é""#).unwrap().as_str(), Some("é"));
        assert_eq!(parse(r#""😀""#).unwrap().as_str(), Some("😀"));
        assert_eq!(parse("\"héllo\"").unwrap().as_str(), Some("héllo"));
    }

    #[test]
    fn parses_nested_arrays() {
        let v = parse("[1, [2, 3], {\"x\": [true, false, null]}]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[1].as_arr().unwrap()[1].as_f64(), Some(3.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_compact() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":true,"d":null}}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = obj(vec![
            ("xs", num_arr(&[1.0, 2.0])),
            ("name", Value::Str("comet".into())),
        ]);
        let pretty = v.to_string_pretty();
        assert!(pretty.contains('\n'));
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Value::Num(64.0).to_string_compact(), "64");
        assert_eq!(Value::Num(2.5).to_string_compact(), "2.5");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(BTreeMap::new()));
        assert_eq!(Value::Arr(vec![]).to_string_pretty(), "[]");
    }
}
