//! Cooperative cancellation and deadlines for long-running paths.
//!
//! Every driver that can run for minutes (the branch-and-bound search,
//! batched coordinator evaluation, the goodput renewal simulation)
//! accepts a [`RunControl`] and polls [`RunControl::should_stop`] at its
//! safe boundaries: sequential-pop iterations, parallel batch-collection
//! boundaries, and fault-event steps. Polling is cheap — one relaxed
//! atomic load plus (when a deadline is armed and the poll stride says
//! so) one monotonic-clock read — so drivers can poll every iteration
//! without measurable overhead.
//!
//! Stopping is *cooperative*: a set token never interrupts a leaf
//! evaluation mid-flight, it only prevents the next unit of work from
//! starting. That is what makes checkpoint/resume deterministic — the
//! run always halts at a state the sequential driver could also have
//! been in (see `optimizer::checkpoint`).
//!
//! The module also hosts the process-wide SIGINT/SIGTERM hookup used by
//! `main.rs` and the serve layer: a signal handler (installed via a
//! direct `signal(2)` FFI declaration — the offline crate set has no
//! `libc`) that trips a global flag, which [`install_signal_token`]
//! bridges onto ordinary [`CancelToken`]s. Installation is idempotent
//! and multi-consumer: every call registers its own token and *all*
//! registered tokens observe the first signal. A second signal restores
//! the default disposition and kills the process the usual way.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Once};
use std::time::{Duration, Instant};

/// Why a run stopped early. Ordered by precedence: explicit cancellation
/// wins over a deadline when both trip in the same poll.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// The [`CancelToken`] was set (Ctrl-C, a dropped client, an
    /// explicit test hook).
    Cancelled,
    /// The monotonic [`Deadline`] passed.
    DeadlineExceeded,
}

impl StopReason {
    /// Short lower-case label (used in notes, checkpoints, stderr).
    pub fn label(&self) -> &'static str {
        match self {
            StopReason::Cancelled => "cancelled",
            StopReason::DeadlineExceeded => "deadline",
        }
    }
}

/// Clone-cheap cooperative cancellation flag shared between the
/// requesting side (signal handler, server, test) and the running side.
#[derive(Debug, Clone, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
}

impl CancelToken {
    /// Fresh, unset token.
    pub fn new() -> Self {
        CancelToken {
            flag: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Request cancellation. Idempotent; visible to all clones.
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::Release);
    }

    /// Has cancellation been requested?
    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::Acquire)
    }
}

/// A monotonic wall-clock budget. Constructed once at run start;
/// [`Deadline::exceeded`] compares against `Instant::now()`.
#[derive(Debug, Clone, Copy)]
pub struct Deadline {
    at: Instant,
}

impl Deadline {
    /// Deadline `budget` from now.
    pub fn after(budget: Duration) -> Self {
        Deadline {
            at: Instant::now() + budget,
        }
    }

    /// Deadline `secs` seconds from now.
    pub fn after_secs(secs: f64) -> Self {
        Deadline::after(Duration::from_secs_f64(secs.max(0.0)))
    }

    /// Has the deadline passed?
    pub fn exceeded(&self) -> bool {
        Instant::now() >= self.at
    }

    /// Time left (zero once exceeded).
    pub fn remaining(&self) -> Duration {
        self.at.saturating_duration_since(Instant::now())
    }
}

/// Everything a driver needs to decide "keep going?": an optional
/// cancellation token, an optional deadline, and an optional
/// deterministic poll-count trip wire (tests cancel "after exactly N
/// safe-boundary polls" so resume properties never depend on timing).
#[derive(Debug, Clone, Default)]
pub struct RunControl {
    token: Option<CancelToken>,
    deadline: Option<Deadline>,
    /// Trip as Cancelled once `polls` reaches this count.
    cancel_at_poll: Option<u64>,
    polls: Arc<AtomicU64>,
}

impl RunControl {
    /// A control that never stops — the default for plain library calls.
    pub fn unbounded() -> Self {
        RunControl::default()
    }

    /// True when no stop source is armed; drivers may skip polling work.
    pub fn is_unbounded(&self) -> bool {
        self.token.is_none()
            && self.deadline.is_none()
            && self.cancel_at_poll.is_none()
    }

    /// Attach a cancellation token.
    pub fn with_token(mut self, token: CancelToken) -> Self {
        self.token = Some(token);
        self
    }

    /// Attach a deadline.
    pub fn with_deadline(mut self, deadline: Deadline) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Attach a deadline, keeping the sooner one when one is already
    /// armed: two budgets compose by stopping at whichever expires
    /// first (e.g. a serve request deadline meeting a resilience
    /// study's own `deadline_s`).
    pub fn with_deadline_sooner(mut self, deadline: Deadline) -> Self {
        self.deadline = Some(match self.deadline {
            Some(d) if d.at <= deadline.at => d,
            _ => deadline,
        });
        self
    }

    /// Deterministic test hook: report Cancelled on the `n`-th poll
    /// (0-based: `cancel_after_polls(0)` trips on the first poll).
    pub fn cancel_after_polls(mut self, n: u64) -> Self {
        self.cancel_at_poll = Some(n);
        self
    }

    /// Poll at a safe boundary. Returns the stop reason, if any.
    /// Cancellation takes precedence over the deadline. Cost: one
    /// relaxed atomic (when the poll-count hook is armed), one acquire
    /// load (when a token is attached), one monotonic clock read (when
    /// a deadline is armed) — nothing when unbounded.
    pub fn should_stop(&self) -> Option<StopReason> {
        if let Some(n) = self.cancel_at_poll {
            // The counter is shared across clones so parallel drivers
            // that poll from one logical loop still count globally.
            let seen = self.polls.fetch_add(1, Ordering::Relaxed);
            if seen >= n {
                return Some(StopReason::Cancelled);
            }
        }
        if let Some(t) = &self.token {
            if t.is_cancelled() {
                return Some(StopReason::Cancelled);
            }
        }
        if let Some(d) = &self.deadline {
            if d.exceeded() {
                return Some(StopReason::DeadlineExceeded);
            }
        }
        None
    }

    /// Remaining deadline budget, when a deadline is armed. Batch fan-
    /// out paths use this to arm a watchdog sized to the budget, so a
    /// stuck batch turns into a deadline error instead of a hang.
    pub fn deadline_remaining(&self) -> Option<Duration> {
        self.deadline.map(|d| d.remaining())
    }

    /// Poll, converting a stop into an error (for paths without a
    /// partial-result channel, e.g. coordinator batch evaluation).
    pub fn check(&self, what: &str) -> crate::error::Result<()> {
        match self.should_stop() {
            None => Ok(()),
            Some(StopReason::Cancelled) => {
                Err(crate::error::Error::Cancelled(what.to_string()))
            }
            Some(StopReason::DeadlineExceeded) => {
                Err(crate::error::Error::Deadline(what.to_string()))
            }
        }
    }
}

// ---------------------------------------------------------------------
// SIGINT/SIGTERM -> CancelToken bridge (no libc crate in the offline
// set).
// ---------------------------------------------------------------------

/// Process-global flag the signal handler is allowed to touch
/// (async-signal-safe: a single atomic store).
static SIGNAL_TRIPPED: AtomicBool = AtomicBool::new(false);

/// Tokens registered by [`install_signal_token`]. The watcher thread
/// cancels every entry once the flag trips; registration after the trip
/// returns an already-cancelled token instead.
static TOKENS: Mutex<Vec<CancelToken>> = Mutex::new(Vec::new());

/// One-time installation of the handlers and the watcher thread.
static INSTALL: Once = Once::new();

#[cfg(unix)]
mod sys {
    use super::SIGNAL_TRIPPED;
    use std::sync::atomic::Ordering;

    pub const SIGINT: i32 = 2;
    pub const SIGTERM: i32 = 15;
    pub const SIG_DFL: usize = 0;

    extern "C" {
        /// `signal(2)` from the platform C library; the offline crate
        /// set has no `libc`, so the symbol is declared directly.
        fn signal(signum: i32, handler: usize) -> usize;
    }

    extern "C" fn on_signal(sig: i32) {
        SIGNAL_TRIPPED.store(true, Ordering::Release);
        // Restore the default disposition so a second signal kills the
        // process immediately instead of being swallowed.
        unsafe {
            signal(sig, SIG_DFL);
        }
    }

    pub fn install() {
        unsafe {
            signal(SIGINT, on_signal as extern "C" fn(i32) as usize);
            signal(SIGTERM, on_signal as extern "C" fn(i32) as usize);
        }
    }
}

#[cfg(not(unix))]
mod sys {
    pub fn install() {}
}

/// Register a fresh [`CancelToken`] with the process-wide SIGINT/SIGTERM
/// bridge and return it.
///
/// Idempotent and multi-consumer: the handlers and the single 50 ms
/// watcher thread are installed exactly once per process, every call
/// returns its own token, and *all* registered tokens observe the first
/// signal (an earlier install is never clobbered by a later one). A
/// token requested after the signal has already fired comes back
/// already cancelled. The first signal cancels cooperatively; a second
/// one kills the process (the handler restores the default disposition
/// for the signal that fired).
pub fn install_signal_token() -> CancelToken {
    INSTALL.call_once(|| {
        sys::install();
        // Detached watcher: polls the signal flag at 50ms, fans the
        // trip out to every registered token, then exits. The process
        // exits through main() long before thread teardown matters.
        std::thread::Builder::new()
            .name("comet-signal".into())
            .spawn(|| loop {
                if SIGNAL_TRIPPED.load(Ordering::Acquire) {
                    let tokens = TOKENS.lock().expect("signal token registry");
                    for t in tokens.iter() {
                        t.cancel();
                    }
                    return;
                }
                std::thread::sleep(Duration::from_millis(50));
            })
            .expect("spawn signal watcher");
    });
    let token = CancelToken::new();
    TOKENS
        .lock()
        .expect("signal token registry")
        .push(token.clone());
    // A signal that fired before (or while) this token registered must
    // still be observed — the watcher may already have drained the
    // registry and exited. The flag only ever transitions false -> true,
    // so this load closes the race.
    if SIGNAL_TRIPPED.load(Ordering::Acquire) {
        token.cancel();
    }
    token
}

/// Backwards-compatible alias for [`install_signal_token`]. The bridge
/// covers SIGTERM as well as SIGINT; both cancel the returned token.
pub fn install_sigint_token() -> CancelToken {
    install_signal_token()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_cancels_across_clones() {
        let t = CancelToken::new();
        let u = t.clone();
        assert!(!t.is_cancelled());
        u.cancel();
        assert!(t.is_cancelled());
        // Idempotent.
        u.cancel();
        assert!(t.is_cancelled());
    }

    #[test]
    fn unbounded_control_never_stops() {
        let c = RunControl::unbounded();
        assert!(c.is_unbounded());
        for _ in 0..1000 {
            assert_eq!(c.should_stop(), None);
        }
        assert!(c.check("noop").is_ok());
    }

    #[test]
    fn token_stop_maps_to_cancelled() {
        let t = CancelToken::new();
        let c = RunControl::unbounded().with_token(t.clone());
        assert!(!c.is_unbounded());
        assert_eq!(c.should_stop(), None);
        t.cancel();
        assert_eq!(c.should_stop(), Some(StopReason::Cancelled));
        assert!(matches!(
            c.check("search"),
            Err(crate::error::Error::Cancelled(_))
        ));
    }

    #[test]
    fn zero_deadline_trips_on_first_poll() {
        let c = RunControl::unbounded()
            .with_deadline(Deadline::after(Duration::from_secs(0)));
        assert_eq!(c.should_stop(), Some(StopReason::DeadlineExceeded));
        assert!(matches!(
            c.check("search"),
            Err(crate::error::Error::Deadline(_))
        ));
    }

    #[test]
    fn generous_deadline_does_not_trip() {
        let c = RunControl::unbounded()
            .with_deadline(Deadline::after(Duration::from_secs(3600)));
        for _ in 0..100 {
            assert_eq!(c.should_stop(), None);
        }
        assert!(c.should_stop().is_none());
    }

    #[test]
    fn cancel_after_polls_is_deterministic() {
        let c = RunControl::unbounded().cancel_after_polls(3);
        assert_eq!(c.should_stop(), None); // poll 0
        assert_eq!(c.should_stop(), None); // poll 1
        assert_eq!(c.should_stop(), None); // poll 2
        assert_eq!(c.should_stop(), Some(StopReason::Cancelled)); // poll 3
        // Stays stopped.
        assert_eq!(c.should_stop(), Some(StopReason::Cancelled));
    }

    #[test]
    fn poll_counter_is_shared_across_clones() {
        let c = RunControl::unbounded().cancel_after_polls(2);
        let d = c.clone();
        assert_eq!(c.should_stop(), None); // poll 0
        assert_eq!(d.should_stop(), None); // poll 1
        assert_eq!(c.should_stop(), Some(StopReason::Cancelled)); // poll 2
    }

    #[test]
    fn cancellation_outranks_deadline() {
        let t = CancelToken::new();
        t.cancel();
        let c = RunControl::unbounded()
            .with_token(t)
            .with_deadline(Deadline::after(Duration::from_secs(0)));
        assert_eq!(c.should_stop(), Some(StopReason::Cancelled));
    }

    #[test]
    fn with_deadline_sooner_keeps_the_earlier_budget() {
        // Earlier-then-later: the zero deadline must survive.
        let c = RunControl::unbounded()
            .with_deadline(Deadline::after_secs(0.0))
            .with_deadline_sooner(Deadline::after_secs(3600.0));
        assert_eq!(c.should_stop(), Some(StopReason::DeadlineExceeded));
        // Later-then-earlier: the zero deadline must win.
        let c = RunControl::unbounded()
            .with_deadline(Deadline::after_secs(3600.0))
            .with_deadline_sooner(Deadline::after_secs(0.0));
        assert_eq!(c.should_stop(), Some(StopReason::DeadlineExceeded));
        // On an unarmed control it simply arms.
        let c = RunControl::unbounded()
            .with_deadline_sooner(Deadline::after_secs(0.0));
        assert_eq!(c.should_stop(), Some(StopReason::DeadlineExceeded));
    }

    #[test]
    fn deadline_remaining_saturates() {
        let d = Deadline::after_secs(0.0);
        assert!(d.exceeded());
        assert_eq!(d.remaining(), Duration::ZERO);
        let d = Deadline::after_secs(-5.0);
        assert!(d.exceeded());
    }

    #[test]
    fn stop_reason_labels() {
        assert_eq!(StopReason::Cancelled.label(), "cancelled");
        assert_eq!(StopReason::DeadlineExceeded.label(), "deadline");
    }

    /// Regression: a second install used to clobber the first token
    /// (each call spawned its own watcher around a fresh flagless
    /// token). Both tokens must now observe one raised signal, and a
    /// token requested after the trip must be born cancelled. This is
    /// the only in-process test that raises a signal (the handler
    /// restores the default disposition after the first one); the serve
    /// integration tests signal child processes instead.
    #[cfg(unix)]
    #[test]
    fn two_installed_tokens_both_observe_a_signal() {
        extern "C" {
            fn raise(sig: i32) -> i32;
        }
        let a = install_signal_token();
        let b = install_sigint_token(); // the alias registers too
        assert!(!a.is_cancelled());
        assert!(!b.is_cancelled());
        unsafe {
            raise(sys::SIGTERM);
        }
        let start = Instant::now();
        while !(a.is_cancelled() && b.is_cancelled()) {
            assert!(
                start.elapsed() < Duration::from_secs(5),
                "watcher never fanned the signal out to both tokens"
            );
            std::thread::sleep(Duration::from_millis(10));
        }
        let c = install_signal_token();
        assert!(c.is_cancelled(), "post-trip install must come back set");
    }
}
