//! Descriptive statistics for benchmark reporting and result summaries.

/// Summary statistics over a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Minimum.
    pub min: f64,
    /// Maximum.
    pub max: f64,
    /// Arithmetic mean.
    pub mean: f64,
    /// 50th percentile (linear interpolation).
    pub median: f64,
    /// 95th percentile (linear interpolation).
    pub p95: f64,
    /// Population standard deviation.
    pub stddev: f64,
}

impl Summary {
    /// Compute a summary; panics on empty input.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty slice");
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        Summary {
            n,
            min: sorted[0],
            max: sorted[n - 1],
            mean,
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            stddev: var.sqrt(),
        }
    }
}

/// Percentile (linear interpolation) of a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Geometric mean (the paper's "best on average" cluster uses this).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Relative difference |a-b| / max(|a|,|b|, eps).
pub fn rel_diff(a: f64, b: f64) -> f64 {
    (a - b).abs() / a.abs().max(b.abs()).max(1e-30)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert_eq!(percentile_sorted(&sorted, 50.0), 5.0);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 100.0), 10.0);
    }

    #[test]
    fn geomean_matches_hand_calc() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn rel_diff_symmetric() {
        assert_eq!(rel_diff(1.0, 2.0), rel_diff(2.0, 1.0));
        assert_eq!(rel_diff(5.0, 5.0), 0.0);
        assert!(rel_diff(0.0, 0.0) == 0.0);
    }

    #[test]
    fn stddev_zero_for_constant() {
        let s = Summary::of(&[3.0; 10]);
        assert_eq!(s.stddev, 0.0);
    }
}
