//! Micro-benchmark harness for `cargo bench` (harness = false).
//!
//! The offline crate set vendors no `criterion`, so COMET ships its own
//! small harness with the same ergonomics: warmup, timed iterations,
//! median/p95 reporting, and a `black_box` to defeat dead-code elimination.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

use super::json::{obj, parse, Value};
use super::stats::Summary;
use crate::error::{Error, Result};

/// Re-export of `std::hint::black_box` under the criterion-familiar name.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Benchmark runner configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Warmup wall-clock budget.
    pub warmup: Duration,
    /// Measurement wall-clock budget.
    pub measure: Duration,
    /// Hard cap on measured iterations.
    pub max_iters: usize,
    /// Minimum measured iterations (even if over budget).
    pub min_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            max_iters: 10_000,
            min_iters: 5,
        }
    }
}

/// One benchmark's results.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Timing summary over the measured iterations, seconds.
    pub summary: Summary,
}

impl BenchResult {
    /// Serialize to a JSON object (seconds, like the summary).
    pub fn to_json(&self) -> Value {
        let s = &self.summary;
        obj(vec![
            ("name", Value::Str(self.name.clone())),
            ("median_s", Value::Num(s.median)),
            ("mean_s", Value::Num(s.mean)),
            ("p95_s", Value::Num(s.p95)),
            ("min_s", Value::Num(s.min)),
            ("max_s", Value::Num(s.max)),
            ("iters", Value::Num(s.n as f64)),
        ])
    }

    /// Render one line, criterion-style.
    pub fn line(&self) -> String {
        let s = &self.summary;
        format!(
            "{:<44} median {:>12}  mean {:>12}  p95 {:>12}  ({} iters)",
            self.name,
            fmt_dur(s.median),
            fmt_dur(s.mean),
            fmt_dur(s.p95),
            s.n
        )
    }
}

fn fmt_dur(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} us", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Benchmark group: runs closures, collects results, prints a report.
pub struct Bencher {
    cfg: BenchConfig,
    results: Vec<BenchResult>,
    /// Scalar side-metrics (cache hit counts, evaluated-point counts, ...)
    /// recorded alongside the timings in every trajectory point.
    metrics: Vec<(String, f64)>,
}

impl Bencher {
    /// New bencher with default config. Honors `COMET_BENCH_FAST=1` to
    /// shrink budgets (used by `cargo test`-driven smoke runs).
    pub fn new() -> Self {
        let mut cfg = BenchConfig::default();
        if std::env::var("COMET_BENCH_FAST").as_deref() == Ok("1") {
            cfg.warmup = Duration::from_millis(20);
            cfg.measure = Duration::from_millis(100);
        }
        Bencher {
            cfg,
            results: Vec::new(),
            metrics: Vec::new(),
        }
    }

    /// With an explicit config.
    pub fn with_config(cfg: BenchConfig) -> Self {
        Bencher {
            cfg,
            results: Vec::new(),
            metrics: Vec::new(),
        }
    }

    /// Record a scalar side-metric (e.g. cache hits, evaluated points).
    /// Metrics print with the report and land in the `metrics` object of
    /// the JSON trajectory point, so counters stop being write-only.
    pub fn metric(&mut self, name: &str, value: f64) {
        self.metrics.push((name.to_string(), value));
    }

    /// Recorded side-metrics.
    pub fn metrics(&self) -> &[(String, f64)] {
        &self.metrics
    }

    /// Time `f`, which must consume its work via `black_box`.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.cfg.warmup {
            f();
        }
        // Measure.
        let mut samples = Vec::new();
        let start = Instant::now();
        while (start.elapsed() < self.cfg.measure
            && samples.len() < self.cfg.max_iters)
            || samples.len() < self.cfg.min_iters
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        self.results.push(BenchResult {
            name: name.to_string(),
            summary: Summary::of(&samples),
        });
        self.results.last().unwrap()
    }

    /// Print the report for all benches run so far.
    pub fn report(&self, title: &str) {
        println!("\n== {title} ==");
        for r in &self.results {
            println!("{}", r.line());
        }
        for (name, value) in &self.metrics {
            println!("{name:<44} {value}");
        }
    }

    /// Access collected results.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Append one trajectory point to a `BENCH_*.json` file: the file is
    /// an object `{"points": [...]}` and each run pushes
    /// `{"label", "unix_time_s", "results": [...]}` so successive runs on
    /// the same machine build a wall-clock trajectory (see BENCHMARKS.md).
    pub fn append_json(&self, path: &str, label: &str) -> Result<()> {
        let mut root = match std::fs::read_to_string(path) {
            Ok(text) => parse(&text)?,
            // Only a genuinely absent file starts a fresh trajectory; any
            // other read failure must not clobber an existing history
            // (BENCH_*.json carries schema/baseline metadata alongside
            // "points").
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                obj(vec![("points", Value::Arr(Vec::new()))])
            }
            Err(e) => return Err(Error::Io(format!("{path}: {e}"))),
        };
        let unix = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs() as f64)
            .unwrap_or(0.0);
        let mut point = obj(vec![
            ("label", Value::Str(label.to_string())),
            ("unix_time_s", Value::Num(unix)),
            (
                "results",
                Value::Arr(self.results.iter().map(|r| r.to_json()).collect()),
            ),
        ]);
        if !self.metrics.is_empty() {
            let mut mm = std::collections::BTreeMap::new();
            for (name, value) in &self.metrics {
                mm.insert(name.clone(), Value::Num(*value));
            }
            if let Value::Obj(p) = &mut point {
                p.insert("metrics".into(), Value::Obj(mm));
            }
        }
        let Value::Obj(m) = &mut root else {
            return Err(Error::Json(format!("{path}: root is not an object")));
        };
        let points = m
            .entry("points".to_string())
            .or_insert_with(|| Value::Arr(Vec::new()));
        let Value::Arr(a) = points else {
            return Err(Error::Json(format!(
                "{path}: \"points\" is not an array"
            )));
        };
        a.push(point);
        // Write-then-rename so a crash mid-write can't leave a truncated
        // trajectory behind.
        let tmp = format!("{path}.tmp");
        std::fs::write(&tmp, root.to_string_pretty() + "\n")?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast() -> Bencher {
        Bencher::with_config(BenchConfig {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(10),
            max_iters: 1000,
            min_iters: 3,
        })
    }

    #[test]
    fn bench_collects_samples() {
        let mut b = fast();
        let r = b.bench("noop", || {
            black_box(1 + 1);
        });
        assert!(r.summary.n >= 3);
        assert!(r.summary.median >= 0.0);
    }

    #[test]
    fn results_accumulate() {
        let mut b = fast();
        b.bench("a", || {
            black_box(0);
        });
        b.bench("b", || {
            black_box(0);
        });
        assert_eq!(b.results().len(), 2);
        assert_eq!(b.results()[0].name, "a");
    }

    #[test]
    fn append_json_builds_trajectory() {
        let path = std::env::temp_dir()
            .join(format!("comet_bench_{}.json", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);

        let mut b = fast();
        b.bench("noop", || {
            black_box(1);
        });
        b.append_json(&path, "first").unwrap();
        b.append_json(&path, "second").unwrap();

        let text = std::fs::read_to_string(&path).unwrap();
        let v = crate::util::json::parse(&text).unwrap();
        let points = v.get("points").unwrap().as_arr().unwrap();
        assert_eq!(points.len(), 2);
        assert_eq!(points[0].get("label").unwrap().as_str(), Some("first"));
        let results = points[1].get("results").unwrap().as_arr().unwrap();
        assert_eq!(results[0].get("name").unwrap().as_str(), Some("noop"));
        assert!(results[0].get("median_s").unwrap().as_f64().unwrap() >= 0.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn metrics_land_in_trajectory_point() {
        let path = std::env::temp_dir()
            .join(format!("comet_bench_metrics_{}.json", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);

        let mut b = fast();
        b.bench("noop", || {
            black_box(1);
        });
        b.metric("cache_hits", 42.0);
        b.metric("evaluated_points", 9.0);
        assert_eq!(b.metrics().len(), 2);
        b.append_json(&path, "with-metrics").unwrap();

        let text = std::fs::read_to_string(&path).unwrap();
        let v = crate::util::json::parse(&text).unwrap();
        let point = &v.get("points").unwrap().as_arr().unwrap()[0];
        let metrics = point.get("metrics").unwrap();
        assert_eq!(metrics.get("cache_hits").unwrap().as_f64(), Some(42.0));
        assert_eq!(
            metrics.get("evaluated_points").unwrap().as_f64(),
            Some(9.0)
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fmt_dur_scales() {
        assert_eq!(fmt_dur(2.0), "2.000 s");
        assert_eq!(fmt_dur(2e-3), "2.000 ms");
        assert_eq!(fmt_dur(2e-6), "2.000 us");
        assert_eq!(fmt_dur(2e-9), "2.0 ns");
    }
}
