//! SI unit helpers. COMET is unit-disciplined: FLOP/s, bytes, bytes/s,
//! seconds everywhere; these constructors keep config code legible and
//! mistakes greppable.

/// 1 kilo (10^3).
pub const K: f64 = 1e3;
/// 1 mega (10^6).
pub const M: f64 = 1e6;
/// 1 giga (10^9).
pub const G: f64 = 1e9;
/// 1 tera (10^12).
pub const T: f64 = 1e12;
/// 1 peta (10^15).
pub const P: f64 = 1e15;

/// Tera-FLOP/s → FLOP/s.
#[inline]
pub fn tflops(x: f64) -> f64 {
    x * T
}

/// Peta-FLOP/s → FLOP/s.
#[inline]
pub fn pflops(x: f64) -> f64 {
    x * P
}

/// Gigabytes → bytes (decimal GB, as in the paper's tables).
#[inline]
pub fn gb(x: f64) -> f64 {
    x * G
}

/// Megabytes → bytes.
#[inline]
pub fn mb(x: f64) -> f64 {
    x * M
}

/// Terabytes → bytes.
#[inline]
pub fn tb(x: f64) -> f64 {
    x * T
}

/// GB/s → bytes/s.
#[inline]
pub fn gbps(x: f64) -> f64 {
    x * G
}

/// TB/s → bytes/s.
#[inline]
pub fn tbps(x: f64) -> f64 {
    x * T
}

/// Microseconds → seconds.
#[inline]
pub fn us(x: f64) -> f64 {
    x * 1e-6
}

/// Render a byte count human-readably (decimal units, 1 decimal place).
pub fn fmt_bytes(b: f64) -> String {
    if b >= T {
        format!("{:.1} TB", b / T)
    } else if b >= G {
        format!("{:.1} GB", b / G)
    } else if b >= M {
        format!("{:.1} MB", b / M)
    } else if b >= K {
        format!("{:.1} KB", b / K)
    } else {
        format!("{b:.0} B")
    }
}

/// Render seconds human-readably.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_scale() {
        assert_eq!(tflops(624.0), 624e12);
        assert_eq!(gb(80.0), 80e9);
        assert_eq!(gbps(2039.0), 2039e9);
        assert_eq!(tbps(2.0), 2e12);
        assert_eq!(mb(40.0), 40e6);
        assert_eq!(pflops(54.3), 54.3e15);
        assert_eq!(us(1.0), 1e-6);
    }

    #[test]
    fn fmt_bytes_picks_unit() {
        assert_eq!(fmt_bytes(80e9), "80.0 GB");
        assert_eq!(fmt_bytes(1.5e12), "1.5 TB");
        assert_eq!(fmt_bytes(40e6), "40.0 MB");
        assert_eq!(fmt_bytes(512.0), "512 B");
    }

    #[test]
    fn fmt_secs_picks_unit() {
        assert_eq!(fmt_secs(2.5), "2.500 s");
        assert_eq!(fmt_secs(0.0025), "2.500 ms");
        assert_eq!(fmt_secs(2.5e-6), "2.500 us");
    }
}
