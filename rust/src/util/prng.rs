//! Deterministic PRNG (SplitMix64 + xoshiro256**), built from scratch —
//! the vendored crate set has no `rand`. Used by the property-test harness
//! and synthetic-workload generators. Not cryptographic.

/// xoshiro256** seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f64 in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform usize in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Pick one element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Log-uniform value in [lo, hi) — good for sweeping magnitudes.
    pub fn log_range(&mut self, lo: f64, hi: f64) -> f64 {
        (self.range(lo.ln(), hi.ln())).exp()
    }

    /// Random power of two in [2^lo_exp, 2^hi_exp].
    pub fn pow2(&mut self, lo_exp: u32, hi_exp: u32) -> f64 {
        let e = lo_exp + (self.next_u64() % (hi_exp - lo_exp + 1) as u64) as u32;
        (1u64 << e) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_roughly_uniform() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn pow2_is_power_of_two_in_range() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            let x = r.pow2(0, 10) as u64;
            assert!(x.is_power_of_two());
            assert!((1..=1024).contains(&x));
        }
    }

    #[test]
    fn log_range_spans_magnitudes() {
        let mut r = Rng::new(13);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..10_000 {
            let x = r.log_range(1.0, 1e6);
            assert!((1.0..1e6).contains(&x));
            if x < 10.0 {
                lo_seen = true;
            }
            if x > 1e5 {
                hi_seen = true;
            }
        }
        assert!(lo_seen && hi_seen);
    }
}
