//! PJRT client wrapper: artifact discovery, ABI verification, compilation.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::model::batch;
use crate::util::json;

// The offline build vendors no external crates; the stub mirrors the PJRT
// API surface and fails at `PjRtClient::cpu()`. Swap this import for the
// real `xla` crate to re-enable the artifact backend.
use super::xla_stub as xla;

/// A loaded PJRT runtime holding one compiled executable per exported
/// batch size.
pub struct Runtime {
    client: xla::PjRtClient,
    exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("dir", &self.dir)
            .field("batch_sizes", &self.batch_sizes())
            .finish()
    }
}

impl Runtime {
    /// Load and compile every artifact listed in `<dir>/manifest.json`.
    ///
    /// Fails fast on ABI drift between the manifest and this crate's
    /// compiled-in layout.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Artifact(format!(
                "{} (run `make artifacts`): {e}",
                manifest_path.display()
            ))
        })?;
        let manifest = json::parse(&text)?;
        batch::verify_manifest(&manifest)?;

        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PjRtClient::cpu: {e}")))?;

        let arts = manifest.get("artifacts").unwrap();
        let mut exes = BTreeMap::new();
        for b in batch::BATCH_SIZES {
            let name = arts
                .get(&b.to_string())
                .and_then(|v| v.as_str())
                .ok_or_else(|| {
                    Error::AbiMismatch(format!("no artifact for batch {b}"))
                })?;
            let path = dir.join(name);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| {
                    Error::Artifact(format!("non-utf8 path {}", path.display()))
                })?,
            )
            .map_err(|e| {
                Error::Artifact(format!("parse {}: {e}", path.display()))
            })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(|e| {
                Error::Runtime(format!("compile {}: {e}", path.display()))
            })?;
            exes.insert(b, exe);
        }
        Ok(Runtime {
            client,
            exes,
            dir: dir.to_path_buf(),
        })
    }

    /// Load from the default `artifacts/` directory.
    pub fn load_default() -> Result<Runtime> {
        Self::load(Path::new(super::DEFAULT_ARTIFACTS_DIR))
    }

    /// Available batch sizes (ascending).
    pub fn batch_sizes(&self) -> Vec<usize> {
        self.exes.keys().copied().collect()
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Smallest exported batch size that fits `n` configs, or the largest
    /// available (callers then chunk).
    pub fn pick_batch_size(&self, n: usize) -> usize {
        for &b in self.exes.keys() {
            if n <= b {
                return b;
            }
        }
        *self.exes.keys().last().unwrap()
    }

    /// Execute the `b`-batch executable on packed tensors; returns the raw
    /// `[b, OUTF]` output.
    pub fn execute(&self, tensors: &batch::BatchTensors) -> Result<Vec<f32>> {
        let exe = self.exes.get(&tensors.b).ok_or_else(|| {
            Error::Runtime(format!("no executable for batch size {}", tensors.b))
        })?;
        let b = tensors.b as i64;
        let mk = |data: &[f32], dims: &[i64]| -> Result<xla::Literal> {
            xla::Literal::vec1(data)
                .reshape(dims)
                .map_err(|e| Error::Runtime(format!("reshape: {e}")))
        };
        let compute = mk(
            &tensors.compute,
            &[b, batch::L as i64, batch::CF as i64],
        )?;
        let comm = mk(&tensors.comm, &[b, batch::L as i64, batch::MF as i64])?;
        let params = mk(&tensors.params, &[b, batch::P as i64])?;

        let result = exe
            .execute::<xla::Literal>(&[compute, comm, params])
            .map_err(|e| Error::Runtime(format!("execute: {e}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("to_literal: {e}")))?;
        // Lowered with return_tuple=True: unwrap the 1-tuple.
        let out = result
            .to_tuple1()
            .map_err(|e| Error::Runtime(format!("to_tuple1: {e}")))?;
        out.to_vec::<f32>()
            .map_err(|e| Error::Runtime(format!("to_vec: {e}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        // Integration-grade tests live in rust/tests/; here we only
        // exercise load when artifacts exist.
        Runtime::load_default().ok()
    }

    #[test]
    fn load_reports_missing_dir() {
        let err = Runtime::load(Path::new("/nonexistent/prefix")).unwrap_err();
        assert!(matches!(err, Error::Artifact(_)));
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn loaded_runtime_has_all_batch_sizes() {
        let Some(rt) = runtime() else { return };
        assert_eq!(rt.batch_sizes(), batch::BATCH_SIZES.to_vec());
        assert_eq!(rt.platform(), "cpu");
    }

    #[test]
    fn pick_batch_size_rounds_up() {
        let Some(rt) = runtime() else { return };
        assert_eq!(rt.pick_batch_size(1), 8);
        assert_eq!(rt.pick_batch_size(8), 8);
        assert_eq!(rt.pick_batch_size(9), 64);
        assert_eq!(rt.pick_batch_size(64), 64);
        assert_eq!(rt.pick_batch_size(1000), 64);
    }
}
