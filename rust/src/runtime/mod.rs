//! PJRT runtime: loads the AOT-compiled COMET cost-model artifacts
//! (`artifacts/comet_eval_b{B}.hlo.txt`, exported once at build time by
//! `python/compile/aot.py`) and executes them on the request path via the
//! `xla` crate's PJRT CPU client. Python never runs here.
//!
//! HLO **text** is the interchange format: jax >= 0.5 serializes protos
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see DESIGN.md and python/compile/aot.py).

mod batch_eval;
mod client;
mod xla_stub;

pub use batch_eval::BatchEvaluator;
pub use client::Runtime;

/// Default artifacts directory relative to the repo root.
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";
