//! Batched design-space evaluation through the AOT artifact: pack many
//! (workload, cluster) configurations, execute them per batch, and unpack
//! per-config [`TrainingBreakdown`]s.

use std::cell::RefCell;

use crate::analytical::TrainingBreakdown;
use crate::error::Result;
use crate::model::batch::{self, BatchTensors, PackedConfig};
use crate::model::inputs::ModelInputs;

use super::client::Runtime;

/// Batched evaluator over a loaded runtime.
pub struct BatchEvaluator<'a> {
    runtime: &'a Runtime,
    /// Scratch batch tensors reused across chunks and calls (SPerf).
    scratch: RefCell<BatchTensors>,
}

impl<'a> BatchEvaluator<'a> {
    /// Wrap a runtime.
    pub fn new(runtime: &'a Runtime) -> Self {
        BatchEvaluator {
            runtime,
            scratch: RefCell::new(BatchTensors {
                b: 0,
                compute: Vec::new(),
                comm: Vec::new(),
                params: Vec::new(),
                n_real: 0,
            }),
        }
    }

    /// Evaluate many derived inputs; returns one breakdown per input, in
    /// order. Inputs are packed and chunked to the artifact batch sizes.
    pub fn evaluate(
        &self,
        inputs: &[ModelInputs],
    ) -> Result<Vec<TrainingBreakdown>> {
        let packed: Vec<PackedConfig> = inputs
            .iter()
            .map(batch::pack)
            .collect::<Result<Vec<_>>>()?;
        let mut out = Vec::with_capacity(packed.len());
        let mut i = 0;
        let mut scratch = self.scratch.borrow_mut();
        while i < packed.len() {
            let remaining = packed.len() - i;
            let b = self.runtime.pick_batch_size(remaining);
            let take = remaining.min(b);
            batch::stack_into(&packed[i..i + take], b, &mut scratch)?;
            let raw = self.runtime.execute(&scratch)?;
            debug_assert_eq!(raw.len(), b * batch::OUTF);
            for k in 0..take {
                let mut a = [0.0f64; 6];
                for (j, v) in a.iter_mut().enumerate() {
                    *v = raw[k * batch::OUTF + j] as f64;
                }
                out.push(TrainingBreakdown::from_array(a));
            }
            i += take;
        }
        Ok(out)
    }

    /// Evaluate a single configuration (uses the smallest artifact).
    pub fn evaluate_one(&self, inputs: &ModelInputs) -> Result<TrainingBreakdown> {
        Ok(self.evaluate(std::slice::from_ref(inputs))?.remove(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytical::evaluate as native_eval;
    use crate::config::presets;
    use crate::model::inputs::{derive_inputs, EvalOptions};
    use crate::parallel::Strategy;
    use crate::util::stats::rel_diff;
    use crate::workload::transformer::Transformer;

    /// Artifact (f32, Pallas kernels) vs native (f64) cross-validation —
    /// the heart of the three-layer contract. Skips when artifacts are
    /// absent (rust/tests/ has the hard-required variant).
    #[test]
    fn artifact_matches_native_when_available() {
        let Ok(rt) = Runtime::load_default() else {
            return;
        };
        let ev = BatchEvaluator::new(&rt);
        let cluster = presets::dgx_a100_1024();
        let opts = EvalOptions {
            ignore_capacity: true,
            ..Default::default()
        };
        let inputs: Vec<_> = Strategy::sweep_bounded(1024, 1, 128)
            .unwrap()
            .iter()
            .map(|s| {
                derive_inputs(
                    &Transformer::t1().build(s).unwrap(),
                    &cluster,
                    &opts,
                )
                .unwrap()
            })
            .collect();
        let got = ev.evaluate(&inputs).unwrap();
        assert_eq!(got.len(), inputs.len());
        for (inp, g) in inputs.iter().zip(&got) {
            let want = native_eval(inp);
            assert!(
                rel_diff(want.total(), g.total()) < 1e-4,
                "{}: native {} artifact {}",
                inp.name,
                want.total(),
                g.total()
            );
        }
    }
}
