//! Offline stand-in for the `xla` crate's PJRT bindings.
//!
//! The build environment vendors no external crates, so the PJRT surface
//! [`super::client`] consumes is mirrored here with the same signatures.
//! Artifact discovery and ABI verification still run against the real
//! `artifacts/` manifest; the first call that would need the native XLA
//! runtime ([`PjRtClient::cpu`]) fails with a descriptive error, which
//! `Coordinator::auto` turns into a clean fallback to the native backend.
//! Swapping `use super::xla_stub as xla;` in `client.rs` for the real
//! crate re-enables the PJRT path unchanged.

/// Stub error: a plain message (the real crate's error is also rendered
/// via `Display` at every call site).
pub type XlaError = String;

fn unavailable(what: &str) -> XlaError {
    format!("{what} unavailable: the `xla` PJRT bindings are not vendored in this offline build")
}

/// PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    /// The real entry point; always fails in the stub.
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Platform name (diagnostics).
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile an HLO computation.
    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse HLO text from a file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping a module proto.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute on device; returns per-device, per-output buffers.
    pub fn execute<T>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A host tensor literal.
pub struct Literal;

impl Literal {
    /// Build a rank-1 f32 literal.
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    /// Reshape to `dims`.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Ok(Literal)
    }

    /// Unwrap a 1-tuple result.
    pub fn to_tuple1(self) -> Result<Literal, XlaError> {
        Err(unavailable("Literal::to_tuple1"))
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly_at_the_entry_point() {
        let err = PjRtClient::cpu().map(|_| ()).unwrap_err();
        assert!(err.contains("not vendored"), "{err}");
    }

    #[test]
    fn literal_shapes_are_inert() {
        // The packing path runs before execution; it must not error.
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_ok());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
