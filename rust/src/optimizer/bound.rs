//! Admissible lower bounds for the branch-and-bound optimizer.
//!
//! Both bounds are assembled from a [`WorkloadDecomposition`] with the
//! **same accumulation order** as [`crate::analytical::evaluate`], so a
//! fully specified leaf's bound is bit-for-bit `<=` its evaluated total:
//!
//! * [`compute_times`] — per-phase roofline compute time at a given
//!   memory bandwidth. Evaluated at the best bandwidth any point of a
//!   subtree can reach, it lower-bounds every point's compute time
//!   (compute delay is monotone non-increasing in bandwidth).
//! * [`blocking_comm_times`] — the FP and IG collective times for one
//!   collective implementation. These are *exact* (they do not depend on
//!   the expanded-memory axes); the WG collective is dropped entirely,
//!   which lower-bounds its exposed share (overlap can only shrink it
//!   to zero, never below).

use crate::analytical::pipeline_makespan;
use crate::compute::{compute_delay, gemm_traffic};
use crate::model::inputs::{LayerRecord, NodeParams, WorkloadDecomposition};
use crate::network::{collective_cost_auto, CollectiveImpl};
use crate::workload::Collective;

/// Per-phase `[FP, IG, WG]` compute times at memory bandwidth `bw`,
/// mirroring `analytical::evaluate`'s layer/phase accumulation order.
pub(crate) fn compute_times(
    dec: &WorkloadDecomposition,
    perf_peak: f64,
    sram: f64,
    bw: f64,
) -> [f64; 3] {
    let mut compute = [0.0f64; 3];
    for layer in &dec.layers {
        for (slot, q) in compute.iter_mut().zip(&layer.q) {
            let traffic = gemm_traffic(q.u, q.v, q.w, sram);
            *slot +=
                layer.repeat * compute_delay(q.flops, traffic, perf_peak, bw);
        }
    }
    compute
}

/// Blocking `(FP, IG)` collective times for one implementation over the
/// branch template's already-resolved layer records, mirroring
/// `analytical::evaluate`'s layer accumulation order (and its
/// `Collective::None` fast path). The records carry the group shapes —
/// two-level or tiered — so the dispatch matches evaluation exactly and
/// the FP/IG comm terms stay *exact* (not just admissible).
pub(crate) fn blocking_comm_times(
    layers: &[LayerRecord],
    p: &NodeParams,
    impl_: CollectiveImpl,
) -> (f64, f64) {
    let mut comm = [0.0f64; 2];
    for layer in layers {
        for (phase, slot) in comm.iter_mut().enumerate() {
            let c = &layer.comm[phase];
            if matches!(c.collective, Collective::None) {
                continue;
            }
            *slot += layer.repeat
                * collective_cost_auto(
                    c,
                    p.bw_intra,
                    p.bw_inter,
                    p.link_latency,
                    &p.tier_bw,
                    &p.tier_lat,
                    impl_,
                );
        }
    }
    (comm[0], comm[1])
}

/// Assemble a leaf bound from per-phase compute times and blocking FP/IG
/// communication, in the exact association order of
/// [`crate::analytical::TrainingBreakdown::total`] with the WG exposed
/// term replaced by its lower bound (zero). Because every term is
/// non-negative and f64 addition is monotone, the result is `<=` the
/// evaluated total bit-for-bit.
pub(crate) fn assemble(compute: [f64; 3], comm_fp: f64, comm_ig: f64) -> f64 {
    (((compute[0] + comm_fp) + compute[1]) + comm_ig) + compute[2]
}

/// Per-stage per-phase `[FP, IG, WG]` compute times at memory bandwidth
/// `bw`, mirroring the pipeline backend's per-stage accumulation order
/// (`analytical::evaluate`'s pipeline path).
pub(crate) fn stage_compute_times(
    dec: &WorkloadDecomposition,
    perf_peak: f64,
    sram: f64,
    bw: f64,
) -> Vec<[f64; 3]> {
    let pp = dec.pp.max(1);
    let mut compute = vec![[0.0f64; 3]; pp];
    for layer in &dec.layers {
        let s = layer.stage.min(pp - 1);
        for (slot, q) in compute[s].iter_mut().zip(&layer.q) {
            let traffic = gemm_traffic(q.u, q.v, q.w, sram);
            *slot +=
                layer.repeat * compute_delay(q.flops, traffic, perf_peak, bw);
        }
    }
    compute
}

/// Per-stage blocking `(FP, IG)` collective times for one implementation
/// over the branch template's resolved layer records, mirroring the
/// pipeline backend's per-stage accumulation order.
pub(crate) fn stage_blocking_comm_times(
    layers: &[LayerRecord],
    p: &NodeParams,
    impl_: CollectiveImpl,
) -> Vec<(f64, f64)> {
    let pp = p.pp.max(1);
    let mut comm = vec![(0.0f64, 0.0f64); pp];
    for layer in layers {
        let s = layer.stage.min(pp - 1);
        for phase in 0..2 {
            let c = &layer.comm[phase];
            if matches!(c.collective, Collective::None) {
                continue;
            }
            let cost = layer.repeat
                * collective_cost_auto(
                    c,
                    p.bw_intra,
                    p.bw_inter,
                    p.link_latency,
                    &p.tier_bw,
                    &p.tier_lat,
                    impl_,
                );
            if phase == 0 {
                comm[s].0 += cost;
            } else {
                comm[s].1 += cost;
            }
        }
    }
    comm
}

/// Assemble a pipeline leaf bound: per-microbatch stage services built
/// from the per-stage compute floors + exact blocking FP/IG collectives
/// (WG dropped — its exposed share is >= 0), composed through the same
/// fill–drain recurrence the evaluation uses
/// ([`crate::analytical::pipeline_makespan`]), with the exact boundary
/// transfer time `x`. The recurrence is monotone in every service time,
/// so the result lower-bounds the evaluated total bit-for-bit.
pub(crate) fn assemble_pipeline(
    compute: &[[f64; 3]],
    comm: &[(f64, f64)],
    m: usize,
    x: f64,
) -> f64 {
    let mf = m.max(1) as f64;
    let u: Vec<f64> = compute
        .iter()
        .zip(comm)
        .map(|(c, (fp, _))| (c[0] + fp) / mf)
        .collect();
    let b: Vec<f64> = compute
        .iter()
        .zip(comm)
        .map(|(c, (_, ig))| (c[1] + ig + c[2]) / mf)
        .collect();
    pipeline_makespan(&u, &b, x, m.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytical::evaluate;
    use crate::config::presets;
    use crate::model::inputs::{decompose, derive_inputs, EvalOptions};
    use crate::parallel::Strategy;
    use crate::workload::transformer::Transformer;

    #[test]
    fn assembled_bound_never_exceeds_evaluated_total() {
        let cluster = presets::dgx_a100_1024();
        let opts = EvalOptions {
            ignore_capacity: true,
            ..Default::default()
        };
        for s in Strategy::sweep_bounded(1024, 1, 128).unwrap() {
            let w = Transformer::t1().build(&s).unwrap();
            let dec = decompose(&w);
            let inputs = derive_inputs(&w, &cluster, &opts).unwrap();
            let b = evaluate(&inputs);
            // ignore_capacity forces the full local bandwidth — the bound
            // bandwidth equals the evaluated one, so the bound is the
            // total minus the exposed WG share, exactly.
            let compute = compute_times(
                &dec,
                cluster.node.perf_peak,
                cluster.node.sram,
                cluster.node.local.bandwidth,
            );
            let (c0, c1) = blocking_comm_times(
                &inputs.layers,
                &inputs.params,
                opts.collective_impl,
            );
            let lb = assemble(compute, c0, c1);
            assert!(
                lb <= b.total(),
                "{}: bound {lb} > total {}",
                s.label(),
                b.total()
            );
            // With WG fully overlapped (fig. 8), the bound is tight.
            if b.wg_exposed_comm == 0.0 {
                assert_eq!(lb.to_bits(), b.total().to_bits(), "{}", s.label());
            }
        }
    }

    #[test]
    fn pipeline_bound_never_exceeds_evaluated_total() {
        let cluster = presets::dgx_a100_1024();
        let view = cluster.two_level().unwrap();
        for (pp, m) in [(2usize, 4usize), (4, 8), (8, 2)] {
            let s = Strategy::new_3d(8, 128 / pp, pp).unwrap();
            let w = Transformer::t1().build(&s).unwrap();
            let dec = decompose(&w);
            let opts = EvalOptions {
                ignore_capacity: true,
                microbatches: m,
                ..Default::default()
            };
            let inputs = derive_inputs(&w, &cluster, &opts).unwrap();
            let total = evaluate(&inputs).total();
            let compute = stage_compute_times(
                &dec,
                cluster.node.perf_peak,
                cluster.node.sram,
                cluster.node.local.bandwidth,
            );
            let comm = stage_blocking_comm_times(
                &inputs.layers,
                &inputs.params,
                opts.collective_impl,
            );
            let bw_b = if inputs.params.pp_inter {
                view.bw_inter
            } else {
                view.bw_intra
            };
            let x = (inputs.params.pp_boundary_bytes / m as f64)
                / bw_b.max(1.0)
                + cluster.link_latency;
            let lb = assemble_pipeline(&compute, &comm, m, x);
            assert!(
                lb <= total,
                "{} m={m}: bound {lb} > total {total}",
                s.label()
            );
        }
    }

    #[test]
    fn compute_times_monotone_in_bandwidth() {
        let w = Transformer::t1()
            .build(&Strategy::new(8, 128).unwrap())
            .unwrap();
        let dec = decompose(&w);
        let node = &presets::dgx_a100_1024().node;
        let slow: f64 = compute_times(&dec, node.perf_peak, node.sram, 500e9)
            .iter()
            .sum();
        let fast: f64 = compute_times(&dec, node.perf_peak, node.sram, 2039e9)
            .iter()
            .sum();
        assert!(fast <= slow);
    }
}
