//! Admissible lower bounds for the branch-and-bound optimizer.
//!
//! Both bounds are assembled from a [`WorkloadDecomposition`] with the
//! **same accumulation order** as [`crate::analytical::evaluate`], so a
//! fully specified leaf's bound is bit-for-bit `<=` its evaluated total:
//!
//! * [`compute_times`] — per-phase roofline compute time at a given
//!   memory bandwidth. Evaluated at the best bandwidth any point of a
//!   subtree can reach, it lower-bounds every point's compute time
//!   (compute delay is monotone non-increasing in bandwidth).
//! * [`blocking_comm_times`] — the FP and IG collective times for one
//!   collective implementation. These are *exact* (they do not depend on
//!   the expanded-memory axes); the WG collective is dropped entirely,
//!   which lower-bounds its exposed share (overlap can only shrink it
//!   to zero, never below).

use crate::compute::{compute_delay, gemm_traffic};
use crate::model::inputs::WorkloadDecomposition;
use crate::network::{collective_cost, CollectiveImpl};
use crate::workload::Collective;

/// Per-phase `[FP, IG, WG]` compute times at memory bandwidth `bw`,
/// mirroring `analytical::evaluate`'s layer/phase accumulation order.
pub(crate) fn compute_times(
    dec: &WorkloadDecomposition,
    perf_peak: f64,
    sram: f64,
    bw: f64,
) -> [f64; 3] {
    let mut compute = [0.0f64; 3];
    for layer in &dec.layers {
        for (slot, q) in compute.iter_mut().zip(&layer.q) {
            let traffic = gemm_traffic(q.u, q.v, q.w, sram);
            *slot +=
                layer.repeat * compute_delay(q.flops, traffic, perf_peak, bw);
        }
    }
    compute
}

/// Blocking `(FP, IG)` collective times for one implementation on the
/// cluster's two-level view, mirroring `analytical::evaluate`'s layer
/// accumulation order (and its `Collective::None` fast path).
pub(crate) fn blocking_comm_times(
    dec: &WorkloadDecomposition,
    pod_size: usize,
    bw_intra: f64,
    bw_inter: f64,
    lat: f64,
    impl_: CollectiveImpl,
) -> (f64, f64) {
    let mut comm = [0.0f64; 2];
    for layer in &dec.layers {
        for (phase, slot) in comm.iter_mut().enumerate() {
            let c = &layer.comm[phase];
            if matches!(c.collective, Collective::None) {
                continue;
            }
            let spec = dec.resolve_comm(c, pod_size);
            *slot += layer.repeat
                * collective_cost(&spec, bw_intra, bw_inter, lat, impl_);
        }
    }
    (comm[0], comm[1])
}

/// Assemble a leaf bound from per-phase compute times and blocking FP/IG
/// communication, in the exact association order of
/// [`crate::analytical::TrainingBreakdown::total`] with the WG exposed
/// term replaced by its lower bound (zero). Because every term is
/// non-negative and f64 addition is monotone, the result is `<=` the
/// evaluated total bit-for-bit.
pub(crate) fn assemble(compute: [f64; 3], comm_fp: f64, comm_ig: f64) -> f64 {
    (((compute[0] + comm_fp) + compute[1]) + comm_ig) + compute[2]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytical::evaluate;
    use crate::config::presets;
    use crate::model::inputs::{decompose, derive_inputs, EvalOptions};
    use crate::parallel::Strategy;
    use crate::workload::transformer::Transformer;

    #[test]
    fn assembled_bound_never_exceeds_evaluated_total() {
        let cluster = presets::dgx_a100_1024();
        let opts = EvalOptions {
            ignore_capacity: true,
            ..Default::default()
        };
        for s in Strategy::sweep_bounded(1024, 1, 128) {
            let w = Transformer::t1().build(&s).unwrap();
            let dec = decompose(&w);
            let inputs = derive_inputs(&w, &cluster, &opts).unwrap();
            let b = evaluate(&inputs);
            let view = cluster.two_level();
            // ignore_capacity forces the full local bandwidth — the bound
            // bandwidth equals the evaluated one, so the bound is the
            // total minus the exposed WG share, exactly.
            let compute = compute_times(
                &dec,
                cluster.node.perf_peak,
                cluster.node.sram,
                cluster.node.local.bandwidth,
            );
            let (c0, c1) = blocking_comm_times(
                &dec,
                view.pod_size,
                view.bw_intra,
                view.bw_inter,
                cluster.link_latency,
                opts.collective_impl,
            );
            let lb = assemble(compute, c0, c1);
            assert!(
                lb <= b.total(),
                "{}: bound {lb} > total {}",
                s.label(),
                b.total()
            );
            // With WG fully overlapped (fig. 8), the bound is tight.
            if b.wg_exposed_comm == 0.0 {
                assert_eq!(lb.to_bits(), b.total().to_bits(), "{}", s.label());
            }
        }
    }

    #[test]
    fn compute_times_monotone_in_bandwidth() {
        let w = Transformer::t1()
            .build(&Strategy::new(8, 128))
            .unwrap();
        let dec = decompose(&w);
        let node = &presets::dgx_a100_1024().node;
        let slow: f64 = compute_times(&dec, node.perf_peak, node.sram, 500e9)
            .iter()
            .sum();
        let fast: f64 = compute_times(&dec, node.perf_peak, node.sram, 2039e9)
            .iter()
            .sum();
        assert!(fast <= slow);
    }
}
