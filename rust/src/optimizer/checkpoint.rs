//! Versioned, crash-safe checkpoints for the branch-and-bound search.
//!
//! A checkpoint captures the driver state at a **batch-collection
//! boundary** — the only places the parallel driver's state provably
//! equals the sequential driver's state after the same evaluation prefix
//! (see the determinism argument in the module docs of
//! [`crate::optimizer`]). Because of that equality, a checkpoint written
//! by any driver at any thread count resumes on any driver at any thread
//! count to the same final [`crate::optimizer::Outcome`], bit for bit.
//!
//! The format stores **integers only**: evaluated leaves as canonical
//! lattice indices in evaluation order, the frontier heap as
//! `(sequence, branch-index | leaf-lattice-index)` pairs, and the next
//! sequence number. No f64 crosses the file boundary — on resume the
//! optimizer re-runs its deterministic preparation, re-expands the
//! referenced branches, and **replays** the evaluated indices through
//! the exact `eval_leaf`/`admit` sequence, reconstructing every bound,
//! score, and incumbent from scratch. Replay is cheap relative to the
//! search it saves (bounded by the evaluated prefix) and immune to any
//! question of float round-tripping.
//!
//! A fingerprint of the optimizer's full specification (cluster,
//! branches, axes, objective, fault model, options, top-k) guards
//! against resuming with a different spec; the `comet_checkpoint`
//! version key guards against format drift.

use std::path::Path;

use crate::error::{Error, Result};
use crate::util::json::{self, obj, Value};

/// Checkpoint format version. Bump on any layout change; old files are
/// rejected with an actionable error instead of being misread.
pub const VERSION: usize = 1;

/// A frontier-heap node: an unexpanded branch subtree (by branch index)
/// or a pending leaf (by canonical lattice index).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Node {
    /// Unexpanded branch subtree.
    Branch(usize),
    /// Pending feasible leaf, by canonical lattice index.
    Leaf(usize),
}

/// One frontier-heap entry: the node plus its insertion sequence number
/// (the deterministic FIFO tie-breaker of equal bounds).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HeapEntry {
    /// Heap insertion sequence (unique per entry).
    pub seq: usize,
    /// What the entry refers to.
    pub node: Node,
}

/// A serialized search state at a batch-collection boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Format version (see [`VERSION`]).
    pub version: usize,
    /// FNV-1a fingerprint of the optimizer spec that wrote this file;
    /// resume refuses a mismatch.
    pub fingerprint: u64,
    /// Why the checkpoint was written (`"cancelled"`, `"deadline"`,
    /// `"interval"`) — informational only.
    pub stop: String,
    /// Canonical lattice indices of every evaluated leaf, **in
    /// evaluation order** (the order `admit` replays them in).
    pub evaluated: Vec<usize>,
    /// The frontier heap, sorted by `seq` for a stable file layout
    /// (heap semantics do not depend on entry order — the (bound, seq)
    /// total order is strict).
    pub heap: Vec<HeapEntry>,
    /// The next sequence number the resumed driver hands out.
    pub next_seq: usize,
}

impl Checkpoint {
    /// Serialize to the on-disk JSON layout.
    pub fn to_json(&self) -> Value {
        let heap: Vec<Value> = self
            .heap
            .iter()
            .map(|e| {
                let (key, idx) = match e.node {
                    Node::Branch(i) => ("branch", i),
                    Node::Leaf(i) => ("leaf", i),
                };
                obj(vec![
                    ("seq", Value::Num(e.seq as f64)),
                    (key, Value::Num(idx as f64)),
                ])
            })
            .collect();
        obj(vec![
            ("comet_checkpoint", Value::Num(self.version as f64)),
            ("fingerprint", Value::Str(format!("{:016x}", self.fingerprint))),
            ("stop", Value::Str(self.stop.clone())),
            (
                "evaluated",
                Value::Arr(
                    self.evaluated
                        .iter()
                        .map(|&i| Value::Num(i as f64))
                        .collect(),
                ),
            ),
            ("heap", Value::Arr(heap)),
            ("next_seq", Value::Num(self.next_seq as f64)),
        ])
    }

    /// Parse the on-disk JSON layout, validating version and structure.
    pub fn from_json(v: &Value) -> Result<Checkpoint> {
        let version = v
            .get("comet_checkpoint")
            .and_then(Value::as_usize)
            .ok_or_else(|| {
                Error::Json(
                    "not a comet checkpoint (missing 'comet_checkpoint' \
                     version key)"
                        .into(),
                )
            })?;
        if version != VERSION {
            return Err(Error::Config(format!(
                "checkpoint version {version} is not supported (this build \
                 reads version {VERSION}); re-run without --resume"
            )));
        }
        let fingerprint = v
            .get("fingerprint")
            .and_then(Value::as_str)
            .and_then(|s| u64::from_str_radix(s, 16).ok())
            .ok_or_else(|| {
                Error::Json("checkpoint: bad or missing 'fingerprint'".into())
            })?;
        let stop = v
            .get("stop")
            .and_then(Value::as_str)
            .unwrap_or("unknown")
            .to_string();
        let evaluated = v
            .get("evaluated")
            .and_then(Value::as_arr)
            .ok_or_else(|| {
                Error::Json("checkpoint: missing 'evaluated' array".into())
            })?
            .iter()
            .map(|e| {
                e.as_usize().ok_or_else(|| {
                    Error::Json(
                        "checkpoint: non-integer lattice index in \
                         'evaluated'"
                            .into(),
                    )
                })
            })
            .collect::<Result<Vec<usize>>>()?;
        let heap = v
            .get("heap")
            .and_then(Value::as_arr)
            .ok_or_else(|| {
                Error::Json("checkpoint: missing 'heap' array".into())
            })?
            .iter()
            .map(|e| {
                let seq = e.get("seq").and_then(Value::as_usize).ok_or_else(
                    || Error::Json("checkpoint: heap entry missing 'seq'".into()),
                )?;
                let node = match (
                    e.get("branch").and_then(Value::as_usize),
                    e.get("leaf").and_then(Value::as_usize),
                ) {
                    (Some(b), None) => Node::Branch(b),
                    (None, Some(l)) => Node::Leaf(l),
                    _ => {
                        return Err(Error::Json(
                            "checkpoint: heap entry needs exactly one of \
                             'branch' or 'leaf'"
                                .into(),
                        ))
                    }
                };
                Ok(HeapEntry { seq, node })
            })
            .collect::<Result<Vec<HeapEntry>>>()?;
        let next_seq =
            v.get("next_seq").and_then(Value::as_usize).ok_or_else(|| {
                Error::Json("checkpoint: missing 'next_seq'".into())
            })?;
        Ok(Checkpoint {
            version,
            fingerprint,
            stop,
            evaluated,
            heap,
            next_seq,
        })
    }

    /// Parse a checkpoint from JSON text.
    pub fn parse(text: &str) -> Result<Checkpoint> {
        Checkpoint::from_json(&json::parse(text)?)
    }

    /// Write atomically: serialize to `<path>.tmp`, then rename over
    /// `path`, so a crash mid-write never leaves a torn checkpoint.
    pub fn save(&self, path: &Path) -> Result<()> {
        let text = self.to_json().to_string_pretty();
        let tmp = path.with_file_name(format!(
            "{}.tmp",
            path.file_name()
                .map(|n| n.to_string_lossy().into_owned())
                .unwrap_or_else(|| "checkpoint.json".into())
        ));
        std::fs::write(&tmp, text.as_bytes()).map_err(|e| {
            Error::Io(format!("writing checkpoint {}: {e}", tmp.display()))
        })?;
        std::fs::rename(&tmp, path).map_err(|e| {
            Error::Io(format!(
                "committing checkpoint {}: {e}",
                path.display()
            ))
        })
    }

    /// Load and parse a checkpoint file.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            Error::Io(format!("reading checkpoint {}: {e}", path.display()))
        })?;
        Checkpoint::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            version: VERSION,
            fingerprint: 0xdead_beef_0123_4567,
            stop: "deadline".into(),
            evaluated: vec![3, 0, 7],
            heap: vec![
                HeapEntry {
                    seq: 2,
                    node: Node::Branch(1),
                },
                HeapEntry {
                    seq: 5,
                    node: Node::Leaf(12),
                },
            ],
            next_seq: 6,
        }
    }

    #[test]
    fn json_round_trip_is_lossless() {
        let ck = sample();
        let text = ck.to_json().to_string_pretty();
        let back = Checkpoint::parse(&text).unwrap();
        assert_eq!(ck, back);
        // Fingerprints above 2^53 must survive (hex string, not f64).
        assert_eq!(back.fingerprint, 0xdead_beef_0123_4567);
    }

    #[test]
    fn unsupported_version_is_rejected_with_context() {
        let mut v = sample().to_json();
        if let Value::Obj(m) = &mut v {
            m.insert("comet_checkpoint".into(), Value::Num(99.0));
        }
        let err = Checkpoint::from_json(&v).unwrap_err();
        let s = err.to_string();
        assert!(s.contains("version 99"), "{s}");
        assert!(s.contains("--resume"), "{s}");
    }

    #[test]
    fn non_checkpoint_json_is_rejected() {
        let err = Checkpoint::parse("{\"hello\": 1}").unwrap_err();
        assert!(
            err.to_string().contains("comet_checkpoint"),
            "{err}"
        );
    }

    #[test]
    fn heap_entry_must_name_branch_xor_leaf() {
        let mut v = sample().to_json();
        if let Value::Obj(m) = &mut v {
            m.insert(
                "heap".into(),
                Value::Arr(vec![obj(vec![
                    ("seq", Value::Num(0.0)),
                    ("branch", Value::Num(1.0)),
                    ("leaf", Value::Num(2.0)),
                ])]),
            );
        }
        let err = Checkpoint::from_json(&v).unwrap_err();
        assert!(err.to_string().contains("exactly one"), "{err}");
    }

    #[test]
    fn save_and_load_round_trip_through_disk() {
        let ck = sample();
        let dir = std::env::temp_dir();
        let path = dir.join(format!(
            "comet_ckpt_test_{}.json",
            std::process::id()
        ));
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(ck, back);
    }

    #[test]
    fn load_of_missing_file_reports_path() {
        let err =
            Checkpoint::load(Path::new("/nonexistent/ck.json")).unwrap_err();
        let s = err.to_string();
        assert!(s.contains("/nonexistent/ck.json"), "{s}");
    }
}
