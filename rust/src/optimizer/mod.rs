//! Pruned co-design optimizer: branch-and-bound / best-first search over
//! the strategy x memory x collective design lattice.
//!
//! Every study in the repo used to answer "which configuration is best?"
//! by evaluating its *entire* cross-product grid. This module turns that
//! into a near-O(frontier) search: the lattice is explored best-first,
//! ordered by **admissible lower bounds** derived from the analytical
//! model (`bound.rs`), and a subtree is pruned the moment its bound
//! proves it cannot reach the current top-k —
//!
//! * a compute-only roofline floor at the best memory bandwidth any point
//!   of the subtree can reach lower-bounds total time (exposed
//!   communication is non-negative),
//! * the exact blocking FP/IG collective cost per implementation tightens
//!   the floor (it is independent of the expanded-memory axes), and
//! * `footprint_per_node` infeasibility (footprint beyond local +
//!   expanded capacity) prunes points without evaluating them, matching
//!   the Fig. 15 feasibility rule.
//!
//! The search tree has two levels: a **branch** per (workload, ZeRO
//! stage) — its decomposition is computed once through the coordinator's
//! derive cache — and a **leaf** per (expanded-memory bandwidth,
//! capacity, collective implementation) point under it. Pipeline-parallel
//! branches (`pp > 1`, optionally with per-branch microbatch/schedule
//! overrides) get an admissible pipeline bound: per-stage compute floors
//! + exact blocking collectives composed through the same fill–drain
//! recurrence the evaluation uses, with the exact boundary-transfer and
//! bubble terms at the branch's microbatch count (`bound.rs`). Results are the
//! exact argmin and top-k of exhaustive enumeration (ties broken by
//! canonical lattice order; pinned by `tests/properties.rs`), plus the
//! compute-vs-exposed-communication Pareto frontier of the evaluated
//! candidates. [`Optimizer::exhaustive`] evaluates the full grid through
//! the batched path and is both the testing oracle and the
//! `bench_optimizer` comparison baseline.
//!
//! # Parallel search
//!
//! [`Optimizer::search`] runs the branch-and-bound across the
//! coordinator's [`crate::coordinator::WorkerPool`] **without giving up
//! exactness**: the shared best-first frontier feeds batches of
//! speculative leaves to the pool, workers read an atomic incumbent
//! (monotonically tightening pruning threshold) before evaluating and
//! CAS it down after, and the results are merged back *deterministically*
//! in the frontier's canonical (bound, sequence) order by replaying the
//! sequential driver's incumbent updates. A speculative leaf the
//! sequential driver would never have reached is discarded; a leaf a
//! worker skipped (its bound lost to a mid-batch incumbent) but that the
//! replay does reach is evaluated lazily at merge time. The resulting
//! [`Outcome`] — argmin, top-k, frontier, and the
//! evaluated/pruned/infeasible counters — is therefore **bit-identical
//! at every thread count** to [`Optimizer::search_sequential`], the
//! in-tree equivalence oracle (pinned by `tests/properties.rs` at 1, 2,
//! and 8 lanes).
//!
//! Each leaf evaluation takes a zero-allocation fast path: the
//! branch-invariant resolved inputs (layer records, node parameters) are
//! computed once per branch during preparation, and a leaf only
//! stack-copies the parameter block, patches its two leaf-dependent
//! fields (expanded-memory bandwidth, collective implementation), and
//! calls [`crate::analytical::evaluate_parts`] — no per-point heap
//! allocation, no `ModelInputs` rebuild.
//!
//! # Cancellation, deadlines, and checkpoint/resume
//!
//! Both search drivers poll a [`crate::util::cancel::RunControl`] at
//! their safe boundaries — every heap pop in the sequential driver,
//! every batch-collection boundary in the parallel driver — via
//! [`Optimizer::search_with`] and friends. A stop does not discard the
//! run: it returns a *partial* [`Outcome`] (`complete == false`) with
//! the incumbent top-k, the frontier of what was evaluated, and a
//! `remaining` counter, and can flush a versioned JSON checkpoint
//! ([`checkpoint`]). Resuming from that checkpoint replays the recorded
//! evaluation prefix through the exact sequential admit/cutoff logic,
//! so the resumed run's final outcome is **bit-identical to an
//! uninterrupted run at any thread count** — the batch-boundary states
//! of the parallel driver are, by the determinism argument above,
//! exactly the sequential driver's states after the same prefix.

mod bound;
pub mod checkpoint;

use std::collections::BinaryHeap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::analytical::{
    evaluate_parts, goodput, pp_boundary_link, TrainingBreakdown,
};
use crate::compute::{em_fraction, hybrid_bandwidth};
use crate::config::ClusterConfig;
use crate::coordinator::{Backend, Coordinator};
use crate::error::{Error, Result};
use crate::model::inputs::{
    resolve_inputs, EvalOptions, ModelInputs, WorkloadDecomposition,
};
use crate::network::CollectiveImpl;
use crate::parallel::{PipeSchedule, ZeroStage};
use crate::resilience::{checkpoint_bandwidth, FaultModel};
use crate::util::cancel::{RunControl, StopReason};
use crate::workload::Workload;

use checkpoint::Checkpoint;

/// What the optimizer ranks candidates by.
///
/// Under [`Objective::Time`] a candidate's score **is** its evaluated
/// iteration time, bit-for-bit — nothing in the search changes. Under
/// [`Objective::Goodput`] the score is the *effective* time
/// `total / efficiency`, where the efficiency folds in Young/Daly
/// checkpoint–restart waste (from the candidate's own footprint over
/// the effective checkpoint bandwidth), straggler inflation, and link
/// degradation (see [`crate::analytical::goodput`]).
///
/// The existing analytical lower bounds stay admissible for the goodput
/// score: efficiency is clamped to `(0, 1]`, and dividing a total by a
/// value in `(0, 1]` is a single correctly-rounded, monotone f64
/// operation, so `score >= total >= bound` holds bit-wise. Pruning
/// against the incumbent k-th *score* therefore never discards a point
/// that could reach the top-k, and search == exhaustive is preserved at
/// every thread count.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Objective {
    /// Rank by raw per-iteration training time (the default).
    #[default]
    Time,
    /// Rank by failure-aware effective time (goodput).
    Goodput,
}

impl Objective {
    /// Parse a CLI/scenario objective name.
    pub fn parse(s: &str) -> Result<Objective> {
        match s {
            "time" => Ok(Objective::Time),
            "goodput" => Ok(Objective::Goodput),
            other => Err(Error::Config(format!(
                "unknown objective '{other}' (expected time|goodput)"
            ))),
        }
    }

    /// The canonical name (`time` / `goodput`).
    pub fn name(&self) -> &'static str {
        match self {
            Objective::Time => "time",
            Objective::Goodput => "goodput",
        }
    }
}

/// The per-branch memory/collective axes of the design lattice. Axes
/// default to a single baseline point (local memory only, spill-sized
/// capacity, logical-ring collectives), mirroring
/// [`crate::coordinator::GridSweep`].
#[derive(Debug, Clone)]
pub struct AxisSpec {
    /// Expanded-memory bandwidths, bytes/s (`None` = local memory only).
    em_bandwidths: Vec<Option<f64>>,
    /// Expanded-memory capacities, bytes (`None` = sized to the spill).
    em_capacities: Vec<Option<f64>>,
    /// Collective implementations.
    collectives: Vec<CollectiveImpl>,
}

impl Default for AxisSpec {
    fn default() -> Self {
        AxisSpec::new()
    }
}

impl AxisSpec {
    /// Baseline axes: local memory only, spill-sized capacity,
    /// logical-ring collectives.
    pub fn new() -> AxisSpec {
        AxisSpec {
            em_bandwidths: vec![None],
            em_capacities: vec![None],
            collectives: vec![CollectiveImpl::LogicalRing],
        }
    }

    /// Sweep expanded-memory bandwidth (bytes/s).
    pub fn em_bandwidths(mut self, bws: &[f64]) -> AxisSpec {
        self.em_bandwidths = bws.iter().map(|&b| Some(b)).collect();
        self
    }

    /// Sweep expanded-memory capacity (bytes) instead of sizing it to the
    /// spill.
    pub fn em_capacities(mut self, caps: &[f64]) -> AxisSpec {
        self.em_capacities = caps.iter().map(|&c| Some(c)).collect();
        self
    }

    /// Sweep collective implementations.
    pub fn collective_impls(mut self, impls: &[CollectiveImpl]) -> AxisSpec {
        self.collectives = impls.to_vec();
        self
    }

    /// Points per branch (cross-product of the three axes).
    pub fn len(&self) -> usize {
        self.em_bandwidths.len()
            * self.em_capacities.len()
            * self.collectives.len()
    }

    /// Whether the axes name no points.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One (workload, ZeRO stage) branch of the search tree. The caller
/// builds these — e.g. one per strategy x stage for a transformer, or a
/// single one for a rigid DLRM packing.
#[derive(Debug, Clone)]
pub struct Branch {
    /// Row label ("MP8_DP128", "MP8_DP128 zero-3", "16 nodes", ...).
    pub label: String,
    /// The decomposed workload (ZeRO communication multipliers already
    /// applied when the stage axis is explicit).
    pub workload: Workload,
    /// ZeRO stage of this branch (drives the footprint and the
    /// evaluation options).
    pub stage: ZeroStage,
    /// `Some` for workloads whose footprint is not the generic ZeRO
    /// formula (DLRM's embedding shard); forwarded into
    /// [`EvalOptions::footprint_override`]. When `None`, the optimizer
    /// computes the footprint from the decomposition — exactly what
    /// derivation will use, so the bounds stay exact by construction.
    pub footprint_override: Option<f64>,
    /// Per-branch microbatch-count override for pipeline workloads
    /// (`None` = the optimizer-wide options) — this is how the pipeline
    /// study's PP x microbatch x schedule lattice maps onto branches.
    pub microbatches: Option<usize>,
    /// Per-branch pipeline-schedule override (`None` = the options).
    pub schedule: Option<PipeSchedule>,
}

/// One fully specified point of the design lattice.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DesignPoint {
    /// Index of the branch this point belongs to.
    pub branch: usize,
    /// Expanded-memory bandwidth, bytes/s (`None` = local memory only).
    pub em_bandwidth: Option<f64>,
    /// Expanded-memory capacity, bytes (`None` = sized to the spill).
    pub em_capacity: Option<f64>,
    /// Collective implementation.
    pub collective: CollectiveImpl,
    /// Canonical lattice index (branch-major, then bandwidth, capacity,
    /// collective) — the deterministic tie-breaker for equal totals.
    pub index: usize,
}

/// One evaluated configuration.
#[derive(Debug, Clone)]
pub struct Candidate {
    /// Human-readable label (branch label + the explicit point axes).
    pub label: String,
    /// The lattice point.
    pub point: DesignPoint,
    /// The evaluated time breakdown.
    pub breakdown: TrainingBreakdown,
    /// Per-node footprint of the point's branch, bytes.
    pub footprint: f64,
    /// The admissible lower bound under which the point was admitted;
    /// always `<=` `breakdown.total()` `<=` [`Candidate::score`].
    pub lower_bound: f64,
    /// The ranking key under the optimizer's [`Objective`]: the raw
    /// total under [`Objective::Time`] (bit-identical), the effective
    /// time `total / efficiency` under [`Objective::Goodput`].
    pub score: f64,
    /// Modeled resilience efficiency in (0, 1]; exactly `1.0` under
    /// [`Objective::Time`] or a disabled fault model.
    pub efficiency: f64,
}

impl Candidate {
    /// Total iteration time.
    pub fn total(&self) -> f64 {
        self.breakdown.total()
    }
}

/// The result of a search (or exhaustive enumeration).
#[derive(Debug, Clone)]
pub struct Outcome {
    /// The best `top_k` candidates, ascending by (score, lattice index)
    /// — score == total under the default time objective; `top[0]` is
    /// the argmin. Identical between [`Optimizer::search`] (at any
    /// thread count) and [`Optimizer::exhaustive`].
    pub top: Vec<Candidate>,
    /// Pareto frontier of the *evaluated* candidates in (compute,
    /// exposed communication), ascending compute. Under search, subtrees
    /// dominated in total time are pruned before evaluation, so this is
    /// the frontier of the region competitive with the top-k.
    pub frontier: Vec<Candidate>,
    /// Feasible points actually evaluated.
    pub evaluated: usize,
    /// Feasible points pruned by the bound without evaluation.
    pub pruned: usize,
    /// Points skipped as capacity-infeasible.
    pub infeasible: usize,
    /// Full lattice size (feasible + infeasible).
    pub total_points: usize,
    /// `true` for a run that reached its natural cutoff (the counters
    /// partition the lattice as evaluated + pruned + infeasible);
    /// `false` for a run stopped early by cancellation or a deadline.
    pub complete: bool,
    /// Feasible points neither evaluated nor provably pruned when the
    /// run stopped (always `0` when `complete`). The full invariant is
    /// `evaluated + pruned + infeasible + remaining == total_points`.
    pub remaining: usize,
    /// Why a partial run stopped (`None` when `complete`).
    pub stop: Option<StopReason>,
}

impl Outcome {
    /// The argmin configuration, if any point was feasible.
    pub fn best(&self) -> Option<&Candidate> {
        self.top.first()
    }

    /// Test/bench support: assert that every result field of two
    /// outcomes is identical — counters, top-k (label, lattice index,
    /// full breakdown by bit pattern, bound, footprint), and frontier.
    /// Panics with `ctx` on the first difference. One checker shared by
    /// the unit tests, the integration tests, and `bench_optimizer`, so
    /// their strictness cannot drift apart. Hidden from docs — not a
    /// stability surface.
    #[doc(hidden)]
    pub fn assert_bit_identical(&self, other: &Outcome, ctx: &str) {
        assert_eq!(self.evaluated, other.evaluated, "{ctx}: evaluated");
        assert_eq!(self.pruned, other.pruned, "{ctx}: pruned");
        assert_eq!(self.infeasible, other.infeasible, "{ctx}: infeasible");
        assert_eq!(
            self.total_points, other.total_points,
            "{ctx}: total_points"
        );
        assert_eq!(self.complete, other.complete, "{ctx}: complete");
        assert_eq!(self.remaining, other.remaining, "{ctx}: remaining");
        let check = |which: &str, a: &[Candidate], b: &[Candidate]| {
            assert_eq!(a.len(), b.len(), "{ctx}: {which} length");
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.label, y.label, "{ctx}: {which}");
                assert_eq!(
                    x.point.index, y.point.index,
                    "{ctx}: {which} {}",
                    x.label
                );
                assert_eq!(
                    x.lower_bound.to_bits(),
                    y.lower_bound.to_bits(),
                    "{ctx}: {which} {} bound",
                    x.label
                );
                assert_eq!(
                    x.footprint.to_bits(),
                    y.footprint.to_bits(),
                    "{ctx}: {which} {} footprint",
                    x.label
                );
                assert_eq!(
                    x.score.to_bits(),
                    y.score.to_bits(),
                    "{ctx}: {which} {} score",
                    x.label
                );
                assert_eq!(
                    x.efficiency.to_bits(),
                    y.efficiency.to_bits(),
                    "{ctx}: {which} {} efficiency",
                    x.label
                );
                let (ba, bb) = (&x.breakdown, &y.breakdown);
                for (i, (va, vb)) in ba
                    .as_array()
                    .iter()
                    .chain([&ba.bubble, &ba.pp_exposed_comm])
                    .zip(bb.as_array().iter().chain([&bb.bubble, &bb.pp_exposed_comm]))
                    .enumerate()
                {
                    assert_eq!(
                        va.to_bits(),
                        vb.to_bits(),
                        "{ctx}: {which} {} component {i}",
                        x.label
                    );
                }
            }
        };
        check("top", &self.top, &other.top);
        check("frontier", &self.frontier, &other.frontier);
    }
}

/// Execution policy for a search run: cooperative stop sources, an
/// optional checkpoint sink, and an optional checkpoint to resume from.
/// The default is today's behavior exactly — unbounded, no
/// checkpointing — so plain [`Optimizer::search`] callers see no change.
#[derive(Debug, Clone, Default)]
pub struct SearchExec {
    /// Stop sources polled at every safe boundary.
    pub control: RunControl,
    /// Where to flush checkpoints (on stop, and on the interval below).
    pub checkpoint_path: Option<PathBuf>,
    /// Also checkpoint every this-many seconds at safe boundaries
    /// (`Some(0.0)` = every boundary; `None` = only on stop).
    pub checkpoint_every_s: Option<f64>,
    /// Resume from a previously written checkpoint instead of starting
    /// fresh. The checkpoint's spec fingerprint must match.
    pub resume: Option<Checkpoint>,
}

impl SearchExec {
    /// Attach stop sources.
    pub fn with_control(mut self, control: RunControl) -> Self {
        self.control = control;
        self
    }

    /// Attach a checkpoint sink (flushed on stop; plus on the interval
    /// when one is set).
    pub fn with_checkpoint(mut self, path: PathBuf) -> Self {
        self.checkpoint_path = Some(path);
        self
    }

    /// Also checkpoint on a wall-clock interval (`0.0` = every safe
    /// boundary — useful for tests and crash-safety drills).
    pub fn with_checkpoint_every(mut self, secs: f64) -> Self {
        self.checkpoint_every_s = Some(secs.max(0.0));
        self
    }

    /// Resume from `ck` instead of starting fresh.
    pub fn with_resume(mut self, ck: Checkpoint) -> Self {
        self.resume = Some(ck);
        self
    }
}

/// The driver-independent mutable search state: the best-first frontier
/// heap, its sequence counter, the incumbent top-k, and the evaluated
/// candidates in evaluation order. Both drivers mutate exactly this; a
/// checkpoint is a pure function of it (plus the optimizer spec).
struct SearchState {
    heap: BinaryHeap<Entry>,
    seq: usize,
    incumbents: Vec<(f64, usize)>,
    evaluated: Vec<Candidate>,
}

/// Per-branch precomputed search state.
struct BranchState {
    dec: Arc<WorkloadDecomposition>,
    /// Branch-invariant resolved inputs at the *base* cluster: the layer
    /// records and every parameter except the two leaf axes
    /// (expanded-memory bandwidth, collective implementation). A leaf
    /// evaluation stack-copies `template.params`, patches those two
    /// fields, and calls [`evaluate_parts`] — the zero-allocation fast
    /// path. `tests` pin it bit-for-bit against the per-leaf
    /// `resolve_inputs` oracle [`Optimizer::exhaustive`] uses.
    template: ModelInputs,
    /// The footprint evaluation will actually use for this branch's
    /// points (taken from the template, so pruning and evaluation cannot
    /// drift).
    footprint: f64,
    /// Expanded-memory traffic fraction of this branch's footprint
    /// (mirrors the backend's `em_fraction` resolution, including the
    /// `ignore_capacity` / `em_frac` overrides).
    frac: f64,
    /// Exact blocking (FP, IG) collective times per collectives-axis
    /// entry, per pipeline stage (one stage at `pp = 1`).
    comm: Vec<Vec<(f64, f64)>>,
    /// Microbatch count this branch evaluates with (1 at `pp = 1`).
    m: usize,
    /// Exact per-microbatch stage-boundary transfer time (0 at `pp = 1`;
    /// independent of the expanded-memory axes, so exact for bounds).
    x: f64,
    /// Admissible bound over the whole subtree.
    bound: f64,
    /// Capacity-infeasible points under this branch.
    infeasible: usize,
}

/// A fully specified, feasible leaf awaiting evaluation. `Copy` — leaf
/// expansion allocates nothing; everything leaf-dependent that
/// evaluation needs is the point itself plus the effective
/// expanded-memory bandwidth.
#[derive(Clone, Copy)]
struct Leaf {
    point: DesignPoint,
    /// Expanded-memory bandwidth the evaluation will see: the axis value
    /// when the point attaches a spill-backed expansion, else the base
    /// node's own (mirrors `leaf_cluster` + `resolve_inputs` exactly).
    bw_em: f64,
    bound: f64,
}

/// A heap node: an unexpanded branch subtree or a leaf.
enum NodeRef {
    Branch(usize),
    Leaf(Leaf),
}

/// Min-heap entry ordered by (bound, insertion sequence).
struct Entry {
    bound: f64,
    seq: usize,
    node: NodeRef,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.bound.to_bits() == other.bound.to_bits() && self.seq == other.seq
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed: `BinaryHeap` is a max-heap, we pop smallest bound
        // first (then FIFO by sequence for determinism).
        other
            .bound
            .total_cmp(&self.bound)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Safety factor applied to *subtree* bounds (not leaf bounds): a branch
/// bound sums terms in a different association order than the evaluated
/// totals, so shave a relative epsilon to keep pruning sound against
/// f64 rounding. Leaf bounds reproduce the evaluation order exactly and
/// need no margin.
const BRANCH_BOUND_MARGIN: f64 = 1.0 - 1e-9;

/// Speculative leaves fetched per pool lane per batch. Larger batches
/// amortize the merge barrier but speculate further past the point where
/// the sequential driver would have stopped; the merge replay discards
/// the overshoot, so this constant trades wasted work against
/// synchronization — it cannot affect the result.
const LEAVES_PER_LANE: usize = 4;

/// The branch-and-bound co-design optimizer. Borrows a [`Coordinator`]
/// for (cached, backend-agnostic) evaluation and for its worker pool.
pub struct Optimizer<'a> {
    coord: &'a Coordinator,
    cluster: ClusterConfig,
    opts: EvalOptions,
    branches: Vec<Branch>,
    axes: AxisSpec,
    top_k: usize,
    /// Evaluation lanes for [`Optimizer::search`] (`None` = the
    /// coordinator's pool width; `1` = the sequential driver).
    threads: Option<usize>,
    /// Ranking objective (default: raw iteration time).
    objective: Objective,
    /// Fault model the goodput objective scores against (identity under
    /// [`Objective::Time`]).
    faults: FaultModel,
    /// Fault-injection hook: panic when evaluating this lattice index.
    /// Seeded from `COMET_PANIC_LEAF` at construction (read once — no
    /// per-leaf env traffic); used by the pool-isolation tests and the
    /// CI panic-injection smoke. `None` in every real run.
    panic_leaf: Option<usize>,
}

impl<'a> Optimizer<'a> {
    /// A new optimizer over `branches` x `axes` on `cluster`.
    ///
    /// `opts` supplies the evaluation defaults; each branch's stage and
    /// each point's collective implementation override it per leaf.
    pub fn new(
        coord: &'a Coordinator,
        cluster: ClusterConfig,
        opts: EvalOptions,
        branches: Vec<Branch>,
        axes: AxisSpec,
    ) -> Result<Optimizer<'a>> {
        cluster.validate()?;
        if branches.is_empty() {
            return Err(Error::Config(
                "optimizer: needs at least one branch".into(),
            ));
        }
        if axes.is_empty() {
            return Err(Error::Config(
                "optimizer: axes name no points".into(),
            ));
        }
        if axes.em_capacities.iter().any(|c| c.is_some())
            && axes.em_bandwidths.iter().all(|b| b.is_none())
        {
            return Err(Error::Config(
                "optimizer: em_capacities without em_bandwidths; \
                 expanded-memory capacity needs a bandwidth axis"
                    .into(),
            ));
        }
        // Degenerate axis values would previously surface as per-leaf
        // `cluster.validate()` errors; the template fast path never
        // builds those clusters, so reject them up front — search and
        // exhaustive must fail identically.
        for bw in axes.em_bandwidths.iter().flatten() {
            if !bw.is_finite() || *bw <= 0.0 {
                return Err(Error::Config(format!(
                    "optimizer: expanded-memory bandwidth must be positive \
                     and finite, got {bw}"
                )));
            }
        }
        for cap in axes.em_capacities.iter().flatten() {
            if !cap.is_finite() || *cap < 0.0 {
                return Err(Error::Config(format!(
                    "optimizer: expanded-memory capacity must be \
                     non-negative and finite, got {cap}"
                )));
            }
        }
        Ok(Optimizer {
            coord,
            cluster,
            opts,
            branches,
            axes,
            top_k: 5,
            threads: None,
            objective: Objective::Time,
            faults: FaultModel::none(),
            panic_leaf: std::env::var("COMET_PANIC_LEAF")
                .ok()
                .and_then(|v| v.parse().ok()),
        })
    }

    /// Test support: arm the panic-injection hook directly (the
    /// in-process alternative to `COMET_PANIC_LEAF`, which unit tests
    /// must not set — the environment is process-global and tests run
    /// concurrently). Hidden from docs — not a stability surface.
    #[doc(hidden)]
    pub fn with_panic_leaf(mut self, index: usize) -> Optimizer<'a> {
        self.panic_leaf = Some(index);
        self
    }

    /// Rank candidates by `objective`, scoring goodput against `faults`
    /// (validated here). With [`Objective::Time`] the fault model is
    /// ignored and the optimizer behaves bit-identically to the
    /// default; the same holds for [`Objective::Goodput`] with
    /// [`FaultModel::none`], whose efficiency is exactly 1.
    pub fn with_objective(
        mut self,
        objective: Objective,
        faults: FaultModel,
    ) -> Result<Optimizer<'a>> {
        faults.validate()?;
        self.objective = objective;
        self.faults = faults;
        Ok(self)
    }

    /// Keep the best `k` configurations (default 5; clamped to >= 1).
    pub fn with_top_k(mut self, k: usize) -> Optimizer<'a> {
        self.top_k = k.max(1);
        self
    }

    /// Run [`Optimizer::search`] with at most `threads` evaluation lanes
    /// (clamped to >= 1 and, effectively, to the coordinator's pool
    /// width; `1` selects the sequential driver). Both the speculation
    /// batch size and the pool fan-out are bounded by it, so the knob
    /// genuinely caps CPU use. The default is the coordinator's pool
    /// width. The outcome is bit-identical at every width — this knob
    /// trades wall-clock only.
    pub fn with_threads(mut self, threads: usize) -> Optimizer<'a> {
        self.threads = Some(threads.max(1));
        self
    }

    /// Full lattice size.
    pub fn total_points(&self) -> usize {
        self.branches.len() * self.axes.len()
    }

    // ---- lattice geometry -------------------------------------------------

    /// Total capacity available to a point, bytes. Under
    /// `ignore_capacity` (the Fig. 8a infinite-memory mode) every point
    /// is feasible by definition — the same switch that forces the EM
    /// traffic fraction to zero must also disable footprint pruning.
    fn point_capacity(&self, bw: Option<f64>, cap: Option<f64>) -> f64 {
        if self.opts.ignore_capacity {
            return f64::INFINITY;
        }
        match bw {
            // No expansion at this point: whatever the base node has.
            None => self.cluster.node.total_capacity(),
            Some(_) => match cap {
                // Sized to the spill: always fits.
                None => f64::INFINITY,
                Some(c) => self.cluster.node.local.capacity + c,
            },
        }
    }

    /// Expanded-memory capacity a bandwidth-axis point attaches, bytes:
    /// the explicit axis capacity, or the branch's spill when sized to
    /// it. Zero disables attachment. The single predicate behind both
    /// [`Optimizer::exhaustive`]'s leaf clusters and the search fast
    /// path's `bw_em` patch — they cannot drift.
    fn expansion_need(&self, footprint: f64, cap: Option<f64>) -> f64 {
        cap.unwrap_or_else(|| {
            (footprint - self.cluster.node.local.capacity).max(0.0)
        })
    }

    /// The point's cluster: expanded memory attached exactly the way
    /// [`crate::coordinator::GridSweep::specs`] does it. Used by the
    /// [`Optimizer::exhaustive`] oracle path; the search drivers use the
    /// equivalent `bw_em` patch on the branch template instead.
    fn leaf_cluster(
        &self,
        footprint: f64,
        bw: Option<f64>,
        cap: Option<f64>,
    ) -> ClusterConfig {
        match bw {
            None => self.cluster.clone(),
            Some(bw) => {
                let need = self.expansion_need(footprint, cap);
                if need > 0.0 {
                    self.cluster
                        .with_node(self.cluster.node.with_expanded(need, bw))
                } else {
                    self.cluster.clone()
                }
            }
        }
    }

    /// The expanded-memory bandwidth a point's evaluation sees —
    /// `leaf_cluster`'s node without building it: the axis bandwidth iff
    /// the point actually attaches an expansion (positive capacity need),
    /// else the base node's own.
    fn leaf_bw_em(
        &self,
        footprint: f64,
        bw: Option<f64>,
        cap: Option<f64>,
    ) -> f64 {
        match bw {
            None => self.cluster.node.expanded.bandwidth,
            Some(bw) if self.expansion_need(footprint, cap) > 0.0 => bw,
            Some(_) => self.cluster.node.expanded.bandwidth,
        }
    }

    /// The point's evaluation options.
    fn leaf_opts(&self, b: &Branch, impl_: CollectiveImpl) -> EvalOptions {
        EvalOptions {
            zero_stage: b.stage,
            collective_impl: impl_,
            footprint_override: b
                .footprint_override
                .or(self.opts.footprint_override),
            microbatches: b.microbatches.unwrap_or(self.opts.microbatches),
            pipe_schedule: b.schedule.unwrap_or(self.opts.pipe_schedule),
            ..self.opts
        }
    }

    fn label_of(&self, b: &Branch, p: &DesignPoint) -> String {
        let mut l = b.label.clone();
        if let Some(bw) = p.em_bandwidth {
            l.push_str(&format!(" EM@{:.0}GB/s", bw / 1e9));
        }
        if let Some(cap) = p.em_capacity {
            l.push_str(&format!(" cap{:.0}GB", cap / 1e9));
        }
        if self.axes.collectives.len() > 1 {
            l.push(' ');
            l.push_str(p.collective.name());
        }
        l
    }

    // ---- bounds -----------------------------------------------------------

    /// The branch's expanded-memory traffic fraction, mirroring the
    /// backend's resolution of the same quantity. `cap_lm` is the branch
    /// template's local capacity — possibly group-scaled on a
    /// heterogeneous cluster — so the fraction matches the evaluation's
    /// exactly.
    fn branch_frac(&self, footprint: f64, cap_lm: f64) -> f64 {
        if self.opts.ignore_capacity {
            0.0
        } else {
            self.opts
                .em_frac_override
                .unwrap_or_else(|| em_fraction(footprint, cap_lm))
        }
    }

    /// Per-branch search state: bounds, exact blocking collectives, and
    /// the branch-invariant evaluation template. Stage 1 (decomposition)
    /// runs serially through the coordinator's derive cache — each
    /// distinct workload decomposes exactly once, deterministically —
    /// and the per-branch state computation fans out over the pool
    /// (pure per branch, order preserved), bounded by the driver's lane
    /// count so a `threads` cap applies to preparation too.
    fn prepare(&self, lanes: usize) -> Result<Vec<BranchState>> {
        let decs: Vec<Arc<WorkloadDecomposition>> = self
            .branches
            .iter()
            .map(|b| self.coord.decomposition(&b.workload))
            .collect();
        let idx: Vec<usize> = (0..self.branches.len()).collect();
        self.coord
            .pool()
            .scoped_map_bounded(&idx, lanes, |&i| {
                self.branch_state(i, decs[i].clone())
            })
            .into_iter()
            .collect()
    }

    fn branch_state(
        &self,
        bi: usize,
        dec: Arc<WorkloadDecomposition>,
    ) -> Result<BranchState> {
        let b = &self.branches[bi];
        let node = &self.cluster.node;
        // Best expanded-memory bandwidth any point can reach. The base
        // node's own expanded memory is always a candidate: points
        // without an expansion axis keep it, and so do axis points whose
        // branch has no spill (leaf_cluster's need == 0 path) — folding
        // it in unconditionally keeps the subtree bound admissible even
        // when the base expansion outruns every axis bandwidth.
        let bw_em_best = self
            .axes
            .em_bandwidths
            .iter()
            .map(|b| b.unwrap_or(0.0))
            .fold(node.expanded.bandwidth, f64::max);
        let pipeline = dec.pp > 1;
        let m = if pipeline {
            b.microbatches.unwrap_or(self.opts.microbatches).max(1)
        } else {
            1
        };
        // The branch-invariant half of every leaf's inputs, resolved
        // once: the collective axis is patched per leaf, so any entry
        // serves as the template's placeholder.
        let template = resolve_inputs(
            &dec,
            &self.cluster,
            &self.leaf_opts(b, self.axes.collectives[0]),
        )?;
        // The footprint evaluation will actually use (same precedence
        // `resolve_inputs` applies — taken from the template so the
        // feasibility rule and the evaluation cannot drift).
        let footprint = template.params.footprint;
        let frac = self.branch_frac(footprint, template.params.cap_lm);
        let x = if pipeline {
            // Same boundary-link dispatch the evaluation uses (one
            // shared helper, no drift) — two-level or tiered, and the
            // boundary bytes are the template's own resolution.
            let (bw_b, lat_b) = pp_boundary_link(&template.params);
            (template.params.pp_boundary_bytes / m as f64) / bw_b.max(1.0)
                + lat_b
        } else {
            0.0
        };
        let comm: Vec<Vec<(f64, f64)>> = self
            .axes
            .collectives
            .iter()
            .map(|&ci| {
                if pipeline {
                    bound::stage_blocking_comm_times(
                        &template.layers,
                        &template.params,
                        ci,
                    )
                } else {
                    vec![bound::blocking_comm_times(
                        &template.layers,
                        &template.params,
                        ci,
                    )]
                }
            })
            .collect();
        let bw_best =
            hybrid_bandwidth(template.params.bw_lm, bw_em_best, frac);
        let subtree_bound = if pipeline {
            let compute = bound::stage_compute_times(
                &dec,
                template.params.perf_peak,
                template.params.sram,
                bw_best,
            );
            comm.iter()
                .map(|c| bound::assemble_pipeline(&compute, c, m, x))
                .fold(f64::INFINITY, f64::min)
                * BRANCH_BOUND_MARGIN
        } else {
            let compute = bound::compute_times(
                &dec,
                template.params.perf_peak,
                template.params.sram,
                bw_best,
            );
            let comm_min = comm
                .iter()
                .map(|c| c[0].0 + c[0].1)
                .fold(f64::INFINITY, f64::min);
            (compute[0] + compute[1] + compute[2] + comm_min)
                * BRANCH_BOUND_MARGIN
        };
        let mut infeasible = 0;
        for &bw in &self.axes.em_bandwidths {
            for &cap in &self.axes.em_capacities {
                if footprint > self.point_capacity(bw, cap) {
                    infeasible += self.axes.collectives.len();
                }
            }
        }
        Ok(BranchState {
            dec,
            template,
            footprint,
            frac,
            comm,
            m,
            x,
            bound: subtree_bound,
            infeasible,
        })
    }

    /// Expand one branch into its feasible leaves, canonically ordered.
    fn expand(&self, bi: usize, st: &BranchState) -> Vec<Leaf> {
        let p = &st.template.params;
        let (nbw, ncap, ncoll) = (
            self.axes.em_bandwidths.len(),
            self.axes.em_capacities.len(),
            self.axes.collectives.len(),
        );
        let mut leaves = Vec::new();
        for (ibw, &bw) in self.axes.em_bandwidths.iter().enumerate() {
            for (icap, &cap) in self.axes.em_capacities.iter().enumerate() {
                if st.footprint > self.point_capacity(bw, cap) {
                    continue;
                }
                let bw_em = self.leaf_bw_em(st.footprint, bw, cap);
                // Exact effective bandwidth of this point — em_fraction
                // depends only on footprint and local capacity, so the
                // leaf's compute floor is the backend's compute time.
                // Template parameters, not the raw node: on a
                // heterogeneous cluster the group-scaled values are what
                // the evaluation sees.
                let bw_eff = hybrid_bandwidth(p.bw_lm, bw_em, st.frac);
                let pipeline = st.dec.pp > 1;
                let compute_flat;
                let compute_stages;
                if pipeline {
                    compute_flat = [0.0f64; 3];
                    compute_stages = bound::stage_compute_times(
                        &st.dec,
                        p.perf_peak,
                        p.sram,
                        bw_eff,
                    );
                } else {
                    compute_flat = bound::compute_times(
                        &st.dec,
                        p.perf_peak,
                        p.sram,
                        bw_eff,
                    );
                    compute_stages = Vec::new();
                }
                for (ici, &ci) in self.axes.collectives.iter().enumerate() {
                    let index =
                        ((bi * nbw + ibw) * ncap + icap) * ncoll + ici;
                    let bound = if pipeline {
                        bound::assemble_pipeline(
                            &compute_stages,
                            &st.comm[ici],
                            st.m,
                            st.x,
                        )
                    } else {
                        let (c0, c1) = st.comm[ici][0];
                        bound::assemble(compute_flat, c0, c1)
                    };
                    leaves.push(Leaf {
                        point: DesignPoint {
                            branch: bi,
                            em_bandwidth: bw,
                            em_capacity: cap,
                            collective: ci,
                            index,
                        },
                        bw_em,
                        bound,
                    });
                }
            }
        }
        leaves
    }

    // ---- evaluation -------------------------------------------------------

    /// The zero-allocation leaf evaluation: stack-copy the branch
    /// template's parameter block, patch the two leaf-dependent fields,
    /// and run the closed-form model over the shared layer records.
    /// Bit-identical to resolving the leaf's full `ModelInputs` (the
    /// exhaustive oracle path) and evaluating that — pinned by the
    /// `search == exhaustive` bit-equality tests.
    fn eval_leaf(&self, st: &BranchState, leaf: &Leaf) -> TrainingBreakdown {
        if self.panic_leaf == Some(leaf.point.index) {
            panic!(
                "injected leaf panic at lattice index {} (COMET_PANIC_LEAF)",
                leaf.point.index
            );
        }
        let mut params = st.template.params;
        params.bw_em = leaf.bw_em;
        params.collective_impl = leaf.point.collective;
        evaluate_parts(&st.template.layers, &params)
    }

    /// The ranking key of an evaluated leaf under the active objective,
    /// as (score, efficiency). The time objective returns the total
    /// untouched — no arithmetic, so the disabled slice is bit-identical.
    /// The goodput score divides by an efficiency clamped to `(0, 1]`,
    /// a monotone correctly-rounded operation, so
    /// `score >= total >= leaf.bound` holds bit-wise and every
    /// bound-vs-incumbent comparison in the drivers stays admissible.
    fn score_of(
        &self,
        leaf: &Leaf,
        footprint: f64,
        breakdown: &TrainingBreakdown,
    ) -> (f64, f64) {
        match self.objective {
            Objective::Time => (breakdown.total(), 1.0),
            Objective::Goodput => {
                let ckpt_bw = checkpoint_bandwidth(
                    self.cluster.inter_bandwidth(),
                    self.cluster.node.local.bandwidth,
                    leaf.bw_em,
                );
                let g = goodput::analyze(
                    &self.faults,
                    self.cluster.n_nodes,
                    footprint,
                    ckpt_bw,
                    breakdown,
                );
                (breakdown.total() / g.efficiency, g.efficiency)
            }
        }
    }

    fn candidate(
        &self,
        leaf: &Leaf,
        footprint: f64,
        breakdown: TrainingBreakdown,
    ) -> Candidate {
        let b = &self.branches[leaf.point.branch];
        let (score, efficiency) = self.score_of(leaf, footprint, &breakdown);
        Candidate {
            label: self.label_of(b, &leaf.point),
            point: leaf.point,
            breakdown,
            footprint,
            lower_bound: leaf.bound,
            score,
            efficiency,
        }
    }

    /// Insert a candidate's (score, lattice index) key into the sorted
    /// incumbent list, keeping the best `top_k`. Shared by both drivers —
    /// the parallel merge replays exactly this update sequence.
    fn admit(&self, incumbents: &mut Vec<(f64, usize)>, cand: &Candidate) {
        let key = (cand.score, cand.point.index);
        let pos = incumbents
            .binary_search_by(|(t, i)| {
                t.total_cmp(&key.0).then_with(|| i.cmp(&key.1))
            })
            .unwrap_or_else(|p| p);
        incumbents.insert(pos, key);
        incumbents.truncate(self.top_k);
    }

    fn outcome_from(
        &self,
        evaluated: Vec<Candidate>,
        pruned: usize,
        infeasible: usize,
    ) -> Outcome {
        let n_eval = evaluated.len();
        // The counter invariant every driver must satisfy — a hard
        // assert (not debug) so a drifting driver fails loudly in
        // release CI too.
        assert_eq!(
            n_eval + pruned + infeasible,
            self.total_points(),
            "optimizer counters must partition the lattice: \
             {n_eval} evaluated + {pruned} pruned + {infeasible} infeasible \
             != {} total",
            self.total_points()
        );
        let mut top = evaluated.clone();
        top.sort_by(|a, b| {
            a.score
                .total_cmp(&b.score)
                .then_with(|| a.point.index.cmp(&b.point.index))
        });
        top.truncate(self.top_k);
        Outcome {
            top,
            frontier: pareto(evaluated),
            evaluated: n_eval,
            pruned,
            infeasible,
            total_points: self.total_points(),
            complete: true,
            remaining: 0,
            stop: None,
        }
    }

    /// A *partial* outcome for a run stopped at a safe boundary:
    /// best-so-far top-k and frontier over the evaluated prefix, with
    /// everything not yet evaluated reported as `remaining` (nothing is
    /// claimed pruned — the run never reached its cutoff proof).
    fn outcome_partial(
        &self,
        evaluated: Vec<Candidate>,
        infeasible: usize,
        reason: StopReason,
    ) -> Outcome {
        let n_eval = evaluated.len();
        let remaining = self
            .total_points()
            .checked_sub(infeasible + n_eval)
            .expect("partial outcome: evaluated + infeasible exceeds lattice");
        let mut top = evaluated.clone();
        top.sort_by(|a, b| {
            a.score
                .total_cmp(&b.score)
                .then_with(|| a.point.index.cmp(&b.point.index))
        });
        top.truncate(self.top_k);
        Outcome {
            top,
            frontier: pareto(evaluated),
            evaluated: n_eval,
            pruned: 0,
            infeasible,
            total_points: self.total_points(),
            complete: false,
            remaining,
            stop: Some(reason),
        }
    }

    /// FNV-1a fingerprint of the full optimizer specification — cluster,
    /// branches, axes (by f64 bit pattern, via the shortest-round-trip
    /// `Debug` rendering), options, objective, fault model, and top-k.
    /// Written into checkpoints; resume refuses a mismatch, because a
    /// checkpoint's lattice indices are only meaningful against the
    /// exact spec that wrote them.
    pub fn fingerprint(&self) -> u64 {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = write!(
            s,
            "ckpt-v{};cluster={:?};opts={:?};axes={:?};objective={};\
             faults={:?};top_k={};",
            checkpoint::VERSION,
            self.cluster,
            self.opts,
            self.axes,
            self.objective.name(),
            self.faults,
            self.top_k,
        );
        for b in &self.branches {
            let _ = write!(
                s,
                "branch[{:?},{:?},{:?},{:?},{:?}];",
                b.label, b.workload, b.stage, b.footprint_override, b.schedule,
            );
            let _ = write!(s, "mb={:?};", b.microbatches);
        }
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for byte in s.as_bytes() {
            h ^= u64::from(*byte);
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        h
    }

    /// The initial driver state: fresh (seeded heap) or restored from a
    /// resume checkpoint.
    fn initial_state(
        &self,
        states: &[BranchState],
        exec: &SearchExec,
    ) -> Result<SearchState> {
        match &exec.resume {
            None => {
                let (heap, seq) = self.seed_heap(states);
                Ok(SearchState {
                    heap,
                    seq,
                    incumbents: Vec::new(),
                    evaluated: Vec::new(),
                })
            }
            Some(ck) => self.restore_state(states, ck),
        }
    }

    /// Rebuild a driver state from a checkpoint: validate the spec
    /// fingerprint, re-expand the referenced branch subtrees (the same
    /// deterministic `expand` the live search uses), rebuild the heap
    /// with its recorded sequence numbers, and **replay** the recorded
    /// evaluation prefix through the exact `eval_leaf`/`admit` sequence.
    /// Every bound, score, and incumbent is recomputed — the file stores
    /// only integers, so no float ever round-trips through disk.
    fn restore_state(
        &self,
        states: &[BranchState],
        ck: &Checkpoint,
    ) -> Result<SearchState> {
        let fp = self.fingerprint();
        if ck.fingerprint != fp {
            return Err(Error::Config(format!(
                "checkpoint fingerprint {:016x} does not match this \
                 search's spec ({fp:016x}); the checkpoint was written by \
                 a different cluster/branch/axis configuration",
                ck.fingerprint
            )));
        }
        // Lazily expanded per-branch leaf tables (lattice index -> leaf).
        let mut tables: Vec<Option<Vec<Leaf>>> =
            states.iter().map(|_| None).collect();
        let axes_len = self.axes.len();
        let mut leaf_at = |idx: usize| -> Result<Leaf> {
            let bi = idx / axes_len.max(1);
            if bi >= states.len() {
                return Err(Error::Config(format!(
                    "checkpoint references lattice index {idx}, outside \
                     this search's {} points",
                    self.total_points()
                )));
            }
            if tables[bi].is_none() {
                tables[bi] = Some(self.expand(bi, &states[bi]));
            }
            tables[bi]
                .as_ref()
                .unwrap()
                .iter()
                .find(|l| l.point.index == idx)
                .copied()
                .ok_or_else(|| {
                    Error::Config(format!(
                        "checkpoint references lattice index {idx}, which \
                         is capacity-infeasible under this spec"
                    ))
                })
        };
        let mut heap: BinaryHeap<Entry> = BinaryHeap::new();
        for e in &ck.heap {
            let (bound, node) = match e.node {
                checkpoint::Node::Branch(bi) => {
                    if bi >= states.len() {
                        return Err(Error::Config(format!(
                            "checkpoint references branch {bi}, outside \
                             this search's {} branches",
                            states.len()
                        )));
                    }
                    (states[bi].bound, NodeRef::Branch(bi))
                }
                checkpoint::Node::Leaf(idx) => {
                    let leaf = leaf_at(idx)?;
                    (leaf.bound, NodeRef::Leaf(leaf))
                }
            };
            heap.push(Entry {
                bound,
                seq: e.seq,
                node,
            });
        }
        let mut incumbents: Vec<(f64, usize)> = Vec::new();
        let mut evaluated: Vec<Candidate> =
            Vec::with_capacity(ck.evaluated.len());
        for &idx in &ck.evaluated {
            let leaf = leaf_at(idx)?;
            let st = &states[leaf.point.branch];
            let b = self.eval_leaf(st, &leaf);
            let cand = self.candidate(&leaf, st.footprint, b);
            self.admit(&mut incumbents, &cand);
            evaluated.push(cand);
        }
        Ok(SearchState {
            heap,
            seq: ck.next_seq,
            incumbents,
            evaluated,
        })
    }

    /// Serialize the driver state (integers only — see
    /// [`Optimizer::restore_state`] for the inverse).
    fn checkpoint_of(&self, state: &SearchState, reason: &str) -> Checkpoint {
        let mut heap: Vec<checkpoint::HeapEntry> = state
            .heap
            .iter()
            .map(|e| checkpoint::HeapEntry {
                seq: e.seq,
                node: match &e.node {
                    NodeRef::Branch(i) => checkpoint::Node::Branch(*i),
                    NodeRef::Leaf(l) => checkpoint::Node::Leaf(l.point.index),
                },
            })
            .collect();
        heap.sort_by_key(|e| e.seq);
        Checkpoint {
            version: checkpoint::VERSION,
            fingerprint: self.fingerprint(),
            stop: reason.to_string(),
            evaluated: state.evaluated.iter().map(|c| c.point.index).collect(),
            heap,
            next_seq: state.seq,
        }
    }

    /// Safe-boundary bookkeeping shared by both drivers: poll the stop
    /// sources (flushing a final checkpoint on a stop) and service the
    /// periodic checkpoint interval. Returns the stop reason when the
    /// driver must return a partial outcome.
    fn at_boundary(
        &self,
        state: &SearchState,
        exec: &SearchExec,
        last_ckpt: &mut Option<Instant>,
    ) -> Result<Option<StopReason>> {
        if let Some(reason) = exec.control.should_stop() {
            if let Some(path) = &exec.checkpoint_path {
                self.checkpoint_of(state, reason.label()).save(path)?;
            }
            return Ok(Some(reason));
        }
        if let (Some(path), Some(every)) =
            (&exec.checkpoint_path, exec.checkpoint_every_s)
        {
            let now = Instant::now();
            let due = match last_ckpt {
                None => true,
                Some(t) => now.duration_since(*t).as_secs_f64() >= every,
            };
            if due {
                self.checkpoint_of(state, "interval").save(path)?;
                *last_ckpt = Some(now);
            }
        }
        Ok(None)
    }

    /// Seed the search heap with every branch subtree that has at least
    /// one feasible point. Returns (heap, next sequence number).
    fn seed_heap(&self, states: &[BranchState]) -> (BinaryHeap<Entry>, usize) {
        let mut heap: BinaryHeap<Entry> = BinaryHeap::new();
        let mut seq = 0usize;
        for (i, st) in states.iter().enumerate() {
            if st.infeasible == self.axes.len() {
                // The whole subtree is capacity-infeasible: pruned
                // without ever entering the heap.
                continue;
            }
            heap.push(Entry {
                bound: st.bound,
                seq,
                node: NodeRef::Branch(i),
            });
            seq += 1;
        }
        (heap, seq)
    }

    /// Branch-and-bound best-first search. Returns the exact argmin and
    /// top-k of [`Optimizer::exhaustive`] while evaluating only the
    /// points whose lower bound does not already lose to the incumbent
    /// top-k.
    ///
    /// Runs across the coordinator's worker pool by default (or the
    /// explicit [`Optimizer::with_threads`] width); the outcome is
    /// bit-identical to [`Optimizer::search_sequential`] at every thread
    /// count — see the module docs for the determinism argument.
    ///
    /// The bounds come from the closed-form analytical model and are
    /// admissible only against the native backend's totals — DES and
    /// artifact evaluations may land a few percent below the analytical
    /// value, so pruning against them could discard the true argmin. On
    /// a non-native coordinator this therefore falls back to exhaustive
    /// enumeration: the exactness guarantee is kept, the pruning speedup
    /// is not.
    pub fn search(&self) -> Result<Outcome> {
        self.search_with(&SearchExec::default())
    }

    /// [`Optimizer::search`] under an execution policy: cooperative
    /// cancellation/deadline stop sources, checkpoint flushing, and
    /// resume. The default policy reproduces `search` exactly.
    pub fn search_with(&self, exec: &SearchExec) -> Result<Outcome> {
        let lanes = self.threads.unwrap_or_else(|| self.coord.threads());
        self.search_parallel_with(lanes, exec)
    }

    /// The single-threaded best-first driver — the in-tree equivalence
    /// oracle the parallel driver is pinned against (and the exact
    /// search semantics: leaves are evaluated in ascending (bound,
    /// sequence) order, tightening the incumbent top-k after each, until
    /// the next bound strictly loses to the k-th incumbent).
    pub fn search_sequential(&self) -> Result<Outcome> {
        self.search_sequential_with(&SearchExec::default())
    }

    /// [`Optimizer::search_sequential`] under an execution policy. The
    /// safe boundary is every heap pop: each iteration polls the stop
    /// sources before popping, so the state a stop (or an interval
    /// checkpoint) observes is exactly a between-evaluations state.
    pub fn search_sequential_with(
        &self,
        exec: &SearchExec,
    ) -> Result<Outcome> {
        if self.coord.backend() != Backend::Native {
            if exec.resume.is_some() {
                return Err(Error::Config(
                    "optimizer: --resume requires the native backend \
                     (non-native backends enumerate exhaustively and \
                     write no checkpoints)"
                        .into(),
                ));
            }
            return self.exhaustive_controlled(&exec.control);
        }
        let states = self.prepare(1)?;
        let infeasible: usize = states.iter().map(|s| s.infeasible).sum();
        let feasible_total = self.total_points() - infeasible;

        let mut state = self.initial_state(&states, exec)?;
        let mut last_ckpt: Option<Instant> = None;
        loop {
            if let Some(reason) =
                self.at_boundary(&state, exec, &mut last_ckpt)?
            {
                return Ok(self.outcome_partial(
                    state.evaluated,
                    infeasible,
                    reason,
                ));
            }
            let Some(e) = state.heap.pop() else { break };
            // Incumbent top-k scores (with lattice-index tie-break);
            // score == total under the default time objective, so bound
            // comparisons against them stay admissible either way.
            if state.incumbents.len() >= self.top_k {
                let worst = state.incumbents[self.top_k - 1].0;
                // Everything still queued has bound >= e.bound; a strict
                // loss here prunes the rest of the lattice. Equal bounds
                // must still be expanded — an equal-total candidate with
                // a smaller lattice index belongs in the top-k.
                if e.bound > worst {
                    break;
                }
            }
            match e.node {
                NodeRef::Branch(i) => {
                    for leaf in self.expand(i, &states[i]) {
                        state.heap.push(Entry {
                            bound: leaf.bound,
                            seq: state.seq,
                            node: NodeRef::Leaf(leaf),
                        });
                        state.seq += 1;
                    }
                }
                NodeRef::Leaf(leaf) => {
                    let st = &states[leaf.point.branch];
                    let b = self.eval_leaf(st, &leaf);
                    let cand = self.candidate(&leaf, st.footprint, b);
                    self.admit(&mut state.incumbents, &cand);
                    state.evaluated.push(cand);
                }
            }
        }
        let pruned = feasible_total - state.evaluated.len();
        Ok(self.outcome_from(state.evaluated, pruned, infeasible))
    }

    /// The parallel driver: batched speculative leaf expansion over the
    /// coordinator's pool with a deterministic replay merge.
    ///
    /// Per cycle: pop entries from the shared frontier in canonical
    /// (bound, sequence) order — expanding branch subtrees inline — until
    /// `lanes * LEAVES_PER_LANE` leaves are pending or the batch-start
    /// incumbent cuts the frontier; evaluate the pending leaves
    /// concurrently (each worker reads the atomic incumbent first and
    /// skips leaves that already lose, CAS-tightening it after each
    /// evaluation when `top_k == 1`); then merge by replaying the
    /// pending leaves *in collection order* through the sequential
    /// driver's exact incumbent updates and cutoff. Leaves the replay
    /// never reaches are discarded (speculation waste, not results);
    /// leaves a worker skipped but the replay does reach are evaluated
    /// lazily. Every decision that shapes the outcome happens in replay
    /// order, so the result is bit-identical to the sequential driver.
    pub fn search_parallel(&self, lanes: usize) -> Result<Outcome> {
        self.search_parallel_with(lanes, &SearchExec::default())
    }

    /// [`Optimizer::search_parallel`] under an execution policy. The
    /// safe boundary is the batch-collection boundary — the start of
    /// each collect/evaluate/merge cycle, where (by the determinism
    /// argument in the module docs) the driver state equals the
    /// sequential driver's state after the same evaluation prefix, so
    /// checkpoints written here resume bit-identically on any driver at
    /// any thread count. A leaf evaluation that panics surfaces as a
    /// structured [`Error::Job`] (the pool captures it per job index and
    /// stays healthy) instead of aborting the process.
    pub fn search_parallel_with(
        &self,
        lanes: usize,
        exec: &SearchExec,
    ) -> Result<Outcome> {
        if self.coord.backend() != Backend::Native {
            if exec.resume.is_some() {
                return Err(Error::Config(
                    "optimizer: --resume requires the native backend \
                     (non-native backends enumerate exhaustively and \
                     write no checkpoints)"
                        .into(),
                ));
            }
            return self.exhaustive_controlled(&exec.control);
        }
        if lanes <= 1 {
            return self.search_sequential_with(exec);
        }
        let states = self.prepare(lanes)?;
        let infeasible: usize = states.iter().map(|s| s.infeasible).sum();
        let feasible_total = self.total_points() - infeasible;

        let mut state = self.initial_state(&states, exec)?;
        // Shared pruning threshold, f64 bits (scores are positive, so
        // the bit pattern orders like the value): the k-th incumbent
        // score once the top-k is full, +inf before (score == total
        // under the time objective). The merge step owns it between
        // batches; workers read it before evaluating and CAS-min it
        // with fresh scores during a batch when `top_k == 1` (any
        // single evaluated score upper-bounds the final argmin score;
        // for k > 1 no single score bounds the k-th best, so workers
        // leave it to the merge). A resumed run seeds it from the
        // replayed incumbents.
        let threshold =
            AtomicU64::new(if state.incumbents.len() >= self.top_k {
                state.incumbents[self.top_k - 1].0.to_bits()
            } else {
                f64::INFINITY.to_bits()
            });
        let batch_cap = lanes.saturating_mul(LEAVES_PER_LANE).max(1);
        let mut last_ckpt: Option<Instant> = None;
        let mut done = false;
        while !done {
            // ---- safe boundary: between-batch state is sequential-
            // reachable, so stops and checkpoints happen only here.
            if let Some(reason) =
                self.at_boundary(&state, exec, &mut last_ckpt)?
            {
                return Ok(self.outcome_partial(
                    state.evaluated,
                    infeasible,
                    reason,
                ));
            }
            // ---- collect: pop the frontier in canonical order.
            let cut = if state.incumbents.len() >= self.top_k {
                state.incumbents[self.top_k - 1].0
            } else {
                f64::INFINITY
            };
            let mut pending: Vec<Leaf> = Vec::with_capacity(batch_cap);
            while pending.len() < batch_cap {
                let Some(e) = state.heap.pop() else {
                    done = true;
                    break;
                };
                // The sequential driver stops at the first entry whose
                // bound strictly loses to the k-th incumbent. `cut` is
                // that incumbent as of the batch start; mid-batch
                // results only tighten it, so stopping here is exact —
                // the replay below re-checks against the live value.
                if e.bound > cut {
                    done = true;
                    break;
                }
                match e.node {
                    NodeRef::Branch(i) => {
                        for leaf in self.expand(i, &states[i]) {
                            state.heap.push(Entry {
                                bound: leaf.bound,
                                seq: state.seq,
                                node: NodeRef::Leaf(leaf),
                            });
                            state.seq += 1;
                        }
                    }
                    NodeRef::Leaf(leaf) => pending.push(leaf),
                }
            }
            // ---- evaluate: speculative fan-out over the pool, capped
            // at the driver's lane count. A panicking evaluation is
            // captured per job index by the pool (which respawns the
            // worker and finishes the rest of the batch) and surfaces
            // here as `Error::Job`.
            let evals: Vec<Option<TrainingBreakdown>> = self
                .coord
                .pool()
                .try_scoped_map_bounded(&pending, lanes, |leaf| {
                    let t = f64::from_bits(threshold.load(Ordering::Relaxed));
                    if leaf.bound > t {
                        // Provably cut at merge time (the threshold only
                        // tightens): skip the work. If the replay still
                        // reaches this leaf it evaluates lazily there.
                        return None;
                    }
                    let st = &states[leaf.point.branch];
                    let b = self.eval_leaf(st, leaf);
                    if self.top_k == 1 {
                        // The threshold holds the incumbent *score* —
                        // under the goodput objective a total would be
                        // too tight a cut (score >= total).
                        let (score, _) = self.score_of(leaf, st.footprint, &b);
                        let bits = score.to_bits();
                        let mut cur = threshold.load(Ordering::Relaxed);
                        while f64::from_bits(cur) > score {
                            match threshold.compare_exchange_weak(
                                cur,
                                bits,
                                Ordering::Relaxed,
                                Ordering::Relaxed,
                            ) {
                                Ok(_) => break,
                                Err(now) => cur = now,
                            }
                        }
                    }
                    Some(b)
                })?;
            // ---- merge: replay in collection order — exactly the
            // sequential driver's update-and-cutoff sequence.
            for (leaf, eval) in pending.iter().zip(evals) {
                if state.incumbents.len() >= self.top_k
                    && leaf.bound > state.incumbents[self.top_k - 1].0
                {
                    // The sequential driver terminates here; everything
                    // speculatively evaluated beyond this point is
                    // discarded.
                    done = true;
                    break;
                }
                let st = &states[leaf.point.branch];
                let b = eval.unwrap_or_else(|| self.eval_leaf(st, leaf));
                let cand = self.candidate(leaf, st.footprint, b);
                self.admit(&mut state.incumbents, &cand);
                state.evaluated.push(cand);
            }
            if state.incumbents.len() >= self.top_k {
                threshold.store(
                    state.incumbents[self.top_k - 1].0.to_bits(),
                    Ordering::Relaxed,
                );
            }
        }
        let pruned = feasible_total - state.evaluated.len();
        Ok(self.outcome_from(state.evaluated, pruned, infeasible))
    }

    /// Exhaustive enumeration of the full lattice through the batched
    /// evaluation path: every feasible point is resolved from the shared
    /// decomposition into full `ModelInputs` and evaluated in **one**
    /// [`Coordinator::evaluate_inputs`] call. Deliberately independent
    /// plumbing from the search drivers' template fast path — the oracle
    /// `search()` is tested against (bit-for-bit), and the baseline
    /// `bench_optimizer` compares evaluated-point counts with.
    pub fn exhaustive(&self) -> Result<Outcome> {
        self.exhaustive_controlled(&RunControl::unbounded())
    }

    /// [`Optimizer::exhaustive`] with cooperative stop checks between
    /// its phases (and per-leaf during input resolution). Exhaustive
    /// enumeration has no incremental state worth keeping, so a stop is
    /// an [`Error::Cancelled`] / [`Error::Deadline`] rather than a
    /// partial outcome.
    fn exhaustive_controlled(&self, control: &RunControl) -> Result<Outcome> {
        control.check("exhaustive enumeration")?;
        let states = self.prepare(usize::MAX)?;
        let infeasible: usize = states.iter().map(|s| s.infeasible).sum();
        let mut leaves: Vec<Leaf> = Vec::new();
        for (i, st) in states.iter().enumerate() {
            leaves.extend(self.expand(i, st));
        }
        let mut inputs: Vec<ModelInputs> = Vec::with_capacity(leaves.len());
        for l in &leaves {
            control.check("exhaustive input resolution")?;
            let st = &states[l.point.branch];
            let b = &self.branches[l.point.branch];
            let cluster = self.leaf_cluster(
                st.footprint,
                l.point.em_bandwidth,
                l.point.em_capacity,
            );
            inputs.push(resolve_inputs(
                &st.dec,
                &cluster,
                &self.leaf_opts(b, l.point.collective),
            )?);
        }
        let evals = self.coord.evaluate_inputs_controlled(&inputs, control)?;
        let evaluated: Vec<Candidate> = leaves
            .iter()
            .zip(&evals)
            .map(|(l, &b)| {
                self.candidate(l, states[l.point.branch].footprint, b)
            })
            .collect();
        Ok(self.outcome_from(evaluated, 0, infeasible))
    }

    /// Resolve a finished candidate back into the exact [`ModelInputs`]
    /// its evaluation saw (same decomposition through the coordinator's
    /// derive cache, same expanded-memory attachment, same per-leaf
    /// options) — the re-simulation hook behind `comet optimize
    /// --cross-check des`, which re-runs the DES on the top-k of every
    /// argmin and compares against the search's analytical totals.
    pub fn inputs_for(&self, cand: &Candidate) -> Result<ModelInputs> {
        let b = self.branches.get(cand.point.branch).ok_or_else(|| {
            Error::Config(format!(
                "cross-check: candidate names branch {} but the optimizer \
                 has {}",
                cand.point.branch,
                self.branches.len()
            ))
        })?;
        let dec = self.coord.decomposition(&b.workload);
        let cluster = self.leaf_cluster(
            cand.footprint,
            cand.point.em_bandwidth,
            cand.point.em_capacity,
        );
        resolve_inputs(&dec, &cluster, &self.leaf_opts(b, cand.point.collective))
    }
}

/// Non-dominated set in (compute, exposed communication), ascending
/// compute. Duplicate (compute, comm) pairs keep the smallest lattice
/// index.
fn pareto(mut evaluated: Vec<Candidate>) -> Vec<Candidate> {
    evaluated.sort_by(|a, b| {
        a.breakdown
            .compute()
            .total_cmp(&b.breakdown.compute())
            .then_with(|| {
                a.breakdown
                    .exposed_comm()
                    .total_cmp(&b.breakdown.exposed_comm())
            })
            .then_with(|| a.point.index.cmp(&b.point.index))
    });
    let mut out: Vec<Candidate> = Vec::new();
    let mut best_comm = f64::INFINITY;
    for c in evaluated {
        if c.breakdown.exposed_comm() < best_comm {
            best_comm = c.breakdown.exposed_comm();
            out.push(c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::parallel::{footprint_per_node, Strategy};
    use crate::util::units::gb;
    use crate::workload::transformer::Transformer;

    fn transformer_branches(
        n_nodes: usize,
        min_mp: usize,
        max_mp: usize,
    ) -> Vec<Branch> {
        let stage = ZeroStage::OsG;
        Strategy::sweep_bounded(n_nodes, min_mp, max_mp)
            .unwrap()
            .into_iter()
            .map(|s| Branch {
                label: s.label(),
                workload: Transformer::t1().build(&s).unwrap(),
                stage,
                footprint_override: None,
                microbatches: None,
                schedule: None,
            })
            .collect()
    }

    #[test]
    fn search_matches_exhaustive_with_pruning() {
        let coord = Coordinator::native();
        let opt = Optimizer::new(
            &coord,
            presets::dgx_a100_1024(),
            EvalOptions::default(),
            transformer_branches(1024, 2, 128),
            AxisSpec::new().em_bandwidths(&[gb(250.0), gb(1000.0), gb(2039.0)]),
        )
        .unwrap()
        .with_top_k(3);
        let s = opt.search().unwrap();
        let e = opt.exhaustive().unwrap();
        assert_eq!(s.top.len(), e.top.len());
        for (a, b) in s.top.iter().zip(&e.top) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.point.index, b.point.index);
            assert_eq!(a.total().to_bits(), b.total().to_bits());
        }
        assert_eq!(e.evaluated, 21);
        assert_eq!(e.pruned, 0);
        assert!(s.evaluated < e.evaluated, "{} pruned", s.pruned);
        assert_eq!(s.evaluated + s.pruned, e.evaluated);
        // The best co-design is MP8 at full-rate expansion (paper Ex. 1).
        assert_eq!(s.best().unwrap().label, "MP8_DP128 EM@2039GB/s");
    }

    #[test]
    fn parallel_search_is_bit_identical_to_sequential() {
        // The tentpole guarantee: the full Outcome — counters and
        // frontier included — is invariant in the lane count.
        let coord = Coordinator::native().with_threads(8);
        for top_k in [1usize, 3] {
            let opt = Optimizer::new(
                &coord,
                presets::dgx_a100_1024(),
                EvalOptions::default(),
                transformer_branches(1024, 2, 128),
                AxisSpec::new()
                    .em_bandwidths(&[gb(250.0), gb(1000.0), gb(2039.0)])
                    .collective_impls(&[
                        CollectiveImpl::LogicalRing,
                        CollectiveImpl::Hierarchical,
                    ]),
            )
            .unwrap()
            .with_top_k(top_k);
            let seq = opt.search_sequential().unwrap();
            for lanes in [2usize, 3, 8] {
                let par = opt.search_parallel(lanes).unwrap();
                seq.assert_bit_identical(
                    &par,
                    &format!("top_k={top_k} lanes={lanes}"),
                );
            }
            // The default dispatch (pool width) agrees too.
            let dispatched = opt.search().unwrap();
            seq.assert_bit_identical(&dispatched, "dispatch");
            // And with_threads(1) forces the sequential driver.
            let one = opt.search_parallel(1).unwrap();
            seq.assert_bit_identical(&one, "lanes=1");
        }
    }

    #[test]
    fn counters_partition_the_lattice_in_every_driver() {
        let coord = Coordinator::native();
        // No expansion axis: some Transformer-1T branches are
        // capacity-infeasible, so all three counters are non-trivial.
        let opt = Optimizer::new(
            &coord,
            presets::dgx_a100_1024(),
            EvalOptions::default(),
            transformer_branches(1024, 2, 128),
            AxisSpec::new(),
        )
        .unwrap()
        .with_top_k(2);
        for out in [
            opt.search_sequential().unwrap(),
            opt.search_parallel(4).unwrap(),
            opt.exhaustive().unwrap(),
        ] {
            assert_eq!(
                out.evaluated + out.pruned + out.infeasible,
                out.total_points
            );
            assert!(out.infeasible > 0);
        }
    }

    #[test]
    fn leaf_fast_path_matches_resolved_inputs_oracle() {
        // The zero-allocation template patch must reproduce the full
        // per-leaf resolve bit-for-bit, across capacity-spilled,
        // spill-free, and infinite-memory branches.
        use crate::analytical::evaluate;
        let coord = Coordinator::native();
        for opts in [
            EvalOptions::default(),
            EvalOptions {
                ignore_capacity: true,
                ..Default::default()
            },
        ] {
            let opt = Optimizer::new(
                &coord,
                presets::dgx_a100_1024(),
                opts,
                transformer_branches(1024, 2, 128),
                AxisSpec::new()
                    .em_bandwidths(&[gb(500.0), gb(2039.0)])
                    .em_capacities(&[gb(40.0), gb(400.0)])
                    .collective_impls(&[
                        CollectiveImpl::LogicalRing,
                        CollectiveImpl::Hierarchical,
                    ]),
            )
            .unwrap();
            let states = opt.prepare(usize::MAX).unwrap();
            for (i, st) in states.iter().enumerate() {
                for leaf in opt.expand(i, st) {
                    let fast = opt.eval_leaf(st, &leaf);
                    let cluster = opt.leaf_cluster(
                        st.footprint,
                        leaf.point.em_bandwidth,
                        leaf.point.em_capacity,
                    );
                    let inputs = resolve_inputs(
                        &st.dec,
                        &cluster,
                        &opt.leaf_opts(
                            &opt.branches[i],
                            leaf.point.collective,
                        ),
                    )
                    .unwrap();
                    assert_eq!(inputs.params.bw_em, leaf.bw_em);
                    let slow = evaluate(&inputs);
                    assert_eq!(
                        fast.total().to_bits(),
                        slow.total().to_bits(),
                        "branch {i} point {}",
                        leaf.point.index
                    );
                }
            }
        }
    }

    #[test]
    fn bounds_are_admissible() {
        let coord = Coordinator::native();
        let opt = Optimizer::new(
            &coord,
            presets::dgx_a100_1024(),
            EvalOptions::default(),
            transformer_branches(1024, 2, 128),
            AxisSpec::new().em_bandwidths(&[gb(500.0), gb(2039.0)]),
        )
        .unwrap();
        let e = opt.exhaustive().unwrap();
        for c in e.top.iter().chain(&e.frontier) {
            assert!(
                c.lower_bound <= c.total(),
                "{}: bound {} > total {}",
                c.label,
                c.lower_bound,
                c.total()
            );
        }
    }

    #[test]
    fn infeasible_points_are_skipped_not_evaluated() {
        // Without expansion, low-MP Transformer-1T footprints exceed the
        // 80 GB node: those branches must be pruned as infeasible.
        let coord = Coordinator::native();
        let opt = Optimizer::new(
            &coord,
            presets::dgx_a100_1024(),
            EvalOptions::default(),
            transformer_branches(1024, 2, 128),
            AxisSpec::new(),
        )
        .unwrap();
        let s = opt.search().unwrap();
        let e = opt.exhaustive().unwrap();
        assert_eq!(s.total_points, 7);
        assert_eq!(s.infeasible, e.infeasible);
        // The fitting strategies are exactly those whose footprint stays
        // within the 80 GB node (MP8_DP128 at ~264 GB is out).
        let fitting = Strategy::sweep_bounded(1024, 2, 128)
            .unwrap()
            .iter()
            .filter(|s| {
                let w = Transformer::t1().build(s).unwrap();
                footprint_per_node(&w, s, ZeroStage::OsG).total() <= 80e9
            })
            .count();
        assert!((1..7).contains(&fitting));
        assert_eq!(s.infeasible, 7 - fitting);
        assert_eq!(s.evaluated + s.pruned, fitting);
        let best = s.best().unwrap();
        assert_eq!(best.label, e.best().unwrap().label);
        assert!(best.footprint <= 80e9, "argmin must be feasible");
    }

    #[test]
    fn frontier_is_nondominated_and_contains_argmin() {
        let coord = Coordinator::native();
        let opt = Optimizer::new(
            &coord,
            presets::dgx_a100_1024(),
            EvalOptions {
                ignore_capacity: true,
                ..Default::default()
            },
            transformer_branches(1024, 2, 128),
            AxisSpec::new(),
        )
        .unwrap()
        .with_top_k(7);
        let e = opt.exhaustive().unwrap();
        // Infinite-memory mode: no footprint pruning — all 7 strategies
        // evaluate even though most spill the 80 GB node.
        assert_eq!(e.infeasible, 0);
        assert_eq!(e.evaluated, 7);
        let f = &e.frontier;
        assert!(!f.is_empty());
        for w in f.windows(2) {
            assert!(
                w[0].breakdown.compute() <= w[1].breakdown.compute()
                    && w[0].breakdown.exposed_comm()
                        > w[1].breakdown.exposed_comm(),
                "frontier must trade compute for communication"
            );
        }
        let best = e.best().unwrap();
        assert!(
            f.iter().any(|c| c.point.index == best.point.index),
            "argmin must sit on the frontier"
        );
    }

    #[test]
    fn search_matches_exhaustive_on_3d_lattice() {
        // MP fixed at 8, PP in {1, 2, 4, 8}: the lattice grown by the
        // pipeline axis must keep the search == exhaustive oracle, with
        // every reported bound admissible.
        let coord = Coordinator::native();
        let branches: Vec<Branch> = Strategy::sweep_3d(1024, 8, 8, 8)
            .unwrap()
            .into_iter()
            .map(|s| Branch {
                label: s.label(),
                workload: Transformer::t1().build(&s).unwrap(),
                stage: ZeroStage::OsG,
                footprint_override: None,
                microbatches: None,
                schedule: None,
            })
            .collect();
        assert_eq!(branches.len(), 4);
        let opt = Optimizer::new(
            &coord,
            presets::dgx_a100_1024(),
            EvalOptions::default(),
            branches,
            AxisSpec::new().em_bandwidths(&[gb(500.0), gb(2039.0)]),
        )
        .unwrap()
        .with_top_k(3);
        let s = opt.search().unwrap();
        let e = opt.exhaustive().unwrap();
        assert_eq!(e.evaluated, 8);
        assert_eq!(s.top.len(), e.top.len());
        for (a, b) in s.top.iter().zip(&e.top) {
            assert_eq!(a.point.index, b.point.index);
            assert_eq!(a.label, b.label);
            assert_eq!(a.total().to_bits(), b.total().to_bits());
        }
        assert_eq!(s.evaluated + s.pruned, e.evaluated);
        for c in e.top.iter().chain(&e.frontier) {
            assert!(
                c.lower_bound <= c.total(),
                "{}: bound {} > total {}",
                c.label,
                c.lower_bound,
                c.total()
            );
        }
        // The pipeline lattice stays lane-invariant too.
        let seq = opt.search_sequential().unwrap();
        let par = opt.search_parallel(4).unwrap();
        seq.assert_bit_identical(&par, "3d lanes=4");
    }

    #[test]
    fn pipeline_branch_overrides_reach_evaluation() {
        // Two branches over the same 3D strategy, different microbatch
        // counts: the fewer-microbatch branch pays a larger bubble.
        let coord = Coordinator::native();
        let s = Strategy::new_3d(8, 16, 8).unwrap();
        let mk = |m: usize| Branch {
            label: format!("{} m{m}", s.label()),
            workload: Transformer::t1().build(&s).unwrap(),
            stage: ZeroStage::OsG,
            footprint_override: None,
            microbatches: Some(m),
            schedule: Some(crate::parallel::PipeSchedule::OneFOneB),
        };
        let opt = Optimizer::new(
            &coord,
            presets::dgx_a100_1024(),
            EvalOptions {
                ignore_capacity: true,
                ..Default::default()
            },
            vec![mk(2), mk(32)],
            AxisSpec::new(),
        )
        .unwrap()
        .with_top_k(2);
        let e = opt.exhaustive().unwrap();
        assert_eq!(e.evaluated, 2);
        let best = e.best().unwrap();
        assert!(best.label.contains("m32"), "{}", best.label);
        assert!(e.top[1].breakdown.bubble > e.top[0].breakdown.bubble);
    }

    #[test]
    fn non_native_backend_search_falls_back_to_exhaustive() {
        // The analytical bounds are not admissible against DES totals
        // (they agree only to ~5%), so search() must not prune there.
        let coord = Coordinator::des();
        let opt = Optimizer::new(
            &coord,
            presets::dgx_a100_64(),
            EvalOptions::default(),
            transformer_branches(64, 8, 16),
            AxisSpec::new().em_bandwidths(&[gb(500.0), gb(2039.0)]),
        )
        .unwrap()
        .with_top_k(1);
        let s = opt.search().unwrap();
        assert_eq!(s.pruned, 0, "DES search must enumerate exhaustively");
        assert_eq!(s.evaluated, 4);
        let e = opt.exhaustive().unwrap();
        assert_eq!(s.best().unwrap().label, e.best().unwrap().label);
    }

    #[test]
    fn capacity_axis_requires_bandwidths() {
        let coord = Coordinator::native();
        let err = Optimizer::new(
            &coord,
            presets::dgx_a100_1024(),
            EvalOptions::default(),
            transformer_branches(1024, 8, 8),
            AxisSpec::new().em_capacities(&[gb(100.0)]),
        );
        assert!(err.is_err());
    }

    #[test]
    fn degenerate_axis_values_rejected_at_construction() {
        // A zero/NaN bandwidth used to surface as a per-leaf
        // cluster-validation error in the old search; the fast path
        // must reject it before any driver runs (identically for
        // search and exhaustive).
        let coord = Coordinator::native();
        for axes in [
            AxisSpec::new().em_bandwidths(&[0.0]),
            AxisSpec::new().em_bandwidths(&[-1.0]),
            AxisSpec::new().em_bandwidths(&[f64::NAN]),
            AxisSpec::new()
                .em_bandwidths(&[gb(500.0)])
                .em_capacities(&[-1.0]),
        ] {
            let err = Optimizer::new(
                &coord,
                presets::dgx_a100_1024(),
                EvalOptions::default(),
                transformer_branches(1024, 8, 8),
                axes,
            );
            assert!(err.is_err());
        }
        // Empty collectives collapse the lattice to zero points — also
        // rejected at construction (axes.is_empty()).
        let err = Optimizer::new(
            &coord,
            presets::dgx_a100_1024(),
            EvalOptions::default(),
            transformer_branches(1024, 8, 8),
            AxisSpec::new().collective_impls(&[]),
        );
        assert!(err.is_err());
    }

    #[test]
    fn goodput_with_disabled_faults_is_bit_identical_to_time() {
        // The faults-disabled slice of the goodput objective must be the
        // time objective, bit for bit: efficiency is exactly 1.0 and
        // `total / 1.0` is exact.
        let coord = Coordinator::native();
        let mk = |objective| {
            Optimizer::new(
                &coord,
                presets::dgx_a100_1024(),
                EvalOptions::default(),
                transformer_branches(1024, 2, 128),
                AxisSpec::new()
                    .em_bandwidths(&[gb(250.0), gb(1000.0), gb(2039.0)]),
            )
            .unwrap()
            .with_top_k(3)
            .with_objective(objective, FaultModel::none())
            .unwrap()
        };
        let time = mk(Objective::Time).search().unwrap();
        let good = mk(Objective::Goodput).search().unwrap();
        time.assert_bit_identical(&good, "goodput(none) vs time");
        assert_eq!(good.best().unwrap().efficiency, 1.0);
    }

    #[test]
    fn goodput_search_matches_exhaustive_at_every_lane_count() {
        // The acceptance criterion: with faults enabled, search ==
        // exhaustive (argmin / top-k / counter partition) and the
        // parallel driver is bit-identical at 1, 2, and 8 lanes.
        let coord = Coordinator::native().with_threads(8);
        let faults = FaultModel {
            mtbf_node_hours: 200.0,
            restart_s: 120.0,
            straggler_frac: 0.02,
            straggler_slowdown: 1.5,
            link_degrade_frac: 0.05,
            link_degrade_factor: 2.0,
            seed: 42,
        };
        let opt = Optimizer::new(
            &coord,
            presets::dgx_a100_1024(),
            EvalOptions::default(),
            transformer_branches(1024, 2, 128),
            AxisSpec::new().em_bandwidths(&[gb(250.0), gb(1000.0), gb(2039.0)]),
        )
        .unwrap()
        .with_top_k(3)
        .with_objective(Objective::Goodput, faults)
        .unwrap();
        let seq = opt.search_sequential().unwrap();
        for lanes in [1usize, 2, 8] {
            let par = opt.search_parallel(lanes).unwrap();
            seq.assert_bit_identical(&par, &format!("goodput lanes={lanes}"));
        }
        let e = opt.exhaustive().unwrap();
        assert_eq!(seq.top.len(), e.top.len());
        for (a, b) in seq.top.iter().zip(&e.top) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.point.index, b.point.index);
            assert_eq!(a.score.to_bits(), b.score.to_bits());
        }
        assert_eq!(seq.evaluated + seq.pruned, e.evaluated);
        // The admissibility chain for every reported candidate.
        for c in seq.top.iter().chain(&seq.frontier) {
            assert!(c.efficiency > 0.0 && c.efficiency <= 1.0);
            assert!(
                c.lower_bound <= c.total() && c.total() <= c.score,
                "{}: bound {} total {} score {}",
                c.label,
                c.lower_bound,
                c.total(),
                c.score
            );
        }
    }

    #[test]
    fn goodput_objective_penalizes_large_checkpoints() {
        // Two branches with identical step times (ignore_capacity pins
        // the EM fraction to zero, so the footprint override cannot
        // change the evaluation) but very different checkpoint sizes.
        // The time objective breaks the tie by lattice order — the big
        // checkpoint wins; under failures the goodput objective flips
        // the argmin to the small checkpoint.
        let coord = Coordinator::native();
        let s = Strategy::new(8, 128).unwrap();
        let mk_branch = |label: &str, fp: f64| Branch {
            label: label.into(),
            workload: Transformer::t1().build(&s).unwrap(),
            stage: ZeroStage::OsG,
            footprint_override: Some(fp),
            microbatches: None,
            schedule: None,
        };
        let opts = EvalOptions {
            ignore_capacity: true,
            ..Default::default()
        };
        let mk_opt = |objective, faults| {
            Optimizer::new(
                &coord,
                presets::dgx_a100_1024(),
                opts,
                vec![
                    mk_branch("big-ckpt", 10e12),
                    mk_branch("small-ckpt", 100e9),
                ],
                AxisSpec::new(),
            )
            .unwrap()
            .with_top_k(2)
            .with_objective(objective, faults)
            .unwrap()
        };
        let faults = FaultModel {
            mtbf_node_hours: 100.0,
            restart_s: 60.0,
            ..FaultModel::none()
        };
        let time = mk_opt(Objective::Time, FaultModel::none());
        let good = mk_opt(Objective::Goodput, faults);
        let t = time.search().unwrap();
        assert_eq!(t.best().unwrap().label, "big-ckpt");
        let g = good.search().unwrap();
        assert_eq!(g.best().unwrap().label, "small-ckpt");
        assert!(g.best().unwrap().efficiency < 1.0);
        // The flip is driver-invariant.
        let e = good.exhaustive().unwrap();
        assert_eq!(e.best().unwrap().label, "small-ckpt");
        good.search_sequential()
            .unwrap()
            .assert_bit_identical(&good.search_parallel(4).unwrap(), "flip");
    }

    #[test]
    fn objective_parse_and_validation() {
        assert_eq!(Objective::parse("time").unwrap(), Objective::Time);
        assert_eq!(Objective::parse("goodput").unwrap(), Objective::Goodput);
        assert!(Objective::parse("speed").is_err());
        assert_eq!(Objective::Goodput.name(), "goodput");
        assert_eq!(Objective::default(), Objective::Time);
        // with_objective validates the fault model.
        let coord = Coordinator::native();
        let bad = FaultModel {
            straggler_frac: 2.0,
            ..FaultModel::none()
        };
        let err = Optimizer::new(
            &coord,
            presets::dgx_a100_1024(),
            EvalOptions::default(),
            transformer_branches(1024, 8, 8),
            AxisSpec::new(),
        )
        .unwrap()
        .with_objective(Objective::Goodput, bad);
        assert!(err.is_err());
    }

    #[test]
    fn axis_spec_cross_product() {
        let a = AxisSpec::new()
            .em_bandwidths(&[1e9, 2e9])
            .em_capacities(&[1e9, 2e9, 3e9])
            .collective_impls(&[
                CollectiveImpl::LogicalRing,
                CollectiveImpl::Hierarchical,
            ]);
        assert_eq!(a.len(), 12);
        assert!(!a.is_empty());
        assert_eq!(AxisSpec::new().len(), 1);
    }

    fn robust_fixture(coord: &Coordinator) -> Optimizer<'_> {
        Optimizer::new(
            coord,
            presets::dgx_a100_1024(),
            EvalOptions::default(),
            transformer_branches(1024, 2, 128),
            AxisSpec::new().em_bandwidths(&[gb(250.0), gb(1000.0), gb(2039.0)]),
        )
        .unwrap()
        .with_top_k(3)
    }

    #[test]
    fn cancelled_search_returns_partial_outcome_with_counters() {
        let coord = Coordinator::native().with_threads(2);
        // top_k = 21 covers the whole 21-point lattice, so no pruning
        // cutoff can finish the search early: the sequential driver
        // takes 7 branch + 21 leaf iterations and the 2-lane driver
        // needs ceil(21/8) batches, making the cancel points below
        // mid-search by construction.
        let opt = robust_fixture(&coord).with_top_k(21);
        let full = opt.search_sequential().unwrap();
        assert!(full.complete && full.remaining == 0 && full.stop.is_none());
        assert_eq!(full.evaluated, 21);
        for (lanes, polls) in [(1usize, 4u64), (2, 1)] {
            let exec = SearchExec::default().with_control(
                RunControl::unbounded().cancel_after_polls(polls),
            );
            let out = opt.search_parallel_with(lanes, &exec).unwrap();
            assert!(!out.complete, "lanes={lanes}");
            assert_eq!(out.stop, Some(StopReason::Cancelled));
            // Partial runs prove nothing about unexplored points:
            // everything not evaluated (and not statically infeasible)
            // is `remaining`, never `pruned`.
            assert_eq!(out.pruned, 0);
            assert_eq!(
                out.evaluated + out.infeasible + out.remaining,
                out.total_points
            );
            assert!(out.remaining > 0, "cancelled too late to be partial");
            assert!(out.evaluated < full.evaluated);
        }
        // A zero deadline stops before the first batch.
        let exec = SearchExec::default().with_control(
            RunControl::unbounded()
                .with_deadline(crate::util::cancel::Deadline::after_secs(0.0)),
        );
        let out = opt.search_with(&exec).unwrap();
        assert!(!out.complete);
        assert_eq!(out.stop, Some(StopReason::DeadlineExceeded));
        assert_eq!(out.evaluated, 0);
    }

    #[test]
    fn checkpoint_resume_is_bit_identical_to_uninterrupted() {
        let coord = Coordinator::native().with_threads(8);
        // A 42-point lattice (3 bandwidths x 2 collectives over 7
        // branches) with top_k covering it all: no pruning cutoff, so
        // every driver needs multiple batches (8-lane cap is 32) and
        // each cancel point below lands strictly mid-search.
        let opt = Optimizer::new(
            &coord,
            presets::dgx_a100_1024(),
            EvalOptions::default(),
            transformer_branches(1024, 2, 128),
            AxisSpec::new()
                .em_bandwidths(&[gb(250.0), gb(1000.0), gb(2039.0)])
                .collective_impls(&[
                    CollectiveImpl::LogicalRing,
                    CollectiveImpl::Hierarchical,
                ]),
        )
        .unwrap()
        .with_top_k(42);
        let oracle = opt.search_sequential().unwrap();
        assert!(oracle.complete);
        let dir = std::env::temp_dir();
        for (case, lanes, polls) in
            [("seq", 1usize, 6u64), ("par2", 2, 2), ("par8", 8, 1)]
        {
            let path = dir.join(format!(
                "comet-ckpt-resume-{}-{case}.json",
                std::process::id()
            ));
            let exec = SearchExec::default()
                .with_control(RunControl::unbounded().cancel_after_polls(polls))
                .with_checkpoint(path.clone());
            let partial = opt.search_parallel_with(lanes, &exec).unwrap();
            assert!(!partial.complete, "{case}: cancelled run completed");
            // The flushed checkpoint resumes — on ANY driver — to the
            // exact uninterrupted outcome, counters included.
            let ck = Checkpoint::load(&path).unwrap();
            let resumed = opt.search_parallel_with(
                lanes,
                &SearchExec::default().with_resume(ck.clone()),
            );
            oracle.assert_bit_identical(
                &resumed.unwrap(),
                &format!("resume {case} same-lanes"),
            );
            let cross = opt
                .search_sequential_with(
                    &SearchExec::default().with_resume(ck),
                )
                .unwrap();
            oracle.assert_bit_identical(&cross, &format!("resume {case} seq"));
            let _ = std::fs::remove_file(&path);
        }
    }

    #[test]
    fn checkpoint_rejects_mismatched_spec_fingerprint() {
        let coord = Coordinator::native();
        let opt = robust_fixture(&coord);
        let exec = SearchExec::default()
            .with_control(RunControl::unbounded().cancel_after_polls(1));
        let path = std::env::temp_dir().join(format!(
            "comet-ckpt-fp-{}.json",
            std::process::id()
        ));
        let exec = exec.with_checkpoint(path.clone());
        opt.search_sequential_with(&exec).unwrap();
        let ck = Checkpoint::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        // A different lattice (extra EM capacity axis) must refuse the
        // checkpoint instead of resuming into the wrong search.
        let other = Optimizer::new(
            &coord,
            presets::dgx_a100_1024(),
            EvalOptions::default(),
            transformer_branches(1024, 2, 128),
            AxisSpec::new()
                .em_bandwidths(&[gb(250.0), gb(1000.0), gb(2039.0)])
                .em_capacities(&[gb(100.0)]),
        )
        .unwrap()
        .with_top_k(3);
        let err = other
            .search_sequential_with(&SearchExec::default().with_resume(ck))
            .unwrap_err();
        assert!(
            err.to_string().contains("fingerprint"),
            "unexpected error: {err}"
        );
    }

    #[test]
    fn panicking_leaf_surfaces_job_error_and_pool_survives() {
        let coord = Coordinator::native().with_threads(2);
        let clean = robust_fixture(&coord).search_parallel(2).unwrap();
        let victim = clean.best().unwrap().point.index;
        let err = robust_fixture(&coord)
            .with_panic_leaf(victim)
            .search_parallel(2)
            .unwrap_err();
        match &err {
            crate::error::Error::Job { cause, .. } => {
                assert!(
                    cause.contains("injected leaf panic"),
                    "cause: {cause}"
                );
            }
            other => panic!("expected Error::Job, got {other:?}"),
        }
        // The pool healed: the same coordinator completes a fresh
        // search bit-identically to the pre-panic run.
        let after = robust_fixture(&coord).search_parallel(2).unwrap();
        clean.assert_bit_identical(&after, "post-panic pool reuse");
    }
}
