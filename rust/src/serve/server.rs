//! The `comet serve` server: accept loop, admission, serving workers,
//! per-request execution with deadline/cancel/panic isolation, and
//! graceful drain.
//!
//! One [`Server`] owns one shared [`Coordinator`] — the whole point of
//! the daemon: the derive/eval caches and the worker pool are
//! process-lifetime state, so repeated `/run`s on related scenarios hit
//! warm caches. Robustness invariants:
//!
//! * **Bounded admission** — accepted connections enter an
//!   [`AdmissionQueue`]; when it is full the accept loop answers `503`
//!   + `Retry-After: 1` immediately and in-flight work is untouched.
//! * **Per-request deadlines/cancellation** — `?deadline_s=` (or the
//!   server-wide `--request-deadline`) arms a [`RunControl`] deadline;
//!   a client disconnect trips the same [`CancelToken`] via a watcher
//!   thread. Optimize studies return their partial best-so-far table
//!   (`206`); other studies stop at a batch boundary (`504`).
//! * **Panic isolation** — the scenario executes as a single bounded
//!   pool job ([`WorkerPool::try_scoped_map_bounded`]); a panic comes
//!   back as a structured `500` and the pool is healed. Caches and
//!   concurrent requests are unaffected.
//! * **Graceful drain** — cancelling the shutdown token stops the
//!   accept loop, closes the queue, lets workers finish every admitted
//!   request (their tokens are *not* cancelled), then returns.
//!
//! [`WorkerPool::try_scoped_map_bounded`]:
//! crate::coordinator::scheduler::WorkerPool::try_scoped_map_bounded

use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::Coordinator;
use crate::error::{Error, Result};
use crate::report::FigureData;
use crate::scenario::{self, ScenarioSpec, Study};
use crate::util::cancel::{CancelToken, Deadline, RunControl};
use crate::util::json::{self, obj, Value};

use super::admission::AdmissionQueue;
use super::conn::{read_request, Request, Response};
use super::router::{route, Route};
use super::stats::ServeStats;

/// How long a connection may take to deliver its request or absorb its
/// response before the server gives up on it.
const CONN_IO_TIMEOUT: Duration = Duration::from_secs(10);
/// Accept-loop poll interval (shutdown responsiveness).
const ACCEPT_POLL: Duration = Duration::from_millis(15);
/// Disconnect-watcher poll interval.
const WATCH_POLL: Duration = Duration::from_millis(50);

/// `comet serve` configuration (the CLI flags, with their defaults).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Listen address (`--addr`); `:0` picks an ephemeral port.
    pub addr: String,
    /// Admission-queue bound (`--max-queue`): connections waiting for a
    /// serving worker beyond this are shed with a `503`.
    pub max_queue: usize,
    /// Serving workers (`--max-concurrency`): requests executing at
    /// once. Each still fans its evaluation across the coordinator's
    /// worker pool.
    pub max_concurrency: usize,
    /// Server-wide default `/run` deadline in seconds
    /// (`--request-deadline`); a request's `?deadline_s=` overrides it.
    pub request_deadline_s: Option<f64>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:8787".into(),
            max_queue: 64,
            max_concurrency: 4,
            request_deadline_s: None,
        }
    }
}

/// A bound-but-not-yet-running serve instance. [`Server::run`] blocks
/// until the shutdown token fires and the drain completes.
#[derive(Debug)]
pub struct Server {
    listener: TcpListener,
    coord: Coordinator,
    cfg: ServeConfig,
    queue: AdmissionQueue<TcpStream>,
    stats: ServeStats,
}

impl Server {
    /// Bind `cfg.addr` and wire the shared coordinator. Validates the
    /// bounds up front so a misconfiguration fails before listening.
    pub fn bind(cfg: ServeConfig, coord: Coordinator) -> Result<Server> {
        if cfg.max_concurrency == 0 {
            return Err(Error::Config(
                "serve: --max-concurrency must be >= 1".into(),
            ));
        }
        if cfg.max_queue == 0 {
            return Err(Error::Config(
                "serve: --max-queue must be >= 1".into(),
            ));
        }
        let listener = TcpListener::bind(&cfg.addr).map_err(|e| {
            Error::Io(format!("serve: bind {}: {e}", cfg.addr))
        })?;
        listener.set_nonblocking(true).map_err(|e| {
            Error::Io(format!("serve: set_nonblocking: {e}"))
        })?;
        let queue = AdmissionQueue::new(cfg.max_queue);
        Ok(Server {
            listener,
            coord,
            cfg,
            queue,
            stats: ServeStats::new(),
        })
    }

    /// The bound address (resolves `:0` to the actual port).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener
            .local_addr()
            .map_err(|e| Error::Io(format!("serve: local_addr: {e}")))
    }

    /// The server's request counters (bench/test introspection).
    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Serve until `shutdown` is cancelled, then drain: stop accepting,
    /// finish every admitted request (in-flight tokens are untouched),
    /// join the workers, and return `Ok(())` — the exit-0 path.
    pub fn run(&self, shutdown: &CancelToken) -> Result<()> {
        std::thread::scope(|s| {
            for _ in 0..self.cfg.max_concurrency {
                s.spawn(|| self.worker_loop());
            }
            self.accept_loop(shutdown);
            self.queue.close();
            // Scope exit joins the workers after the queue drains.
        });
        Ok(())
    }

    /// Accept until shutdown. A connection either enters the admission
    /// queue or is shed right here with `503` + `Retry-After` — never
    /// buffered unboundedly, never allowed to disturb in-flight work.
    fn accept_loop(&self, shutdown: &CancelToken) {
        while !shutdown.is_cancelled() {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    self.stats.inc_received();
                    if let Err(stream) = self.queue.try_push(stream) {
                        shed_response(stream);
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(_) => {
                    // Transient accept failure (EMFILE, aborted
                    // handshake): back off and keep serving.
                    std::thread::sleep(ACCEPT_POLL);
                }
            }
        }
    }

    /// One serving worker: pop admitted connections until the queue
    /// closes and drains. The whole per-connection handler sits under
    /// `catch_unwind` as a last-resort guard — scenario execution
    /// panics are already contained per-job by the pool — so a framing
    /// bug cannot take the serving thread (and the scope) down.
    fn worker_loop(&self) {
        while let Some(stream) = self.queue.pop() {
            let unwound = catch_unwind(AssertUnwindSafe(|| {
                self.handle_conn(stream);
            }));
            if unwound.is_err() {
                self.stats.inc_failed();
            }
        }
    }

    /// Parse one request and dispatch it by route.
    fn handle_conn(&self, mut stream: TcpStream) {
        let _ = stream.set_read_timeout(Some(CONN_IO_TIMEOUT));
        let _ = stream.set_write_timeout(Some(CONN_IO_TIMEOUT));
        let req = match read_request(&mut stream) {
            Ok(r) => r,
            Err(Error::Parse(m)) => {
                self.stats.inc_rejected();
                let _ = error_response(400, "bad-request", &m)
                    .write_to(&mut stream);
                return;
            }
            // I/O failure mid-read: the client is gone; nothing to say.
            Err(_) => return,
        };
        let resp = match route(&req.method, &req.path) {
            Route::Healthz => {
                Response::json(200, "{\"status\": \"ok\"}\n")
            }
            Route::Stats => Response::json(200, self.stats_body()),
            Route::Run => self.run_response(&req, &stream),
            Route::NotFound => {
                self.stats.inc_rejected();
                error_response(
                    404,
                    "not-found",
                    &format!("no such endpoint '{}'", req.path),
                )
            }
            Route::MethodNotAllowed => {
                self.stats.inc_rejected();
                error_response(
                    405,
                    "method-not-allowed",
                    &format!("{} {} is not allowed", req.method, req.path),
                )
            }
        };
        let _ = resp.write_to(&mut stream);
    }

    /// The `GET /stats` body (pretty JSON + trailing newline, like
    /// every other JSON surface in the CLI).
    fn stats_body(&self) -> String {
        let v = self.stats.to_json(
            &self.coord.stats(),
            self.queue.len(),
            self.queue.capacity(),
            self.queue.shed(),
        );
        let mut s = v.to_string_pretty();
        s.push('\n');
        s
    }

    /// Execute `POST /run`: parse the spec, arm deadline + disconnect
    /// cancellation, run on the shared coordinator under pool panic
    /// isolation, and classify the outcome into a status code.
    fn run_response(&self, req: &Request, stream: &TcpStream) -> Response {
        let body = match std::str::from_utf8(&req.body) {
            Ok(s) => s,
            Err(_) => {
                self.stats.inc_rejected();
                return error_response(
                    400,
                    "bad-request",
                    "request body is not UTF-8",
                );
            }
        };
        let spec = match json::parse(body)
            .and_then(|v| ScenarioSpec::from_json(&v))
        {
            Ok(s) => s,
            Err(e) => {
                self.stats.inc_rejected();
                return error_response(400, "bad-request", &e.to_string());
            }
        };
        let deadline_s = match req.query_param("deadline_s") {
            None => self.cfg.request_deadline_s,
            Some(v) => match v.parse::<f64>() {
                Ok(d) if d.is_finite() && d >= 0.0 => Some(d),
                _ => {
                    self.stats.inc_rejected();
                    return error_response(
                        400,
                        "bad-request",
                        &format!(
                            "deadline_s: bad value '{v}' (seconds >= 0)"
                        ),
                    );
                }
            },
        };

        self.stats.inc_in_flight();
        let token = CancelToken::new();
        let watcher = DisconnectWatcher::spawn(stream, token.clone());
        let result = self.execute(&spec, &token, deadline_s);
        drop(watcher);
        self.stats.dec_in_flight();

        match result {
            Ok((fig, partial)) => {
                let mut body = fig.to_json().to_string_pretty();
                body.push('\n');
                if partial {
                    self.stats.inc_partial();
                    Response::json(206, body)
                } else {
                    self.stats.inc_completed();
                    Response::json(200, body)
                }
            }
            Err(Error::Cancelled(m)) => {
                self.stats.inc_cancelled();
                error_response(504, "cancelled", &m)
            }
            Err(Error::Deadline(m)) => {
                self.stats.inc_deadline_expired();
                error_response(504, "deadline", &m)
            }
            Err(e @ (Error::Job { .. } | Error::Worker(_))) => {
                self.stats.inc_panicked();
                error_response(500, "panic", &e.to_string())
            }
            Err(
                e @ (Error::Config(_) | Error::Parse(_) | Error::Json(_)),
            ) => {
                self.stats.inc_rejected();
                error_response(400, "bad-request", &e.to_string())
            }
            Err(e) => {
                self.stats.inc_failed();
                error_response(500, "internal", &e.to_string())
            }
        }
    }

    /// Run the spec as **one bounded pool job** so a panic anywhere in
    /// evaluation surfaces as [`Error::Job`] instead of unwinding the
    /// serving worker; the pool is healed before the `500` goes out, so
    /// the next request sees a full-width pool.
    fn execute(
        &self,
        spec: &ScenarioSpec,
        token: &CancelToken,
        deadline_s: Option<f64>,
    ) -> Result<(FigureData, bool)> {
        let jobs = [()];
        let out = self.coord.pool().try_scoped_map_bounded(&jobs, 1, |_| {
            self.run_spec(spec, token, deadline_s)
        });
        match out {
            Ok(mut results) => {
                results.pop().expect("one pool job yields one result")
            }
            Err(e @ Error::Job { .. }) => {
                self.coord.pool().heal();
                Err(e)
            }
            Err(e) => Err(e),
        }
    }

    /// Study-aware execution. Optimize studies go through
    /// [`scenario::run_optimize_exec`] so a deadline/cancel stop yields
    /// the partial best-so-far figure (`true` = partial); every other
    /// study runs under [`scenario::run_controlled`] and stops with an
    /// error at the next batch boundary.
    fn run_spec(
        &self,
        spec: &ScenarioSpec,
        token: &CancelToken,
        deadline_s: Option<f64>,
    ) -> Result<(FigureData, bool)> {
        if matches!(spec.study, Study::Optimize { .. }) {
            let ex = scenario::ExecOverrides {
                token: Some(token.clone()),
                deadline_s,
                ..Default::default()
            };
            let (fig, out) =
                scenario::run_optimize_exec(spec, &self.coord, &ex)?;
            Ok((fig, out.stop.is_some()))
        } else {
            let mut control =
                RunControl::unbounded().with_token(token.clone());
            if let Some(d) = deadline_s {
                control = control.with_deadline(Deadline::after_secs(d));
            }
            let fig = scenario::run_controlled(spec, &self.coord, &control)?;
            Ok((fig, false))
        }
    }
}

/// The structured error body every non-2xx response carries:
/// `{"complete": false, "error": ..., "kind": ...}`.
fn error_body(kind: &str, message: &str) -> String {
    let mut s = obj(vec![
        ("complete", Value::Bool(false)),
        ("error", Value::Str(message.into())),
        ("kind", Value::Str(kind.into())),
    ])
    .to_string_compact();
    s.push('\n');
    s
}

/// A non-2xx JSON response with the documented error shape.
fn error_response(status: u16, kind: &str, message: &str) -> Response {
    Response::json(status, error_body(kind, message))
}

/// Answer a shed connection on the accept thread: `503` +
/// `Retry-After: 1`, written with a short timeout so a slow client
/// cannot stall accepting.
fn shed_response(mut stream: TcpStream) {
    let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
    let resp = error_response(
        503,
        "overloaded",
        "server busy: admission queue full; retry shortly",
    )
    .with_header("Retry-After", "1");
    let _ = resp.write_to(&mut stream);
}

/// Watches a `/run` client for disconnect while its scenario executes:
/// a cloned handle on the same socket is peeked every 50 ms, and an
/// orderly EOF (or a hard socket error) cancels the request token so
/// the evaluation stops at its next safe point. Dropping the watcher
/// (response ready) stops and joins the thread.
struct DisconnectWatcher {
    done: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl DisconnectWatcher {
    fn spawn(stream: &TcpStream, token: CancelToken) -> DisconnectWatcher {
        let done = Arc::new(AtomicBool::new(false));
        // `try_clone` shares the open socket, so the watcher's short
        // read timeout applies to the request stream too — safe here
        // because the request is fully read before the watcher starts
        // and the response path only writes.
        let handle = stream.try_clone().ok().and_then(|watch| {
            let _ = watch.set_read_timeout(Some(WATCH_POLL));
            let done = done.clone();
            std::thread::Builder::new()
                .name("comet-serve-watch".into())
                .spawn(move || {
                    let mut byte = [0u8; 1];
                    while !done.load(Ordering::Acquire) {
                        match watch.peek(&mut byte) {
                            // Orderly EOF: the client hung up.
                            Ok(0) => {
                                token.cancel();
                                return;
                            }
                            // Stray bytes after the request: ignore,
                            // but don't spin on them.
                            Ok(_) => std::thread::sleep(WATCH_POLL),
                            Err(e)
                                if matches!(
                                    e.kind(),
                                    io::ErrorKind::WouldBlock
                                        | io::ErrorKind::TimedOut
                                ) => {}
                            // Hard socket error: treat as gone.
                            Err(_) => {
                                token.cancel();
                                return;
                            }
                        }
                    }
                })
                .ok()
        });
        DisconnectWatcher { done, handle }
    }
}

impl Drop for DisconnectWatcher {
    fn drop(&mut self) {
        self.done.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::registry;
    use std::io::{Read, Write};
    use std::sync::Arc;

    /// Bind an in-process server on an ephemeral port and run it on a
    /// background thread; returns the address, the shutdown token, and
    /// the join handle (which yields the server back for inspection).
    fn start(
        cfg: ServeConfig,
    ) -> (
        SocketAddr,
        CancelToken,
        std::thread::JoinHandle<Arc<Server>>,
    ) {
        let server = Arc::new(
            Server::bind(cfg, Coordinator::native()).expect("bind :0"),
        );
        let addr = server.local_addr().expect("local addr");
        let shutdown = CancelToken::new();
        let (srv, tok) = (server.clone(), shutdown.clone());
        let handle = std::thread::spawn(move || {
            srv.run(&tok).expect("serve run");
            srv
        });
        (addr, shutdown, handle)
    }

    /// One full request/response exchange as raw bytes.
    fn http(addr: SocketAddr, raw: &str) -> String {
        let mut s = TcpStream::connect(addr).expect("connect");
        s.write_all(raw.as_bytes()).expect("send request");
        let mut out = String::new();
        s.read_to_string(&mut out).expect("read response");
        out
    }

    fn post_run(addr: SocketAddr, spec_json: &str, query: &str) -> String {
        http(
            addr,
            &format!(
                "POST /run{query} HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
                spec_json.len(),
                spec_json
            ),
        )
    }

    fn ephemeral() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:0".into(),
            ..ServeConfig::default()
        }
    }

    fn stop(
        shutdown: &CancelToken,
        handle: std::thread::JoinHandle<Arc<Server>>,
    ) -> Arc<Server> {
        shutdown.cancel();
        handle.join().expect("server thread")
    }

    #[test]
    fn healthz_stats_and_routing_errors() {
        let (addr, shutdown, handle) = start(ephemeral());
        let health = http(addr, "GET /healthz HTTP/1.1\r\n\r\n");
        assert!(health.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(health.ends_with("{\"status\": \"ok\"}\n"));

        let stats = http(addr, "GET /stats HTTP/1.1\r\n\r\n");
        assert!(stats.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(stats.contains("\"eval_cache\""));
        assert!(stats.contains("\"received\""));

        let missing = http(addr, "GET /nope HTTP/1.1\r\n\r\n");
        assert!(missing.starts_with("HTTP/1.1 404 Not Found\r\n"));
        assert!(missing.contains("\"complete\":false"));

        let wrong = http(addr, "GET /run HTTP/1.1\r\n\r\n");
        assert!(wrong.starts_with("HTTP/1.1 405 Method Not Allowed\r\n"));

        let garbled = post_run(addr, "not json at all", "");
        assert!(garbled.starts_with("HTTP/1.1 400 Bad Request\r\n"));
        assert!(garbled.contains("\"kind\":\"bad-request\""));
        stop(&shutdown, handle);
    }

    #[test]
    fn run_body_matches_the_library_result_byte_for_byte() {
        let (addr, shutdown, handle) = start(ephemeral());
        let spec = registry::get("quickstart").expect("builtin spec");
        let posted = spec.to_json().to_string_pretty();
        let got = post_run(addr, &posted, "");
        assert!(got.starts_with("HTTP/1.1 200 OK\r\n"), "got: {got}");
        let body = got.split("\r\n\r\n").nth(1).expect("response body");
        let want = scenario::run(&spec, &Coordinator::native())
            .expect("library run");
        let mut expect = want.to_json().to_string_pretty();
        expect.push('\n');
        assert_eq!(body, expect);
        let srv = stop(&shutdown, handle);
        assert_eq!(srv.stats().completed(), 1);
    }

    #[test]
    fn second_identical_run_hits_the_shared_caches() {
        let (addr, shutdown, handle) = start(ephemeral());
        let spec = registry::get("quickstart").expect("builtin spec");
        let posted = spec.to_json().to_string_pretty();
        let first = post_run(addr, &posted, "");
        let second = post_run(addr, &posted, "");
        assert!(first.starts_with("HTTP/1.1 200 OK\r\n"));
        assert_eq!(
            first.split("\r\n\r\n").nth(1),
            second.split("\r\n\r\n").nth(1),
            "identical requests must produce identical bodies"
        );
        let stats = http(addr, "GET /stats HTTP/1.1\r\n\r\n");
        let body = stats.split("\r\n\r\n").nth(1).expect("stats body");
        let v = json::parse(body).expect("stats json");
        let derive = v
            .get("coordinator")
            .and_then(|c| c.get("derive_cache"))
            .expect("derive_cache");
        let hits = derive.get("hits").and_then(|h| h.as_f64()).unwrap();
        assert!(
            hits >= 1.0,
            "second identical /run must hit the derive cache; stats: {body}"
        );
        stop(&shutdown, handle);
    }

    #[test]
    fn bad_deadline_param_is_rejected() {
        let (addr, shutdown, handle) = start(ephemeral());
        let spec = registry::get("quickstart").expect("builtin spec");
        let posted = spec.to_json().to_string_pretty();
        for q in ["?deadline_s=abc", "?deadline_s=-1", "?deadline_s=inf"] {
            let got = post_run(addr, &posted, q);
            assert!(
                got.starts_with("HTTP/1.1 400 Bad Request\r\n"),
                "query '{q}' must 400, got: {got}"
            );
        }
        stop(&shutdown, handle);
    }

    #[test]
    fn drain_returns_ok_and_refuses_new_connections() {
        let (addr, shutdown, handle) = start(ephemeral());
        let ok = http(addr, "GET /healthz HTTP/1.1\r\n\r\n");
        assert!(ok.starts_with("HTTP/1.1 200 OK\r\n"));
        stop(&shutdown, handle);
        // The listener is gone with the server; new connections fail
        // (or are reset before a response) rather than hanging.
        let refused = TcpStream::connect(addr);
        if let Ok(mut s) = refused {
            let _ = s.write_all(b"GET /healthz HTTP/1.1\r\n\r\n");
            let mut out = String::new();
            let _ = s.read_to_string(&mut out);
            assert!(!out.starts_with("HTTP/1.1 200"));
        }
    }

    #[test]
    fn rejects_zero_bounds() {
        let cfg = ServeConfig {
            max_concurrency: 0,
            ..ephemeral()
        };
        assert!(Server::bind(cfg, Coordinator::native()).is_err());
        let cfg = ServeConfig {
            max_queue: 0,
            ..ephemeral()
        };
        assert!(Server::bind(cfg, Coordinator::native()).is_err());
    }
}
