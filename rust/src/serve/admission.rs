//! Bounded admission queue: the load-shedding boundary of `comet serve`.
//!
//! Accepted connections wait here until a serving worker picks them up.
//! The queue is **bounded**: when it is full, [`AdmissionQueue::try_push`]
//! rejects the connection immediately (the accept loop turns that into a
//! `503` + `Retry-After`) instead of letting an unbounded backlog starve
//! the requests already in flight. Shedding is counted so `/stats` can
//! report it.
//!
//! [`AdmissionQueue::close`] begins a graceful drain: pushes are refused
//! (not counted as shed — the server is exiting, not overloaded), but
//! [`AdmissionQueue::pop`] keeps handing out already-admitted items until
//! the queue is empty, then returns `None` so every worker unblocks and
//! exits.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};

/// A bounded MPMC queue with explicit load-shedding and drain-on-close.
#[derive(Debug)]
pub struct AdmissionQueue<T> {
    state: Mutex<State<T>>,
    ready: Condvar,
    capacity: usize,
    shed: AtomicU64,
}

#[derive(Debug)]
struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> AdmissionQueue<T> {
    /// A queue admitting at most `capacity` waiting items (min 1).
    pub fn new(capacity: usize) -> AdmissionQueue<T> {
        AdmissionQueue {
            state: Mutex::new(State {
                items: VecDeque::with_capacity(capacity.max(1)),
                closed: false,
            }),
            ready: Condvar::new(),
            capacity: capacity.max(1),
            shed: AtomicU64::new(0),
        }
    }

    /// Admit `item`, or hand it back when there is no room.
    ///
    /// A full queue increments the shed counter (this is load-shedding);
    /// a closed queue refuses without counting (this is drain). Either
    /// way the item is returned so the caller can answer the client.
    pub fn try_push(&self, item: T) -> std::result::Result<(), T> {
        let mut st = self.state.lock().expect("admission queue lock");
        if st.closed {
            return Err(item);
        }
        if st.items.len() >= self.capacity {
            self.shed.fetch_add(1, Ordering::Relaxed);
            return Err(item);
        }
        st.items.push_back(item);
        drop(st);
        self.ready.notify_one();
        Ok(())
    }

    /// Block until an item is available (FIFO) or the queue is closed
    /// **and** empty — the `None` that tells a worker to exit. Items
    /// admitted before [`close`](Self::close) are still handed out, so a
    /// drain finishes every request that was already accepted.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().expect("admission queue lock");
        loop {
            if let Some(item) = st.items.pop_front() {
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.ready.wait(st).expect("admission queue wait");
        }
    }

    /// Stop admitting; wake every blocked [`pop`](Self::pop). Idempotent.
    pub fn close(&self) {
        let mut st = self.state.lock().expect("admission queue lock");
        st.closed = true;
        drop(st);
        self.ready.notify_all();
    }

    /// Items currently waiting (admitted, not yet popped).
    pub fn len(&self) -> usize {
        self.state.lock().expect("admission queue lock").items.len()
    }

    /// Is the queue currently empty?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The admission bound this queue was built with.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// How many pushes were rejected because the queue was **full**
    /// (drain-time refusals are not shedding and are not counted).
    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn bounded_fifo_with_shed_counting() {
        let q = AdmissionQueue::new(2);
        assert_eq!(q.capacity(), 2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        // Full: the item comes back and the shed counter moves.
        assert_eq!(q.try_push(3), Err(3));
        assert_eq!(q.shed(), 1);
        assert_eq!(q.len(), 2);
        // FIFO order, and popping frees a slot.
        assert_eq!(q.pop(), Some(1));
        assert!(q.try_push(4).is_ok());
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(4));
        assert_eq!(q.shed(), 1);
    }

    #[test]
    fn close_drains_admitted_items_then_unblocks() {
        let q = AdmissionQueue::new(4);
        assert!(q.try_push(10).is_ok());
        assert!(q.try_push(11).is_ok());
        q.close();
        // Closed: refusals are not shedding.
        assert_eq!(q.try_push(12), Err(12));
        assert_eq!(q.shed(), 0);
        // Already-admitted items still drain in order, then None forever.
        assert_eq!(q.pop(), Some(10));
        assert_eq!(q.pop(), Some(11));
        assert_eq!(q.pop(), None);
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn pop_blocks_until_a_push_arrives() {
        let q = std::sync::Arc::new(AdmissionQueue::new(1));
        let q2 = q.clone();
        let popper = std::thread::spawn(move || q2.pop());
        std::thread::sleep(Duration::from_millis(20));
        assert!(q.try_push(7).is_ok());
        assert_eq!(popper.join().unwrap(), Some(7));
    }

    #[test]
    fn close_wakes_blocked_poppers() {
        let q = std::sync::Arc::new(AdmissionQueue::<u32>::new(1));
        let q2 = q.clone();
        let popper = std::thread::spawn(move || q2.pop());
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(popper.join().unwrap(), None);
    }
}
