//! Minimal HTTP/1.1 framing for `comet serve` — hand-rolled on `std`,
//! in the same dependency-free style as `util/json.rs` and
//! `scenario/parse.rs`.
//!
//! Scope is deliberately narrow: one request per connection
//! (`Connection: close` on every response), `Content-Length` bodies
//! only (no chunked transfer coding), ASCII request targets with a
//! simple `k=v&k=v` query string and no percent-decoding. That covers
//! the whole `comet serve` API — JSON bodies on `/run`, numeric query
//! parameters — with hard caps on header and body size so a misbehaving
//! client cannot balloon server memory.

use std::io::{self, Read, Write};

use crate::error::{Error, Result};

/// Cap on the request line + headers (bytes, including the terminator).
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Cap on a request body (bytes); a `ScenarioSpec` is a few KiB.
pub const MAX_BODY_BYTES: usize = 1024 * 1024;

/// A parsed HTTP request: method, split target, headers, raw body.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    /// Request method, verbatim (`GET`, `POST`, ...).
    pub method: String,
    /// Target path without the query string (`/run`).
    pub path: String,
    /// Raw query string without the leading `?` (may be empty).
    pub query: String,
    /// Header `(name, value)` pairs; names are lowercased at parse time.
    pub headers: Vec<(String, String)>,
    /// Raw request body (`Content-Length` bytes).
    pub body: Vec<u8>,
}

impl Request {
    /// First value of header `name`, matched case-insensitively.
    pub fn header(&self, name: &str) -> Option<&str> {
        let want = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(n, _)| *n == want)
            .map(|(_, v)| v.as_str())
    }

    /// First value of query parameter `name` (`?deadline_s=1.5`). No
    /// percent-decoding — the serve API only uses plain numeric values.
    pub fn query_param(&self, name: &str) -> Option<&str> {
        self.query.split('&').find_map(|pair| {
            let (k, v) = pair.split_once('=')?;
            (k == name).then_some(v)
        })
    }
}

/// Byte offset of the `\r\n\r\n` head terminator, if present.
fn head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Read and parse one HTTP/1.x request from `r`.
///
/// Returns [`Error::Parse`] for anything malformed or over the caps —
/// the server maps that to a `400`. I/O failures (including read
/// timeouts on a stalled client) surface as [`Error::Io`], which the
/// server treats as a dead connection.
pub fn read_request<R: Read>(r: &mut R) -> Result<Request> {
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 1024];
    let head_len = loop {
        if let Some(pos) = head_end(&buf) {
            break pos;
        }
        if buf.len() > MAX_HEAD_BYTES {
            return Err(Error::Parse(format!(
                "http: header section exceeds {MAX_HEAD_BYTES} bytes"
            )));
        }
        let n = r
            .read(&mut chunk)
            .map_err(|e| Error::Io(format!("http read: {e}")))?;
        if n == 0 {
            return Err(Error::Parse(
                "http: connection closed before the request was complete"
                    .into(),
            ));
        }
        buf.extend_from_slice(&chunk[..n]);
    };

    let head = std::str::from_utf8(&buf[..head_len])
        .map_err(|_| Error::Parse("http: non-UTF-8 request head".into()))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let (method, target, version) =
        match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(t), Some(v), None) if !m.is_empty() => (m, t, v),
            _ => {
                return Err(Error::Parse(format!(
                    "http: malformed request line '{request_line}'"
                )))
            }
        };
    if !version.starts_with("HTTP/1.") {
        return Err(Error::Parse(format!(
            "http: unsupported protocol '{version}'"
        )));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };

    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let (name, value) = line.split_once(':').ok_or_else(|| {
            Error::Parse(format!("http: malformed header line '{line}'"))
        })?;
        headers.push((
            name.trim().to_ascii_lowercase(),
            value.trim().to_string(),
        ));
    }

    let mut req = Request {
        method: method.to_string(),
        path,
        query,
        headers,
        body: Vec::new(),
    };
    if req.header("transfer-encoding").is_some() {
        return Err(Error::Parse(
            "http: chunked transfer coding is not supported \
             (send Content-Length)"
                .into(),
        ));
    }
    let content_length = match req.header("content-length") {
        None => 0,
        Some(v) => v.parse::<usize>().map_err(|_| {
            Error::Parse(format!("http: bad Content-Length '{v}'"))
        })?,
    };
    if content_length > MAX_BODY_BYTES {
        return Err(Error::Parse(format!(
            "http: body of {content_length} bytes exceeds the \
             {MAX_BODY_BYTES}-byte cap"
        )));
    }
    // Whatever followed the head terminator in the last read is the
    // start of the body; read the remainder exactly.
    let mut body = buf[head_len + 4..].to_vec();
    if body.len() > content_length {
        return Err(Error::Parse(
            "http: more body bytes than Content-Length".into(),
        ));
    }
    let already = body.len();
    body.resize(content_length, 0);
    r.read_exact(&mut body[already..])
        .map_err(|e| Error::Io(format!("http body read: {e}")))?;
    req.body = body;
    Ok(req)
}

/// Canonical reason phrase for the status codes the server emits.
fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        206 => "Partial Content",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// An HTTP response under construction; written with
/// [`Response::write_to`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Response {
    /// HTTP status code.
    pub status: u16,
    headers: Vec<(String, String)>,
    body: Vec<u8>,
}

impl Response {
    /// A JSON response: sets `Content-Type: application/json`.
    pub fn json(status: u16, body: impl Into<Vec<u8>>) -> Response {
        Response {
            status,
            headers: vec![(
                "Content-Type".into(),
                "application/json".into(),
            )],
            body: body.into(),
        }
    }

    /// Append a header (builder-style).
    pub fn with_header(mut self, name: &str, value: &str) -> Response {
        self.headers.push((name.into(), value.into()));
        self
    }

    /// The response body, for tests and byte-identity checks.
    pub fn body(&self) -> &[u8] {
        &self.body
    }

    /// Serialize head + body to `w` and flush. `Content-Length` and
    /// `Connection: close` are always emitted (one request per
    /// connection).
    pub fn write_to<W: Write>(&self, w: &mut W) -> io::Result<()> {
        let mut head = format!(
            "HTTP/1.1 {} {}\r\n",
            self.status,
            reason(self.status)
        );
        for (name, value) in &self.headers {
            head.push_str(name);
            head.push_str(": ");
            head.push_str(value);
            head.push_str("\r\n");
        }
        head.push_str(&format!("Content-Length: {}\r\n", self.body.len()));
        head.push_str("Connection: close\r\n\r\n");
        w.write_all(head.as_bytes())?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(raw: &str) -> Result<Request> {
        read_request(&mut raw.as_bytes())
    }

    #[test]
    fn parses_get_with_query_and_headers() {
        let req = parse(
            "GET /run?deadline_s=1.5&x=2 HTTP/1.1\r\n\
             Host: localhost\r\n\
             X-Custom: a value \r\n\r\n",
        )
        .unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/run");
        assert_eq!(req.query, "deadline_s=1.5&x=2");
        assert_eq!(req.query_param("deadline_s"), Some("1.5"));
        assert_eq!(req.query_param("x"), Some("2"));
        assert_eq!(req.query_param("missing"), None);
        // Header names are matched case-insensitively, values trimmed.
        assert_eq!(req.header("x-custom"), Some("a value"));
        assert_eq!(req.header("X-CUSTOM"), Some("a value"));
        assert!(req.body.is_empty());
    }

    #[test]
    fn reads_content_length_body_exactly() {
        let req = parse(
            "POST /run HTTP/1.1\r\nContent-Length: 11\r\n\r\nhello world",
        )
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.body, b"hello world");
    }

    #[test]
    fn rejects_malformed_and_oversized_input() {
        assert!(matches!(parse("BOGUS\r\n\r\n"), Err(Error::Parse(_))));
        assert!(matches!(
            parse("GET / SPDY/3\r\n\r\n"),
            Err(Error::Parse(_))
        ));
        assert!(matches!(
            parse("GET / HTTP/1.1\r\nbad header line\r\n\r\n"),
            Err(Error::Parse(_))
        ));
        assert!(matches!(
            parse("GET / HTTP/1.1\r\nContent-Length: nope\r\n\r\n"),
            Err(Error::Parse(_))
        ));
        assert!(matches!(
            parse("GET / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"),
            Err(Error::Parse(_))
        ));
        // EOF before the head terminator.
        assert!(matches!(parse("GET / HTT"), Err(Error::Parse(_))));
        // Head over the cap.
        let huge = format!(
            "GET / HTTP/1.1\r\nX-Pad: {}\r\n\r\n",
            "x".repeat(MAX_HEAD_BYTES + 1)
        );
        assert!(matches!(parse(&huge), Err(Error::Parse(_))));
        // Body over the cap is refused before reading it.
        let fat = format!(
            "POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1
        );
        assert!(matches!(parse(&fat), Err(Error::Parse(_))));
    }

    #[test]
    fn response_frames_status_headers_and_body() {
        let mut out = Vec::new();
        Response::json(503, "{\"error\":\"busy\"}\n")
            .with_header("Retry-After", "1")
            .write_to(&mut out)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 503 Service Unavailable\r\n"));
        assert!(text.contains("Content-Type: application/json\r\n"));
        assert!(text.contains("Retry-After: 1\r\n"));
        assert!(text.contains("Content-Length: 17\r\n"));
        assert!(text.contains("Connection: close\r\n\r\n"));
        assert!(text.ends_with("{\"error\":\"busy\"}\n"));
    }
}
