//! Pure request routing for `comet serve`: `(method, path)` → [`Route`].
//!
//! Kept free of I/O and state so the full route table is unit-testable
//! as data. Unknown paths and wrong methods are distinct outcomes (`404`
//! vs `405`) so clients can tell a typo from a misuse.

/// Where a request goes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// `GET /healthz` — liveness probe.
    Healthz,
    /// `GET /stats` — cache/queue/request counters snapshot.
    Stats,
    /// `POST /run` — execute a `ScenarioSpec` JSON body.
    Run,
    /// Unknown path → `404`.
    NotFound,
    /// Known path, wrong method → `405`.
    MethodNotAllowed,
}

/// Route a request. Paths are matched exactly (the query string is
/// already split off by the parser).
pub fn route(method: &str, path: &str) -> Route {
    match path {
        "/healthz" if method == "GET" => Route::Healthz,
        "/stats" if method == "GET" => Route::Stats,
        "/run" if method == "POST" => Route::Run,
        "/healthz" | "/stats" | "/run" => Route::MethodNotAllowed,
        _ => Route::NotFound,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn the_route_table() {
        assert_eq!(route("GET", "/healthz"), Route::Healthz);
        assert_eq!(route("GET", "/stats"), Route::Stats);
        assert_eq!(route("POST", "/run"), Route::Run);
        // Wrong method on a known path is 405, not 404.
        assert_eq!(route("POST", "/healthz"), Route::MethodNotAllowed);
        assert_eq!(route("DELETE", "/stats"), Route::MethodNotAllowed);
        assert_eq!(route("GET", "/run"), Route::MethodNotAllowed);
        // Unknown paths are 404 regardless of method.
        assert_eq!(route("GET", "/"), Route::NotFound);
        assert_eq!(route("POST", "/run/extra"), Route::NotFound);
        assert_eq!(route("GET", "/Healthz"), Route::NotFound);
    }
}
