//! Per-request counters and the `GET /stats` JSON snapshot.
//!
//! [`ServeStats`] counts request **outcomes** (all atomics — updated
//! lock-free from every serving worker); [`ServeStats::to_json`] folds
//! them together with the shared coordinator's
//! [`CoordinatorStats`] and the admission queue's depth/shed counters
//! into the documented `/stats` body. The cache hit counters in that
//! body are how the integration tests prove that requests share one
//! coordinator: a second identical `/run` moves `derive_cache.hits`.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::coordinator::CoordinatorStats;
use crate::util::json::{obj, Value};

/// Lock-free request-outcome counters for one server.
///
/// `received` counts every accepted connection; the outcome counters
/// (`completed`, `partial`, `rejected`, `cancelled`, `deadline_expired`,
/// `panicked`, `failed`) classify `/run` requests and input errors.
/// `in_flight` is the number of `/run` bodies executing right now.
#[derive(Debug, Default)]
pub struct ServeStats {
    received: AtomicU64,
    completed: AtomicU64,
    partial: AtomicU64,
    rejected: AtomicU64,
    cancelled: AtomicU64,
    deadline_expired: AtomicU64,
    panicked: AtomicU64,
    failed: AtomicU64,
    in_flight: AtomicU64,
}

/// `hits / (hits + misses)`, `0.0` for an untouched cache.
fn hit_rate(hits: u64, misses: u64) -> f64 {
    let total = hits + misses;
    if total == 0 {
        0.0
    } else {
        hits as f64 / total as f64
    }
}

impl ServeStats {
    /// Fresh, all-zero counters.
    pub fn new() -> ServeStats {
        ServeStats::default()
    }

    /// Count an accepted connection.
    pub fn inc_received(&self) {
        self.received.fetch_add(1, Ordering::Relaxed);
    }

    /// Count a `/run` that finished completely (`200`).
    pub fn inc_completed(&self) {
        self.completed.fetch_add(1, Ordering::Relaxed);
    }

    /// Count a `/run` that returned a partial best-so-far result (`206`).
    pub fn inc_partial(&self) {
        self.partial.fetch_add(1, Ordering::Relaxed);
    }

    /// Count a client-input rejection (`400`/`404`/`405`).
    pub fn inc_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Count a `/run` cancelled by client disconnect (`504`).
    pub fn inc_cancelled(&self) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
    }

    /// Count a `/run` stopped by its deadline mid-study (`504`).
    pub fn inc_deadline_expired(&self) {
        self.deadline_expired.fetch_add(1, Ordering::Relaxed);
    }

    /// Count a `/run` whose evaluation panicked (`500`, worker healed).
    pub fn inc_panicked(&self) {
        self.panicked.fetch_add(1, Ordering::Relaxed);
    }

    /// Count any other internal failure (`500`).
    pub fn inc_failed(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    /// Mark a `/run` execution as started.
    pub fn inc_in_flight(&self) {
        self.in_flight.fetch_add(1, Ordering::Relaxed);
    }

    /// Mark a `/run` execution as finished (any outcome).
    pub fn dec_in_flight(&self) {
        self.in_flight.fetch_sub(1, Ordering::Relaxed);
    }

    /// Completed-request count (tests / bench bookkeeping).
    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    /// The `GET /stats` body: request counters, admission-queue state,
    /// and the shared coordinator's cache/pool/DES counters with derived
    /// hit rates.
    pub fn to_json(
        &self,
        coord: &CoordinatorStats,
        queue_depth: usize,
        queue_capacity: usize,
        queue_shed: u64,
    ) -> Value {
        let n = |x: u64| Value::Num(x as f64);
        obj(vec![
            (
                "requests",
                obj(vec![
                    ("received", n(self.received.load(Ordering::Relaxed))),
                    ("completed", n(self.completed.load(Ordering::Relaxed))),
                    ("partial", n(self.partial.load(Ordering::Relaxed))),
                    ("rejected", n(self.rejected.load(Ordering::Relaxed))),
                    ("cancelled", n(self.cancelled.load(Ordering::Relaxed))),
                    (
                        "deadline_expired",
                        n(self.deadline_expired.load(Ordering::Relaxed)),
                    ),
                    ("panicked", n(self.panicked.load(Ordering::Relaxed))),
                    ("failed", n(self.failed.load(Ordering::Relaxed))),
                    ("in_flight", n(self.in_flight.load(Ordering::Relaxed))),
                ]),
            ),
            (
                "queue",
                obj(vec![
                    ("depth", n(queue_depth as u64)),
                    ("capacity", n(queue_capacity as u64)),
                    ("shed", n(queue_shed)),
                ]),
            ),
            (
                "coordinator",
                obj(vec![
                    (
                        "eval_cache",
                        obj(vec![
                            ("hits", n(coord.eval_hits)),
                            ("misses", n(coord.eval_misses)),
                            (
                                "hit_rate",
                                Value::Num(hit_rate(
                                    coord.eval_hits,
                                    coord.eval_misses,
                                )),
                            ),
                        ]),
                    ),
                    (
                        "derive_cache",
                        obj(vec![
                            ("hits", n(coord.derive_hits)),
                            ("misses", n(coord.derive_misses)),
                            (
                                "hit_rate",
                                Value::Num(hit_rate(
                                    coord.derive_hits,
                                    coord.derive_misses,
                                )),
                            ),
                        ]),
                    ),
                    (
                        "pool",
                        obj(vec![
                            ("jobs_run", n(coord.jobs_run)),
                            (
                                "workers_respawned",
                                n(coord.workers_respawned),
                            ),
                        ]),
                    ),
                    ("des_peak_events", n(coord.des_peak_events)),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_rate_is_zero_safe() {
        assert_eq!(hit_rate(0, 0), 0.0);
        assert_eq!(hit_rate(3, 1), 0.75);
        assert_eq!(hit_rate(0, 5), 0.0);
        assert_eq!(hit_rate(5, 0), 1.0);
    }

    #[test]
    fn snapshot_reflects_counters_and_coordinator() {
        let s = ServeStats::new();
        s.inc_received();
        s.inc_received();
        s.inc_completed();
        s.inc_partial();
        s.inc_in_flight();
        let coord = CoordinatorStats {
            eval_hits: 6,
            eval_misses: 2,
            derive_hits: 1,
            derive_misses: 1,
            jobs_run: 8,
            workers_respawned: 0,
            des_peak_events: 17,
        };
        let v = s.to_json(&coord, 3, 64, 5);
        let req = v.get("requests").unwrap();
        assert_eq!(req.get("received").unwrap().as_f64(), Some(2.0));
        assert_eq!(req.get("completed").unwrap().as_f64(), Some(1.0));
        assert_eq!(req.get("partial").unwrap().as_f64(), Some(1.0));
        assert_eq!(req.get("in_flight").unwrap().as_f64(), Some(1.0));
        assert_eq!(req.get("panicked").unwrap().as_f64(), Some(0.0));
        let q = v.get("queue").unwrap();
        assert_eq!(q.get("depth").unwrap().as_f64(), Some(3.0));
        assert_eq!(q.get("capacity").unwrap().as_f64(), Some(64.0));
        assert_eq!(q.get("shed").unwrap().as_f64(), Some(5.0));
        let c = v.get("coordinator").unwrap();
        let eval = c.get("eval_cache").unwrap();
        assert_eq!(eval.get("hits").unwrap().as_f64(), Some(6.0));
        assert_eq!(eval.get("hit_rate").unwrap().as_f64(), Some(0.75));
        let derive = c.get("derive_cache").unwrap();
        assert_eq!(derive.get("hit_rate").unwrap().as_f64(), Some(0.5));
        assert_eq!(
            c.get("pool").unwrap().get("jobs_run").unwrap().as_f64(),
            Some(8.0)
        );
        assert_eq!(c.get("des_peak_events").unwrap().as_f64(), Some(17.0));
    }

    #[test]
    fn in_flight_rises_and_falls() {
        let s = ServeStats::new();
        s.inc_in_flight();
        s.inc_in_flight();
        s.dec_in_flight();
        let v = s.to_json(&CoordinatorStats::default(), 0, 1, 0);
        let inflight =
            v.get("requests").unwrap().get("in_flight").unwrap().as_f64();
        assert_eq!(inflight, Some(1.0));
    }
}
