//! `comet serve` — the co-design service: one process-lifetime
//! [`Coordinator`](crate::coordinator::Coordinator) behind a std-only
//! HTTP/1.1 API, so repeated scenario runs share warm derive/eval
//! caches and one worker pool.
//!
//! Endpoints (see `docs/SERVE.md` for the full contract):
//!
//! * `POST /run` — a [`ScenarioSpec`](crate::scenario::ScenarioSpec)
//!   JSON body (exactly what `comet scenario show NAME` prints);
//!   responds with the figure JSON, byte-identical to
//!   `comet scenario run NAME --json`. `?deadline_s=` arms a
//!   per-request deadline; optimize studies answer `206` with the
//!   partial best-so-far table when stopped early.
//! * `GET /stats` — request counters, admission-queue depth/shed, and
//!   the shared coordinator's cache hit rates, pool counters, and DES
//!   peak-event high-water mark.
//! * `GET /healthz` — liveness.
//!
//! Robustness is the point of the layer, not an afterthought: bounded
//! admission with `503` load-shedding ([`admission`]), per-request
//! cancellation on client disconnect and deadline expiry ([`server`]),
//! per-request panic isolation on the shared pool, and graceful drain
//! on SIGINT/SIGTERM. The module splits along those seams:
//!
//! * [`conn`] — hand-rolled HTTP/1.1 framing (no new crates).
//! * [`router`] — the pure `(method, path)` route table.
//! * [`admission`] — the bounded, load-shedding connection queue.
//! * [`stats`] — per-request counters + the `/stats` snapshot.
//! * [`server`] — accept loop, serving workers, request execution.

pub mod admission;
pub mod conn;
pub mod router;
pub mod server;
pub mod stats;

pub use admission::AdmissionQueue;
pub use conn::{read_request, Request, Response};
pub use router::{route, Route};
pub use server::{ServeConfig, Server};
pub use stats::ServeStats;
