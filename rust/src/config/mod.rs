//! Cluster configuration system: per-node resources, network topology,
//! preset clusters from the paper's Tables I and III, and JSON I/O.
//!
//! Every quantity is SI (FLOP/s, bytes, bytes/s, seconds); use
//! [`crate::util::units`] constructors when building configs by hand.

mod cluster;
mod node;
pub mod presets;
pub mod serde_io;

pub use cluster::{
    ClusterConfig, GroupScales, NodeGroup, TierChain, TierSpec, Topology,
    TwoLevelView, MAX_TIERS,
};
pub use node::{MemoryConfig, NodeConfig};
pub use serde_io::apply_cluster_overrides;
