//! Preset clusters: the paper's Table I baseline and Table III variants.

use super::cluster::{ClusterConfig, NodeGroup, TierSpec, Topology};
use super::node::{MemoryConfig, NodeConfig};
use crate::util::units::*;

/// Default per-hop link latency (the paper leaves alpha unspecified; 1 us is
/// a typical switched-fabric value and is a CLI-overridable knob).
pub const DEFAULT_LINK_LATENCY: f64 = 1e-6;

/// Table I baseline: 1024 NVIDIA A100 GPUs in 128 8-GPU DGX pods,
/// NVLink Gen-3 intra-pod (300 GB/s/dir), InfiniBand inter-pod
/// (31.25 GB/s/dir), logical-ring collectives.
pub fn dgx_a100_1024() -> ClusterConfig {
    ClusterConfig {
        name: "dgx-a100-1024".into(),
        node: NodeConfig {
            name: "A100".into(),
            perf_peak: tflops(624.0),
            sram: mb(40.0),
            local: MemoryConfig::new(gb(80.0), gbps(2039.0)),
            expanded: MemoryConfig::none(),
        },
        n_nodes: 1024,
        topology: Topology::HierarchicalSwitch {
            pod_size: 8,
            bw_intra: gbps(300.0),
            bw_inter: gbps(31.25),
        },
        link_latency: DEFAULT_LINK_LATENCY,
        groups: vec![],
    }
}

/// DLRM study baseline (SV-C): 64 GPUs = 8 pods of the Table I cluster.
pub fn dgx_a100_64() -> ClusterConfig {
    let mut c = dgx_a100_1024();
    c.name = "dgx-a100-64".into();
    c.n_nodes = 64;
    c
}

// ---- Table III: eleven cluster variants -----------------------------------

fn v100_node() -> NodeConfig {
    NodeConfig {
        name: "V100".into(),
        perf_peak: tflops(125.0),
        sram: mb(40.0),
        // The paper models 80 GB (not the real 32 GB) to align memory
        // options across clusters A/B/C — see Table III footnote.
        local: MemoryConfig::new(gb(80.0), gbps(900.0)),
        expanded: MemoryConfig::none(),
    }
}

fn a100_node() -> NodeConfig {
    NodeConfig {
        name: "A100".into(),
        perf_peak: tflops(625.0),
        sram: mb(40.0),
        local: MemoryConfig::new(gb(80.0), gbps(2039.0)),
        expanded: MemoryConfig::none(),
    }
}

fn h100_node() -> NodeConfig {
    NodeConfig {
        name: "H100".into(),
        perf_peak: tflops(1979.0),
        sram: mb(40.0),
        local: MemoryConfig::new(gb(80.0), gbps(3350.0)),
        expanded: MemoryConfig::none(),
    }
}

/// Memory system variants 0/1/2 of Table III.
fn with_memory_system(node: NodeConfig, system: usize) -> NodeConfig {
    match system {
        0 => node,
        1 => node.with_expanded(gb(480.0), gbps(500.0)),
        2 => node.with_expanded(gb(201.0), gbps(1000.0)),
        _ => panic!("memory system {system} not in Table III"),
    }
}

fn gpu_cluster(
    name: &str,
    node: NodeConfig,
    bw_intra: f64,
    bw_inter: f64,
) -> ClusterConfig {
    ClusterConfig {
        name: name.into(),
        node,
        n_nodes: 1024,
        // Table III: "All GPU cluster variants are organized in 16-GPU pods".
        topology: Topology::HierarchicalSwitch {
            pod_size: 16,
            bw_intra,
            bw_inter,
        },
        link_latency: DEFAULT_LINK_LATENCY,
        groups: vec![],
    }
}

/// Table III cluster `A{mem}` / `B{mem}` / `C{mem}`; `mem` in 0..=2.
pub fn table3_gpu(base: char, mem: usize) -> ClusterConfig {
    let (node, bw_intra, bw_inter) = match base {
        'A' => (v100_node(), gbps(150.0), gbps(6.25)),
        'B' => (a100_node(), gbps(300.0), gbps(31.25)),
        'C' => (h100_node(), gbps(450.0), gbps(62.5)),
        _ => panic!("cluster base {base} not in Table III"),
    };
    gpu_cluster(
        &format!("{base}{mem}"),
        with_memory_system(node, mem),
        bw_intra,
        bw_inter,
    )
}

/// Table III TPU v4 cluster: 4096 chips, 3D torus, 6 x 48 GB/s links.
pub fn tpu_v4_4096() -> ClusterConfig {
    ClusterConfig {
        name: "TPUv4".into(),
        node: NodeConfig {
            name: "TPUv4".into(),
            perf_peak: tflops(275.0),
            sram: mb(32.0),
            local: MemoryConfig::new(gb(32.0), gbps(1200.0)),
            expanded: MemoryConfig::new(gb(39.0), gbps(1200.0)),
        },
        n_nodes: 4096,
        topology: Topology::Torus3D {
            dims: [16, 16, 16],
            links: 6,
            link_bw: gbps(48.0),
        },
        link_latency: DEFAULT_LINK_LATENCY,
        groups: vec![],
    }
}

/// Table III Dojo cluster: 64 trays behind one logical switch,
/// 20 x 50 GB/s = 1 TB/s per node per direction.
pub fn dojo_64() -> ClusterConfig {
    ClusterConfig {
        name: "Dojo".into(),
        node: NodeConfig {
            name: "DojoTray".into(),
            perf_peak: tflops(54_300.0),
            sram: gb(66.0),
            local: MemoryConfig::new(gb(640.0), tbps(16.0)),
            expanded: MemoryConfig::none(),
        },
        n_nodes: 64,
        topology: Topology::SingleSwitch { bw: tbps(1.0) },
        link_latency: DEFAULT_LINK_LATENCY,
        groups: vec![],
    }
}

/// A 64-node exercise cluster for the multi-tier + heterogeneity path:
/// three fabric tiers (8-GPU NVLink boards, 4-board racks, 2-rack rows)
/// with decreasing per-tier bandwidth, and two node generations — 48
/// full-speed nodes plus 16 older ones at half compute/fabric speed.
/// The synchronous-training bottleneck rule makes the old generation's
/// scales the effective ones.
pub fn tiered_het_64() -> ClusterConfig {
    ClusterConfig {
        name: "tiered-het-64".into(),
        node: NodeConfig {
            name: "A100".into(),
            perf_peak: tflops(624.0),
            sram: mb(40.0),
            local: MemoryConfig::new(gb(80.0), gbps(2039.0)),
            expanded: MemoryConfig::none(),
        },
        n_nodes: 64,
        topology: Topology::Tiered {
            tiers: vec![
                TierSpec {
                    group: 8,
                    bandwidth: gbps(300.0),
                    latency: 1e-6,
                },
                TierSpec {
                    group: 4,
                    bandwidth: gbps(50.0),
                    latency: 2e-6,
                },
                TierSpec {
                    group: 2,
                    bandwidth: gbps(12.5),
                    latency: 5e-6,
                },
            ],
        },
        link_latency: DEFAULT_LINK_LATENCY,
        groups: vec![
            NodeGroup {
                count: 48,
                perf_scale: 1.0,
                mem_scale: 1.0,
                bw_scale: 1.0,
            },
            NodeGroup {
                count: 16,
                perf_scale: 0.5,
                mem_scale: 1.0,
                bw_scale: 0.5,
            },
        ],
    }
}

/// All eleven Table III clusters in the paper's Fig. 15 order.
pub fn table3_all() -> Vec<ClusterConfig> {
    let mut v = Vec::new();
    for base in ['A', 'B', 'C'] {
        for mem in 0..=2 {
            v.push(table3_gpu(base, mem));
        }
    }
    v.push(tpu_v4_4096());
    v.push(dojo_64());
    v
}

/// Look up any preset by name (CLI surface).
pub fn by_name(name: &str) -> Option<ClusterConfig> {
    match name {
        "baseline" | "dgx-a100-1024" => Some(dgx_a100_1024()),
        "dgx-a100-64" => Some(dgx_a100_64()),
        "tiered-het-64" => Some(tiered_het_64()),
        "TPUv4" | "tpuv4" => Some(tpu_v4_4096()),
        "Dojo" | "dojo" => Some(dojo_64()),
        _ => {
            let mut ch = name.chars();
            let base = ch.next()?;
            let mem = ch.next()?.to_digit(10)? as usize;
            if ch.next().is_none()
                && matches!(base, 'A' | 'B' | 'C')
                && mem <= 2
            {
                Some(table3_gpu(base, mem))
            } else {
                None
            }
        }
    }
}

/// Names accepted by [`by_name`].
pub fn preset_names() -> Vec<&'static str> {
    vec![
        "baseline",
        "dgx-a100-64",
        "tiered-het-64",
        "A0",
        "A1",
        "A2",
        "B0",
        "B1",
        "B2",
        "C0",
        "C1",
        "C2",
        "TPUv4",
        "Dojo",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_validate() {
        for c in table3_all() {
            c.validate().unwrap_or_else(|e| panic!("{}: {e}", c.name));
        }
        dgx_a100_1024().validate().unwrap();
        dgx_a100_64().validate().unwrap();
        tiered_het_64().validate().unwrap();
    }

    #[test]
    fn tiered_preset_shapes() {
        let c = tiered_het_64();
        let chain = c.tier_chain().unwrap();
        assert_eq!(chain.n_tiers, 3);
        assert_eq!(&chain.groups[..3], &[8, 4, 2]);
        assert!(chain.bandwidth[0] > chain.bandwidth[2]);
        assert_eq!(c.inter_bandwidth(), 12.5e9);
        assert_eq!(c.groups.iter().map(|g| g.count).sum::<usize>(), 64);
    }

    #[test]
    fn table3_has_eleven() {
        assert_eq!(table3_all().len(), 11);
    }

    #[test]
    fn table1_baseline_values() {
        let c = dgx_a100_1024();
        assert_eq!(c.node.perf_peak, 624e12);
        assert_eq!(c.node.local.capacity, 80e9);
        assert_eq!(c.node.local.bandwidth, 2039e9);
        assert_eq!(c.node.sram, 40e6);
        assert_eq!(c.n_nodes, 1024);
    }

    #[test]
    fn table3_memory_systems() {
        assert!(!table3_gpu('B', 0).node.expanded.present());
        let b1 = table3_gpu('B', 1);
        assert_eq!(b1.node.expanded.capacity, 480e9);
        assert_eq!(b1.node.expanded.bandwidth, 500e9);
        let b2 = table3_gpu('B', 2);
        assert_eq!(b2.node.expanded.capacity, 201e9);
        assert_eq!(b2.node.expanded.bandwidth, 1000e9);
    }

    #[test]
    fn table3_network_tiers() {
        let a = table3_gpu('A', 0).two_level().unwrap();
        let c = table3_gpu('C', 0).two_level().unwrap();
        assert_eq!(a.bw_intra, 150e9);
        assert_eq!(a.bw_inter, 6.25e9);
        assert_eq!(c.bw_intra, 450e9);
        assert_eq!(c.bw_inter, 62.5e9);
        assert_eq!(a.pod_size, 16);
    }

    #[test]
    fn dojo_and_tpu_scale() {
        assert_eq!(dojo_64().node.perf_peak, 54.3e15);
        assert_eq!(tpu_v4_4096().n_nodes, 4096);
        assert_eq!(tpu_v4_4096().two_level().unwrap().bw_intra, 288e9);
    }

    #[test]
    fn by_name_resolves_everything() {
        for n in preset_names() {
            assert!(by_name(n).is_some(), "{n}");
        }
        assert!(by_name("Z9").is_none());
        assert!(by_name("A3").is_none());
        assert!(by_name("A12").is_none());
    }
}
