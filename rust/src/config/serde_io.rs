//! JSON (de)serialization of cluster configs — lets users describe their own
//! clusters in files and load them via `comet --cluster-file my.json`.

use std::collections::BTreeMap;
use std::path::Path;

use super::cluster::{ClusterConfig, NodeGroup, TierSpec, Topology};
use super::node::{MemoryConfig, NodeConfig};
use crate::error::{Error, Result};
use crate::util::json::{self, Value};

impl ClusterConfig {
    /// Serialize to a JSON value.
    pub fn to_json(&self) -> Value {
        let mut o = BTreeMap::new();
        o.insert("name".into(), Value::Str(self.name.clone()));
        o.insert("n_nodes".into(), Value::Num(self.n_nodes as f64));
        o.insert("link_latency".into(), Value::Num(self.link_latency));
        o.insert("node".into(), node_to_json(&self.node));
        o.insert("topology".into(), topo_to_json(&self.topology));
        // Homogeneous clusters stay byte-identical to the legacy schema.
        if !self.groups.is_empty() {
            o.insert("groups".into(), groups_to_json(&self.groups));
        }
        Value::Obj(o)
    }

    /// Parse from a JSON value.
    pub fn from_json(v: &Value) -> Result<Self> {
        let name = req_str(v, "name")?;
        let n_nodes = req_num(v, "n_nodes")? as usize;
        let link_latency = req_num(v, "link_latency")?;
        let node = node_from_json(
            v.get("node")
                .ok_or_else(|| Error::Json("missing 'node'".into()))?,
        )?;
        let topology = topo_from_json(
            v.get("topology")
                .ok_or_else(|| Error::Json("missing 'topology'".into()))?,
        )?;
        let groups = match v.get("groups") {
            None => Vec::new(),
            Some(g) => groups_from_json(g)?,
        };
        let c = ClusterConfig {
            name,
            node,
            n_nodes,
            topology,
            link_latency,
            groups,
        };
        c.validate()?;
        Ok(c)
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Io(format!("{}: {e}", path.display())))?;
        Self::from_json(&json::parse(&text)?)
    }

    /// Save to a file (pretty-printed).
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty() + "\n")
            .map_err(|e| Error::Io(format!("{}: {e}", path.display())))?;
        Ok(())
    }
}

fn node_to_json(n: &NodeConfig) -> Value {
    let mut o = BTreeMap::new();
    o.insert("name".into(), Value::Str(n.name.clone()));
    o.insert("perf_peak".into(), Value::Num(n.perf_peak));
    o.insert("sram".into(), Value::Num(n.sram));
    o.insert("local_capacity".into(), Value::Num(n.local.capacity));
    o.insert("local_bandwidth".into(), Value::Num(n.local.bandwidth));
    o.insert("expanded_capacity".into(), Value::Num(n.expanded.capacity));
    o.insert(
        "expanded_bandwidth".into(),
        Value::Num(n.expanded.bandwidth),
    );
    Value::Obj(o)
}

fn node_from_json(v: &Value) -> Result<NodeConfig> {
    Ok(NodeConfig {
        name: req_str(v, "name")?,
        perf_peak: req_num(v, "perf_peak")?,
        sram: req_num(v, "sram")?,
        local: MemoryConfig::new(
            req_num(v, "local_capacity")?,
            req_num(v, "local_bandwidth")?,
        ),
        expanded: MemoryConfig::new(
            opt_num(v, "expanded_capacity"),
            opt_num(v, "expanded_bandwidth"),
        ),
    })
}

fn topo_to_json(t: &Topology) -> Value {
    let mut o = BTreeMap::new();
    match *t {
        Topology::HierarchicalSwitch {
            pod_size,
            bw_intra,
            bw_inter,
        } => {
            o.insert("kind".into(), Value::Str("hierarchical".into()));
            o.insert("pod_size".into(), Value::Num(pod_size as f64));
            o.insert("bw_intra".into(), Value::Num(bw_intra));
            o.insert("bw_inter".into(), Value::Num(bw_inter));
        }
        Topology::SingleSwitch { bw } => {
            o.insert("kind".into(), Value::Str("single_switch".into()));
            o.insert("bw".into(), Value::Num(bw));
        }
        Topology::Torus3D {
            dims,
            links,
            link_bw,
        } => {
            o.insert("kind".into(), Value::Str("torus3d".into()));
            o.insert(
                "dims".into(),
                Value::Arr(dims.iter().map(|d| Value::Num(*d as f64)).collect()),
            );
            o.insert("links".into(), Value::Num(links as f64));
            o.insert("link_bw".into(), Value::Num(link_bw));
        }
        Topology::Tiered { ref tiers } => {
            o.insert("kind".into(), Value::Str("tiered".into()));
            o.insert(
                "group".into(),
                Value::Arr(
                    tiers.iter().map(|t| Value::Num(t.group as f64)).collect(),
                ),
            );
            o.insert(
                "bandwidth".into(),
                Value::Arr(
                    tiers.iter().map(|t| Value::Num(t.bandwidth)).collect(),
                ),
            );
            o.insert(
                "latency".into(),
                Value::Arr(
                    tiers.iter().map(|t| Value::Num(t.latency)).collect(),
                ),
            );
        }
    }
    Value::Obj(o)
}

fn topo_from_json(v: &Value) -> Result<Topology> {
    match req_str(v, "kind")?.as_str() {
        "hierarchical" => Ok(Topology::HierarchicalSwitch {
            pod_size: req_num(v, "pod_size")? as usize,
            bw_intra: req_num(v, "bw_intra")?,
            bw_inter: req_num(v, "bw_inter")?,
        }),
        "single_switch" => Ok(Topology::SingleSwitch {
            bw: req_num(v, "bw")?,
        }),
        "torus3d" => {
            let dims_v = v
                .get("dims")
                .and_then(|d| d.as_arr())
                .ok_or_else(|| Error::Json("missing 'dims'".into()))?;
            if dims_v.len() != 3 {
                return Err(Error::Json("'dims' must have 3 entries".into()));
            }
            let mut dims = [0usize; 3];
            for (i, d) in dims_v.iter().enumerate() {
                dims[i] = d
                    .as_usize()
                    .ok_or_else(|| Error::Json("bad dim".into()))?;
            }
            Ok(Topology::Torus3D {
                dims,
                links: req_num(v, "links")? as usize,
                link_bw: req_num(v, "link_bw")?,
            })
        }
        "tiered" => {
            let group = num_arr(v, "group")?;
            let bandwidth = num_arr(v, "bandwidth")?;
            let latency = num_arr(v, "latency")?;
            if group.len() != bandwidth.len() || group.len() != latency.len()
            {
                return Err(Error::Json(format!(
                    "tiered topology arrays must have equal length, got \
                     group={}, bandwidth={}, latency={}",
                    group.len(),
                    bandwidth.len(),
                    latency.len()
                )));
            }
            let tiers = group
                .iter()
                .zip(&bandwidth)
                .zip(&latency)
                .map(|((&g, &bw), &lat)| TierSpec {
                    group: g as usize,
                    bandwidth: bw,
                    latency: lat,
                })
                .collect();
            Ok(Topology::Tiered { tiers })
        }
        k => Err(Error::Json(format!("unknown topology kind '{k}'"))),
    }
}

fn groups_to_json(groups: &[NodeGroup]) -> Value {
    let mut o = BTreeMap::new();
    let col = |f: &dyn Fn(&NodeGroup) -> f64| {
        Value::Arr(groups.iter().map(|g| Value::Num(f(g))).collect())
    };
    o.insert("count".into(), col(&|g| g.count as f64));
    o.insert("perf_scale".into(), col(&|g| g.perf_scale));
    o.insert("mem_scale".into(), col(&|g| g.mem_scale));
    o.insert("bw_scale".into(), col(&|g| g.bw_scale));
    Value::Obj(o)
}

fn groups_from_json(v: &Value) -> Result<Vec<NodeGroup>> {
    let count = num_arr(v, "count")?;
    let perf = num_arr(v, "perf_scale")?;
    let mem = num_arr(v, "mem_scale")?;
    let bw = num_arr(v, "bw_scale")?;
    if perf.len() != count.len()
        || mem.len() != count.len()
        || bw.len() != count.len()
    {
        return Err(Error::Json(format!(
            "node group arrays must have equal length, got count={}, \
             perf_scale={}, mem_scale={}, bw_scale={}",
            count.len(),
            perf.len(),
            mem.len(),
            bw.len()
        )));
    }
    Ok(count
        .iter()
        .zip(&perf)
        .zip(&mem)
        .zip(&bw)
        .map(|(((&c, &p), &m), &b)| NodeGroup {
            count: c as usize,
            perf_scale: p,
            mem_scale: m,
            bw_scale: b,
        })
        .collect())
}

fn num_arr(v: &Value, key: &str) -> Result<Vec<f64>> {
    v.get(key)
        .and_then(|a| a.as_arr())
        .ok_or_else(|| Error::Json(format!("missing array '{key}'")))?
        .iter()
        .map(|x| {
            x.as_f64().ok_or_else(|| {
                Error::Json(format!("'{key}' entries must be numbers"))
            })
        })
        .collect()
}

/// Apply scenario-style overrides (human units: GB, GB/s, TFLOP/s, us) to
/// a preset cluster. `v` is an object that may carry a `preset` key (the
/// caller resolved it) plus any of the override keys below; unknown keys
/// are an error so typos fail loudly. The result is re-validated.
pub fn apply_cluster_overrides(c: &mut ClusterConfig, v: &Value) -> Result<()> {
    const ALLOWED: [&str; 13] = [
        "preset",
        "name",
        "n_nodes",
        "link_latency_us",
        "perf_peak_tflops",
        "sram_mb",
        "local_capacity_gb",
        "local_bandwidth_gbps",
        "expanded_capacity_gb",
        "expanded_bandwidth_gbps",
        "pod_size",
        "bw_intra_gbps",
        "bw_inter_gbps",
    ];
    let Value::Obj(m) = v else {
        return Err(Error::Json("cluster overrides must be an object".into()));
    };
    for k in m.keys() {
        if !ALLOWED.contains(&k.as_str()) {
            return Err(Error::Json(format!(
                "unknown cluster override '{k}' (allowed: {})",
                ALLOWED.join(", ")
            )));
        }
    }
    let num = |key: &str| -> Result<Option<f64>> {
        match m.get(key) {
            None => Ok(None),
            Some(Value::Num(n)) => Ok(Some(*n)),
            Some(_) => Err(Error::Json(format!(
                "cluster override '{key}' must be a number"
            ))),
        }
    };
    let int = |key: &str| -> Result<Option<usize>> {
        match num(key)? {
            None => Ok(None),
            Some(n) if n >= 0.0 && n.fract() == 0.0 => Ok(Some(n as usize)),
            Some(n) => Err(Error::Json(format!(
                "cluster override '{key}' must be a non-negative integer, \
                 got {n}"
            ))),
        }
    };
    if let Some(Value::Str(s)) = m.get("name") {
        c.name = s.clone();
    } else if m.contains_key("name") {
        return Err(Error::Json("cluster override 'name' must be a string".into()));
    }
    if let Some(n) = int("n_nodes")? {
        c.n_nodes = n;
    }
    if let Some(x) = num("link_latency_us")? {
        c.link_latency = x * 1e-6;
    }
    if let Some(x) = num("perf_peak_tflops")? {
        c.node.perf_peak = x * 1e12;
    }
    if let Some(x) = num("sram_mb")? {
        c.node.sram = x * 1e6;
    }
    if let Some(x) = num("local_capacity_gb")? {
        c.node.local.capacity = x * 1e9;
    }
    if let Some(x) = num("local_bandwidth_gbps")? {
        c.node.local.bandwidth = x * 1e9;
    }
    if let Some(x) = num("expanded_capacity_gb")? {
        c.node.expanded.capacity = x * 1e9;
    }
    if let Some(x) = num("expanded_bandwidth_gbps")? {
        c.node.expanded.bandwidth = x * 1e9;
    }
    let pod = int("pod_size")?;
    let net = [num("bw_intra_gbps")?, num("bw_inter_gbps")?];
    if pod.is_some() || net.iter().any(Option::is_some) {
        match c.topology {
            Topology::HierarchicalSwitch {
                ref mut pod_size,
                ref mut bw_intra,
                ref mut bw_inter,
            } => {
                if let Some(p) = pod {
                    *pod_size = p;
                }
                if let Some(x) = net[0] {
                    *bw_intra = x * 1e9;
                }
                if let Some(x) = net[1] {
                    *bw_inter = x * 1e9;
                }
            }
            _ => {
                return Err(Error::Json(
                    "pod/bandwidth overrides require a hierarchical topology"
                        .into(),
                ))
            }
        }
    }
    c.validate()
}

fn req_str(v: &Value, key: &str) -> Result<String> {
    v.get(key)
        .and_then(|x| x.as_str())
        .map(|s| s.to_string())
        .ok_or_else(|| Error::Json(format!("missing string '{key}'")))
}

fn req_num(v: &Value, key: &str) -> Result<f64> {
    v.get(key)
        .and_then(|x| x.as_f64())
        .ok_or_else(|| Error::Json(format!("missing number '{key}'")))
}

fn opt_num(v: &Value, key: &str) -> f64 {
    v.get(key).and_then(|x| x.as_f64()).unwrap_or(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn roundtrip_all_presets() {
        for c in presets::table3_all() {
            let j = c.to_json();
            let back = ClusterConfig::from_json(&j).unwrap();
            assert_eq!(c, back, "{}", c.name);
        }
    }

    #[test]
    fn roundtrip_through_file() {
        let dir = std::env::temp_dir().join("comet_serde_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cluster.json");
        let c = presets::dgx_a100_1024();
        c.save(&path).unwrap();
        let back = ClusterConfig::load(&path).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn invalid_config_rejected_on_parse() {
        let c = presets::dgx_a100_1024();
        let mut j = c.to_json();
        if let Value::Obj(ref mut o) = j {
            o.insert("n_nodes".into(), Value::Num(1000.0)); // not pow2
        }
        assert!(ClusterConfig::from_json(&j).is_err());
    }

    #[test]
    fn missing_field_is_json_error() {
        let v = json::parse(r#"{"name": "x"}"#).unwrap();
        assert!(matches!(
            ClusterConfig::from_json(&v),
            Err(Error::Json(_))
        ));
    }

    #[test]
    fn overrides_apply_and_validate() {
        let mut c = presets::dgx_a100_1024();
        let v = json::parse(
            r#"{"preset": "baseline", "n_nodes": 256,
                "expanded_capacity_gb": 480, "expanded_bandwidth_gbps": 500,
                "bw_inter_gbps": 62.5, "link_latency_us": 2}"#,
        )
        .unwrap();
        apply_cluster_overrides(&mut c, &v).unwrap();
        assert_eq!(c.n_nodes, 256);
        assert_eq!(c.node.expanded.capacity, 480e9);
        assert_eq!(c.node.expanded.bandwidth, 500e9);
        assert_eq!(c.two_level().unwrap().bw_inter, 62.5e9);
        assert_eq!(c.link_latency, 2e-6);
    }

    #[test]
    fn overrides_reject_unknown_and_invalid() {
        let mut c = presets::dgx_a100_1024();
        let bad = json::parse(r#"{"local_cap_gb": 80}"#).unwrap();
        assert!(apply_cluster_overrides(&mut c, &bad).is_err());
        let mut c = presets::dgx_a100_1024();
        let non_pow2 = json::parse(r#"{"n_nodes": 1000}"#).unwrap();
        assert!(apply_cluster_overrides(&mut c, &non_pow2).is_err());
        // Fractional node counts must not silently truncate.
        let mut c = presets::dgx_a100_1024();
        let frac = json::parse(r#"{"n_nodes": 512.5}"#).unwrap();
        assert!(apply_cluster_overrides(&mut c, &frac).is_err());
        let mut c = presets::dojo_64();
        let net = json::parse(r#"{"bw_intra_gbps": 600}"#).unwrap();
        assert!(apply_cluster_overrides(&mut c, &net).is_err());
    }

    #[test]
    fn roundtrip_tiered_heterogeneous() {
        let c = presets::tiered_het_64();
        assert!(!c.groups.is_empty(), "preset should be heterogeneous");
        let j = c.to_json();
        let back = ClusterConfig::from_json(&j).unwrap();
        assert_eq!(c, back);
        // The legacy schema has no "groups" key for homogeneous clusters.
        let legacy = presets::dgx_a100_1024().to_json();
        assert!(legacy.get("groups").is_none());
    }

    #[test]
    fn mismatched_group_arrays_rejected() {
        let v = json::parse(
            r#"{"count": [48, 16], "perf_scale": [1.0],
                "mem_scale": [1.0, 1.0], "bw_scale": [1.0, 1.0]}"#,
        )
        .unwrap();
        assert!(groups_from_json(&v).is_err());
    }

    #[test]
    fn unknown_topology_kind_rejected() {
        let v = json::parse(
            r#"{"kind": "hypercube"}"#,
        )
        .unwrap();
        assert!(topo_from_json(&v).is_err());
    }
}
