//! JSON (de)serialization of cluster configs — lets users describe their own
//! clusters in files and load them via `comet --cluster-file my.json`.

use std::collections::BTreeMap;
use std::path::Path;

use super::cluster::{ClusterConfig, Topology};
use super::node::{MemoryConfig, NodeConfig};
use crate::error::{Error, Result};
use crate::util::json::{self, Value};

impl ClusterConfig {
    /// Serialize to a JSON value.
    pub fn to_json(&self) -> Value {
        let mut o = BTreeMap::new();
        o.insert("name".into(), Value::Str(self.name.clone()));
        o.insert("n_nodes".into(), Value::Num(self.n_nodes as f64));
        o.insert("link_latency".into(), Value::Num(self.link_latency));
        o.insert("node".into(), node_to_json(&self.node));
        o.insert("topology".into(), topo_to_json(&self.topology));
        Value::Obj(o)
    }

    /// Parse from a JSON value.
    pub fn from_json(v: &Value) -> Result<Self> {
        let name = req_str(v, "name")?;
        let n_nodes = req_num(v, "n_nodes")? as usize;
        let link_latency = req_num(v, "link_latency")?;
        let node = node_from_json(
            v.get("node")
                .ok_or_else(|| Error::Json("missing 'node'".into()))?,
        )?;
        let topology = topo_from_json(
            v.get("topology")
                .ok_or_else(|| Error::Json("missing 'topology'".into()))?,
        )?;
        let c = ClusterConfig {
            name,
            node,
            n_nodes,
            topology,
            link_latency,
        };
        c.validate()?;
        Ok(c)
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Io(format!("{}: {e}", path.display())))?;
        Self::from_json(&json::parse(&text)?)
    }

    /// Save to a file (pretty-printed).
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty() + "\n")
            .map_err(|e| Error::Io(format!("{}: {e}", path.display())))?;
        Ok(())
    }
}

fn node_to_json(n: &NodeConfig) -> Value {
    let mut o = BTreeMap::new();
    o.insert("name".into(), Value::Str(n.name.clone()));
    o.insert("perf_peak".into(), Value::Num(n.perf_peak));
    o.insert("sram".into(), Value::Num(n.sram));
    o.insert("local_capacity".into(), Value::Num(n.local.capacity));
    o.insert("local_bandwidth".into(), Value::Num(n.local.bandwidth));
    o.insert("expanded_capacity".into(), Value::Num(n.expanded.capacity));
    o.insert(
        "expanded_bandwidth".into(),
        Value::Num(n.expanded.bandwidth),
    );
    Value::Obj(o)
}

fn node_from_json(v: &Value) -> Result<NodeConfig> {
    Ok(NodeConfig {
        name: req_str(v, "name")?,
        perf_peak: req_num(v, "perf_peak")?,
        sram: req_num(v, "sram")?,
        local: MemoryConfig::new(
            req_num(v, "local_capacity")?,
            req_num(v, "local_bandwidth")?,
        ),
        expanded: MemoryConfig::new(
            opt_num(v, "expanded_capacity"),
            opt_num(v, "expanded_bandwidth"),
        ),
    })
}

fn topo_to_json(t: &Topology) -> Value {
    let mut o = BTreeMap::new();
    match *t {
        Topology::HierarchicalSwitch {
            pod_size,
            bw_intra,
            bw_inter,
        } => {
            o.insert("kind".into(), Value::Str("hierarchical".into()));
            o.insert("pod_size".into(), Value::Num(pod_size as f64));
            o.insert("bw_intra".into(), Value::Num(bw_intra));
            o.insert("bw_inter".into(), Value::Num(bw_inter));
        }
        Topology::SingleSwitch { bw } => {
            o.insert("kind".into(), Value::Str("single_switch".into()));
            o.insert("bw".into(), Value::Num(bw));
        }
        Topology::Torus3D {
            dims,
            links,
            link_bw,
        } => {
            o.insert("kind".into(), Value::Str("torus3d".into()));
            o.insert(
                "dims".into(),
                Value::Arr(dims.iter().map(|d| Value::Num(*d as f64)).collect()),
            );
            o.insert("links".into(), Value::Num(links as f64));
            o.insert("link_bw".into(), Value::Num(link_bw));
        }
    }
    Value::Obj(o)
}

fn topo_from_json(v: &Value) -> Result<Topology> {
    match req_str(v, "kind")?.as_str() {
        "hierarchical" => Ok(Topology::HierarchicalSwitch {
            pod_size: req_num(v, "pod_size")? as usize,
            bw_intra: req_num(v, "bw_intra")?,
            bw_inter: req_num(v, "bw_inter")?,
        }),
        "single_switch" => Ok(Topology::SingleSwitch {
            bw: req_num(v, "bw")?,
        }),
        "torus3d" => {
            let dims_v = v
                .get("dims")
                .and_then(|d| d.as_arr())
                .ok_or_else(|| Error::Json("missing 'dims'".into()))?;
            if dims_v.len() != 3 {
                return Err(Error::Json("'dims' must have 3 entries".into()));
            }
            let mut dims = [0usize; 3];
            for (i, d) in dims_v.iter().enumerate() {
                dims[i] = d
                    .as_usize()
                    .ok_or_else(|| Error::Json("bad dim".into()))?;
            }
            Ok(Topology::Torus3D {
                dims,
                links: req_num(v, "links")? as usize,
                link_bw: req_num(v, "link_bw")?,
            })
        }
        k => Err(Error::Json(format!("unknown topology kind '{k}'"))),
    }
}

fn req_str(v: &Value, key: &str) -> Result<String> {
    v.get(key)
        .and_then(|x| x.as_str())
        .map(|s| s.to_string())
        .ok_or_else(|| Error::Json(format!("missing string '{key}'")))
}

fn req_num(v: &Value, key: &str) -> Result<f64> {
    v.get(key)
        .and_then(|x| x.as_f64())
        .ok_or_else(|| Error::Json(format!("missing number '{key}'")))
}

fn opt_num(v: &Value, key: &str) -> f64 {
    v.get(key).and_then(|x| x.as_f64()).unwrap_or(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn roundtrip_all_presets() {
        for c in presets::table3_all() {
            let j = c.to_json();
            let back = ClusterConfig::from_json(&j).unwrap();
            assert_eq!(c, back, "{}", c.name);
        }
    }

    #[test]
    fn roundtrip_through_file() {
        let dir = std::env::temp_dir().join("comet_serde_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cluster.json");
        let c = presets::dgx_a100_1024();
        c.save(&path).unwrap();
        let back = ClusterConfig::load(&path).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn invalid_config_rejected_on_parse() {
        let c = presets::dgx_a100_1024();
        let mut j = c.to_json();
        if let Value::Obj(ref mut o) = j {
            o.insert("n_nodes".into(), Value::Num(1000.0)); // not pow2
        }
        assert!(ClusterConfig::from_json(&j).is_err());
    }

    #[test]
    fn missing_field_is_json_error() {
        let v = json::parse(r#"{"name": "x"}"#).unwrap();
        assert!(matches!(
            ClusterConfig::from_json(&v),
            Err(Error::Json(_))
        ));
    }

    #[test]
    fn unknown_topology_kind_rejected() {
        let v = json::parse(
            r#"{"kind": "hypercube"}"#,
        )
        .unwrap();
        assert!(topo_from_json(&v).is_err());
    }
}
