//! Per-node (compute unit) configuration: peak compute, on-chip buffer,
//! local memory, and optional expanded memory (paper Fig. 1 knobs).

use crate::error::{Error, Result};

/// A memory level: capacity + bandwidth.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemoryConfig {
    /// Capacity in bytes.
    pub capacity: f64,
    /// Bandwidth in bytes/s.
    pub bandwidth: f64,
}

impl MemoryConfig {
    /// A memory level.
    pub fn new(capacity: f64, bandwidth: f64) -> Self {
        MemoryConfig {
            capacity,
            bandwidth,
        }
    }

    /// The "absent" expanded memory.
    pub fn none() -> Self {
        MemoryConfig {
            capacity: 0.0,
            bandwidth: 0.0,
        }
    }

    /// Whether this level exists.
    pub fn present(&self) -> bool {
        self.capacity > 0.0 && self.bandwidth > 0.0
    }
}

/// One compute node ("node" = one GPU / TPU / tray, per the paper's
/// terminology footnote).
#[derive(Debug, Clone, PartialEq)]
pub struct NodeConfig {
    /// Human-readable name (e.g. "A100").
    pub name: String,
    /// Peak compute performance, FLOP/s (fp16/bf16 tensor peak).
    pub perf_peak: f64,
    /// On-chip buffer (SRAM) size in bytes — the `S` of the tiling
    /// traffic model (paper SIII-C2).
    pub sram: f64,
    /// Local memory (HBM).
    pub local: MemoryConfig,
    /// Expanded memory (host/CXL-attached); `MemoryConfig::none()` if absent.
    pub expanded: MemoryConfig,
}

impl NodeConfig {
    /// Validate physical sanity. Every quantity must be a finite,
    /// strictly positive number — `!(x > 0.0)` style checks catch NaN
    /// (all comparisons with NaN are false), and explicit `is_finite`
    /// guards reject infinities that would otherwise sail through and
    /// surface as NaN step times downstream.
    pub fn validate(&self) -> Result<()> {
        if !self.perf_peak.is_finite() || self.perf_peak <= 0.0 {
            return Err(Error::Config(format!(
                "{}: perf_peak must be a finite number > 0, got {}",
                self.name, self.perf_peak
            )));
        }
        if !self.sram.is_finite() || self.sram <= 0.0 {
            return Err(Error::Config(format!(
                "{}: sram must be a finite number > 0, got {}",
                self.name, self.sram
            )));
        }
        for (tier, m) in [("local", &self.local), ("expanded", &self.expanded)]
        {
            if !m.capacity.is_finite()
                || !m.bandwidth.is_finite()
                || m.capacity < 0.0
                || m.bandwidth < 0.0
            {
                return Err(Error::Config(format!(
                    "{}: {tier} memory capacity/bandwidth must be finite \
                     numbers >= 0, got capacity {} bandwidth {}",
                    self.name, m.capacity, m.bandwidth
                )));
            }
        }
        if !self.local.present() {
            return Err(Error::Config(format!(
                "{}: local memory must have capacity and bandwidth",
                self.name
            )));
        }
        if self.expanded.capacity > 0.0 && self.expanded.bandwidth <= 0.0 {
            return Err(Error::Config(format!(
                "{}: expanded memory has capacity but no bandwidth",
                self.name
            )));
        }
        Ok(())
    }

    /// Total memory capacity across local + expanded, bytes.
    pub fn total_capacity(&self) -> f64 {
        self.local.capacity + self.expanded.capacity
    }

    /// Scale peak compute by `factor` (fig. 10's compute-capability knob).
    pub fn scale_compute(&self, factor: f64) -> NodeConfig {
        let mut n = self.clone();
        n.perf_peak *= factor;
        n.name = format!("{}x{:.2}", n.name, factor);
        n
    }

    /// Replace the expanded memory (fig. 9/13b's memory-expansion knob).
    pub fn with_expanded(&self, capacity: f64, bandwidth: f64) -> NodeConfig {
        let mut n = self.clone();
        n.expanded = MemoryConfig::new(capacity, bandwidth);
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::*;

    fn a100() -> NodeConfig {
        NodeConfig {
            name: "A100".into(),
            perf_peak: tflops(624.0),
            sram: mb(40.0),
            local: MemoryConfig::new(gb(80.0), gbps(2039.0)),
            expanded: MemoryConfig::none(),
        }
    }

    #[test]
    fn valid_node_passes() {
        assert!(a100().validate().is_ok());
    }

    #[test]
    fn zero_compute_fails() {
        let mut n = a100();
        n.perf_peak = 0.0;
        assert!(n.validate().is_err());
    }

    #[test]
    fn expanded_without_bandwidth_fails() {
        let mut n = a100();
        n.expanded = MemoryConfig {
            capacity: gb(480.0),
            bandwidth: 0.0,
        };
        assert!(n.validate().is_err());
    }

    #[test]
    fn total_capacity_sums_levels() {
        let n = a100().with_expanded(gb(480.0), gbps(500.0));
        assert_eq!(n.total_capacity(), gb(560.0));
    }

    #[test]
    fn scale_compute_scales_only_perf() {
        let n = a100().scale_compute(2.0);
        assert_eq!(n.perf_peak, tflops(1248.0));
        assert_eq!(n.local, a100().local);
    }

    #[test]
    fn memory_none_is_absent() {
        assert!(!MemoryConfig::none().present());
        assert!(MemoryConfig::new(gb(1.0), gbps(1.0)).present());
    }

    #[test]
    fn nan_and_infinite_values_are_rejected() {
        // NaN passes `<= 0.0` style checks (all NaN comparisons are
        // false), so validation must catch it explicitly.
        let mut n = a100();
        n.perf_peak = f64::NAN;
        assert!(n.validate().is_err());
        let mut n = a100();
        n.sram = f64::INFINITY;
        assert!(n.validate().is_err());
        let mut n = a100();
        n.local.bandwidth = f64::NAN;
        let e = n.validate().unwrap_err().to_string();
        assert!(e.contains("local"), "{e}");
        let mut n = a100();
        n.expanded = MemoryConfig::new(gb(480.0), -1.0);
        assert!(n.validate().is_err());
    }
}
