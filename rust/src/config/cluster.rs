//! Cluster-level configuration: node count, network topology, link latency,
//! and (optionally) heterogeneous node groups.
//!
//! Topologies are described either by the paper's three closed shapes
//! (hierarchical switch, flat switch, 3D torus) or by an explicit N-tier
//! switch chain ([`Topology::Tiered`]). Every topology *lowers* to a
//! [`TierChain`] — the canonical form consumed by the collective cost
//! model — and, for backends that only understand two link classes, to
//! the legacy [`TwoLevelView`] projection.

use super::node::NodeConfig;
use crate::error::{Error, Result};

/// Maximum number of tiers a lowered topology chain can carry. Four is
/// enough for node -> rack -> pod -> spine fabrics; the cap lets
/// per-tier data live in `Copy` arrays inside hot-path structs.
pub const MAX_TIERS: usize = 4;

/// One tier of an N-tier switch chain, innermost first.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierSpec {
    /// Fan-out: how many units of the tier below are grouped at this
    /// tier (tier 0 groups individual nodes).
    pub group: usize,
    /// Per-node, per-direction bandwidth through this tier, bytes/s.
    pub bandwidth: f64,
    /// Per-hop latency at this tier, seconds.
    pub latency: f64,
}

/// A topology lowered to its canonical tier chain, innermost tier first.
/// The product of `groups[..n_tiers]` equals the cluster node count.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierChain {
    /// Number of active tiers (1..=[`MAX_TIERS`]).
    pub n_tiers: usize,
    /// Per-tier group fan-out; unused slots are 1.
    pub groups: [usize; MAX_TIERS],
    /// Per-tier per-node bandwidth, bytes/s; unused slots are 0.
    pub bandwidth: [f64; MAX_TIERS],
    /// Per-tier per-hop latency, seconds; unused slots are 0.
    pub latency: [f64; MAX_TIERS],
}

impl TierChain {
    /// Project the chain onto the legacy two-level view: tier 0 is the
    /// pod, the outermost tier supplies the inter-pod bandwidth.
    pub fn two_level(&self) -> TwoLevelView {
        let top = self.n_tiers.saturating_sub(1);
        TwoLevelView {
            pod_size: self.groups[0],
            bw_intra: self.bandwidth[0],
            bw_inter: self.bandwidth[top],
        }
    }
}

/// One group of identical nodes in a heterogeneous cluster. Scales are
/// relative to the cluster's base [`NodeConfig`]: `perf_scale` multiplies
/// peak compute, `mem_scale` multiplies local memory capacity, and
/// `bw_scale` multiplies network tier bandwidths. Synchronous training is
/// gated by the slowest group, so evaluation applies the minimum of each
/// scale across groups (see [`ClusterConfig::group_scales`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeGroup {
    /// Nodes in this group; counts must sum to the cluster node count.
    pub count: usize,
    /// Peak-compute multiplier vs the base node.
    pub perf_scale: f64,
    /// Local-memory-capacity multiplier vs the base node.
    pub mem_scale: f64,
    /// Network-bandwidth multiplier vs the base node's tier bandwidths.
    pub bw_scale: f64,
}

/// Bottleneck scales of a heterogeneous cluster: the minimum of each
/// [`NodeGroup`] scale, applied uniformly by the evaluators.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GroupScales {
    /// Minimum `perf_scale` across groups.
    pub perf: f64,
    /// Minimum `mem_scale` across groups.
    pub mem: f64,
    /// Minimum `bw_scale` across groups.
    pub bw: f64,
}

/// Network topology of the cluster (paper Fig. 14's three shapes, plus
/// an explicit multi-tier chain).
#[derive(Debug, Clone, PartialEq)]
pub enum Topology {
    /// Two-level switch hierarchy: pods of `pod_size` nodes with high
    /// intra-pod bandwidth, lower inter-pod bandwidth (DGX-style, Fig. 7).
    /// Bandwidths are per node, per direction, bytes/s.
    HierarchicalSwitch {
        pod_size: usize,
        bw_intra: f64,
        bw_inter: f64,
    },
    /// One flat switch delivering `bw` bytes/s per node per direction
    /// (the paper's Dojo model).
    SingleSwitch { bw: f64 },
    /// 3D torus with `links` bidirectional links per node of `link_bw`
    /// bytes/s per direction each (the paper's TPU v4 model: 6 x 48 GB/s).
    /// Collectives use multi-ring schedules across all links, so the
    /// effective per-node collective bandwidth is `links x link_bw`.
    Torus3D {
        dims: [usize; 3],
        links: usize,
        link_bw: f64,
    },
    /// Explicit N-tier switch chain, innermost tier first (e.g. NVLink
    /// island -> rack -> spine). Group fan-outs must multiply to the
    /// cluster node count.
    Tiered { tiers: Vec<TierSpec> },
}

/// The legacy two-level network view: groups of `pod_size` peers
/// communicating at `bw_intra`, pods talking to each other at `bw_inter`.
/// Flat topologies set `pod_size = n_nodes`; tiered topologies project
/// their innermost and outermost tiers onto it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TwoLevelView {
    /// Peers per pod (flat topologies: the whole cluster).
    pub pod_size: usize,
    /// Intra-pod bandwidth per node per direction, bytes/s.
    pub bw_intra: f64,
    /// Inter-pod bandwidth per node per direction, bytes/s.
    pub bw_inter: f64,
}

impl Topology {
    /// Reduce to the two-level view used by the legacy collective cost
    /// model. Errors when a hierarchical `pod_size` does not divide the
    /// cluster: a remainder pod would silently skew every collective
    /// cost, so it must be rejected, not truncated.
    pub fn two_level(&self, n_nodes: usize) -> Result<TwoLevelView> {
        match *self {
            Topology::HierarchicalSwitch {
                pod_size,
                bw_intra,
                bw_inter,
            } => {
                if pod_size == 0 || n_nodes % pod_size != 0 {
                    return Err(Error::Config(format!(
                        "pod_size {pod_size} does not divide n_nodes \
                         {n_nodes}: a remainder pod would skew the \
                         two-level collective model; pick a pod_size \
                         that divides the cluster (or shrink n_nodes)"
                    )));
                }
                Ok(TwoLevelView {
                    pod_size,
                    bw_intra,
                    bw_inter,
                })
            }
            Topology::SingleSwitch { bw } => Ok(TwoLevelView {
                pod_size: n_nodes,
                bw_intra: bw,
                bw_inter: bw,
            }),
            Topology::Torus3D { links, link_bw, .. } => {
                let agg = links as f64 * link_bw;
                Ok(TwoLevelView {
                    pod_size: n_nodes,
                    bw_intra: agg,
                    bw_inter: agg,
                })
            }
            Topology::Tiered { .. } => {
                Ok(self.tier_chain(n_nodes, 0.0)?.two_level())
            }
        }
    }

    /// Lower to the canonical tier chain. Legacy topologies become a
    /// 2-tier (hierarchical) or 1-tier (flat, torus) chain carrying
    /// `link_latency` at every tier; [`Topology::Tiered`] carries its
    /// own per-tier latencies.
    pub fn tier_chain(
        &self,
        n_nodes: usize,
        link_latency: f64,
    ) -> Result<TierChain> {
        let mut chain = TierChain {
            n_tiers: 1,
            groups: [1; MAX_TIERS],
            bandwidth: [0.0; MAX_TIERS],
            latency: [0.0; MAX_TIERS],
        };
        match *self {
            Topology::HierarchicalSwitch {
                bw_intra, bw_inter, ..
            } => {
                let view = self.two_level(n_nodes)?;
                chain.n_tiers = 2;
                chain.groups[0] = view.pod_size;
                chain.groups[1] = n_nodes / view.pod_size.max(1);
                chain.bandwidth[0] = bw_intra;
                chain.bandwidth[1] = bw_inter;
                chain.latency[0] = link_latency;
                chain.latency[1] = link_latency;
            }
            Topology::SingleSwitch { .. } | Topology::Torus3D { .. } => {
                let view = self.two_level(n_nodes)?;
                chain.groups[0] = n_nodes;
                chain.bandwidth[0] = view.bw_intra;
                chain.latency[0] = link_latency;
            }
            Topology::Tiered { ref tiers } => {
                if tiers.is_empty() || tiers.len() > MAX_TIERS {
                    return Err(Error::Config(format!(
                        "tiered topology must have 1..={MAX_TIERS} tiers, \
                         got {}",
                        tiers.len()
                    )));
                }
                let product: usize =
                    tiers.iter().map(|t| t.group.max(1)).product();
                if product != n_nodes || tiers.iter().any(|t| t.group == 0) {
                    return Err(Error::Config(format!(
                        "tier group fan-outs {:?} must be > 0 and multiply \
                         to n_nodes {n_nodes} (got {product})",
                        tiers.iter().map(|t| t.group).collect::<Vec<_>>()
                    )));
                }
                chain.n_tiers = tiers.len();
                for (i, t) in tiers.iter().enumerate() {
                    chain.groups[i] = t.group;
                    chain.bandwidth[i] = t.bandwidth;
                    chain.latency[i] = t.latency;
                }
            }
        }
        Ok(chain)
    }

    /// Number of pods for a given cluster size (flat topologies: 1).
    pub fn n_pods(&self, n_nodes: usize) -> usize {
        match *self {
            Topology::HierarchicalSwitch { pod_size, .. } => {
                n_nodes.div_ceil(pod_size.max(1))
            }
            Topology::SingleSwitch { .. } | Topology::Torus3D { .. } => 1,
            Topology::Tiered { ref tiers } => {
                let pod = tiers.first().map(|t| t.group).unwrap_or(n_nodes);
                n_nodes.div_ceil(pod.max(1))
            }
        }
    }
}

/// A complete cluster description.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Name (e.g. "B1", "dgx-a100-1024").
    pub name: String,
    /// Per-node resources of the base node type.
    pub node: NodeConfig,
    /// Total node count.
    pub n_nodes: usize,
    /// Network topology.
    pub topology: Topology,
    /// Per-hop link latency, seconds (the alpha term of collectives).
    /// Tiered topologies carry per-tier latencies instead.
    pub link_latency: f64,
    /// Heterogeneous node groups; empty means homogeneous (the base
    /// node everywhere), which is the paper's setting.
    pub groups: Vec<NodeGroup>,
}

impl ClusterConfig {
    /// Validate the whole configuration.
    pub fn validate(&self) -> Result<()> {
        self.node.validate()?;
        if self.n_nodes == 0 {
            return Err(Error::Config(format!("{}: n_nodes == 0", self.name)));
        }
        if !self.n_nodes.is_power_of_two() {
            return Err(Error::Config(format!(
                "{}: n_nodes {} must be a power of two for the (MP, DP) sweep",
                self.name, self.n_nodes
            )));
        }
        match self.topology {
            Topology::HierarchicalSwitch {
                pod_size,
                bw_intra,
                bw_inter,
            } => {
                if pod_size == 0 || self.n_nodes % pod_size != 0 {
                    return Err(Error::Config(format!(
                        "{}: pod_size {} must divide n_nodes {}",
                        self.name, pod_size, self.n_nodes
                    )));
                }
                if !bw_intra.is_finite()
                    || !bw_inter.is_finite()
                    || bw_intra <= 0.0
                    || bw_inter <= 0.0
                {
                    return Err(Error::Config(format!(
                        "{}: network bandwidths must be finite numbers > 0, \
                         got intra {bw_intra} inter {bw_inter}",
                        self.name
                    )));
                }
            }
            Topology::SingleSwitch { bw } => {
                if !bw.is_finite() || bw <= 0.0 {
                    return Err(Error::Config(format!(
                        "{}: switch bandwidth must be a finite number > 0, \
                         got {bw}",
                        self.name
                    )));
                }
            }
            Topology::Torus3D {
                dims,
                links,
                link_bw,
            } => {
                if dims.iter().product::<usize>() != self.n_nodes {
                    return Err(Error::Config(format!(
                        "{}: torus dims {:?} != n_nodes {}",
                        self.name, dims, self.n_nodes
                    )));
                }
                if links == 0 || !link_bw.is_finite() || link_bw <= 0.0 {
                    return Err(Error::Config(format!(
                        "{}: torus links/bandwidth must be finite numbers \
                         > 0, got {links} links at {link_bw}",
                        self.name
                    )));
                }
            }
            Topology::Tiered { ref tiers } => {
                // Structural checks (tier count, fan-out product).
                self.topology.tier_chain(self.n_nodes, self.link_latency)?;
                for (i, t) in tiers.iter().enumerate() {
                    if !t.bandwidth.is_finite() || t.bandwidth <= 0.0 {
                        return Err(Error::Config(format!(
                            "{}: tier {i} bandwidth must be a finite number \
                             > 0, got {}",
                            self.name, t.bandwidth
                        )));
                    }
                    if !t.latency.is_finite() || t.latency < 0.0 {
                        return Err(Error::Config(format!(
                            "{}: tier {i} latency must be a finite number \
                             >= 0, got {}",
                            self.name, t.latency
                        )));
                    }
                }
            }
        }
        if !self.link_latency.is_finite() || self.link_latency < 0.0 {
            return Err(Error::Config(format!(
                "{}: link latency must be a finite number >= 0, got {}",
                self.name, self.link_latency
            )));
        }
        if !self.groups.is_empty() {
            let total: usize = self.groups.iter().map(|g| g.count).sum();
            if total != self.n_nodes {
                return Err(Error::Config(format!(
                    "{}: node group counts sum to {total}, expected n_nodes {}",
                    self.name, self.n_nodes
                )));
            }
            for (i, g) in self.groups.iter().enumerate() {
                let ok = |s: f64| s.is_finite() && s > 0.0;
                if g.count == 0
                    || !ok(g.perf_scale)
                    || !ok(g.mem_scale)
                    || !ok(g.bw_scale)
                {
                    return Err(Error::Config(format!(
                        "{}: node group {i} needs count > 0 and finite \
                         scales > 0, got count {} perf {} mem {} bw {}",
                        self.name,
                        g.count,
                        g.perf_scale,
                        g.mem_scale,
                        g.bw_scale
                    )));
                }
            }
        }
        Ok(())
    }

    /// Two-level network view for the legacy cost model. Errors when the
    /// topology's pod structure does not divide the cluster.
    pub fn two_level(&self) -> Result<TwoLevelView> {
        self.topology.two_level(self.n_nodes)
    }

    /// Canonical tier chain for the tier-aware cost model.
    pub fn tier_chain(&self) -> Result<TierChain> {
        self.topology.tier_chain(self.n_nodes, self.link_latency)
    }

    /// Outermost-tier (cluster-egress) bandwidth, bytes/s. Infallible:
    /// reads the topology parameters directly, so callers that only
    /// need an egress bandwidth (checkpoint drains) avoid the
    /// divisibility checks of [`ClusterConfig::two_level`].
    pub fn inter_bandwidth(&self) -> f64 {
        match self.topology {
            Topology::HierarchicalSwitch { bw_inter, .. } => bw_inter,
            Topology::SingleSwitch { bw } => bw,
            Topology::Torus3D { links, link_bw, .. } => {
                links as f64 * link_bw
            }
            Topology::Tiered { ref tiers } => {
                tiers.last().map(|t| t.bandwidth).unwrap_or(0.0)
            }
        }
    }

    /// Bottleneck scales of a heterogeneous cluster, or `None` when the
    /// cluster is homogeneous (no groups). Synchronous training runs at
    /// the pace of the slowest group, so the evaluators multiply the
    /// base node's compute, memory capacity, and tier bandwidths by the
    /// minimum scale across groups.
    pub fn group_scales(&self) -> Option<GroupScales> {
        if self.groups.is_empty() {
            return None;
        }
        let fold = |f: fn(&NodeGroup) -> f64| {
            self.groups.iter().map(f).fold(f64::INFINITY, f64::min)
        };
        Some(GroupScales {
            perf: fold(|g| g.perf_scale),
            mem: fold(|g| g.mem_scale),
            bw: fold(|g| g.bw_scale),
        })
    }

    /// Derived cluster with network bandwidths scaled (fig. 11's knob).
    /// Only meaningful for hierarchical topologies.
    pub fn scale_network(&self, intra_factor: f64, inter_factor: f64) -> Self {
        let mut c = self.clone();
        if let Topology::HierarchicalSwitch {
            ref mut bw_intra,
            ref mut bw_inter,
            ..
        } = c.topology
        {
            *bw_intra *= intra_factor;
            *bw_inter *= inter_factor;
        }
        c.name = format!("{}~net{:.2}x{:.2}", c.name, intra_factor, inter_factor);
        c
    }

    /// Derived cluster with a re-balanced intra/inter bandwidth split that
    /// preserves the aggregate per-node bandwidth (fig. 12's knob).
    /// `ratio` is bw_intra : bw_inter, e.g. 6.0 for the paper's 1:6
    /// inter:intra optimum.
    pub fn rebalance_network(&self, ratio: f64) -> Result<Self> {
        let mut c = self.clone();
        match c.topology {
            Topology::HierarchicalSwitch {
                ref mut bw_intra,
                ref mut bw_inter,
                ..
            } => {
                let total = *bw_intra + *bw_inter;
                let inter = total / (1.0 + ratio);
                *bw_inter = inter;
                *bw_intra = total - inter;
                c.name = format!("{}~ratio1:{:.1}", c.name, ratio);
                Ok(c)
            }
            _ => Err(Error::Config(
                "rebalance_network requires a hierarchical topology".into(),
            )),
        }
    }

    /// Derived cluster with a different node definition.
    pub fn with_node(&self, node: NodeConfig) -> Self {
        let mut c = self.clone();
        c.node = node;
        c
    }

    /// Derived cluster truncated to `n` nodes (fig. 13a's cluster-size
    /// knob). Keeps topology parameters; `n` must be a power of two.
    pub fn with_n_nodes(&self, n: usize) -> Self {
        let mut c = self.clone();
        c.n_nodes = n;
        if let Topology::HierarchicalSwitch {
            ref mut pod_size, ..
        } = c.topology
        {
            // A truncated cluster cannot have pods larger than itself.
            *pod_size = (*pod_size).min(n);
        }
        if let Topology::Torus3D { ref mut dims, .. } = c.topology {
            // Keep a valid torus factorization for truncated clusters.
            let side = (n as f64).cbrt().round() as usize;
            if side * side * side == n {
                *dims = [side, side, side];
            } else {
                let half = (n as f64 / 2.0).sqrt().round() as usize;
                *dims = [2, half, n / (2 * half.max(1))];
            }
        }
        if let Topology::Tiered { ref mut tiers } = c.topology {
            // Shrink from the outermost tier until fan-outs multiply to n
            // (power-of-two groups halve exactly; a fan-out of 1 drops).
            loop {
                let product: usize =
                    tiers.iter().map(|t| t.group.max(1)).product();
                if product <= n.max(1) {
                    break;
                }
                let last = tiers.len() - 1;
                if tiers[last].group > 1 {
                    tiers[last].group /= 2;
                } else if tiers.len() > 1 {
                    tiers.pop();
                } else {
                    break;
                }
            }
        }
        // Groups are sized for the original cluster; a truncated cluster
        // keeps the base node homogeneous rather than guessing a split.
        if c.n_nodes != self.n_nodes {
            c.groups.clear();
        }
        c.name = format!("{}~n{}", c.name, n);
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::util::units::*;

    #[test]
    fn baseline_is_valid() {
        presets::dgx_a100_1024().validate().unwrap();
    }

    #[test]
    fn two_level_of_hierarchical() {
        let c = presets::dgx_a100_1024();
        let v = c.two_level().unwrap();
        assert_eq!(v.pod_size, 8);
        assert_eq!(v.bw_intra, gbps(300.0));
        assert_eq!(v.bw_inter, gbps(31.25));
        assert_eq!(c.topology.n_pods(c.n_nodes), 128);
    }

    #[test]
    fn two_level_of_flat() {
        let t = Topology::SingleSwitch { bw: tbps(1.0) };
        let v = t.two_level(64).unwrap();
        assert_eq!(v.pod_size, 64);
        assert_eq!(v.bw_intra, v.bw_inter);
    }

    #[test]
    fn two_level_of_torus_aggregates_links() {
        let t = Topology::Torus3D {
            dims: [16, 16, 16],
            links: 6,
            link_bw: gbps(48.0),
        };
        let v = t.two_level(4096).unwrap();
        assert_eq!(v.bw_intra, gbps(288.0));
        assert_eq!(v.pod_size, 4096);
    }

    #[test]
    fn two_level_rejects_remainder_pod() {
        // Regression: a pod_size that does not divide n_nodes used to be
        // silently accepted, skewing every downstream collective cost.
        let t = Topology::HierarchicalSwitch {
            pod_size: 7,
            bw_intra: gbps(300.0),
            bw_inter: gbps(31.25),
        };
        let e = t.two_level(1024).unwrap_err().to_string();
        assert!(e.contains("does not divide"), "{e}");
        assert!(e.contains("pod_size 7"), "{e}");
        let e = Topology::HierarchicalSwitch {
            pod_size: 0,
            bw_intra: 1.0,
            bw_inter: 1.0,
        }
        .two_level(8)
        .unwrap_err()
        .to_string();
        assert!(e.contains("pod_size 0"), "{e}");
    }

    #[test]
    fn legacy_topologies_lower_to_expected_chains() {
        let c = presets::dgx_a100_1024();
        let chain = c.tier_chain().unwrap();
        assert_eq!(chain.n_tiers, 2);
        assert_eq!(&chain.groups[..2], &[8, 128]);
        assert_eq!(chain.bandwidth[0], gbps(300.0));
        assert_eq!(chain.bandwidth[1], gbps(31.25));
        assert_eq!(chain.latency[0], c.link_latency);
        assert_eq!(chain.two_level(), c.two_level().unwrap());

        let flat = presets::dojo_64();
        let chain = flat.tier_chain().unwrap();
        assert_eq!(chain.n_tiers, 1);
        assert_eq!(chain.groups[0], 64);
        assert_eq!(chain.two_level(), flat.two_level().unwrap());
    }

    #[test]
    fn tiered_topology_validates_and_projects() {
        let mut c = presets::dgx_a100_64();
        c.topology = Topology::Tiered {
            tiers: vec![
                TierSpec {
                    group: 8,
                    bandwidth: gbps(300.0),
                    latency: 1e-6,
                },
                TierSpec {
                    group: 4,
                    bandwidth: gbps(50.0),
                    latency: 2e-6,
                },
                TierSpec {
                    group: 2,
                    bandwidth: gbps(12.5),
                    latency: 5e-6,
                },
            ],
        };
        c.validate().unwrap();
        let chain = c.tier_chain().unwrap();
        assert_eq!(chain.n_tiers, 3);
        assert_eq!(&chain.groups[..3], &[8, 4, 2]);
        let v = c.two_level().unwrap();
        assert_eq!(v.pod_size, 8);
        assert_eq!(v.bw_intra, gbps(300.0));
        assert_eq!(v.bw_inter, gbps(12.5));
        assert_eq!(c.inter_bandwidth(), gbps(12.5));
        assert_eq!(c.topology.n_pods(64), 8);

        // Fan-outs must multiply to the cluster size.
        if let Topology::Tiered { ref mut tiers } = c.topology {
            tiers[2].group = 4;
        }
        let e = c.validate().unwrap_err().to_string();
        assert!(e.contains("multiply to n_nodes"), "{e}");
    }

    #[test]
    fn tiered_with_n_nodes_shrinks_outer_tiers() {
        let c = presets::tiered_het_64();
        for n in [32usize, 8, 2, 1] {
            let small = c.with_n_nodes(n);
            small.validate().unwrap_or_else(|e| panic!("n={n}: {e}"));
            assert_eq!(small.n_nodes, n);
        }
    }

    #[test]
    fn node_groups_validate() {
        let mut c = presets::dgx_a100_64();
        c.groups = vec![
            NodeGroup {
                count: 48,
                perf_scale: 1.0,
                mem_scale: 1.0,
                bw_scale: 1.0,
            },
            NodeGroup {
                count: 16,
                perf_scale: 0.5,
                mem_scale: 2.0,
                bw_scale: 0.25,
            },
        ];
        c.validate().unwrap();
        let s = c.group_scales().unwrap();
        assert_eq!(s.perf, 0.5);
        assert_eq!(s.mem, 1.0);
        assert_eq!(s.bw, 0.25);

        c.groups[0].count = 40;
        let e = c.validate().unwrap_err().to_string();
        assert!(e.contains("sum to 56"), "{e}");

        c.groups[0].count = 48;
        c.groups[1].perf_scale = f64::NAN;
        assert!(c.validate().is_err());

        c.groups.clear();
        assert!(c.group_scales().is_none());
    }

    #[test]
    fn pod_size_must_divide() {
        let mut c = presets::dgx_a100_1024();
        if let Topology::HierarchicalSwitch {
            ref mut pod_size, ..
        } = c.topology
        {
            *pod_size = 7;
        }
        assert!(c.validate().is_err());
    }

    #[test]
    fn non_pow2_rejected() {
        let mut c = presets::dgx_a100_1024();
        c.n_nodes = 1000;
        assert!(c.validate().is_err());
    }

    #[test]
    fn torus_dims_must_match() {
        let mut c = presets::tpu_v4_4096();
        c.n_nodes = 2048;
        assert!(c.validate().is_err());
    }

    #[test]
    fn scale_network_scales_both() {
        let c = presets::dgx_a100_1024().scale_network(2.0, 0.5);
        let v = c.two_level().unwrap();
        assert_eq!(v.bw_intra, gbps(600.0));
        assert_eq!(v.bw_inter, gbps(15.625));
    }

    #[test]
    fn rebalance_preserves_aggregate() {
        let base = presets::dgx_a100_1024();
        let b0 = base.two_level().unwrap();
        let total = b0.bw_intra + b0.bw_inter;
        for ratio in [1.0, 3.0, 6.0, 9.6, 24.0] {
            let c = base.rebalance_network(ratio).unwrap();
            let v = c.two_level().unwrap();
            assert!((v.bw_intra + v.bw_inter - total).abs() < 1.0);
            assert!((v.bw_intra / v.bw_inter - ratio).abs() / ratio < 1e-9);
        }
    }

    #[test]
    fn rebalance_fig12_values() {
        // Paper: 1:6 ratio on 331.25 GB/s aggregate => ~284 intra, ~47.3 inter.
        let c = presets::dgx_a100_1024().rebalance_network(6.0).unwrap();
        let v = c.two_level().unwrap();
        assert!((v.bw_intra - gbps(283.93)).abs() < gbps(0.1));
        assert!((v.bw_inter - gbps(47.32)).abs() < gbps(0.1));
    }

    #[test]
    fn with_n_nodes_keeps_torus_valid() {
        let c = presets::tpu_v4_4096().with_n_nodes(512);
        c.validate().unwrap();
        assert_eq!(c.n_nodes, 512);
    }

    #[test]
    fn nan_bandwidths_and_latency_are_rejected() {
        let mut c = presets::dgx_a100_1024();
        if let Topology::HierarchicalSwitch {
            ref mut bw_inter, ..
        } = c.topology
        {
            *bw_inter = f64::NAN;
        }
        let e = c.validate().unwrap_err().to_string();
        assert!(e.contains("finite"), "{e}");

        let mut c = presets::dgx_a100_1024();
        c.link_latency = f64::NAN;
        assert!(c.validate().is_err());

        let mut c = presets::dgx_a100_1024();
        c.link_latency = f64::INFINITY;
        assert!(c.validate().is_err());
    }
}
