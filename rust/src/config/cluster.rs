//! Cluster-level configuration: node count, network topology, link latency.

use super::node::NodeConfig;
use crate::error::{Error, Result};

/// Network topology of the cluster (paper Fig. 14's three shapes).
#[derive(Debug, Clone, PartialEq)]
pub enum Topology {
    /// Two-level switch hierarchy: pods of `pod_size` nodes with high
    /// intra-pod bandwidth, lower inter-pod bandwidth (DGX-style, Fig. 7).
    /// Bandwidths are per node, per direction, bytes/s.
    HierarchicalSwitch {
        pod_size: usize,
        bw_intra: f64,
        bw_inter: f64,
    },
    /// One flat switch delivering `bw` bytes/s per node per direction
    /// (the paper's Dojo model).
    SingleSwitch { bw: f64 },
    /// 3D torus with `links` bidirectional links per node of `link_bw`
    /// bytes/s per direction each (the paper's TPU v4 model: 6 x 48 GB/s).
    /// Collectives use multi-ring schedules across all links, so the
    /// effective per-node collective bandwidth is `links x link_bw`.
    Torus3D {
        dims: [usize; 3],
        links: usize,
        link_bw: f64,
    },
}

/// The analytical cost model reduces every topology to a two-level view:
/// groups of `pod_size` peers communicating at `bw_intra`, pods talking to
/// each other at `bw_inter`. Flat topologies set `pod_size = n_nodes`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TwoLevelView {
    /// Peers per pod (flat topologies: the whole cluster).
    pub pod_size: usize,
    /// Intra-pod bandwidth per node per direction, bytes/s.
    pub bw_intra: f64,
    /// Inter-pod bandwidth per node per direction, bytes/s.
    pub bw_inter: f64,
}

impl Topology {
    /// Reduce to the two-level view used by the collective cost model.
    pub fn two_level(&self, n_nodes: usize) -> TwoLevelView {
        match *self {
            Topology::HierarchicalSwitch {
                pod_size,
                bw_intra,
                bw_inter,
            } => TwoLevelView {
                pod_size,
                bw_intra,
                bw_inter,
            },
            Topology::SingleSwitch { bw } => TwoLevelView {
                pod_size: n_nodes,
                bw_intra: bw,
                bw_inter: bw,
            },
            Topology::Torus3D { links, link_bw, .. } => {
                let agg = links as f64 * link_bw;
                TwoLevelView {
                    pod_size: n_nodes,
                    bw_intra: agg,
                    bw_inter: agg,
                }
            }
        }
    }

    /// Number of pods for a given cluster size.
    pub fn n_pods(&self, n_nodes: usize) -> usize {
        let view = self.two_level(n_nodes);
        n_nodes.div_ceil(view.pod_size)
    }
}

/// A complete cluster description.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Name (e.g. "B1", "dgx-a100-1024").
    pub name: String,
    /// Per-node resources (homogeneous cluster, as in the paper).
    pub node: NodeConfig,
    /// Total node count.
    pub n_nodes: usize,
    /// Network topology.
    pub topology: Topology,
    /// Per-hop link latency, seconds (the alpha term of collectives).
    pub link_latency: f64,
}

impl ClusterConfig {
    /// Validate the whole configuration.
    pub fn validate(&self) -> Result<()> {
        self.node.validate()?;
        if self.n_nodes == 0 {
            return Err(Error::Config(format!("{}: n_nodes == 0", self.name)));
        }
        if !self.n_nodes.is_power_of_two() {
            return Err(Error::Config(format!(
                "{}: n_nodes {} must be a power of two for the (MP, DP) sweep",
                self.name, self.n_nodes
            )));
        }
        match self.topology {
            Topology::HierarchicalSwitch {
                pod_size,
                bw_intra,
                bw_inter,
            } => {
                if pod_size == 0 || self.n_nodes % pod_size != 0 {
                    return Err(Error::Config(format!(
                        "{}: pod_size {} must divide n_nodes {}",
                        self.name, pod_size, self.n_nodes
                    )));
                }
                if !bw_intra.is_finite()
                    || !bw_inter.is_finite()
                    || bw_intra <= 0.0
                    || bw_inter <= 0.0
                {
                    return Err(Error::Config(format!(
                        "{}: network bandwidths must be finite numbers > 0, \
                         got intra {bw_intra} inter {bw_inter}",
                        self.name
                    )));
                }
            }
            Topology::SingleSwitch { bw } => {
                if !bw.is_finite() || bw <= 0.0 {
                    return Err(Error::Config(format!(
                        "{}: switch bandwidth must be a finite number > 0, \
                         got {bw}",
                        self.name
                    )));
                }
            }
            Topology::Torus3D {
                dims,
                links,
                link_bw,
            } => {
                if dims.iter().product::<usize>() != self.n_nodes {
                    return Err(Error::Config(format!(
                        "{}: torus dims {:?} != n_nodes {}",
                        self.name, dims, self.n_nodes
                    )));
                }
                if links == 0 || !link_bw.is_finite() || link_bw <= 0.0 {
                    return Err(Error::Config(format!(
                        "{}: torus links/bandwidth must be finite numbers \
                         > 0, got {links} links at {link_bw}",
                        self.name
                    )));
                }
            }
        }
        if !self.link_latency.is_finite() || self.link_latency < 0.0 {
            return Err(Error::Config(format!(
                "{}: link latency must be a finite number >= 0, got {}",
                self.name, self.link_latency
            )));
        }
        Ok(())
    }

    /// Two-level network view for the cost model.
    pub fn two_level(&self) -> TwoLevelView {
        self.topology.two_level(self.n_nodes)
    }

    /// Derived cluster with network bandwidths scaled (fig. 11's knob).
    /// Only meaningful for hierarchical topologies.
    pub fn scale_network(&self, intra_factor: f64, inter_factor: f64) -> Self {
        let mut c = self.clone();
        if let Topology::HierarchicalSwitch {
            ref mut bw_intra,
            ref mut bw_inter,
            ..
        } = c.topology
        {
            *bw_intra *= intra_factor;
            *bw_inter *= inter_factor;
        }
        c.name = format!("{}~net{:.2}x{:.2}", c.name, intra_factor, inter_factor);
        c
    }

    /// Derived cluster with a re-balanced intra/inter bandwidth split that
    /// preserves the aggregate per-node bandwidth (fig. 12's knob).
    /// `ratio` is bw_intra : bw_inter, e.g. 6.0 for the paper's 1:6
    /// inter:intra optimum.
    pub fn rebalance_network(&self, ratio: f64) -> Result<Self> {
        let mut c = self.clone();
        match c.topology {
            Topology::HierarchicalSwitch {
                ref mut bw_intra,
                ref mut bw_inter,
                ..
            } => {
                let total = *bw_intra + *bw_inter;
                let inter = total / (1.0 + ratio);
                *bw_inter = inter;
                *bw_intra = total - inter;
                c.name = format!("{}~ratio1:{:.1}", c.name, ratio);
                Ok(c)
            }
            _ => Err(Error::Config(
                "rebalance_network requires a hierarchical topology".into(),
            )),
        }
    }

    /// Derived cluster with a different node definition.
    pub fn with_node(&self, node: NodeConfig) -> Self {
        let mut c = self.clone();
        c.node = node;
        c
    }

    /// Derived cluster truncated to `n` nodes (fig. 13a's cluster-size
    /// knob). Keeps topology parameters; `n` must be a power of two.
    pub fn with_n_nodes(&self, n: usize) -> Self {
        let mut c = self.clone();
        c.n_nodes = n;
        if let Topology::HierarchicalSwitch {
            ref mut pod_size, ..
        } = c.topology
        {
            // A truncated cluster cannot have pods larger than itself.
            *pod_size = (*pod_size).min(n);
        }
        if let Topology::Torus3D { ref mut dims, .. } = c.topology {
            // Keep a valid torus factorization for truncated clusters.
            let side = (n as f64).cbrt().round() as usize;
            if side * side * side == n {
                *dims = [side, side, side];
            } else {
                let half = (n as f64 / 2.0).sqrt().round() as usize;
                *dims = [2, half, n / (2 * half.max(1))];
            }
        }
        c.name = format!("{}~n{}", c.name, n);
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::util::units::*;

    #[test]
    fn baseline_is_valid() {
        presets::dgx_a100_1024().validate().unwrap();
    }

    #[test]
    fn two_level_of_hierarchical() {
        let c = presets::dgx_a100_1024();
        let v = c.two_level();
        assert_eq!(v.pod_size, 8);
        assert_eq!(v.bw_intra, gbps(300.0));
        assert_eq!(v.bw_inter, gbps(31.25));
        assert_eq!(c.topology.n_pods(c.n_nodes), 128);
    }

    #[test]
    fn two_level_of_flat() {
        let t = Topology::SingleSwitch { bw: tbps(1.0) };
        let v = t.two_level(64);
        assert_eq!(v.pod_size, 64);
        assert_eq!(v.bw_intra, v.bw_inter);
    }

    #[test]
    fn two_level_of_torus_aggregates_links() {
        let t = Topology::Torus3D {
            dims: [16, 16, 16],
            links: 6,
            link_bw: gbps(48.0),
        };
        let v = t.two_level(4096);
        assert_eq!(v.bw_intra, gbps(288.0));
        assert_eq!(v.pod_size, 4096);
    }

    #[test]
    fn pod_size_must_divide() {
        let mut c = presets::dgx_a100_1024();
        if let Topology::HierarchicalSwitch {
            ref mut pod_size, ..
        } = c.topology
        {
            *pod_size = 7;
        }
        assert!(c.validate().is_err());
    }

    #[test]
    fn non_pow2_rejected() {
        let mut c = presets::dgx_a100_1024();
        c.n_nodes = 1000;
        assert!(c.validate().is_err());
    }

    #[test]
    fn torus_dims_must_match() {
        let mut c = presets::tpu_v4_4096();
        c.n_nodes = 2048;
        assert!(c.validate().is_err());
    }

    #[test]
    fn scale_network_scales_both() {
        let c = presets::dgx_a100_1024().scale_network(2.0, 0.5);
        let v = c.two_level();
        assert_eq!(v.bw_intra, gbps(600.0));
        assert_eq!(v.bw_inter, gbps(15.625));
    }

    #[test]
    fn rebalance_preserves_aggregate() {
        let base = presets::dgx_a100_1024();
        let b0 = base.two_level();
        let total = b0.bw_intra + b0.bw_inter;
        for ratio in [1.0, 3.0, 6.0, 9.6, 24.0] {
            let c = base.rebalance_network(ratio).unwrap();
            let v = c.two_level();
            assert!((v.bw_intra + v.bw_inter - total).abs() < 1.0);
            assert!((v.bw_intra / v.bw_inter - ratio).abs() / ratio < 1e-9);
        }
    }

    #[test]
    fn rebalance_fig12_values() {
        // Paper: 1:6 ratio on 331.25 GB/s aggregate => ~284 intra, ~47.3 inter.
        let c = presets::dgx_a100_1024().rebalance_network(6.0).unwrap();
        let v = c.two_level();
        assert!((v.bw_intra - gbps(283.93)).abs() < gbps(0.1));
        assert!((v.bw_inter - gbps(47.32)).abs() < gbps(0.1));
    }

    #[test]
    fn with_n_nodes_keeps_torus_valid() {
        let c = presets::tpu_v4_4096().with_n_nodes(512);
        c.validate().unwrap();
        assert_eq!(c.n_nodes, 512);
    }

    #[test]
    fn nan_bandwidths_and_latency_are_rejected() {
        let mut c = presets::dgx_a100_1024();
        if let Topology::HierarchicalSwitch {
            ref mut bw_inter, ..
        } = c.topology
        {
            *bw_inter = f64::NAN;
        }
        let e = c.validate().unwrap_err().to_string();
        assert!(e.contains("finite"), "{e}");

        let mut c = presets::dgx_a100_1024();
        c.link_latency = f64::NAN;
        assert!(c.validate().is_err());

        let mut c = presets::dgx_a100_1024();
        c.link_latency = f64::INFINITY;
        assert!(c.validate().is_err());
    }
}
