//! Parallelization strategies (paper SIII-B / SIV-B): the (MP, DP) sweep,
//! ZeRO-DP memory optimizations, and per-node footprint estimation.

mod footprint;
mod strategy;
mod zero;

pub use footprint::{
    activation_working_bytes, footprint_per_node, residual_state_bytes,
    FootprintBreakdown,
};
pub use strategy::Strategy;
pub use zero::{model_state_bytes, ZeroStage};
