//! Parallelization strategies (paper SIII-B / SIV-B): the 3D
//! (MP, DP, PP) lattice and its sweeps, pipeline schedules, ZeRO-DP
//! memory optimizations, and per-node footprint estimation.

mod footprint;
mod pipeline;
mod strategy;
mod zero;

pub use footprint::{
    activation_working_bytes, footprint_per_node,
    pipeline_footprint_per_node, pipeline_stage_footprint,
    residual_state_bytes, stage_footprint_terms, FootprintBreakdown,
};
pub use pipeline::PipeSchedule;
pub(crate) use strategy::tier_fill;
pub use strategy::{Strategy, TierMapping};
pub use zero::{model_state_bytes, ZeroStage};
