//! Pipeline-parallel execution schedules.
//!
//! Both schedules the literature uses for synchronous pipeline training
//! share the same bubble — `(pp - 1) / m` of the steady-state work for
//! `m` microbatches over `pp` stages — because both fill and drain the
//! pipeline once per iteration. Where they differ is **activation
//! memory**: GPipe runs all `m` forward microbatches before any backward,
//! holding `m` microbatches of activations per stage, while 1F1B
//! interleaves one-forward-one-backward in steady state and holds at most
//! `min(pp, m)`. The footprint model consumes [`PipeSchedule::in_flight`];
//! the time model treats the two identically (see
//! [`crate::analytical::pipeline_makespan`]).

use crate::error::{Error, Result};

/// Pipeline-parallel microbatch schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum PipeSchedule {
    /// GPipe: all forwards, then all backwards. Holds `m` microbatches of
    /// activations per stage.
    GPipe,
    /// 1F1B (PipeDream-flush): one-forward-one-backward steady state.
    /// Holds at most `min(pp, m)` microbatches of activations per stage.
    #[default]
    OneFOneB,
}

impl PipeSchedule {
    /// Both schedules, spec-file order.
    pub const ALL: [PipeSchedule; 2] =
        [PipeSchedule::GPipe, PipeSchedule::OneFOneB];

    /// Canonical short name — the scenario-file vocabulary
    /// (`gpipe` | `1f1b`); inverse of [`PipeSchedule::parse`].
    pub fn name(self) -> &'static str {
        match self {
            PipeSchedule::GPipe => "gpipe",
            PipeSchedule::OneFOneB => "1f1b",
        }
    }

    /// Parse a spec-file schedule name (`gpipe` | `1f1b`).
    pub fn parse(s: &str) -> Result<PipeSchedule> {
        match s {
            "gpipe" => Ok(PipeSchedule::GPipe),
            "1f1b" => Ok(PipeSchedule::OneFOneB),
            other => Err(Error::Config(format!(
                "unknown pipeline schedule '{other}' (gpipe|1f1b)"
            ))),
        }
    }

    /// Stable numeric code (fingerprinting).
    pub fn code(self) -> f64 {
        match self {
            PipeSchedule::GPipe => 0.0,
            PipeSchedule::OneFOneB => 1.0,
        }
    }

    /// Microbatches whose activations a stage holds live under this
    /// schedule, out of `m` total over `pp` stages.
    pub fn in_flight(self, pp: usize, m: usize) -> usize {
        match self {
            PipeSchedule::GPipe => m,
            PipeSchedule::OneFOneB => pp.min(m),
        }
    }
}

impl std::fmt::Display for PipeSchedule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_roundtrip() {
        for s in PipeSchedule::ALL {
            assert_eq!(PipeSchedule::parse(s.name()).unwrap(), s);
        }
        assert!(PipeSchedule::parse("interleaved").is_err());
    }

    #[test]
    fn in_flight_counts() {
        assert_eq!(PipeSchedule::GPipe.in_flight(4, 16), 16);
        assert_eq!(PipeSchedule::OneFOneB.in_flight(4, 16), 4);
        // Fewer microbatches than stages: both hold m.
        assert_eq!(PipeSchedule::OneFOneB.in_flight(8, 2), 2);
        assert_eq!(PipeSchedule::GPipe.in_flight(8, 2), 2);
    }

    #[test]
    fn codes_distinct() {
        assert_ne!(PipeSchedule::GPipe.code(), PipeSchedule::OneFOneB.code());
    }
}
