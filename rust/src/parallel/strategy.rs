//! The (MP, DP, PP) parallelization strategy lattice and its power-of-two
//! sweeps.
//!
//! The paper's original lattice is 2D — `(MP, DP)` with `mp * dp == nodes`
//! — and every historical label (`MP8_DP128`), spec, and pinned figure
//! lives on that slice. This module generalizes it to 3D by adding a
//! pipeline-parallel degree `pp`: the invariant becomes
//! `mp * dp * pp == nodes`, the label gains a `_PP<k>` suffix **only when
//! `pp > 1`**, and parsing a 2D label yields `pp == 1`, so the 2D lattice
//! is exactly the `pp = 1` slice of the 3D one.
//!
//! Node layout convention (extends SIII-B): MP peers occupy consecutive
//! nodes, DP replicas stride by `mp` within a pipeline stage, and the
//! `pp` stages are outermost, strided by `mp * dp` — stage `s`, replica
//! `d`, MP rank `m` sits at node `s*mp*dp + d*mp + m`.

use crate::config::MAX_TIERS;
use crate::error::{Error, Result};

/// Which strategy axis is packed into the innermost network tiers of a
/// multi-tier fabric (the `tier-mapping` study knob). The legacy
/// two-level resolution is exactly [`TierMapping::MpInner`] on a 2-tier
/// chain: MP peers occupy consecutive nodes, DP replicas stride by `mp`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum TierMapping {
    /// MP innermost (the paper's SIII-B layout): MP peers fill the
    /// lowest tiers first, DP replicas stride across what remains.
    #[default]
    MpInner,
    /// DP innermost: data-parallel replicas fill the lowest tiers first,
    /// MP groups stride across the outer tiers (gradient exchange rides
    /// the fast tiers, activation exchange the slow ones).
    DpInner,
}

impl TierMapping {
    /// Canonical scenario-file name.
    pub fn name(self) -> &'static str {
        match self {
            TierMapping::MpInner => "mp-inner",
            TierMapping::DpInner => "dp-inner",
        }
    }

    /// Parse the scenario-file vocabulary.
    pub fn parse(s: &str) -> Result<TierMapping> {
        match s {
            "mp-inner" => Ok(TierMapping::MpInner),
            "dp-inner" => Ok(TierMapping::DpInner),
            other => Err(Error::Config(format!(
                "unknown tier mapping '{other}', want mp-inner | dp-inner"
            ))),
        }
    }

    /// Both mappings, in presentation order.
    pub const ALL: [TierMapping; 2] = [TierMapping::MpInner, TierMapping::DpInner];
}

/// Greedy bottom-up fill of a communication group of `total` peers onto
/// the remaining per-tier capacity `caps` (fan-out still unclaimed at
/// each tier). Inner tiers are bounded by capacity; the outermost tier
/// absorbs the remainder, mirroring the legacy two-level split where
/// `intra = total.min(pod)` and `inter = total / intra`.
pub(crate) fn tier_fill(
    total: usize,
    caps: &mut [usize; MAX_TIERS],
    k: usize,
) -> [usize; MAX_TIERS] {
    let mut out = [1usize; MAX_TIERS];
    let mut rem = total.max(1);
    for t in 0..k {
        let take = if t + 1 == k {
            rem
        } else {
            rem.min(caps[t].max(1))
        };
        out[t] = take.max(1);
        rem /= out[t];
        caps[t] = (caps[t] / out[t]).max(1);
    }
    out
}

/// A model/data/pipeline parallelism split. Invariant:
/// `mp * dp * pp == cluster size`; `pp == 1` is the paper's 2D lattice.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Strategy {
    /// Model-parallel degree (consecutive nodes share one model copy).
    pub mp: usize,
    /// Data-parallel degree (replicas of the MP group within a stage).
    pub dp: usize,
    /// Pipeline-parallel degree (contiguous layer stages; outermost
    /// dimension of the node layout). `1` = no pipeline parallelism.
    pub pp: usize,
}

impl Strategy {
    /// New 2D strategy (`pp = 1`); degrees must be >= 1.
    pub fn new(mp: usize, dp: usize) -> Result<Strategy> {
        Strategy::new_3d(mp, dp, 1)
    }

    /// New 3D strategy; all degrees must be >= 1.
    pub fn new_3d(mp: usize, dp: usize, pp: usize) -> Result<Strategy> {
        if mp == 0 || dp == 0 || pp == 0 {
            return Err(Error::Config(format!(
                "strategy degrees must be >= 1, got MP{mp}_DP{dp}_PP{pp}"
            )));
        }
        Ok(Strategy { mp, dp, pp })
    }

    /// Total nodes used.
    pub fn nodes(&self) -> usize {
        self.mp * self.dp * self.pp
    }

    /// The label convention: the paper's `MP8_DP128` on the 2D slice,
    /// `MP8_DP16_PP8` when pipeline-parallel. Every pre-3D label is
    /// unchanged by construction.
    pub fn label(&self) -> String {
        if self.pp == 1 {
            format!("MP{}_DP{}", self.mp, self.dp)
        } else {
            format!("MP{}_DP{}_PP{}", self.mp, self.dp, self.pp)
        }
    }

    /// Parse `MP8_DP128` (2D, `pp = 1`) or `MP8_DP16_PP8`. Zero degrees
    /// (`MP0_*`, `*_PP0`), trailing garbage, and non-digit degree fields
    /// are rejected.
    pub fn parse(s: &str) -> Result<Strategy> {
        let err = || {
            Error::Config(format!(
                "bad strategy '{s}', want MP<m>_DP<d>[_PP<p>]"
            ))
        };
        let digits = |t: &str| -> Result<usize> {
            if t.is_empty() || !t.bytes().all(|b| b.is_ascii_digit()) {
                return Err(err());
            }
            t.parse().map_err(|_| err())
        };
        let rest = s.strip_prefix("MP").ok_or_else(err)?;
        let (m, rest) = rest.split_once("_DP").ok_or_else(err)?;
        let (d, p) = match rest.split_once("_PP") {
            Some((d, p)) => (d, Some(p)),
            None => (rest, None),
        };
        let mp = digits(m)?;
        let dp = digits(d)?;
        let pp = match p {
            Some(p) => digits(p)?,
            None => 1,
        };
        if mp == 0 || dp == 0 || pp == 0 {
            return Err(err());
        }
        Ok(Strategy { mp, dp, pp })
    }

    /// All power-of-two 2D splits of a cluster of `n` nodes, from
    /// (MP=n, DP=1) down to (MP=1, DP=n) — the paper's SIII-B sweep
    /// order. Errors on a non-power-of-two cluster size.
    pub fn sweep(n: usize) -> Result<Vec<Strategy>> {
        if n == 0 || !n.is_power_of_two() {
            return Err(Error::Config(format!(
                "strategy sweep needs a power-of-two cluster size, got {n}"
            )));
        }
        let mut out = Vec::new();
        let mut mp = n;
        loop {
            out.push(Strategy {
                mp,
                dp: n / mp,
                pp: 1,
            });
            if mp == 1 {
                break;
            }
            mp /= 2;
        }
        Ok(out)
    }

    /// The 2D sweep restricted to `mp <= max_mp` (fig. 9 omits MP > 256)
    /// and `mp >= min_mp`.
    pub fn sweep_bounded(
        n: usize,
        min_mp: usize,
        max_mp: usize,
    ) -> Result<Vec<Strategy>> {
        Ok(Self::sweep(n)?
            .into_iter()
            .filter(|s| s.mp >= min_mp && s.mp <= max_mp)
            .collect())
    }

    /// All power-of-two 3D splits `mp * dp * pp == n` with
    /// `min_mp <= mp <= max_mp` and `pp <= max_pp`, ordered PP-ascending
    /// with the 2D sweep order inside each PP plane — so the `pp = 1`
    /// prefix is exactly [`Strategy::sweep_bounded`] and 3D lattice
    /// indices extend 2D ones.
    pub fn sweep_3d(
        n: usize,
        min_mp: usize,
        max_mp: usize,
        max_pp: usize,
    ) -> Result<Vec<Strategy>> {
        if n == 0 || !n.is_power_of_two() {
            return Err(Error::Config(format!(
                "strategy sweep needs a power-of-two cluster size, got {n}"
            )));
        }
        let mut out = Vec::new();
        let mut pp = 1usize;
        while pp <= max_pp.max(1) && pp <= n {
            for s in Self::sweep_bounded(n / pp, min_mp, max_mp)? {
                out.push(Strategy { pp, ..s });
            }
            pp *= 2;
        }
        Ok(out)
    }

    /// Two-level decomposition of the MP group on a podded topology:
    /// `(intra, inter)` — how many MP peers share a pod, and how many pods
    /// the group spans. MP groups occupy consecutive nodes (SIII-B).
    pub fn mp_two_level(&self, pod_size: usize) -> (usize, usize) {
        let intra = self.mp.min(pod_size);
        (intra, self.mp / intra)
    }

    /// Two-level decomposition of the DP group. DP peers are strided by
    /// `mp` within a pipeline stage: if an MP group fills (or exceeds) a
    /// pod, every DP peer lives in a different pod; otherwise
    /// `pod_size / mp` DP peers share a pod.
    pub fn dp_two_level(&self, pod_size: usize) -> (usize, usize) {
        let intra = (pod_size / self.mp).max(1).min(self.dp);
        (intra, self.dp / intra)
    }

    /// Whether the stage-boundary point-to-point link crosses pods:
    /// adjacent pipeline stages are `mp * dp` nodes apart, so the
    /// activation transfer rides the inter-pod fabric whenever a stage
    /// fills (or exceeds) a pod. Always `false` at `pp = 1` (there is no
    /// boundary).
    pub fn pp_crosses_pods(&self, pod_size: usize) -> bool {
        self.pp > 1 && self.mp * self.dp >= pod_size
    }

    /// Per-tier fan-out of the MP and DP groups on an N-tier chain with
    /// per-tier group sizes `groups[..k]`, under the given mapping:
    /// the inner axis fills the lowest tiers first, the outer axis
    /// strides across the remaining capacity. Returns
    /// `(mp_tiers, dp_tiers)`; products equal `mp` and `dp`. At
    /// `k = 2` with [`TierMapping::MpInner`] this reproduces
    /// [`Strategy::mp_two_level`] / [`Strategy::dp_two_level`] exactly.
    pub fn tier_split(
        &self,
        groups: &[usize; MAX_TIERS],
        k: usize,
        mapping: TierMapping,
    ) -> ([usize; MAX_TIERS], [usize; MAX_TIERS]) {
        let mut caps = *groups;
        match mapping {
            TierMapping::MpInner => {
                let m = tier_fill(self.mp, &mut caps, k);
                let d = tier_fill(self.dp, &mut caps, k);
                (m, d)
            }
            TierMapping::DpInner => {
                let d = tier_fill(self.dp, &mut caps, k);
                let m = tier_fill(self.mp, &mut caps, k);
                (m, d)
            }
        }
    }

    /// Outermost tier the stage-boundary point-to-point link rides:
    /// adjacent pipeline stages are `mp * dp` nodes apart, so the
    /// transfer crosses tier `t` whenever a stage fills everything below
    /// it. Tier 0 when `pp = 1` (no boundary) or the stage fits inside
    /// the innermost tier; at `k = 2` this is
    /// [`Strategy::pp_crosses_pods`] as a tier index.
    pub fn pp_boundary_tier(
        &self,
        groups: &[usize; MAX_TIERS],
        k: usize,
    ) -> usize {
        if self.pp <= 1 {
            return 0;
        }
        let stride = self.mp * self.dp;
        let mut tier = 0;
        let mut below = 1usize;
        for t in 1..k {
            below *= groups[t - 1];
            if stride >= below {
                tier = t;
            }
        }
        tier
    }
}

impl std::fmt::Display for Strategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_all_pow2_splits() {
        let s = Strategy::sweep(1024).unwrap();
        assert_eq!(s.len(), 11);
        assert_eq!(s[0], Strategy::new(1024, 1).unwrap());
        assert_eq!(s[10], Strategy::new(1, 1024).unwrap());
        for st in &s {
            assert_eq!(st.nodes(), 1024);
            assert_eq!(st.pp, 1);
        }
    }

    #[test]
    fn sweep_bounded_filters() {
        let s = Strategy::sweep_bounded(1024, 2, 256).unwrap();
        assert!(s.iter().all(|st| st.mp >= 2 && st.mp <= 256));
        assert_eq!(s.len(), 8);
    }

    #[test]
    fn non_pow2_and_zero_degrees_are_config_errors() {
        assert!(Strategy::sweep(1000).is_err());
        assert!(Strategy::sweep(0).is_err());
        assert!(Strategy::sweep_bounded(48, 1, 8).is_err());
        assert!(Strategy::sweep_3d(1000, 1, 8, 4).is_err());
        assert!(Strategy::new(0, 4).is_err());
        assert!(Strategy::new(4, 0).is_err());
        assert!(Strategy::new_3d(4, 4, 0).is_err());
        assert!(Strategy::new_3d(1, 1, 1).is_ok());
    }

    #[test]
    fn sweep_3d_extends_the_2d_sweep() {
        let flat = Strategy::sweep_bounded(64, 1, 64).unwrap();
        let cube = Strategy::sweep_3d(64, 1, 64, 4).unwrap();
        // The pp = 1 prefix is the 2D sweep verbatim.
        assert_eq!(&cube[..flat.len()], &flat[..]);
        for st in &cube {
            assert_eq!(st.nodes(), 64);
            assert!(st.pp <= 4);
        }
        // PP planes: 7 (pp=1) + 6 (pp=2) + 5 (pp=4) splits of 64.
        assert_eq!(cube.len(), 7 + 6 + 5);
        // max_pp = 1 degenerates to the 2D sweep.
        assert_eq!(Strategy::sweep_3d(64, 1, 64, 1).unwrap(), flat);
    }

    #[test]
    fn label_roundtrip() {
        for st in Strategy::sweep(64).unwrap() {
            assert_eq!(Strategy::parse(&st.label()).unwrap(), st);
        }
        for st in Strategy::sweep_3d(64, 1, 64, 8).unwrap() {
            assert_eq!(Strategy::parse(&st.label()).unwrap(), st);
        }
        assert!(Strategy::parse("MP0_DP4").is_err());
        assert!(Strategy::parse("DP4_MP2").is_err());
        assert!(Strategy::parse("MP8DP2").is_err());
        assert!(Strategy::parse("MP8_DP4_PP0").is_err());
        assert!(Strategy::parse("MP8_DP4_PP").is_err());
        assert!(Strategy::parse("MP8_DP4_PP2x").is_err());
        assert!(Strategy::parse("MP8_DP4x_PP2").is_err());
        assert!(Strategy::parse("MP+8_DP4").is_err());
        // 3D parse carries the PP degree; an explicit _PP1 is accepted
        // and canonicalizes to the 2D label.
        let s = Strategy::parse("MP8_DP16_PP8").unwrap();
        assert_eq!((s.mp, s.dp, s.pp), (8, 16, 8));
        assert_eq!(Strategy::parse("MP8_DP16_PP1").unwrap().label(), "MP8_DP16");
    }

    #[test]
    fn mp_two_level_respects_pods() {
        // MP8 in 8-GPU pods: fully intra-pod.
        assert_eq!(Strategy::new(8, 128).unwrap().mp_two_level(8), (8, 1));
        // MP64 in 8-GPU pods: 8 peers/pod x 8 pods.
        assert_eq!(Strategy::new(64, 16).unwrap().mp_two_level(8), (8, 8));
        // MP2: inside one pod.
        assert_eq!(Strategy::new(2, 512).unwrap().mp_two_level(8), (2, 1));
    }

    #[test]
    fn dp_two_level_strides() {
        // MP8 fills the pod: every DP peer in a different pod.
        assert_eq!(Strategy::new(8, 128).unwrap().dp_two_level(8), (1, 128));
        // MP2 in 8-GPU pods: 4 DP peers per pod, 128 pods.
        assert_eq!(Strategy::new(2, 512).unwrap().dp_two_level(8), (4, 128));
        // MP1024_DP1: degenerate DP.
        assert_eq!(Strategy::new(1024, 1).unwrap().dp_two_level(8), (1, 1));
    }

    #[test]
    fn two_level_products_match_degrees() {
        for pod in [4usize, 8, 16] {
            for st in Strategy::sweep_3d(256, 1, 256, 8).unwrap() {
                let (mi, mx) = st.mp_two_level(pod);
                assert_eq!(mi * mx, st.mp);
                let (di, dx) = st.dp_two_level(pod);
                assert_eq!(di * dx, st.dp);
            }
        }
    }

    #[test]
    fn pp_boundary_link_class() {
        // MP8_DP16_PP8: a stage spans 128 nodes >> an 8-GPU pod.
        assert!(Strategy::new_3d(8, 16, 8).unwrap().pp_crosses_pods(8));
        // MP2_DP2_PP4: a 4-node stage fits inside an 8-GPU pod.
        assert!(!Strategy::new_3d(2, 2, 4).unwrap().pp_crosses_pods(8));
        // No boundary at pp = 1.
        assert!(!Strategy::new(8, 128).unwrap().pp_crosses_pods(8));
    }

    #[test]
    fn tier_split_matches_two_level_on_two_tiers() {
        // MpInner on a 2-tier chain must reproduce the legacy two-level
        // splits for every strategy in the sweep.
        let groups = [8usize, 128, 1, 1];
        for st in Strategy::sweep(1024).unwrap() {
            let (m, d) = st.tier_split(&groups, 2, TierMapping::MpInner);
            assert_eq!((m[0], m[1]), st.mp_two_level(8), "{st}");
            assert_eq!((d[0], d[1]), st.dp_two_level(8), "{st}");
        }
    }

    #[test]
    fn tier_split_products_match_degrees() {
        let groups = [8usize, 4, 4, 2];
        for st in Strategy::sweep_3d(256, 1, 256, 4).unwrap() {
            for mapping in TierMapping::ALL {
                let (m, d) = st.tier_split(&groups, 4, mapping);
                assert_eq!(m.iter().product::<usize>(), st.mp, "{st}");
                assert_eq!(d.iter().product::<usize>(), st.dp, "{st}");
            }
        }
    }

    #[test]
    fn dp_inner_swaps_the_fill_order() {
        let groups = [8usize, 4, 2, 1];
        let st = Strategy::new(4, 16).unwrap();
        let (m, d) = st.tier_split(&groups, 3, TierMapping::MpInner);
        assert_eq!(&m[..3], &[4, 1, 1]);
        assert_eq!(&d[..3], &[2, 4, 2]);
        let (m, d) = st.tier_split(&groups, 3, TierMapping::DpInner);
        assert_eq!(&d[..3], &[8, 2, 1]);
        assert_eq!(&m[..3], &[1, 2, 2]);
    }

    #[test]
    fn pp_boundary_tier_generalizes_pod_crossing() {
        let groups = [8usize, 4, 2, 1];
        // Stage of 32 nodes fills tiers 0-1: boundary rides tier 2.
        assert_eq!(
            Strategy::new_3d(8, 4, 2).unwrap().pp_boundary_tier(&groups, 3),
            2
        );
        // Stage of 4 nodes fits inside the innermost tier.
        assert_eq!(
            Strategy::new_3d(2, 2, 16).unwrap().pp_boundary_tier(&groups, 3),
            0
        );
        // pp = 1: no boundary.
        assert_eq!(
            Strategy::new(8, 8).unwrap().pp_boundary_tier(&groups, 3),
            0
        );
        // k = 2 agrees with pp_crosses_pods for the whole 3D sweep.
        let two = [8usize, 8, 1, 1];
        for st in Strategy::sweep_3d(64, 1, 64, 8).unwrap() {
            let tier = st.pp_boundary_tier(&two, 2);
            assert_eq!(tier == 1, st.pp_crosses_pods(8), "{st}");
        }
    }
}
