//! The (MP, DP) parallelization strategy and its power-of-two sweep.

use crate::error::{Error, Result};

/// A model/data parallelism split. Invariant: `mp * dp == cluster size`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Strategy {
    /// Model-parallel degree (consecutive nodes share one model copy).
    pub mp: usize,
    /// Data-parallel degree (replicas of the MP group).
    pub dp: usize,
}

impl Strategy {
    /// New strategy; degrees must be >= 1.
    pub fn new(mp: usize, dp: usize) -> Strategy {
        assert!(mp >= 1 && dp >= 1, "degrees must be >= 1");
        Strategy { mp, dp }
    }

    /// Total nodes used.
    pub fn nodes(&self) -> usize {
        self.mp * self.dp
    }

    /// The paper's label convention, e.g. "MP8_DP128".
    pub fn label(&self) -> String {
        format!("MP{}_DP{}", self.mp, self.dp)
    }

    /// Parse "MP8_DP128".
    pub fn parse(s: &str) -> Result<Strategy> {
        let err = || Error::Config(format!("bad strategy '{s}', want MP<m>_DP<d>"));
        let rest = s.strip_prefix("MP").ok_or_else(err)?;
        let (m, d) = rest.split_once("_DP").ok_or_else(err)?;
        let mp = m.parse().map_err(|_| err())?;
        let dp = d.parse().map_err(|_| err())?;
        if mp == 0 || dp == 0 {
            return Err(err());
        }
        Ok(Strategy { mp, dp })
    }

    /// All power-of-two splits of a cluster of `n` nodes, from
    /// (MP=n, DP=1) down to (MP=1, DP=n) — the paper's SIII-B sweep order.
    pub fn sweep(n: usize) -> Vec<Strategy> {
        assert!(n.is_power_of_two(), "cluster size must be a power of two");
        let mut out = Vec::new();
        let mut mp = n;
        loop {
            out.push(Strategy { mp, dp: n / mp });
            if mp == 1 {
                break;
            }
            mp /= 2;
        }
        out
    }

    /// The sweep restricted to `mp <= max_mp` (fig. 9 omits MP > 256) and
    /// `mp >= min_mp`.
    pub fn sweep_bounded(n: usize, min_mp: usize, max_mp: usize) -> Vec<Strategy> {
        Self::sweep(n)
            .into_iter()
            .filter(|s| s.mp >= min_mp && s.mp <= max_mp)
            .collect()
    }

    /// Two-level decomposition of the MP group on a podded topology:
    /// `(intra, inter)` — how many MP peers share a pod, and how many pods
    /// the group spans. MP groups occupy consecutive nodes (SIII-B).
    pub fn mp_two_level(&self, pod_size: usize) -> (usize, usize) {
        let intra = self.mp.min(pod_size);
        (intra, self.mp / intra)
    }

    /// Two-level decomposition of the DP group. DP peers are strided by
    /// `mp`: if an MP group fills (or exceeds) a pod, every DP peer lives
    /// in a different pod; otherwise `pod_size / mp` DP peers share a pod.
    pub fn dp_two_level(&self, pod_size: usize) -> (usize, usize) {
        let intra = (pod_size / self.mp).max(1).min(self.dp);
        (intra, self.dp / intra)
    }
}

impl std::fmt::Display for Strategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_all_pow2_splits() {
        let s = Strategy::sweep(1024);
        assert_eq!(s.len(), 11);
        assert_eq!(s[0], Strategy::new(1024, 1));
        assert_eq!(s[10], Strategy::new(1, 1024));
        for st in &s {
            assert_eq!(st.nodes(), 1024);
        }
    }

    #[test]
    fn sweep_bounded_filters() {
        let s = Strategy::sweep_bounded(1024, 2, 256);
        assert!(s.iter().all(|st| st.mp >= 2 && st.mp <= 256));
        assert_eq!(s.len(), 8);
    }

    #[test]
    fn label_roundtrip() {
        for st in Strategy::sweep(64) {
            assert_eq!(Strategy::parse(&st.label()).unwrap(), st);
        }
        assert!(Strategy::parse("MP0_DP4").is_err());
        assert!(Strategy::parse("DP4_MP2").is_err());
        assert!(Strategy::parse("MP8DP2").is_err());
    }

    #[test]
    fn mp_two_level_respects_pods() {
        // MP8 in 8-GPU pods: fully intra-pod.
        assert_eq!(Strategy::new(8, 128).mp_two_level(8), (8, 1));
        // MP64 in 8-GPU pods: 8 peers/pod x 8 pods.
        assert_eq!(Strategy::new(64, 16).mp_two_level(8), (8, 8));
        // MP2: inside one pod.
        assert_eq!(Strategy::new(2, 512).mp_two_level(8), (2, 1));
    }

    #[test]
    fn dp_two_level_strides() {
        // MP8 fills the pod: every DP peer in a different pod.
        assert_eq!(Strategy::new(8, 128).dp_two_level(8), (1, 128));
        // MP2 in 8-GPU pods: 4 DP peers per pod, 128 pods.
        assert_eq!(Strategy::new(2, 512).dp_two_level(8), (4, 128));
        // MP1024_DP1: degenerate DP.
        assert_eq!(Strategy::new(1024, 1).dp_two_level(8), (1, 1));
    }

    #[test]
    fn two_level_products_match_degrees() {
        for pod in [4usize, 8, 16] {
            for st in Strategy::sweep(256) {
                let (mi, mx) = st.mp_two_level(pod);
                assert_eq!(mi * mx, st.mp);
                let (di, dx) = st.dp_two_level(pod);
                assert_eq!(di * dx, st.dp);
            }
        }
    }
}
