//! ZeRO-DP model-state memory model (paper SIV-B, Fig. 6; Rajbhandari et
//! al.'s ZeRO paper).
//!
//! Mixed-precision Adam training keeps, per parameter:
//!   * 2 B fp16 parameters
//!   * 2 B fp16 gradients
//!   * 12 B fp32 optimizer state (master params + momentum + variance)
//!
//! MP shards all three by `1/MP`. ZeRO additionally partitions across DP:
//!   * stage 0 (baseline): nothing partitioned
//!   * stage 1 (os):       optimizer state / DP
//!   * stage 2 (os+g):     + gradients / DP      (the paper's default)
//!   * stage 3 (os+g+p):   + parameters / DP

/// Bytes per parameter of fp16 parameters.
pub const PARAM_BYTES: f64 = 2.0;
/// Bytes per parameter of fp16 gradients.
pub const GRAD_BYTES: f64 = 2.0;
/// Bytes per parameter of fp32 optimizer state (master + momentum +
/// variance).
pub const OPTIM_BYTES: f64 = 12.0;

/// ZeRO-DP optimization stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ZeroStage {
    /// No ZeRO: every node replicates all model states of its MP shard.
    Baseline,
    /// ZeRO-1: optimizer states partitioned across DP.
    Os,
    /// ZeRO-2: optimizer states + gradients partitioned (paper default).
    OsG,
    /// ZeRO-3: optimizer states + gradients + parameters partitioned.
    OsGP,
}

impl ZeroStage {
    /// All stages in Fig. 6 order.
    pub const ALL: [ZeroStage; 4] =
        [ZeroStage::Baseline, ZeroStage::Os, ZeroStage::OsG, ZeroStage::OsGP];

    /// Figure label.
    pub fn label(&self) -> &'static str {
        match self {
            ZeroStage::Baseline => "baseline",
            ZeroStage::Os => "zero-1",
            ZeroStage::OsG => "zero-2",
            ZeroStage::OsGP => "zero-3",
        }
    }

    /// Relative collective-communication volume vs baseline DP training
    /// (ZeRO paper: stages 1-2 match baseline; stage 3 is 1.5x).
    pub fn comm_multiplier(&self) -> f64 {
        match self {
            ZeroStage::OsGP => 1.5,
            _ => 1.0,
        }
    }
}

/// Per-node model-state bytes for a model of `total_params` parameters
/// trained at (mp, dp) under a ZeRO stage.
pub fn model_state_bytes(
    total_params: f64,
    mp: usize,
    dp: usize,
    stage: ZeroStage,
) -> f64 {
    let shard = total_params / mp as f64;
    let dp = dp as f64;
    let (p, g, o) = match stage {
        ZeroStage::Baseline => (1.0, 1.0, 1.0),
        ZeroStage::Os => (1.0, 1.0, 1.0 / dp),
        ZeroStage::OsG => (1.0, 1.0 / dp, 1.0 / dp),
        ZeroStage::OsGP => (1.0 / dp, 1.0 / dp, 1.0 / dp),
    };
    shard * (PARAM_BYTES * p + GRAD_BYTES * g + OPTIM_BYTES * o)
}

#[cfg(test)]
mod tests {
    use super::*;

    const PSI: f64 = 1e12; // Transformer-1T

    #[test]
    fn baseline_is_16_bytes_per_param() {
        assert_eq!(model_state_bytes(PSI, 1, 1, ZeroStage::Baseline), 16e12);
    }

    #[test]
    fn stages_monotonically_shrink() {
        let b = |s| model_state_bytes(PSI, 8, 128, s);
        assert!(b(ZeroStage::Baseline) > b(ZeroStage::Os));
        assert!(b(ZeroStage::Os) > b(ZeroStage::OsG));
        assert!(b(ZeroStage::OsG) > b(ZeroStage::OsGP));
    }

    #[test]
    fn zero2_matches_paper_formula() {
        // ZeRO-2: 2 psi/mp + 14 psi/(mp dp).
        let got = model_state_bytes(PSI, 8, 128, ZeroStage::OsG);
        let want = 2.0 * PSI / 8.0 + 14.0 * PSI / (8.0 * 128.0);
        assert!((got - want).abs() < 1.0);
        // ~263.7 GB at MP8_DP128 — the paper's "~250 GB" Fig. 8a bar.
        assert!((got - 263.67e9).abs() < 0.5e9, "{got:.4e}");
    }

    #[test]
    fn zero3_invariant_to_mp_dp_split() {
        // Fig. 6: ZeRO-3 footprint is flat as MP falls (16 psi / N).
        let n = 1024usize;
        let mut vals = Vec::new();
        let mut mp = n;
        while mp >= 1 {
            vals.push(model_state_bytes(PSI, mp, n / mp, ZeroStage::OsGP));
            mp /= 2;
        }
        for v in &vals {
            assert!((v - vals[0]).abs() < 1.0);
        }
        assert!((vals[0] - 16.0 * PSI / 1024.0).abs() < 1.0);
    }

    #[test]
    fn baseline_grows_exponentially_as_mp_falls() {
        // Fig. 6's baseline curve: halving MP doubles the footprint.
        let n = 1024usize;
        let b = |mp: usize| {
            model_state_bytes(PSI, mp, n / mp, ZeroStage::Baseline)
        };
        assert!((b(64) / b(128) - 2.0).abs() < 1e-12);
        assert!((b(1) / b(1024) - 1024.0).abs() < 1e-9);
    }

    #[test]
    fn zero3_comm_overhead() {
        assert_eq!(ZeroStage::OsGP.comm_multiplier(), 1.5);
        assert_eq!(ZeroStage::OsG.comm_multiplier(), 1.0);
    }
}
