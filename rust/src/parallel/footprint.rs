//! Total per-node memory footprint (paper SIII-B + SIV-B): model states
//! under ZeRO, residual states (fp16 activation parameters), and the
//! activation working memory between two checkpoints (ZeRO-Infinity's AWM;
//! checkpoint activations themselves are host-offloaded and excluded).

use super::strategy::Strategy;
use super::zero::{model_state_bytes, ZeroStage};
use crate::workload::{LayerOp, Workload, FP16};

/// Per-node footprint decomposition, bytes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FootprintBreakdown {
    /// Parameters + gradients + optimizer state under the ZeRO stage.
    pub model_states: f64,
    /// Residual states: fp16 activation parameters of the MP shard.
    pub residual: f64,
    /// Activation working memory (largest inter-checkpoint activation).
    pub awm: f64,
}

impl FootprintBreakdown {
    /// Total bytes.
    pub fn total(&self) -> f64 {
        self.model_states + self.residual + self.awm
    }
}

/// Footprint for a decomposed workload on its (MP, DP) strategy.
///
/// `workload` must have been built for `strategy` (its layer shards are
/// already per-node); `stage` selects the ZeRO optimization.
pub fn footprint_per_node(
    workload: &Workload,
    strategy: &Strategy,
    stage: ZeroStage,
) -> FootprintBreakdown {
    let model_states = model_state_bytes(
        workload.total_params,
        strategy.mp,
        strategy.dp,
        stage,
    );

    FootprintBreakdown {
        model_states,
        residual: residual_state_bytes(workload),
        awm: activation_working_bytes(workload),
    }
}

/// Residual-state bytes of a workload: fp16 activation parameters held for
/// backward after checkpointing. Workload-only (no cluster, no ZeRO stage),
/// so the two-stage derive precomputes it once per decomposition.
pub fn residual_state_bytes(workload: &Workload) -> f64 {
    // Residual states: activations produced per layer instance held for
    // backward (fp16). Attention scores and embeddings included via
    // activation_elems.
    workload
        .layers
        .iter()
        .map(|l| {
            // Weight-update is bookkeeping, not an activation producer.
            if matches!(l.op, LayerOp::WeightUpdate { .. }) {
                0.0
            } else {
                l.activation_elems() * FP16
            }
        })
        .sum::<f64>()
        * checkpoint_fraction(workload)
}

/// Activation-working-memory bytes (ZeRO-Infinity's AWM): the largest
/// single inter-checkpoint activation, fp16. Workload-only, like
/// [`residual_state_bytes`].
pub fn activation_working_bytes(workload: &Workload) -> f64 {
    workload.activation_working_elems() * FP16
}

/// Fraction of activations held after checkpointing: one stack boundary per
/// repeat group (sqrt-style selective recomputation; checkpoints offloaded
/// to host per SIV-B, so only a thin margin of residual state stays).
fn checkpoint_fraction(w: &Workload) -> f64 {
    let max_repeat = w.layers.iter().map(|l| l.repeat).fold(1.0, f64::max);
    (1.0 / max_repeat).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::transformer::Transformer;

    #[test]
    fn fig3_footprint_doubles_when_dp_doubles() {
        // Paper SIII-B: moving (DP=2, MP=m) -> (DP=4, MP=m/2) doubles the
        // per-node requirement.
        let t = Transformer::t1();
        let f = |mp: usize, dp: usize| {
            let s = Strategy::new(mp, dp);
            let w = t.build(&s).unwrap();
            footprint_per_node(&w, &s, ZeroStage::Baseline).model_states
        };
        let r = f(64, 16) / f(128, 8);
        assert!((r - 2.0).abs() < 1e-9, "{r}");
    }

    #[test]
    fn mp8_dp128_needs_memory_expansion() {
        // Fig. 8a: MP8_DP128 needs ~250+ GB, over 3x the A100's 80 GB.
        let t = Transformer::t1();
        let s = Strategy::new(8, 128);
        let w = t.build(&s).unwrap();
        let fp = footprint_per_node(&w, &s, ZeroStage::OsG);
        assert!(fp.total() > 3.0 * 80e9, "{:.3e}", fp.total());
        assert!(fp.total() < 6.0 * 80e9, "{:.3e}", fp.total());
    }

    #[test]
    fn mp64_dp16_fits_in_80gb() {
        // Fig. 8a: MP64 is the first in-memory-feasible configuration.
        let t = Transformer::t1();
        let s = Strategy::new(64, 16);
        let w = t.build(&s).unwrap();
        let fp = footprint_per_node(&w, &s, ZeroStage::OsG);
        assert!(fp.total() <= 80e9, "{:.4e}", fp.total());
    }

    #[test]
    fn awm_positive_and_below_model_states_at_scale() {
        let t = Transformer::t1();
        let s = Strategy::new(8, 128);
        let w = t.build(&s).unwrap();
        let fp = footprint_per_node(&w, &s, ZeroStage::OsG);
        assert!(fp.awm > 0.0);
        assert!(fp.awm < fp.model_states);
    }

    #[test]
    fn total_sums_components() {
        let fp = FootprintBreakdown {
            model_states: 1.0,
            residual: 2.0,
            awm: 3.0,
        };
        assert_eq!(fp.total(), 6.0);
    }
}
