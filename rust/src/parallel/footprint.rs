//! Total per-node memory footprint (paper SIII-B + SIV-B): model states
//! under ZeRO, residual states (fp16 activation parameters), and the
//! activation working memory between two checkpoints (ZeRO-Infinity's AWM;
//! checkpoint activations themselves are host-offloaded and excluded).

use super::pipeline::PipeSchedule;
use super::strategy::Strategy;
use super::zero::{model_state_bytes, ZeroStage};
use crate::workload::{LayerOp, StageSlice, Workload, FP16};

/// Per-node footprint decomposition, bytes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FootprintBreakdown {
    /// Parameters + gradients + optimizer state under the ZeRO stage.
    pub model_states: f64,
    /// Residual states: fp16 activation parameters of the MP shard.
    pub residual: f64,
    /// Activation working memory (largest inter-checkpoint activation).
    pub awm: f64,
}

impl FootprintBreakdown {
    /// Total bytes.
    pub fn total(&self) -> f64 {
        self.model_states + self.residual + self.awm
    }
}

/// Footprint for a decomposed workload on its (MP, DP) strategy.
///
/// `workload` must have been built for `strategy` (its layer shards are
/// already per-node); `stage` selects the ZeRO optimization. This is the
/// `pp = 1` oracle — it treats the whole layer list as one pipeline
/// stage; pipeline workloads use [`pipeline_footprint_per_node`], whose
/// `pp = 1` value is identical by construction.
pub fn footprint_per_node(
    workload: &Workload,
    strategy: &Strategy,
    stage: ZeroStage,
) -> FootprintBreakdown {
    let model_states = model_state_bytes(
        workload.total_params,
        strategy.mp,
        strategy.dp,
        stage,
    );

    FootprintBreakdown {
        model_states,
        residual: residual_state_bytes(workload),
        awm: activation_working_bytes(workload),
    }
}

/// Residual-state bytes of a workload: fp16 activation parameters held for
/// backward after checkpointing. Workload-only (no cluster, no ZeRO stage),
/// so the two-stage derive precomputes it once per decomposition.
pub fn residual_state_bytes(workload: &Workload) -> f64 {
    // Residual states: activations produced per layer instance held for
    // backward (fp16). Attention scores and embeddings included via
    // activation_elems.
    workload
        .layers
        .iter()
        .map(|l| {
            // Weight-update is bookkeeping, not an activation producer.
            if matches!(l.op, LayerOp::WeightUpdate { .. }) {
                0.0
            } else {
                l.activation_elems() * FP16
            }
        })
        .sum::<f64>()
        * checkpoint_fraction(workload)
}

/// Activation-working-memory bytes (ZeRO-Infinity's AWM): the largest
/// single inter-checkpoint activation, fp16. Workload-only, like
/// [`residual_state_bytes`].
pub fn activation_working_bytes(workload: &Workload) -> f64 {
    workload.activation_working_elems() * FP16
}

/// Fraction of activations held after checkpointing: one stack boundary per
/// repeat group (sqrt-style selective recomputation; checkpoints offloaded
/// to host per SIV-B, so only a thin margin of residual state stays).
fn checkpoint_fraction(w: &Workload) -> f64 {
    let max_repeat = w.layers.iter().map(|l| l.repeat).fold(1.0, f64::max);
    (1.0 / max_repeat).min(1.0)
}

/// Per-stage `(residual, awm)` byte terms for a pipeline partition of
/// `w` (see [`Workload::stage_partition`]): each stage's residual share
/// is its slices' activation bytes weighted by the fraction of the
/// layer's repeats it holds (so the per-stage terms sum to the
/// whole-workload [`residual_state_bytes`]), and its AWM is the largest
/// single activation among its slices. At `pp = 1` the single stage's
/// terms equal the whole-workload values bit-for-bit.
pub fn stage_footprint_terms(
    w: &Workload,
    stages: &[Vec<StageSlice>],
) -> (Vec<f64>, Vec<f64>) {
    let frac = checkpoint_fraction(w);
    let mut residual = Vec::with_capacity(stages.len());
    let mut awm = Vec::with_capacity(stages.len());
    for slices in stages {
        let mut res = 0.0f64;
        let mut peak = 0.0f64;
        for sl in slices {
            let l = &w.layers[sl.layer];
            if matches!(l.op, LayerOp::WeightUpdate { .. }) {
                continue;
            }
            let bytes = l.activation_elems() * FP16;
            let share = if l.repeat > 0.0 { sl.repeat / l.repeat } else { 1.0 };
            res += bytes * share;
            peak = peak.max(bytes);
        }
        residual.push(res * frac);
        awm.push(peak);
    }
    (residual, awm)
}

/// Worst-stage pipeline footprint from precomputed per-stage terms:
/// `max_s(model_shard + residual[s] * held + awm[s] / m)` with
/// `held = in_flight(pp, m) / m`. The single formula behind both
/// [`pipeline_footprint_per_node`] (workload side) and
/// [`crate::model::inputs::WorkloadDecomposition::footprint`] (cached
/// decomposition side) — one implementation, so the optimizer's
/// capacity pruning and sweep-time EM sizing cannot drift.
pub fn pipeline_stage_footprint(
    model_shard: f64,
    residual: &[f64],
    awm: &[f64],
    sched: PipeSchedule,
    pp: usize,
    microbatches: usize,
) -> f64 {
    let m = microbatches.max(1);
    let mf = m as f64;
    let held = sched.in_flight(pp, m) as f64 / mf;
    residual
        .iter()
        .zip(awm)
        .map(|(r, a)| model_shard + r * held + a / mf)
        .fold(0.0, f64::max)
}

/// Pipeline-aware per-node footprint: the worst stage's model states
/// (the MP shard further divided across `pp` stages), residual
/// activations held under the schedule (`in_flight / m` of the
/// full-batch residual), and the per-microbatch activation working
/// memory. At `pp = 1` this is exactly
/// `footprint_per_node(w, .., stage).total()` — pipeline terms collapse
/// to the 2D formula.
pub fn pipeline_footprint_per_node(
    w: &Workload,
    stage: ZeroStage,
    sched: PipeSchedule,
    microbatches: usize,
) -> f64 {
    if w.pp <= 1 {
        let s = Strategy {
            mp: w.mp,
            dp: w.dp,
            pp: 1,
        };
        return footprint_per_node(w, &s, stage).total();
    }
    let stages = w.stage_partition();
    let (residual, awm) = stage_footprint_terms(w, &stages);
    let model =
        model_state_bytes(w.total_params, w.mp, w.dp, stage) / w.pp as f64;
    pipeline_stage_footprint(model, &residual, &awm, sched, w.pp, microbatches)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::transformer::Transformer;

    #[test]
    fn fig3_footprint_doubles_when_dp_doubles() {
        // Paper SIII-B: moving (DP=2, MP=m) -> (DP=4, MP=m/2) doubles the
        // per-node requirement.
        let t = Transformer::t1();
        let f = |mp: usize, dp: usize| {
            let s = Strategy::new(mp, dp).unwrap();
            let w = t.build(&s).unwrap();
            footprint_per_node(&w, &s, ZeroStage::Baseline).model_states
        };
        let r = f(64, 16) / f(128, 8);
        assert!((r - 2.0).abs() < 1e-9, "{r}");
    }

    #[test]
    fn mp8_dp128_needs_memory_expansion() {
        // Fig. 8a: MP8_DP128 needs ~250+ GB, over 3x the A100's 80 GB.
        let t = Transformer::t1();
        let s = Strategy::new(8, 128).unwrap();
        let w = t.build(&s).unwrap();
        let fp = footprint_per_node(&w, &s, ZeroStage::OsG);
        assert!(fp.total() > 3.0 * 80e9, "{:.3e}", fp.total());
        assert!(fp.total() < 6.0 * 80e9, "{:.3e}", fp.total());
    }

    #[test]
    fn mp64_dp16_fits_in_80gb() {
        // Fig. 8a: MP64 is the first in-memory-feasible configuration.
        let t = Transformer::t1();
        let s = Strategy::new(64, 16).unwrap();
        let w = t.build(&s).unwrap();
        let fp = footprint_per_node(&w, &s, ZeroStage::OsG);
        assert!(fp.total() <= 80e9, "{:.4e}", fp.total());
    }

    #[test]
    fn awm_positive_and_below_model_states_at_scale() {
        let t = Transformer::t1();
        let s = Strategy::new(8, 128).unwrap();
        let w = t.build(&s).unwrap();
        let fp = footprint_per_node(&w, &s, ZeroStage::OsG);
        assert!(fp.awm > 0.0);
        assert!(fp.awm < fp.model_states);
    }

    #[test]
    fn pipeline_footprint_collapses_to_2d_at_pp1() {
        let t = Transformer::t1();
        let s = Strategy::new(8, 128).unwrap();
        let w = t.build(&s).unwrap();
        for stage in ZeroStage::ALL {
            let flat = footprint_per_node(&w, &s, stage).total();
            for sched in PipeSchedule::ALL {
                for m in [1usize, 8, 64] {
                    let pipe = pipeline_footprint_per_node(&w, stage, sched, m);
                    assert_eq!(pipe.to_bits(), flat.to_bits());
                }
            }
        }
    }

    #[test]
    fn pipeline_parallelism_shrinks_the_footprint() {
        // MP8_DP128 spills a 80 GB node by >3x; MP8_DP16_PP8 holds a
        // 1/64th model shard per node and fits comfortably.
        let t = Transformer::t1();
        let flat = {
            let s = Strategy::new(8, 128).unwrap();
            let w = t.build(&s).unwrap();
            footprint_per_node(&w, &s, ZeroStage::OsG).total()
        };
        let piped = {
            let s = Strategy::new_3d(8, 16, 8).unwrap();
            let w = t.build(&s).unwrap();
            pipeline_footprint_per_node(
                &w,
                ZeroStage::OsG,
                PipeSchedule::OneFOneB,
                8,
            )
        };
        assert!(flat > 3.0 * 80e9, "{flat:.3e}");
        assert!(piped < 80e9, "{piped:.3e}");
    }

    #[test]
    fn one_f_one_b_holds_no_more_than_gpipe() {
        let t = Transformer::t1();
        let s = Strategy::new_3d(8, 16, 8).unwrap();
        let w = t.build(&s).unwrap();
        for m in [8usize, 16, 64] {
            let g = pipeline_footprint_per_node(
                &w,
                ZeroStage::OsG,
                PipeSchedule::GPipe,
                m,
            );
            let o = pipeline_footprint_per_node(
                &w,
                ZeroStage::OsG,
                PipeSchedule::OneFOneB,
                m,
            );
            assert!(o <= g, "m={m}: 1f1b {o} > gpipe {g}");
        }
    }

    #[test]
    fn stage_terms_sum_to_whole_workload_residual() {
        let t = Transformer::t1();
        let w = t.build(&Strategy::new_3d(8, 32, 4).unwrap()).unwrap();
        let stages = w.stage_partition();
        let (residual, awm) = stage_footprint_terms(&w, &stages);
        assert_eq!(residual.len(), 4);
        let total: f64 = residual.iter().sum();
        let want = residual_state_bytes(&w);
        assert!(
            (total - want).abs() < 1e-6 * want,
            "stage residuals {total} vs whole {want}"
        );
        // Every stage's AWM is bounded by the whole-workload AWM.
        let peak = activation_working_bytes(&w);
        for a in &awm {
            assert!(*a <= peak);
        }
    }

    #[test]
    fn total_sums_components() {
        let fp = FootprintBreakdown {
            model_states: 1.0,
            residual: 2.0,
            awm: 3.0,
        };
        assert_eq!(fp.total(), 6.0);
    }
}
