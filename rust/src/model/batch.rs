//! Artifact ABI: f32 tensor packing for the AOT-compiled batched evaluator.
//!
//! Mirrors `python/compile/kernels/layout.py` exactly; the manifest check
//! ([`verify_manifest`]) refuses to run against artifacts exported with a
//! different layout.

use crate::error::{Error, Result};
use crate::util::json::Value;

use super::inputs::ModelInputs;

/// Layer slots per config (padded).
pub const L: usize = 192;
/// Compute-tensor fields.
pub const CF: usize = 13;
/// Comm-tensor fields.
pub const MF: usize = 13;
/// Params-tensor fields.
pub const P: usize = 12;
/// Output fields.
pub const OUTF: usize = 6;
/// Batch sizes with exported artifacts.
pub const BATCH_SIZES: [usize; 2] = [8, 64];

// compute fields
const C_REPEAT: usize = 12;
// comm fields
const M_REPEAT: usize = 12;
// params fields
const P_PERF_PEAK: usize = 0;
const P_BW_LM: usize = 1;
const P_BW_EM: usize = 2;
const P_CAP_LM: usize = 3;
const P_SRAM: usize = 4;
const P_FOOTPRINT: usize = 5;
const P_BW_INTRA: usize = 6;
const P_BW_INTER: usize = 7;
const P_LINK_LAT: usize = 8;
const P_OVERLAP_WG: usize = 9;
const P_EM_FRAC: usize = 10;
const P_COLL_IMPL: usize = 11;

/// One packed configuration, ready to be stacked into a batch.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedConfig {
    /// `L x CF` row-major.
    pub compute: Vec<f32>,
    /// `L x MF` row-major.
    pub comm: Vec<f32>,
    /// `P` values.
    pub params: Vec<f32>,
}

/// Pack derived model inputs into the artifact ABI.
///
/// The ABI predates the 3D strategy lattice and has no stage/pipeline
/// fields; pipeline-parallel inputs are rejected loudly rather than
/// silently evaluated as if their stages were one flat layer list.
pub fn pack(inputs: &ModelInputs) -> Result<PackedConfig> {
    if inputs.params.pp > 1 {
        return Err(Error::AbiMismatch(format!(
            "{}: pipeline-parallel inputs (pp = {}) are not representable \
             in the artifact ABI; use the native or DES backend",
            inputs.name, inputs.params.pp
        )));
    }
    if inputs.layers.len() > L {
        return Err(Error::AbiMismatch(format!(
            "{} layers exceed the artifact's {} slots",
            inputs.layers.len(),
            L
        )));
    }
    let mut compute = vec![0.0f32; L * CF];
    let mut comm = vec![0.0f32; L * MF];
    for (i, layer) in inputs.layers.iter().enumerate() {
        let c = &mut compute[i * CF..(i + 1) * CF];
        let m = &mut comm[i * MF..(i + 1) * MF];
        for phase in 0..3 {
            let q = &layer.q[phase];
            c[phase * 4] = q.flops as f32;
            c[phase * 4 + 1] = q.u as f32;
            c[phase * 4 + 2] = q.v as f32;
            c[phase * 4 + 3] = q.w as f32;
            let s = &layer.comm[phase];
            m[phase * 4] = s.bytes as f32;
            m[phase * 4 + 1] = s.collective.code() as f32;
            m[phase * 4 + 2] = s.n_intra as f32;
            m[phase * 4 + 3] = s.n_inter as f32;
        }
        c[C_REPEAT] = layer.repeat as f32;
        m[M_REPEAT] = layer.repeat as f32;
    }

    let p = &inputs.params;
    let mut params = vec![0.0f32; P];
    params[P_PERF_PEAK] = p.perf_peak as f32;
    params[P_BW_LM] = p.bw_lm as f32;
    params[P_BW_EM] = p.bw_em as f32;
    params[P_CAP_LM] = p.cap_lm as f32;
    params[P_SRAM] = p.sram as f32;
    params[P_FOOTPRINT] = p.footprint as f32;
    params[P_BW_INTRA] = p.bw_intra as f32;
    params[P_BW_INTER] = p.bw_inter as f32;
    params[P_LINK_LAT] = p.link_latency as f32;
    params[P_OVERLAP_WG] = if p.overlap_wg { 1.0 } else { 0.0 };
    params[P_EM_FRAC] = p.em_frac_override.map(|f| f as f32).unwrap_or(-1.0);
    params[P_COLL_IMPL] = p.collective_impl.code() as f32;

    Ok(PackedConfig {
        compute,
        comm,
        params,
    })
}

/// Stack packed configs into batch tensors, padding the tail by replicating
/// an all-zero config (zero layers produce zero output, harmlessly).
pub fn stack(batch: &[PackedConfig], b: usize) -> Result<BatchTensors> {
    let mut out = BatchTensors {
        b,
        compute: Vec::new(),
        comm: Vec::new(),
        params: Vec::new(),
        n_real: 0,
    };
    stack_into(batch, b, &mut out)?;
    Ok(out)
}

/// Like [`stack`], but reuses the allocations of an existing
/// [`BatchTensors`] (SPerf: avoids re-faulting ~1.3 MB of fresh pages per
/// batch on the artifact hot path).
pub fn stack_into(
    batch: &[PackedConfig],
    b: usize,
    out: &mut BatchTensors,
) -> Result<()> {
    if batch.len() > b {
        return Err(Error::AbiMismatch(format!(
            "{} configs exceed batch size {b}",
            batch.len()
        )));
    }
    out.b = b;
    out.n_real = batch.len();
    out.compute.clear();
    out.comm.clear();
    out.params.clear();
    // No-ops when the scratch buffers are already warm.
    out.compute.reserve(b * L * CF);
    out.comm.reserve(b * L * MF);
    out.params.reserve(b * P);
    for cfg in batch {
        out.compute.extend_from_slice(&cfg.compute);
        out.comm.extend_from_slice(&cfg.comm);
        out.params.extend_from_slice(&cfg.params);
    }
    // Padded configs keep all-zero params; guard divisions exist in the
    // kernels, so outputs for those rows are zero and discarded.
    out.compute.resize(b * L * CF, 0.0);
    out.comm.resize(b * L * MF, 0.0);
    out.params.resize(b * P, 0.0);
    Ok(())
}

/// Stacked batch tensors matching one artifact's input shapes.
#[derive(Debug, Clone)]
pub struct BatchTensors {
    /// Batch size the tensors are padded to.
    pub b: usize,
    /// `b x L x CF` compute tensor, row-major.
    pub compute: Vec<f32>,
    /// `b x L x MF` comm tensor, row-major.
    pub comm: Vec<f32>,
    /// `b x P` params tensor, row-major.
    pub params: Vec<f32>,
    /// Real (unpadded) configurations in the batch.
    pub n_real: usize,
}

/// Verify `artifacts/manifest.json` matches this crate's compiled-in layout.
pub fn verify_manifest(manifest: &Value) -> Result<()> {
    let check = |key: &str, want: usize| -> Result<()> {
        let got = manifest
            .get(key)
            .and_then(|v| v.as_usize())
            .ok_or_else(|| Error::AbiMismatch(format!("manifest missing '{key}'")))?;
        if got != want {
            return Err(Error::AbiMismatch(format!(
                "manifest {key} = {got}, crate expects {want}"
            )));
        }
        Ok(())
    };
    check("l", L)?;
    check("cf", CF)?;
    check("mf", MF)?;
    check("p", P)?;
    check("outf", OUTF)?;
    let arts = manifest
        .get("artifacts")
        .ok_or_else(|| Error::AbiMismatch("manifest missing 'artifacts'".into()))?;
    for b in BATCH_SIZES {
        if arts.get(&b.to_string()).and_then(|v| v.as_str()).is_none() {
            return Err(Error::AbiMismatch(format!(
                "manifest missing artifact for batch size {b}"
            )));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::model::inputs::{derive_inputs, EvalOptions};
    use crate::parallel::Strategy;
    use crate::util::json;
    use crate::workload::transformer::Transformer;

    fn sample_inputs() -> ModelInputs {
        derive_inputs(
            &Transformer::t1()
                .build(&Strategy::new(8, 128).unwrap())
                .unwrap(),
            &presets::dgx_a100_1024(),
            &EvalOptions::default(),
        )
        .unwrap()
    }

    #[test]
    fn pack_shapes() {
        let p = pack(&sample_inputs()).unwrap();
        assert_eq!(p.compute.len(), L * CF);
        assert_eq!(p.comm.len(), L * MF);
        assert_eq!(p.params.len(), P);
    }

    #[test]
    fn pack_places_repeat() {
        let inputs = sample_inputs();
        let p = pack(&inputs).unwrap();
        for (i, l) in inputs.layers.iter().enumerate() {
            assert_eq!(p.compute[i * CF + C_REPEAT], l.repeat as f32);
            assert_eq!(p.comm[i * MF + M_REPEAT], l.repeat as f32);
        }
        // Padding slots: zero repeat.
        let n = inputs.layers.len();
        assert_eq!(p.compute[n * CF + C_REPEAT], 0.0);
    }

    #[test]
    fn stack_pads_with_zeros() {
        let p = pack(&sample_inputs()).unwrap();
        let t = stack(&[p.clone(), p], 8).unwrap();
        assert_eq!(t.n_real, 2);
        assert_eq!(t.compute.len(), 8 * L * CF);
        // Third config slot all zero.
        assert!(t.compute[2 * L * CF..3 * L * CF].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn stack_rejects_overflow() {
        let p = pack(&sample_inputs()).unwrap();
        let many: Vec<_> = (0..9).map(|_| p.clone()).collect();
        assert!(stack(&many, 8).is_err());
    }

    #[test]
    fn manifest_verification() {
        let good = json::parse(
            r#"{"b":64,"l":192,"cf":13,"mf":13,"p":12,"outf":6,
                "artifacts":{"8":"a.hlo.txt","64":"b.hlo.txt"}}"#,
        )
        .unwrap();
        verify_manifest(&good).unwrap();

        let bad = json::parse(
            r#"{"b":64,"l":100,"cf":13,"mf":13,"p":12,"outf":6,
                "artifacts":{"8":"a","64":"b"}}"#,
        )
        .unwrap();
        assert!(verify_manifest(&bad).is_err());
    }

    #[test]
    fn checked_in_manifest_matches_crate() {
        // If `make artifacts` has run, the real manifest must match.
        let path = std::path::Path::new("artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            verify_manifest(&json::parse(&text).unwrap()).unwrap();
        }
    }
}
