//! Derivation of cost-model inputs from (workload, cluster, options).
//!
//! This is the single place where workload structure meets cluster
//! structure; every backend (native analytical, AOT artifact, DES) consumes
//! the same [`ModelInputs`], which is what makes their cross-validation
//! meaningful.
//!
//! Derivation is **two-stage**: [`decompose`] extracts the
//! cluster-independent [`WorkloadDecomposition`] (per-layer
//! [`PhaseQuantities`], unresolved collectives, workload-only footprint
//! terms) and [`resolve_inputs`] binds it to a concrete cluster and
//! options. A sweep that evaluates one workload across 1,000 grid points
//! decomposes it once and resolves 1,000 times; the single-pass
//! [`derive_inputs`] is retained for one-off callers and as the
//! equivalence oracle.

use crate::config::{ClusterConfig, TierChain, MAX_TIERS};
use crate::error::{Error, Result};
use crate::network::{CollectiveImpl, CollectiveSpec};
use crate::parallel::{
    activation_working_bytes, footprint_per_node, model_state_bytes,
    pipeline_stage_footprint, residual_state_bytes, stage_footprint_terms,
    tier_fill, PipeSchedule, Strategy, TierMapping, ZeroStage,
};
use crate::workload::{Comm, CommScope, Phase, PhaseQuantities, Workload};

/// Evaluation options (the paper's per-figure modeling switches).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalOptions {
    /// ZeRO stage for the footprint estimate (paper default: ZeRO-2).
    pub zero_stage: ZeroStage,
    /// Fig. 8a mode: assume infinite capacity at full local bandwidth
    /// (no spill to expanded memory).
    pub ignore_capacity: bool,
    /// Override the derived EM traffic fraction (sensitivity studies).
    pub em_frac_override: Option<f64>,
    /// Override the derived per-node footprint, bytes.
    pub footprint_override: Option<f64>,
    /// Overlap WG communication with WG compute (paper SIII-C4 default).
    pub overlap_wg: bool,
    /// Collective implementation (Table I baseline: logical ring; the
    /// SV-B4 network studies use hierarchical).
    pub collective_impl: CollectiveImpl,
    /// Microbatches per iteration for pipeline-parallel workloads
    /// (`pp > 1`). Ignored — and normalized to 1 in the derived inputs —
    /// on the `pp = 1` slice, where the iteration processes its batch in
    /// one piece.
    pub microbatches: usize,
    /// Pipeline schedule for `pp > 1` workloads (bubble is identical;
    /// 1F1B holds fewer activations — see
    /// [`crate::parallel::PipeSchedule`]). Ignored at `pp = 1`.
    pub pipe_schedule: PipeSchedule,
    /// Which strategy axis packs into the innermost network tiers of a
    /// multi-tier fabric. The default ([`TierMapping::MpInner`]) on a
    /// <= 2-tier chain is exactly the legacy two-level resolution.
    pub tier_mapping: TierMapping,
}

impl Default for EvalOptions {
    fn default() -> Self {
        EvalOptions {
            zero_stage: ZeroStage::OsG,
            ignore_capacity: false,
            em_frac_override: None,
            footprint_override: None,
            overlap_wg: true,
            collective_impl: CollectiveImpl::LogicalRing,
            microbatches: 8,
            pipe_schedule: PipeSchedule::OneFOneB,
            tier_mapping: TierMapping::MpInner,
        }
    }
}

/// Resolved per-node / per-network parameters (f64, SI units).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeParams {
    /// Peak compute, FLOP/s.
    pub perf_peak: f64,
    /// Local-memory bandwidth, bytes/s.
    pub bw_lm: f64,
    /// Expanded-memory bandwidth, bytes/s (0 = absent).
    pub bw_em: f64,
    /// Local-memory capacity, bytes.
    pub cap_lm: f64,
    /// On-chip buffer size, bytes.
    pub sram: f64,
    /// Per-node working footprint driving the spill model.
    pub footprint: f64,
    /// Intra-pod bandwidth per node per direction, bytes/s.
    pub bw_intra: f64,
    /// Inter-pod bandwidth per node per direction, bytes/s.
    pub bw_inter: f64,
    /// Per-hop link latency, seconds.
    pub link_latency: f64,
    /// Overlap WG communication with WG compute.
    pub overlap_wg: bool,
    /// `Some(f)` forces the EM traffic fraction.
    pub em_frac_override: Option<f64>,
    /// Collective implementation.
    pub collective_impl: CollectiveImpl,
    /// Pipeline-parallel degree (`1` = the 2D slice; the backends take
    /// their flat code path and ignore every other pipeline field).
    pub pp: usize,
    /// Microbatches per iteration (normalized to 1 when `pp == 1`).
    pub microbatches: usize,
    /// Pipeline schedule (normalized to the default when `pp == 1`).
    pub pipe_schedule: PipeSchedule,
    /// Largest stage-boundary activation payload, bytes, for the full
    /// mini-batch (0 when `pp == 1`). Per-microbatch transfers move
    /// `pp_boundary_bytes / microbatches`.
    pub pp_boundary_bytes: f64,
    /// Whether the stage-boundary point-to-point transfer crosses pods
    /// (stage stride `mp * dp` >= pod size).
    pub pp_inter: bool,
    /// Active network tiers (0 = legacy two-level resolution; the
    /// backends then read `bw_intra`/`bw_inter` and ignore the tier
    /// arrays).
    pub n_tiers: usize,
    /// Per-tier bandwidth, bytes/s, innermost first (tiered resolution
    /// only; unused slots are 0).
    pub tier_bw: [f64; MAX_TIERS],
    /// Per-tier per-hop latency, seconds (tiered resolution only).
    pub tier_lat: [f64; MAX_TIERS],
    /// Tier the stage-boundary point-to-point transfer rides (tiered
    /// resolution only; the tier-chain analogue of `pp_inter`).
    pub pp_tier: usize,
}

/// One layer's resolved cost-model record.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerRecord {
    /// Layer name (diagnostics).
    pub name: String,
    /// Instance multiplicity.
    pub repeat: f64,
    /// Pipeline stage this record belongs to (0 on the 2D slice).
    pub stage: usize,
    /// Compute quantities for FP / IG / WG.
    pub q: [PhaseQuantities; 3],
    /// Collectives for FP / IG / WG (group shapes already resolved against
    /// the topology).
    pub comm: [CollectiveSpec; 3],
}

/// Everything the cost-model backends need.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelInputs {
    /// `workload%cluster` identifier (diagnostics).
    pub name: String,
    /// Resolved per-layer records.
    pub layers: Vec<LayerRecord>,
    /// Resolved node/network parameters.
    pub params: NodeParams,
}

impl ModelInputs {
    /// Cache fingerprint: FNV-1a over the full numeric content of the
    /// inputs. Collisions across *different* configurations are
    /// astronomically unlikely (64-bit) and would only perturb a figure,
    /// not corrupt state. Computed once per input on the sweep hot path
    /// and reused for both the cache lookup and the insert.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        let mut eat = |x: f64| {
            for b in x.to_bits().to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        };
        let p = &self.params;
        for v in [
            p.perf_peak,
            p.bw_lm,
            p.bw_em,
            p.cap_lm,
            p.sram,
            p.footprint,
            p.bw_intra,
            p.bw_inter,
            p.link_latency,
            if p.overlap_wg { 1.0 } else { 0.0 },
            p.em_frac_override.unwrap_or(-1.0),
            p.collective_impl.code(),
            p.pp as f64,
            p.microbatches as f64,
            p.pipe_schedule.code(),
            p.pp_boundary_bytes,
            if p.pp_inter { 1.0 } else { 0.0 },
            p.n_tiers as f64,
            p.pp_tier as f64,
        ] {
            eat(v);
        }
        for (bw, lat) in p.tier_bw.iter().zip(&p.tier_lat) {
            eat(*bw);
            eat(*lat);
        }
        for l in &self.layers {
            eat(l.repeat);
            eat(l.stage as f64);
            for q in &l.q {
                eat(q.flops);
                eat(q.u);
                eat(q.v);
                eat(q.w);
            }
            for c in &l.comm {
                eat(c.collective.code());
                eat(c.bytes);
                eat(c.n_intra as f64);
                eat(c.n_inter as f64);
                eat(c.n_tiers as f64);
                for t in &c.tier_n {
                    eat(*t as f64);
                }
            }
        }
        h
    }
}

/// Resolve a [`CommScope`] into a two-level group shape for a workload of
/// the given (MP, DP, nodes) layout.
fn resolve_scope(
    scope: CommScope,
    mp: usize,
    dp: usize,
    nodes: usize,
    pod_size: usize,
) -> (usize, usize) {
    // MP/DP scopes live inside one pipeline stage, so the group shapes
    // depend only on (mp, dp) — a pp = 1 view of the stage's layout.
    let strategy = Strategy { mp, dp, pp: 1 };
    match scope {
        CommScope::Mp => strategy.mp_two_level(pod_size),
        CommScope::Dp => strategy.dp_two_level(pod_size),
        CommScope::All => {
            let intra = pod_size.min(nodes).max(1);
            (intra, nodes / intra)
        }
    }
}

/// Resolve a [`CommScope`] into per-tier group counts on an N-tier
/// chain — the tier-aware analogue of [`resolve_scope`]. At `k = 2`
/// under [`TierMapping::MpInner`] the result projects exactly onto the
/// legacy two-level shapes.
fn resolve_scope_tiered(
    scope: CommScope,
    mp: usize,
    dp: usize,
    nodes: usize,
    chain: &TierChain,
    mapping: TierMapping,
) -> [usize; MAX_TIERS] {
    let strategy = Strategy { mp, dp, pp: 1 };
    let k = chain.n_tiers;
    match scope {
        CommScope::Mp => strategy.tier_split(&chain.groups, k, mapping).0,
        CommScope::Dp => strategy.tier_split(&chain.groups, k, mapping).1,
        CommScope::All => {
            let mut caps = chain.groups;
            tier_fill(nodes, &mut caps, k)
        }
    }
}

/// One layer of a [`WorkloadDecomposition`]: everything stage 1 extracts
/// from a [`crate::workload::Layer`] — per-phase compute quantities plus
/// the still-unresolved communication (scopes, not group shapes).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerPlan {
    /// Layer name (diagnostics).
    pub name: String,
    /// Instance multiplicity. A repeated layer straddling a pipeline
    /// stage boundary is split into one plan per stage with fractional
    /// repeats.
    pub repeat: f64,
    /// Pipeline stage this plan belongs to (0 on the 2D slice).
    pub stage: usize,
    /// Compute quantities for FP / IG / WG.
    pub q: [PhaseQuantities; 3],
    /// Communication for FP / IG / WG, with scopes not yet resolved
    /// against a topology.
    pub comm: [Comm; 3],
}

/// Stage 1 of the two-stage derive: the cluster-independent decomposition
/// of a workload.
///
/// Everything here depends only on the workload — per-layer
/// [`PhaseQuantities`], unresolved communication, and the workload-only
/// footprint terms — so one decomposition is shared by every grid point of
/// a sweep that evaluates the same workload on different clusters or
/// options ([`crate::coordinator::Coordinator::derive_batch`] memoizes
/// them by [`Workload::fingerprint`]). Stage 2 ([`resolve_inputs`])
/// resolves it against a concrete cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadDecomposition {
    /// Workload name (flows into [`ModelInputs::name`]).
    pub name: String,
    /// MP degree the workload was built for.
    pub mp: usize,
    /// DP degree the workload was built for.
    pub dp: usize,
    /// Pipeline-parallel degree the workload was built for.
    pub pp: usize,
    /// Total nodes the workload occupies.
    pub nodes: usize,
    /// Total model parameters (across all MP shards, one DP replica).
    pub total_params: f64,
    /// Residual-state bytes (workload-only footprint term; the whole
    /// MP shard, all stages).
    pub residual_bytes: f64,
    /// Activation-working-memory bytes (workload-only footprint term;
    /// whole-shard peak).
    pub awm_bytes: f64,
    /// Per-stage residual-state bytes (length `pp`; sums to
    /// `residual_bytes` and equals `[residual_bytes]` at `pp = 1`).
    pub stage_residual: Vec<f64>,
    /// Per-stage activation-working-memory bytes (length `pp`).
    pub stage_awm: Vec<f64>,
    /// Activation bytes crossing each stage boundary (length `pp - 1`),
    /// full mini-batch.
    pub boundary_bytes: Vec<f64>,
    /// Per-layer plans, in forward order (stage-major: splitting a
    /// repeated layer across stages preserves forward order).
    pub layers: Vec<LayerPlan>,
}

impl WorkloadDecomposition {
    /// Per-node footprint at a ZeRO stage, treating the whole layer list
    /// as one pipeline stage — identical (bit-for-bit) to
    /// `footprint_per_node(workload, strategy, stage).total()` on the
    /// workload this decomposition was built from. This is the `pp = 1`
    /// oracle; pipeline-aware callers use
    /// [`WorkloadDecomposition::footprint`].
    pub fn footprint_total(&self, stage: ZeroStage) -> f64 {
        model_state_bytes(self.total_params, self.mp, self.dp, stage)
            + self.residual_bytes
            + self.awm_bytes
    }

    /// Pipeline-aware per-node footprint: at `pp = 1` exactly
    /// [`WorkloadDecomposition::footprint_total`]; at `pp > 1` the worst
    /// stage's model-state shard (further divided by `pp`), residual
    /// activations held under the schedule, and per-microbatch AWM —
    /// bit-identical to
    /// [`crate::parallel::pipeline_footprint_per_node`] on the source
    /// workload (pinned by tests).
    pub fn footprint(
        &self,
        stage: ZeroStage,
        sched: PipeSchedule,
        microbatches: usize,
    ) -> f64 {
        if self.pp <= 1 {
            return self.footprint_total(stage);
        }
        let model = model_state_bytes(self.total_params, self.mp, self.dp, stage)
            / self.pp as f64;
        pipeline_stage_footprint(
            model,
            &self.stage_residual,
            &self.stage_awm,
            sched,
            self.pp,
            microbatches,
        )
    }

    /// Resolve one layer-phase communication against a pod size, producing
    /// the fully resolved collective the cost models consume.
    pub fn resolve_comm(&self, comm: &Comm, pod_size: usize) -> CollectiveSpec {
        let (n_intra, n_inter) =
            resolve_scope(comm.scope, self.mp, self.dp, self.nodes, pod_size);
        CollectiveSpec::two_level(comm.collective, comm.bytes, n_intra, n_inter)
    }

    /// Resolve one layer-phase communication against an N-tier chain
    /// under a strategy-to-tier mapping. The produced spec carries the
    /// per-tier participant shape plus its two-level projection for
    /// backends that only model two link classes.
    pub fn resolve_comm_tiered(
        &self,
        comm: &Comm,
        chain: &TierChain,
        mapping: TierMapping,
    ) -> CollectiveSpec {
        let tier_n = resolve_scope_tiered(
            comm.scope,
            self.mp,
            self.dp,
            self.nodes,
            chain,
            mapping,
        );
        CollectiveSpec::tiered(
            comm.collective,
            comm.bytes,
            tier_n,
            chain.n_tiers,
        )
    }
}

/// Stage 1: decompose a workload into its cluster-independent plan.
/// Infallible — all validation happens against the cluster in stage 2.
///
/// With pipeline parallelism the per-layer plans follow the contiguous
/// FLOP-balanced stage partition ([`Workload::stage_partition`]): a
/// repeated layer that straddles a stage boundary contributes one plan
/// per stage with fractional repeats. At `pp = 1` the partition is the
/// identity and the plans are exactly the per-layer list.
pub fn decompose(workload: &Workload) -> WorkloadDecomposition {
    let stages = workload.stage_partition();
    let (stage_residual, stage_awm) =
        stage_footprint_terms(workload, &stages);
    let boundary_bytes = workload.stage_boundary_bytes(&stages);
    let layers = stages
        .iter()
        .enumerate()
        .flat_map(|(si, slices)| {
            slices.iter().map(move |sl| {
                let l = &workload.layers[sl.layer];
                LayerPlan {
                    name: l.name.clone(),
                    repeat: sl.repeat,
                    stage: si,
                    q: Phase::ALL.map(|p| l.op.quantities(p)),
                    comm: Phase::ALL.map(|p| l.comm(p)),
                }
            })
        })
        .collect();
    WorkloadDecomposition {
        name: workload.name.clone(),
        mp: workload.mp,
        dp: workload.dp,
        pp: workload.pp,
        nodes: workload.nodes,
        total_params: workload.total_params,
        residual_bytes: residual_state_bytes(workload),
        awm_bytes: activation_working_bytes(workload),
        stage_residual,
        stage_awm,
        boundary_bytes,
        layers,
    }
}

/// Stage 2: resolve a decomposition against a concrete cluster and
/// evaluation options.
///
/// `resolve_inputs(&decompose(w), c, o)` is bit-identical to
/// [`derive_inputs`]`(w, c, o)` — `tests/scenario_roundtrip.rs` pins the
/// two paths against each other across every figure's design space.
pub fn resolve_inputs(
    dec: &WorkloadDecomposition,
    cluster: &ClusterConfig,
    opts: &EvalOptions,
) -> Result<ModelInputs> {
    cluster.validate()?;
    if dec.nodes > cluster.n_nodes {
        return Err(Error::Config(format!(
            "workload spans {} nodes but cluster {} has {}",
            dec.nodes, cluster.name, cluster.n_nodes
        )));
    }
    let view = cluster.two_level()?;
    let chain = cluster.tier_chain()?;
    // Tier-aware resolution only activates beyond what the two-level
    // view can express; <= 2-tier chains under the default mapping take
    // the legacy path so every historical result stays bit-identical.
    let tiered =
        chain.n_tiers > 2 || opts.tier_mapping != TierMapping::MpInner;

    let footprint = opts.footprint_override.unwrap_or_else(|| {
        dec.footprint(opts.zero_stage, opts.pipe_schedule, opts.microbatches)
    });

    // Pipeline fields normalize to fixed values on the 2D slice so
    // `pp = 1` fingerprints (and the single-pass oracle) are unchanged
    // by microbatch/schedule options that cannot affect the result.
    let pp = dec.pp.max(1);
    let (microbatches, pipe_schedule) = if pp > 1 {
        (opts.microbatches.max(1), opts.pipe_schedule)
    } else {
        (1, PipeSchedule::default())
    };
    let pp_boundary_bytes =
        dec.boundary_bytes.iter().copied().fold(0.0, f64::max);
    let strategy = Strategy {
        mp: dec.mp,
        dp: dec.dp,
        pp,
    };
    let pp_inter = strategy.pp_crosses_pods(view.pod_size);
    let pp_tier = if tiered {
        strategy.pp_boundary_tier(&chain.groups, chain.n_tiers)
    } else {
        0
    };

    // Heterogeneous clusters: synchronous training runs at the pace of
    // the slowest node group, so the base node's compute, memory
    // capacity, and fabric bandwidths take the bottleneck scales.
    // Homogeneous clusters skip this entirely (bit-identity).
    let node = &cluster.node;
    let mut perf_peak = node.perf_peak;
    let mut cap_lm = node.local.capacity;
    let mut bw_intra = view.bw_intra;
    let mut bw_inter = view.bw_inter;
    let mut tier_bw = if tiered {
        chain.bandwidth
    } else {
        [0.0; MAX_TIERS]
    };
    if let Some(s) = cluster.group_scales() {
        perf_peak *= s.perf;
        cap_lm *= s.mem;
        bw_intra *= s.bw;
        bw_inter *= s.bw;
        for bw in tier_bw.iter_mut() {
            *bw *= s.bw;
        }
    }

    let params = NodeParams {
        perf_peak,
        bw_lm: node.local.bandwidth,
        bw_em: node.expanded.bandwidth,
        cap_lm,
        sram: node.sram,
        footprint,
        bw_intra,
        bw_inter,
        link_latency: cluster.link_latency,
        overlap_wg: opts.overlap_wg,
        em_frac_override: if opts.ignore_capacity {
            Some(0.0)
        } else {
            opts.em_frac_override
        },
        collective_impl: opts.collective_impl,
        pp,
        microbatches,
        pipe_schedule,
        pp_boundary_bytes,
        pp_inter,
        n_tiers: if tiered { chain.n_tiers } else { 0 },
        tier_bw,
        tier_lat: if tiered {
            chain.latency
        } else {
            [0.0; MAX_TIERS]
        },
        pp_tier,
    };

    let layers = dec
        .layers
        .iter()
        .map(|l| LayerRecord {
            name: l.name.clone(),
            repeat: l.repeat,
            stage: l.stage,
            q: l.q,
            comm: [0usize, 1, 2].map(|i| {
                if tiered {
                    dec.resolve_comm_tiered(
                        &l.comm[i],
                        &chain,
                        opts.tier_mapping,
                    )
                } else {
                    dec.resolve_comm(&l.comm[i], view.pod_size)
                }
            }),
        })
        .collect();

    Ok(ModelInputs {
        name: format!("{}%{}", dec.name, cluster.name),
        layers,
        params,
    })
}

/// Derive the complete model inputs for one (workload, cluster) pair.
///
/// This is the single-pass reference implementation for the `pp = 1`
/// slice, retained as the equivalence oracle for the two-stage path
/// ([`decompose`] + [`resolve_inputs`]) the sweep hot path uses — the
/// two must stay bit-identical (pinned by
/// `tests/scenario_roundtrip.rs`). Pipeline-parallel workloads
/// (`pp > 1`) need the stage partition and therefore delegate to the
/// two-stage path — there is exactly one staging implementation. One-off
/// callers use this; batched callers go through
/// [`crate::coordinator::Coordinator::derive_batch`] so decomposition is
/// memoized per distinct workload.
pub fn derive_inputs(
    workload: &Workload,
    cluster: &ClusterConfig,
    opts: &EvalOptions,
) -> Result<ModelInputs> {
    if workload.pp > 1 {
        return resolve_inputs(&decompose(workload), cluster, opts);
    }
    cluster.validate()?;
    // Tier-aware and heterogeneous resolution lives in one place — the
    // two-stage path — so delegate exactly like pipeline parallelism.
    let chain = cluster.tier_chain()?;
    if chain.n_tiers > 2
        || opts.tier_mapping != TierMapping::MpInner
        || !cluster.groups.is_empty()
    {
        return resolve_inputs(&decompose(workload), cluster, opts);
    }
    if workload.nodes > cluster.n_nodes {
        return Err(Error::Config(format!(
            "workload spans {} nodes but cluster {} has {}",
            workload.nodes, cluster.name, cluster.n_nodes
        )));
    }
    let view = cluster.two_level()?;

    let footprint = opts.footprint_override.unwrap_or_else(|| {
        footprint_per_node(
            workload,
            &Strategy {
                mp: workload.mp,
                dp: workload.dp,
                pp: 1,
            },
            opts.zero_stage,
        )
        .total()
    });

    let node = &cluster.node;
    let params = NodeParams {
        perf_peak: node.perf_peak,
        bw_lm: node.local.bandwidth,
        bw_em: node.expanded.bandwidth,
        cap_lm: node.local.capacity,
        sram: node.sram,
        footprint,
        bw_intra: view.bw_intra,
        bw_inter: view.bw_inter,
        link_latency: cluster.link_latency,
        overlap_wg: opts.overlap_wg,
        em_frac_override: if opts.ignore_capacity {
            Some(0.0)
        } else {
            opts.em_frac_override
        },
        collective_impl: opts.collective_impl,
        // The 2D slice: pipeline fields pinned to their normal forms,
        // matching `resolve_inputs` exactly.
        pp: 1,
        microbatches: 1,
        pipe_schedule: PipeSchedule::default(),
        pp_boundary_bytes: 0.0,
        pp_inter: false,
        n_tiers: 0,
        tier_bw: [0.0; MAX_TIERS],
        tier_lat: [0.0; MAX_TIERS],
        pp_tier: 0,
    };

    let layers = workload
        .layers
        .iter()
        .map(|l| {
            let mut q = [PhaseQuantities::default(); 3];
            let mut comm = [CollectiveSpec::two_level(
                crate::workload::Collective::None,
                0.0,
                1,
                1,
            ); 3];
            for (i, phase) in Phase::ALL.iter().enumerate() {
                q[i] = l.op.quantities(*phase);
                let c = l.comm(*phase);
                let (ni, nx) = resolve_scope(
                    c.scope,
                    workload.mp,
                    workload.dp,
                    workload.nodes,
                    view.pod_size,
                );
                comm[i] =
                    CollectiveSpec::two_level(c.collective, c.bytes, ni, nx);
            }
            LayerRecord {
                name: l.name.clone(),
                repeat: l.repeat,
                stage: 0,
                q,
                comm,
            }
        })
        .collect();

    Ok(ModelInputs {
        name: format!("{}%{}", workload.name, cluster.name),
        layers,
        params,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::workload::dlrm::Dlrm;
    use crate::workload::transformer::Transformer;

    #[test]
    fn mp8_collectives_stay_intra_pod() {
        let cluster = presets::dgx_a100_1024();
        let w = Transformer::t1().build(&Strategy::new(8, 128).unwrap()).unwrap();
        let inp = derive_inputs(&w, &cluster, &EvalOptions::default()).unwrap();
        let mlp2 = inp.layers.iter().find(|l| l.name == "mlp-2").unwrap();
        // FP all-reduce: MP8 inside an 8-GPU pod.
        assert_eq!(mlp2.comm[0].n_intra, 8);
        assert_eq!(mlp2.comm[0].n_inter, 1);
        // WG all-reduce: DP128, one peer per pod.
        assert_eq!(mlp2.comm[2].n_intra, 1);
        assert_eq!(mlp2.comm[2].n_inter, 128);
    }

    #[test]
    fn mp64_straddles_pods() {
        let cluster = presets::dgx_a100_1024();
        let w = Transformer::t1().build(&Strategy::new(64, 16).unwrap()).unwrap();
        let inp = derive_inputs(&w, &cluster, &EvalOptions::default()).unwrap();
        let mlp2 = inp.layers.iter().find(|l| l.name == "mlp-2").unwrap();
        assert_eq!(mlp2.comm[0].n_intra, 8);
        assert_eq!(mlp2.comm[0].n_inter, 8);
    }

    #[test]
    fn dlrm_alltoall_spans_everything() {
        let cluster = presets::dgx_a100_64();
        let w = Dlrm::dlrm_1_2t().build(64).unwrap();
        let inp = derive_inputs(&w, &cluster, &EvalOptions::default()).unwrap();
        let emb = &inp.layers[0];
        assert_eq!(emb.comm[0].n(), 64);
        assert_eq!(emb.comm[0].n_intra, 8);
    }

    #[test]
    fn ignore_capacity_forces_no_spill() {
        let cluster = presets::dgx_a100_1024();
        let w = Transformer::t1().build(&Strategy::new(8, 128).unwrap()).unwrap();
        let opts = EvalOptions {
            ignore_capacity: true,
            ..Default::default()
        };
        let inp = derive_inputs(&w, &cluster, &opts).unwrap();
        assert_eq!(inp.params.em_frac_override, Some(0.0));
        // Footprint still reported (for the figure's secondary axis).
        assert!(inp.params.footprint > 80e9);
    }

    #[test]
    fn oversubscribed_workload_rejected() {
        let cluster = presets::dgx_a100_64();
        let w = Transformer::t1().build(&Strategy::new(8, 128).unwrap()).unwrap();
        assert!(derive_inputs(&w, &cluster, &EvalOptions::default()).is_err());
    }

    #[test]
    fn two_stage_matches_single_pass() {
        let cluster = presets::dgx_a100_1024();
        for (mp, dp) in [(8usize, 128usize), (64, 16), (128, 8)] {
            let w = Transformer::t1()
                .build(&Strategy::new(mp, dp).unwrap())
                .unwrap();
            for opts in [
                EvalOptions::default(),
                EvalOptions {
                    ignore_capacity: true,
                    ..Default::default()
                },
                EvalOptions {
                    footprint_override: Some(123e9),
                    overlap_wg: false,
                    ..Default::default()
                },
            ] {
                let single = derive_inputs(&w, &cluster, &opts).unwrap();
                let staged =
                    resolve_inputs(&decompose(&w), &cluster, &opts).unwrap();
                assert_eq!(single, staged);
                assert_eq!(single.fingerprint(), staged.fingerprint());
            }
        }
    }

    #[test]
    fn decomposition_footprint_matches_footprint_per_node() {
        let w = Transformer::t1().build(&Strategy::new(8, 128).unwrap()).unwrap();
        let dec = decompose(&w);
        for stage in ZeroStage::ALL {
            let want =
                footprint_per_node(&w, &Strategy::new(8, 128).unwrap(), stage)
                    .total();
            assert_eq!(dec.footprint_total(stage).to_bits(), want.to_bits());
        }
    }

    #[test]
    fn decomposition_footprint_matches_pipeline_oracle() {
        // The cached per-stage terms and the workload-side oracle must
        // agree bit-for-bit, for both schedules and several microbatch
        // counts, on 2D and 3D strategies.
        for s in [
            Strategy::new(8, 128).unwrap(),
            Strategy::new_3d(8, 32, 4).unwrap(),
            Strategy::new_3d(8, 16, 8).unwrap(),
        ] {
            let w = Transformer::t1().build(&s).unwrap();
            let dec = decompose(&w);
            for stage in ZeroStage::ALL {
                for sched in PipeSchedule::ALL {
                    for m in [1usize, 4, 16] {
                        let want =
                            crate::parallel::pipeline_footprint_per_node(
                                &w, stage, sched, m,
                            );
                        let got = dec.footprint(stage, sched, m);
                        assert_eq!(
                            got.to_bits(),
                            want.to_bits(),
                            "{} {:?} {sched} m={m}",
                            s.label(),
                            stage
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn pipeline_decomposition_has_staged_plans() {
        let s = Strategy::new_3d(8, 16, 8).unwrap();
        let w = Transformer::t1().build(&s).unwrap();
        let dec = decompose(&w);
        assert_eq!(dec.pp, 8);
        assert_eq!(dec.stage_residual.len(), 8);
        assert_eq!(dec.boundary_bytes.len(), 7);
        assert!(dec.boundary_bytes.iter().all(|&b| b > 0.0));
        // Stages are contiguous and non-decreasing through the plan list.
        let stages: Vec<usize> = dec.layers.iter().map(|l| l.stage).collect();
        assert!(stages.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(*stages.last().unwrap(), 7);
        // Per-stage repeat mass conserves the layer totals.
        let total: f64 = dec.layers.iter().map(|l| l.repeat).sum();
        let want: f64 = w.layers.iter().map(|l| l.repeat).sum();
        assert!((total - want).abs() < 1e-9, "{total} vs {want}");
    }

    #[test]
    fn pipeline_resolve_sets_boundary_params() {
        let cluster = presets::dgx_a100_1024();
        let s = Strategy::new_3d(8, 16, 8).unwrap();
        let w = Transformer::t1().build(&s).unwrap();
        let opts = EvalOptions {
            microbatches: 16,
            pipe_schedule: PipeSchedule::GPipe,
            ..Default::default()
        };
        let inp = derive_inputs(&w, &cluster, &opts).unwrap();
        assert_eq!(inp.params.pp, 8);
        assert_eq!(inp.params.microbatches, 16);
        assert_eq!(inp.params.pipe_schedule, PipeSchedule::GPipe);
        // A 128-node stage exceeds the 8-GPU pod: inter-pod boundary.
        assert!(inp.params.pp_inter);
        assert!(inp.params.pp_boundary_bytes > 0.0);
        // The single-pass entry point and the two-stage path are the same
        // implementation for pp > 1.
        let staged = resolve_inputs(&decompose(&w), &cluster, &opts).unwrap();
        assert_eq!(inp, staged);
    }

    #[test]
    fn pp1_inputs_ignore_microbatch_and_schedule_options() {
        // On the 2D slice the pipeline options are normalized away, so
        // fingerprints (and cache keys) cannot split on irrelevant knobs.
        let cluster = presets::dgx_a100_1024();
        let w = Transformer::t1().build(&Strategy::new(8, 128).unwrap()).unwrap();
        let base = derive_inputs(&w, &cluster, &EvalOptions::default()).unwrap();
        let tweaked = derive_inputs(
            &w,
            &cluster,
            &EvalOptions {
                microbatches: 64,
                pipe_schedule: PipeSchedule::GPipe,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(base, tweaked);
        assert_eq!(base.fingerprint(), tweaked.fingerprint());
    }

    #[test]
    fn resolve_rejects_oversubscription_like_single_pass() {
        let cluster = presets::dgx_a100_64();
        let w = Transformer::t1().build(&Strategy::new(8, 128).unwrap()).unwrap();
        let e =
            resolve_inputs(&decompose(&w), &cluster, &EvalOptions::default());
        assert!(e.is_err());
    }

    #[test]
    fn footprint_override_wins() {
        let cluster = presets::dgx_a100_1024();
        let w = Transformer::t1().build(&Strategy::new(8, 128).unwrap()).unwrap();
        let opts = EvalOptions {
            footprint_override: Some(123e9),
            ..Default::default()
        };
        let inp = derive_inputs(&w, &cluster, &opts).unwrap();
        assert_eq!(inp.params.footprint, 123e9);
    }

    #[test]
    fn tiered_cluster_resolves_per_tier_shapes() {
        // 8 x 4 x 2 chain: MP8 fills tier 0; DP8 spreads across tiers
        // 1-2 under the default MpInner mapping.
        let cluster = presets::tiered_het_64();
        let w = Transformer::t1().build(&Strategy::new(8, 8).unwrap()).unwrap();
        let inp = derive_inputs(&w, &cluster, &EvalOptions::default()).unwrap();
        assert_eq!(inp.params.n_tiers, 3);
        assert!(inp.params.tier_bw[0] > inp.params.tier_bw[2]);
        let mlp2 = inp.layers.iter().find(|l| l.name == "mlp-2").unwrap();
        assert_eq!(mlp2.comm[0].n_tiers, 3);
        assert_eq!(&mlp2.comm[0].tier_n[..3], &[8, 1, 1]);
        assert_eq!(&mlp2.comm[2].tier_n[..3], &[1, 4, 2]);
        // Two-level projection preserved for two-class backends.
        assert_eq!(mlp2.comm[2].n_intra, 1);
        assert_eq!(mlp2.comm[2].n_inter, 8);

        // The single-pass oracle delegates and agrees exactly.
        let staged = resolve_inputs(
            &decompose(&w),
            &cluster,
            &EvalOptions::default(),
        )
        .unwrap();
        assert_eq!(inp, staged);
    }

    #[test]
    fn dp_inner_mapping_swaps_axes_on_tiered_cluster() {
        let cluster = presets::tiered_het_64();
        let w = Transformer::t1().build(&Strategy::new(4, 16).unwrap()).unwrap();
        let opts = EvalOptions {
            tier_mapping: TierMapping::DpInner,
            ..Default::default()
        };
        let inp = derive_inputs(&w, &cluster, &opts).unwrap();
        let mlp2 = inp.layers.iter().find(|l| l.name == "mlp-2").unwrap();
        // DP16 packs the innermost tier first under DpInner.
        assert_eq!(mlp2.comm[2].tier_n[0], 8);
        assert_eq!(mlp2.comm[0].tier_n[0], 1);
    }

    #[test]
    fn heterogeneous_groups_scale_bottleneck_params() {
        use crate::config::NodeGroup;
        let mut cluster = presets::dgx_a100_1024();
        cluster.groups = vec![
            NodeGroup {
                count: 512,
                perf_scale: 1.0,
                mem_scale: 1.0,
                bw_scale: 1.0,
            },
            NodeGroup {
                count: 512,
                perf_scale: 0.5,
                mem_scale: 2.0,
                bw_scale: 0.5,
            },
        ];
        let w = Transformer::t1().build(&Strategy::new(8, 128).unwrap()).unwrap();
        let base =
            derive_inputs(&w, &presets::dgx_a100_1024(), &EvalOptions::default())
                .unwrap();
        let het = derive_inputs(&w, &cluster, &EvalOptions::default()).unwrap();
        assert_eq!(het.params.perf_peak, 0.5 * base.params.perf_peak);
        assert_eq!(het.params.cap_lm, base.params.cap_lm);
        assert_eq!(het.params.bw_intra, 0.5 * base.params.bw_intra);
        assert_eq!(het.params.bw_inter, 0.5 * base.params.bw_inter);
        // Memory-system bandwidths are per-node, not fabric: unscaled.
        assert_eq!(het.params.bw_lm, base.params.bw_lm);
        // Layer resolution is unchanged (same topology shape).
        assert_eq!(het.layers, base.layers);
    }
}
