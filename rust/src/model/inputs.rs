//! Derivation of cost-model inputs from (workload, cluster, options).
//!
//! This is the single place where workload structure meets cluster
//! structure; every backend (native analytical, AOT artifact, DES) consumes
//! the same [`ModelInputs`], which is what makes their cross-validation
//! meaningful.

use crate::config::ClusterConfig;
use crate::error::{Error, Result};
use crate::network::{CollectiveImpl, CollectiveSpec};
use crate::parallel::{footprint_per_node, Strategy, ZeroStage};
use crate::workload::{CommScope, Phase, PhaseQuantities, Workload};

/// Evaluation options (the paper's per-figure modeling switches).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalOptions {
    /// ZeRO stage for the footprint estimate (paper default: ZeRO-2).
    pub zero_stage: ZeroStage,
    /// Fig. 8a mode: assume infinite capacity at full local bandwidth
    /// (no spill to expanded memory).
    pub ignore_capacity: bool,
    /// Override the derived EM traffic fraction (sensitivity studies).
    pub em_frac_override: Option<f64>,
    /// Override the derived per-node footprint, bytes.
    pub footprint_override: Option<f64>,
    /// Overlap WG communication with WG compute (paper SIII-C4 default).
    pub overlap_wg: bool,
    /// Collective implementation (Table I baseline: logical ring; the
    /// SV-B4 network studies use hierarchical).
    pub collective_impl: CollectiveImpl,
}

impl Default for EvalOptions {
    fn default() -> Self {
        EvalOptions {
            zero_stage: ZeroStage::OsG,
            ignore_capacity: false,
            em_frac_override: None,
            footprint_override: None,
            overlap_wg: true,
            collective_impl: CollectiveImpl::LogicalRing,
        }
    }
}

/// Resolved per-node / per-network parameters (f64, SI units).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeParams {
    /// Peak compute, FLOP/s.
    pub perf_peak: f64,
    /// Local-memory bandwidth, bytes/s.
    pub bw_lm: f64,
    /// Expanded-memory bandwidth, bytes/s (0 = absent).
    pub bw_em: f64,
    /// Local-memory capacity, bytes.
    pub cap_lm: f64,
    /// On-chip buffer size, bytes.
    pub sram: f64,
    /// Per-node working footprint driving the spill model.
    pub footprint: f64,
    /// Intra-pod bandwidth per node per direction, bytes/s.
    pub bw_intra: f64,
    /// Inter-pod bandwidth per node per direction, bytes/s.
    pub bw_inter: f64,
    /// Per-hop link latency, seconds.
    pub link_latency: f64,
    /// Overlap WG communication with WG compute.
    pub overlap_wg: bool,
    /// `Some(f)` forces the EM traffic fraction.
    pub em_frac_override: Option<f64>,
    /// Collective implementation.
    pub collective_impl: CollectiveImpl,
}

/// One layer's resolved cost-model record.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerRecord {
    /// Layer name (diagnostics).
    pub name: String,
    /// Instance multiplicity.
    pub repeat: f64,
    /// Compute quantities for FP / IG / WG.
    pub q: [PhaseQuantities; 3],
    /// Collectives for FP / IG / WG (group shapes already resolved against
    /// the topology).
    pub comm: [CollectiveSpec; 3],
}

/// Everything the cost-model backends need.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelInputs {
    /// `workload%cluster` identifier (diagnostics).
    pub name: String,
    /// Resolved per-layer records.
    pub layers: Vec<LayerRecord>,
    /// Resolved node/network parameters.
    pub params: NodeParams,
}

impl ModelInputs {
    /// Cache fingerprint: FNV-1a over the full numeric content of the
    /// inputs. Collisions across *different* configurations are
    /// astronomically unlikely (64-bit) and would only perturb a figure,
    /// not corrupt state. Computed once per input on the sweep hot path
    /// and reused for both the cache lookup and the insert.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        let mut eat = |x: f64| {
            for b in x.to_bits().to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        };
        let p = &self.params;
        for v in [
            p.perf_peak,
            p.bw_lm,
            p.bw_em,
            p.cap_lm,
            p.sram,
            p.footprint,
            p.bw_intra,
            p.bw_inter,
            p.link_latency,
            if p.overlap_wg { 1.0 } else { 0.0 },
            p.em_frac_override.unwrap_or(-1.0),
            p.collective_impl.code(),
        ] {
            eat(v);
        }
        for l in &self.layers {
            eat(l.repeat);
            for q in &l.q {
                eat(q.flops);
                eat(q.u);
                eat(q.v);
                eat(q.w);
            }
            for c in &l.comm {
                eat(c.collective.code());
                eat(c.bytes);
                eat(c.n_intra as f64);
                eat(c.n_inter as f64);
            }
        }
        h
    }
}

/// Resolve a [`CommScope`] into a two-level group shape.
fn resolve_scope(
    scope: CommScope,
    workload: &Workload,
    pod_size: usize,
) -> (usize, usize) {
    let strategy = Strategy::new(workload.mp, workload.dp);
    match scope {
        CommScope::Mp => strategy.mp_two_level(pod_size),
        CommScope::Dp => strategy.dp_two_level(pod_size),
        CommScope::All => {
            let n = workload.nodes;
            let intra = pod_size.min(n).max(1);
            (intra, n / intra)
        }
    }
}

/// Derive the complete model inputs for one (workload, cluster) pair.
pub fn derive_inputs(
    workload: &Workload,
    cluster: &ClusterConfig,
    opts: &EvalOptions,
) -> Result<ModelInputs> {
    cluster.validate()?;
    if workload.nodes > cluster.n_nodes {
        return Err(Error::Config(format!(
            "workload spans {} nodes but cluster {} has {}",
            workload.nodes, cluster.name, cluster.n_nodes
        )));
    }
    let view = cluster.two_level();

    let footprint = opts.footprint_override.unwrap_or_else(|| {
        footprint_per_node(
            workload,
            &Strategy::new(workload.mp, workload.dp),
            opts.zero_stage,
        )
        .total()
    });

    let node = &cluster.node;
    let params = NodeParams {
        perf_peak: node.perf_peak,
        bw_lm: node.local.bandwidth,
        bw_em: node.expanded.bandwidth,
        cap_lm: node.local.capacity,
        sram: node.sram,
        footprint,
        bw_intra: view.bw_intra,
        bw_inter: view.bw_inter,
        link_latency: cluster.link_latency,
        overlap_wg: opts.overlap_wg,
        em_frac_override: if opts.ignore_capacity {
            Some(0.0)
        } else {
            opts.em_frac_override
        },
        collective_impl: opts.collective_impl,
    };

    let layers = workload
        .layers
        .iter()
        .map(|l| {
            let mut q = [PhaseQuantities::default(); 3];
            let mut comm = [CollectiveSpec {
                collective: crate::workload::Collective::None,
                bytes: 0.0,
                n_intra: 1,
                n_inter: 1,
            }; 3];
            for (i, phase) in Phase::ALL.iter().enumerate() {
                q[i] = l.op.quantities(*phase);
                let c = l.comm(*phase);
                let (ni, nx) = resolve_scope(c.scope, workload, view.pod_size);
                comm[i] = CollectiveSpec {
                    collective: c.collective,
                    bytes: c.bytes,
                    n_intra: ni,
                    n_inter: nx,
                };
            }
            LayerRecord {
                name: l.name.clone(),
                repeat: l.repeat,
                q,
                comm,
            }
        })
        .collect();

    Ok(ModelInputs {
        name: format!("{}%{}", workload.name, cluster.name),
        layers,
        params,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::workload::dlrm::Dlrm;
    use crate::workload::transformer::Transformer;

    #[test]
    fn mp8_collectives_stay_intra_pod() {
        let cluster = presets::dgx_a100_1024();
        let w = Transformer::t1().build(&Strategy::new(8, 128)).unwrap();
        let inp = derive_inputs(&w, &cluster, &EvalOptions::default()).unwrap();
        let mlp2 = inp.layers.iter().find(|l| l.name == "mlp-2").unwrap();
        // FP all-reduce: MP8 inside an 8-GPU pod.
        assert_eq!(mlp2.comm[0].n_intra, 8);
        assert_eq!(mlp2.comm[0].n_inter, 1);
        // WG all-reduce: DP128, one peer per pod.
        assert_eq!(mlp2.comm[2].n_intra, 1);
        assert_eq!(mlp2.comm[2].n_inter, 128);
    }

    #[test]
    fn mp64_straddles_pods() {
        let cluster = presets::dgx_a100_1024();
        let w = Transformer::t1().build(&Strategy::new(64, 16)).unwrap();
        let inp = derive_inputs(&w, &cluster, &EvalOptions::default()).unwrap();
        let mlp2 = inp.layers.iter().find(|l| l.name == "mlp-2").unwrap();
        assert_eq!(mlp2.comm[0].n_intra, 8);
        assert_eq!(mlp2.comm[0].n_inter, 8);
    }

    #[test]
    fn dlrm_alltoall_spans_everything() {
        let cluster = presets::dgx_a100_64();
        let w = Dlrm::dlrm_1_2t().build(64).unwrap();
        let inp = derive_inputs(&w, &cluster, &EvalOptions::default()).unwrap();
        let emb = &inp.layers[0];
        assert_eq!(emb.comm[0].n(), 64);
        assert_eq!(emb.comm[0].n_intra, 8);
    }

    #[test]
    fn ignore_capacity_forces_no_spill() {
        let cluster = presets::dgx_a100_1024();
        let w = Transformer::t1().build(&Strategy::new(8, 128)).unwrap();
        let opts = EvalOptions {
            ignore_capacity: true,
            ..Default::default()
        };
        let inp = derive_inputs(&w, &cluster, &opts).unwrap();
        assert_eq!(inp.params.em_frac_override, Some(0.0));
        // Footprint still reported (for the figure's secondary axis).
        assert!(inp.params.footprint > 80e9);
    }

    #[test]
    fn oversubscribed_workload_rejected() {
        let cluster = presets::dgx_a100_64();
        let w = Transformer::t1().build(&Strategy::new(8, 128)).unwrap();
        assert!(derive_inputs(&w, &cluster, &EvalOptions::default()).is_err());
    }

    #[test]
    fn footprint_override_wins() {
        let cluster = presets::dgx_a100_1024();
        let w = Transformer::t1().build(&Strategy::new(8, 128)).unwrap();
        let opts = EvalOptions {
            footprint_override: Some(123e9),
            ..Default::default()
        };
        let inp = derive_inputs(&w, &cluster, &opts).unwrap();
        assert_eq!(inp.params.footprint, 123e9);
    }
}
