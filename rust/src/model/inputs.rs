//! Derivation of cost-model inputs from (workload, cluster, options).
//!
//! This is the single place where workload structure meets cluster
//! structure; every backend (native analytical, AOT artifact, DES) consumes
//! the same [`ModelInputs`], which is what makes their cross-validation
//! meaningful.
//!
//! Derivation is **two-stage**: [`decompose`] extracts the
//! cluster-independent [`WorkloadDecomposition`] (per-layer
//! [`PhaseQuantities`], unresolved collectives, workload-only footprint
//! terms) and [`resolve_inputs`] binds it to a concrete cluster and
//! options. A sweep that evaluates one workload across 1,000 grid points
//! decomposes it once and resolves 1,000 times; the single-pass
//! [`derive_inputs`] is retained for one-off callers and as the
//! equivalence oracle.

use crate::config::ClusterConfig;
use crate::error::{Error, Result};
use crate::network::{CollectiveImpl, CollectiveSpec};
use crate::parallel::{
    activation_working_bytes, footprint_per_node, model_state_bytes,
    residual_state_bytes, Strategy, ZeroStage,
};
use crate::workload::{Comm, CommScope, Phase, PhaseQuantities, Workload};

/// Evaluation options (the paper's per-figure modeling switches).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalOptions {
    /// ZeRO stage for the footprint estimate (paper default: ZeRO-2).
    pub zero_stage: ZeroStage,
    /// Fig. 8a mode: assume infinite capacity at full local bandwidth
    /// (no spill to expanded memory).
    pub ignore_capacity: bool,
    /// Override the derived EM traffic fraction (sensitivity studies).
    pub em_frac_override: Option<f64>,
    /// Override the derived per-node footprint, bytes.
    pub footprint_override: Option<f64>,
    /// Overlap WG communication with WG compute (paper SIII-C4 default).
    pub overlap_wg: bool,
    /// Collective implementation (Table I baseline: logical ring; the
    /// SV-B4 network studies use hierarchical).
    pub collective_impl: CollectiveImpl,
}

impl Default for EvalOptions {
    fn default() -> Self {
        EvalOptions {
            zero_stage: ZeroStage::OsG,
            ignore_capacity: false,
            em_frac_override: None,
            footprint_override: None,
            overlap_wg: true,
            collective_impl: CollectiveImpl::LogicalRing,
        }
    }
}

/// Resolved per-node / per-network parameters (f64, SI units).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeParams {
    /// Peak compute, FLOP/s.
    pub perf_peak: f64,
    /// Local-memory bandwidth, bytes/s.
    pub bw_lm: f64,
    /// Expanded-memory bandwidth, bytes/s (0 = absent).
    pub bw_em: f64,
    /// Local-memory capacity, bytes.
    pub cap_lm: f64,
    /// On-chip buffer size, bytes.
    pub sram: f64,
    /// Per-node working footprint driving the spill model.
    pub footprint: f64,
    /// Intra-pod bandwidth per node per direction, bytes/s.
    pub bw_intra: f64,
    /// Inter-pod bandwidth per node per direction, bytes/s.
    pub bw_inter: f64,
    /// Per-hop link latency, seconds.
    pub link_latency: f64,
    /// Overlap WG communication with WG compute.
    pub overlap_wg: bool,
    /// `Some(f)` forces the EM traffic fraction.
    pub em_frac_override: Option<f64>,
    /// Collective implementation.
    pub collective_impl: CollectiveImpl,
}

/// One layer's resolved cost-model record.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerRecord {
    /// Layer name (diagnostics).
    pub name: String,
    /// Instance multiplicity.
    pub repeat: f64,
    /// Compute quantities for FP / IG / WG.
    pub q: [PhaseQuantities; 3],
    /// Collectives for FP / IG / WG (group shapes already resolved against
    /// the topology).
    pub comm: [CollectiveSpec; 3],
}

/// Everything the cost-model backends need.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelInputs {
    /// `workload%cluster` identifier (diagnostics).
    pub name: String,
    /// Resolved per-layer records.
    pub layers: Vec<LayerRecord>,
    /// Resolved node/network parameters.
    pub params: NodeParams,
}

impl ModelInputs {
    /// Cache fingerprint: FNV-1a over the full numeric content of the
    /// inputs. Collisions across *different* configurations are
    /// astronomically unlikely (64-bit) and would only perturb a figure,
    /// not corrupt state. Computed once per input on the sweep hot path
    /// and reused for both the cache lookup and the insert.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        let mut eat = |x: f64| {
            for b in x.to_bits().to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        };
        let p = &self.params;
        for v in [
            p.perf_peak,
            p.bw_lm,
            p.bw_em,
            p.cap_lm,
            p.sram,
            p.footprint,
            p.bw_intra,
            p.bw_inter,
            p.link_latency,
            if p.overlap_wg { 1.0 } else { 0.0 },
            p.em_frac_override.unwrap_or(-1.0),
            p.collective_impl.code(),
        ] {
            eat(v);
        }
        for l in &self.layers {
            eat(l.repeat);
            for q in &l.q {
                eat(q.flops);
                eat(q.u);
                eat(q.v);
                eat(q.w);
            }
            for c in &l.comm {
                eat(c.collective.code());
                eat(c.bytes);
                eat(c.n_intra as f64);
                eat(c.n_inter as f64);
            }
        }
        h
    }
}

/// Resolve a [`CommScope`] into a two-level group shape for a workload of
/// the given (MP, DP, nodes) layout.
fn resolve_scope(
    scope: CommScope,
    mp: usize,
    dp: usize,
    nodes: usize,
    pod_size: usize,
) -> (usize, usize) {
    let strategy = Strategy::new(mp, dp);
    match scope {
        CommScope::Mp => strategy.mp_two_level(pod_size),
        CommScope::Dp => strategy.dp_two_level(pod_size),
        CommScope::All => {
            let intra = pod_size.min(nodes).max(1);
            (intra, nodes / intra)
        }
    }
}

/// One layer of a [`WorkloadDecomposition`]: everything stage 1 extracts
/// from a [`crate::workload::Layer`] — per-phase compute quantities plus
/// the still-unresolved communication (scopes, not group shapes).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerPlan {
    /// Layer name (diagnostics).
    pub name: String,
    /// Instance multiplicity.
    pub repeat: f64,
    /// Compute quantities for FP / IG / WG.
    pub q: [PhaseQuantities; 3],
    /// Communication for FP / IG / WG, with scopes not yet resolved
    /// against a topology.
    pub comm: [Comm; 3],
}

/// Stage 1 of the two-stage derive: the cluster-independent decomposition
/// of a workload.
///
/// Everything here depends only on the workload — per-layer
/// [`PhaseQuantities`], unresolved communication, and the workload-only
/// footprint terms — so one decomposition is shared by every grid point of
/// a sweep that evaluates the same workload on different clusters or
/// options ([`crate::coordinator::Coordinator::derive_batch`] memoizes
/// them by [`Workload::fingerprint`]). Stage 2 ([`resolve_inputs`])
/// resolves it against a concrete cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadDecomposition {
    /// Workload name (flows into [`ModelInputs::name`]).
    pub name: String,
    /// MP degree the workload was built for.
    pub mp: usize,
    /// DP degree the workload was built for.
    pub dp: usize,
    /// Total nodes the workload occupies.
    pub nodes: usize,
    /// Total model parameters (across all MP shards, one DP replica).
    pub total_params: f64,
    /// Residual-state bytes (workload-only footprint term).
    pub residual_bytes: f64,
    /// Activation-working-memory bytes (workload-only footprint term).
    pub awm_bytes: f64,
    /// Per-layer plans, in forward order.
    pub layers: Vec<LayerPlan>,
}

impl WorkloadDecomposition {
    /// Per-node footprint at a ZeRO stage — identical (bit-for-bit) to
    /// `footprint_per_node(workload, strategy, stage).total()` on the
    /// workload this decomposition was built from.
    pub fn footprint_total(&self, stage: ZeroStage) -> f64 {
        model_state_bytes(self.total_params, self.mp, self.dp, stage)
            + self.residual_bytes
            + self.awm_bytes
    }

    /// Resolve one layer-phase communication against a pod size, producing
    /// the fully resolved collective the cost models consume.
    pub fn resolve_comm(&self, comm: &Comm, pod_size: usize) -> CollectiveSpec {
        let (n_intra, n_inter) =
            resolve_scope(comm.scope, self.mp, self.dp, self.nodes, pod_size);
        CollectiveSpec {
            collective: comm.collective,
            bytes: comm.bytes,
            n_intra,
            n_inter,
        }
    }
}

/// Stage 1: decompose a workload into its cluster-independent plan.
/// Infallible — all validation happens against the cluster in stage 2.
pub fn decompose(workload: &Workload) -> WorkloadDecomposition {
    let layers = workload
        .layers
        .iter()
        .map(|l| LayerPlan {
            name: l.name.clone(),
            repeat: l.repeat,
            q: Phase::ALL.map(|p| l.op.quantities(p)),
            comm: Phase::ALL.map(|p| l.comm(p)),
        })
        .collect();
    WorkloadDecomposition {
        name: workload.name.clone(),
        mp: workload.mp,
        dp: workload.dp,
        nodes: workload.nodes,
        total_params: workload.total_params,
        residual_bytes: residual_state_bytes(workload),
        awm_bytes: activation_working_bytes(workload),
        layers,
    }
}

/// Stage 2: resolve a decomposition against a concrete cluster and
/// evaluation options.
///
/// `resolve_inputs(&decompose(w), c, o)` is bit-identical to
/// [`derive_inputs`]`(w, c, o)` — `tests/scenario_roundtrip.rs` pins the
/// two paths against each other across every figure's design space.
pub fn resolve_inputs(
    dec: &WorkloadDecomposition,
    cluster: &ClusterConfig,
    opts: &EvalOptions,
) -> Result<ModelInputs> {
    cluster.validate()?;
    if dec.nodes > cluster.n_nodes {
        return Err(Error::Config(format!(
            "workload spans {} nodes but cluster {} has {}",
            dec.nodes, cluster.name, cluster.n_nodes
        )));
    }
    let view = cluster.two_level();

    let footprint = opts
        .footprint_override
        .unwrap_or_else(|| dec.footprint_total(opts.zero_stage));

    let node = &cluster.node;
    let params = NodeParams {
        perf_peak: node.perf_peak,
        bw_lm: node.local.bandwidth,
        bw_em: node.expanded.bandwidth,
        cap_lm: node.local.capacity,
        sram: node.sram,
        footprint,
        bw_intra: view.bw_intra,
        bw_inter: view.bw_inter,
        link_latency: cluster.link_latency,
        overlap_wg: opts.overlap_wg,
        em_frac_override: if opts.ignore_capacity {
            Some(0.0)
        } else {
            opts.em_frac_override
        },
        collective_impl: opts.collective_impl,
    };

    let layers = dec
        .layers
        .iter()
        .map(|l| LayerRecord {
            name: l.name.clone(),
            repeat: l.repeat,
            q: l.q,
            comm: [0usize, 1, 2]
                .map(|i| dec.resolve_comm(&l.comm[i], view.pod_size)),
        })
        .collect();

    Ok(ModelInputs {
        name: format!("{}%{}", dec.name, cluster.name),
        layers,
        params,
    })
}

/// Derive the complete model inputs for one (workload, cluster) pair.
///
/// This is the single-pass reference implementation, retained as the
/// equivalence oracle for the two-stage path ([`decompose`] +
/// [`resolve_inputs`]) the sweep hot path uses — the two must stay
/// bit-identical (pinned by `tests/scenario_roundtrip.rs`). One-off
/// callers use this; batched callers go through
/// [`crate::coordinator::Coordinator::derive_batch`] so decomposition is
/// memoized per distinct workload.
pub fn derive_inputs(
    workload: &Workload,
    cluster: &ClusterConfig,
    opts: &EvalOptions,
) -> Result<ModelInputs> {
    cluster.validate()?;
    if workload.nodes > cluster.n_nodes {
        return Err(Error::Config(format!(
            "workload spans {} nodes but cluster {} has {}",
            workload.nodes, cluster.name, cluster.n_nodes
        )));
    }
    let view = cluster.two_level();

    let footprint = opts.footprint_override.unwrap_or_else(|| {
        footprint_per_node(
            workload,
            &Strategy::new(workload.mp, workload.dp),
            opts.zero_stage,
        )
        .total()
    });

    let node = &cluster.node;
    let params = NodeParams {
        perf_peak: node.perf_peak,
        bw_lm: node.local.bandwidth,
        bw_em: node.expanded.bandwidth,
        cap_lm: node.local.capacity,
        sram: node.sram,
        footprint,
        bw_intra: view.bw_intra,
        bw_inter: view.bw_inter,
        link_latency: cluster.link_latency,
        overlap_wg: opts.overlap_wg,
        em_frac_override: if opts.ignore_capacity {
            Some(0.0)
        } else {
            opts.em_frac_override
        },
        collective_impl: opts.collective_impl,
    };

    let layers = workload
        .layers
        .iter()
        .map(|l| {
            let mut q = [PhaseQuantities::default(); 3];
            let mut comm = [CollectiveSpec {
                collective: crate::workload::Collective::None,
                bytes: 0.0,
                n_intra: 1,
                n_inter: 1,
            }; 3];
            for (i, phase) in Phase::ALL.iter().enumerate() {
                q[i] = l.op.quantities(*phase);
                let c = l.comm(*phase);
                let (ni, nx) = resolve_scope(
                    c.scope,
                    workload.mp,
                    workload.dp,
                    workload.nodes,
                    view.pod_size,
                );
                comm[i] = CollectiveSpec {
                    collective: c.collective,
                    bytes: c.bytes,
                    n_intra: ni,
                    n_inter: nx,
                };
            }
            LayerRecord {
                name: l.name.clone(),
                repeat: l.repeat,
                q,
                comm,
            }
        })
        .collect();

    Ok(ModelInputs {
        name: format!("{}%{}", workload.name, cluster.name),
        layers,
        params,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::workload::dlrm::Dlrm;
    use crate::workload::transformer::Transformer;

    #[test]
    fn mp8_collectives_stay_intra_pod() {
        let cluster = presets::dgx_a100_1024();
        let w = Transformer::t1().build(&Strategy::new(8, 128)).unwrap();
        let inp = derive_inputs(&w, &cluster, &EvalOptions::default()).unwrap();
        let mlp2 = inp.layers.iter().find(|l| l.name == "mlp-2").unwrap();
        // FP all-reduce: MP8 inside an 8-GPU pod.
        assert_eq!(mlp2.comm[0].n_intra, 8);
        assert_eq!(mlp2.comm[0].n_inter, 1);
        // WG all-reduce: DP128, one peer per pod.
        assert_eq!(mlp2.comm[2].n_intra, 1);
        assert_eq!(mlp2.comm[2].n_inter, 128);
    }

    #[test]
    fn mp64_straddles_pods() {
        let cluster = presets::dgx_a100_1024();
        let w = Transformer::t1().build(&Strategy::new(64, 16)).unwrap();
        let inp = derive_inputs(&w, &cluster, &EvalOptions::default()).unwrap();
        let mlp2 = inp.layers.iter().find(|l| l.name == "mlp-2").unwrap();
        assert_eq!(mlp2.comm[0].n_intra, 8);
        assert_eq!(mlp2.comm[0].n_inter, 8);
    }

    #[test]
    fn dlrm_alltoall_spans_everything() {
        let cluster = presets::dgx_a100_64();
        let w = Dlrm::dlrm_1_2t().build(64).unwrap();
        let inp = derive_inputs(&w, &cluster, &EvalOptions::default()).unwrap();
        let emb = &inp.layers[0];
        assert_eq!(emb.comm[0].n(), 64);
        assert_eq!(emb.comm[0].n_intra, 8);
    }

    #[test]
    fn ignore_capacity_forces_no_spill() {
        let cluster = presets::dgx_a100_1024();
        let w = Transformer::t1().build(&Strategy::new(8, 128)).unwrap();
        let opts = EvalOptions {
            ignore_capacity: true,
            ..Default::default()
        };
        let inp = derive_inputs(&w, &cluster, &opts).unwrap();
        assert_eq!(inp.params.em_frac_override, Some(0.0));
        // Footprint still reported (for the figure's secondary axis).
        assert!(inp.params.footprint > 80e9);
    }

    #[test]
    fn oversubscribed_workload_rejected() {
        let cluster = presets::dgx_a100_64();
        let w = Transformer::t1().build(&Strategy::new(8, 128)).unwrap();
        assert!(derive_inputs(&w, &cluster, &EvalOptions::default()).is_err());
    }

    #[test]
    fn two_stage_matches_single_pass() {
        let cluster = presets::dgx_a100_1024();
        for (mp, dp) in [(8usize, 128usize), (64, 16), (128, 8)] {
            let w = Transformer::t1()
                .build(&Strategy::new(mp, dp))
                .unwrap();
            for opts in [
                EvalOptions::default(),
                EvalOptions {
                    ignore_capacity: true,
                    ..Default::default()
                },
                EvalOptions {
                    footprint_override: Some(123e9),
                    overlap_wg: false,
                    ..Default::default()
                },
            ] {
                let single = derive_inputs(&w, &cluster, &opts).unwrap();
                let staged =
                    resolve_inputs(&decompose(&w), &cluster, &opts).unwrap();
                assert_eq!(single, staged);
                assert_eq!(single.fingerprint(), staged.fingerprint());
            }
        }
    }

    #[test]
    fn decomposition_footprint_matches_footprint_per_node() {
        let w = Transformer::t1().build(&Strategy::new(8, 128)).unwrap();
        let dec = decompose(&w);
        for stage in ZeroStage::ALL {
            let want =
                footprint_per_node(&w, &Strategy::new(8, 128), stage).total();
            assert_eq!(dec.footprint_total(stage).to_bits(), want.to_bits());
        }
    }

    #[test]
    fn resolve_rejects_oversubscription_like_single_pass() {
        let cluster = presets::dgx_a100_64();
        let w = Transformer::t1().build(&Strategy::new(8, 128)).unwrap();
        let e =
            resolve_inputs(&decompose(&w), &cluster, &EvalOptions::default());
        assert!(e.is_err());
    }

    #[test]
    fn footprint_override_wins() {
        let cluster = presets::dgx_a100_1024();
        let w = Transformer::t1().build(&Strategy::new(8, 128)).unwrap();
        let opts = EvalOptions {
            footprint_override: Some(123e9),
            ..Default::default()
        };
        let inp = derive_inputs(&w, &cluster, &opts).unwrap();
        assert_eq!(inp.params.footprint, 123e9);
    }
}
