//! Native single-config evaluation entry point.

use crate::analytical::{evaluate, TrainingBreakdown};
use crate::config::ClusterConfig;
use crate::error::Result;
use crate::workload::Workload;

use super::inputs::{derive_inputs, EvalOptions};

/// Evaluate one (workload, cluster) pair with the native f64 backend.
pub fn evaluate_native(
    workload: &Workload,
    cluster: &ClusterConfig,
    opts: &EvalOptions,
) -> Result<TrainingBreakdown> {
    Ok(evaluate(&derive_inputs(workload, cluster, opts)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::parallel::Strategy;
    use crate::workload::dlrm::Dlrm;
    use crate::workload::transformer::Transformer;

    #[test]
    fn transformer_on_baseline() {
        let b = evaluate_native(
            &Transformer::t1()
                .build(&Strategy::new(64, 16).unwrap())
                .unwrap(),
            &presets::dgx_a100_1024(),
            &EvalOptions::default(),
        )
        .unwrap();
        assert!(b.total() > 0.0 && b.total().is_finite());
    }

    #[test]
    fn dlrm_on_64_nodes() {
        let b = evaluate_native(
            &Dlrm::dlrm_1_2t().build(64).unwrap(),
            &presets::dgx_a100_64(),
            &EvalOptions::default(),
        )
        .unwrap();
        assert!(b.total() > 0.0 && b.total().is_finite());
        // DLRM FP is dominated by the blocking all-to-all.
        assert!(b.fp_exposed_comm > 0.0);
    }

    #[test]
    fn fig13a_dlrm_time_sublinear_in_node_reduction() {
        // Paper SV-C: halving nodes raises per-instance time sublinearly
        // (in the 64..16 range) thanks to shrinking all-to-all cost.
        let d = Dlrm::dlrm_1_2t();
        let t = |n: usize| {
            // Expanded memory present so spill doesn't explode (fig. 13a
            // normalizes to a 2 TB/s memory system).
            let mut cluster = presets::dgx_a100_64().with_n_nodes(n);
            cluster.node = cluster.node.with_expanded(2e12, 2e12);
            evaluate_native(
                &d.build(n).unwrap(),
                &cluster,
                &EvalOptions::default(),
            )
            .unwrap()
            .total()
        };
        let (t64, t32, t16) = (t(64), t(32), t(16));
        assert!(t32 > t64, "{t64} {t32}");
        assert!(t32 / t64 < 2.0, "sublinear 64->32: {}", t32 / t64);
        assert!(t16 / t32 < 2.0, "sublinear 32->16: {}", t16 / t32);
    }
}
