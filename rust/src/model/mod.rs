//! Glue between workloads, clusters, and the cost-model backends: derives
//! the per-layer model inputs once, then hands them to the native f64
//! evaluator ([`crate::analytical`]), the f32 AOT artifact
//! ([`crate::runtime`]), or the discrete-event simulator ([`crate::sim`]).

pub mod batch;
pub mod eval;
pub mod inputs;

pub use eval::evaluate_native;
pub use inputs::{derive_inputs, EvalOptions, LayerRecord, ModelInputs, NodeParams};
