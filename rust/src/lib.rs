//! # COMET — cluster design methodology for distributed DL training
//!
//! Reproduction of *COMET: A Comprehensive Cluster Design Methodology for
//! Distributed Deep Learning Training* (Kadiyala et al., Georgia Tech, 2022).
//!
//! COMET jointly explores model **parallelization strategies** (the 3D
//! MP × DP × PP lattice — tensor/model, data, and pipeline parallelism;
//! the paper's 2D lattice is the `pp = 1` slice) and **cluster resource
//! provisioning** (per-node compute, local + expanded memory,
//! intra-/inter-pod network) and estimates distributed-training time per
//! iteration with an analytical roofline + hierarchical-collective +
//! pipeline-schedule cost model, optionally cross-checked by a
//! discrete-event simulator.
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the COMET toolchain: workload frontend
//!   ([`workload`]), parallelization strategies and ZeRO footprint models
//!   ([`parallel`]), cluster configuration ([`config`]), the analytical cost
//!   model ([`compute`], [`network`], [`analytical`]), an ASTRA-SIM-like
//!   discrete-event simulator ([`sim`]), the design-space-exploration
//!   coordinator ([`coordinator`]), the pruned co-design optimizer
//!   ([`optimizer`]), the fault/goodput model ([`resilience`],
//!   [`analytical::goodput`]), the declarative scenario engine ([`scenario`]),
//!   figure/report drivers ([`report`]), the `comet serve` co-design
//!   service ([`serve`]), and the PJRT runtime ([`runtime`]).
//! * **L2/L1 (build-time Python)** — the same cost model expressed as a JAX
//!   graph calling Pallas kernels, AOT-lowered once to `artifacts/*.hlo.txt`
//!   and executed from Rust through the PJRT C API on the sweep hot path.
//!   Python never runs at exploration time.
//!
//! ## Quick start
//!
//! ```no_run
//! use comet::config::presets;
//! use comet::coordinator::Coordinator;
//! use comet::parallel::Strategy;
//! use comet::workload::transformer::Transformer;
//!
//! let cluster = presets::dgx_a100_1024();
//! let model = Transformer::t1()                            // Transformer-1T
//!     .build(&Strategy::new(8, 128).unwrap()).unwrap();    // MP8_DP128
//! let coord = Coordinator::native();
//! let breakdown = coord.evaluate(&model, &cluster).unwrap();
//! println!("iteration time: {:.3} s", breakdown.total());
//!
//! // The same model pipeline-parallel: 8 stages of 8-way MP, DP 16.
//! let piped = Transformer::t1()
//!     .build(&Strategy::new_3d(8, 16, 8).unwrap()).unwrap();
//! assert!(piped.pp == 8);
//! ```
//!
//! ## Scenarios
//!
//! Studies are data: a TOML file names a workload, a cluster, the swept
//! axes, and the output shape, and the [`scenario`] engine lowers it onto
//! the batched hot path. Every paper figure ships as a spec under
//! `scenarios/` (`comet scenario list`); see `docs/SCENARIOS.md` for the
//! schema and a cookbook.
//!
//! ## Throughput
//!
//! The DSE hot path is built for sweep throughput (the paper's SV-E
//! claim): the coordinator owns a persistent worker pool, results are
//! memoized in a sharded fingerprint cache, and every figure driver
//! batches its whole grid into one `evaluate_inputs` call. See
//! `BENCHMARKS.md` at the repo root for how to run `bench_dse_speed`
//! and how `BENCH_dse.json` records the wall-clock trajectory.

#![warn(missing_docs)]

pub mod analytical;
pub mod compute;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod model;
pub mod network;
pub mod optimizer;
pub mod parallel;
pub mod report;
pub mod resilience;
pub mod runtime;
pub mod scenario;
pub mod serve;
pub mod sim;
pub mod util;
pub mod workload;

pub use analytical::TrainingBreakdown;
pub use config::{ClusterConfig, NodeConfig};
pub use error::{Error, Result};
pub use parallel::Strategy;
