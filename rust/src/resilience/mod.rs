//! Fault and resilience modeling for cluster design.
//!
//! At the 1k–10k-node scale the paper targets, realized utilization is
//! governed as much by failures and stragglers as by the compute /
//! memory / network balance the rest of the crate models. This module
//! defines the declarative [`FaultModel`] that scenario specs carry:
//! per-node MTBF, a straggler slowdown distribution (fraction of nodes
//! times a slowdown factor), and link-degradation events. Everything is
//! driven by the deterministic [`crate::util::prng`] generator, so a
//! fault-injected run is reproducible from its seed.
//!
//! The model is consumed in three places:
//! * [`crate::analytical::goodput`] turns it into a closed-form
//!   efficiency factor (Young/Daly checkpoint waste, straggler and
//!   link-degradation inflation);
//! * [`crate::sim`] injects it into the discrete-event simulator
//!   (degraded service rates plus a checkpoint–restart renewal process);
//! * [`crate::optimizer`] scales its time objective by the efficiency
//!   to rank candidates by goodput instead of raw step time.

use crate::error::{Error, Result};
use crate::util::prng::Rng;

/// Seconds per hour, for MTBF unit conversion.
pub const SECONDS_PER_HOUR: f64 = 3600.0;

/// Declarative fault model attached to a scenario (`[resilience]`
/// table) or supplied with `--objective goodput`.
///
/// The disabled model ([`FaultModel::none`]) is the identity: infinite
/// MTBF, no stragglers, no link degradation. Every consumer must reduce
/// to its fault-free behaviour bit-for-bit under it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultModel {
    /// Mean time between failures of a single node, in hours.
    /// `f64::INFINITY` disables failures (and checkpointing) entirely.
    pub mtbf_node_hours: f64,
    /// Wall-clock seconds to detect a failure and restart the job from
    /// the last checkpoint (scheduling + reload, not rework).
    pub restart_s: f64,
    /// Fraction of nodes that are stragglers in any given step.
    pub straggler_frac: f64,
    /// Service-time inflation of a straggler node (>= 1). Collectives
    /// and pipeline stages gate on the slowest participant, so one
    /// straggler slows the whole step.
    pub straggler_slowdown: f64,
    /// Fraction of nodes whose links are degraded.
    pub link_degrade_frac: f64,
    /// Bandwidth-division factor on degraded links (>= 1; 2 = half
    /// bandwidth).
    pub link_degrade_factor: f64,
    /// PRNG seed for failure-time and straggler-placement sampling.
    pub seed: u64,
}

impl Default for FaultModel {
    fn default() -> Self {
        FaultModel::none()
    }
}

impl FaultModel {
    /// The disabled fault model: infinite MTBF, no stragglers, no link
    /// degradation. Consumers must behave exactly as if no fault model
    /// existed.
    pub fn none() -> FaultModel {
        FaultModel {
            mtbf_node_hours: f64::INFINITY,
            restart_s: 0.0,
            straggler_frac: 0.0,
            straggler_slowdown: 1.0,
            link_degrade_frac: 0.0,
            link_degrade_factor: 1.0,
            seed: 42,
        }
    }

    /// Documented defaults used by `--objective goodput` when the
    /// scenario spec carries no `[resilience]` table: 500 h per-node
    /// MTBF, 120 s restart, 1% stragglers at 1.5x, no link degradation.
    pub fn default_faults() -> FaultModel {
        FaultModel {
            mtbf_node_hours: 500.0,
            restart_s: 120.0,
            straggler_frac: 0.01,
            straggler_slowdown: 1.5,
            link_degrade_frac: 0.0,
            link_degrade_factor: 1.0,
            seed: 42,
        }
    }

    /// True when any fault dimension is active (seed alone does not
    /// count).
    pub fn enabled(&self) -> bool {
        self.mtbf_node_hours.is_finite()
            || (self.straggler_frac > 0.0 && self.straggler_slowdown > 1.0)
            || (self.link_degrade_frac > 0.0 && self.link_degrade_factor > 1.0)
    }

    /// Validate ranges, with actionable messages.
    pub fn validate(&self) -> Result<()> {
        let err = |m: String| Err(Error::Config(m));
        if !(self.mtbf_node_hours > 0.0) {
            return err(format!(
                "resilience: mtbf_node_hours must be > 0 (or omitted for \
                 no failures), got {}",
                self.mtbf_node_hours
            ));
        }
        if !self.restart_s.is_finite() || self.restart_s < 0.0 {
            return err(format!(
                "resilience: restart_s must be finite and >= 0, got {}",
                self.restart_s
            ));
        }
        for (name, frac) in [
            ("straggler_frac", self.straggler_frac),
            ("link_degrade_frac", self.link_degrade_frac),
        ] {
            if !frac.is_finite() || !(0.0..=1.0).contains(&frac) {
                return err(format!(
                    "resilience: {name} must be in [0, 1], got {frac}"
                ));
            }
        }
        for (name, factor) in [
            ("straggler_slowdown", self.straggler_slowdown),
            ("link_degrade_factor", self.link_degrade_factor),
        ] {
            if !factor.is_finite() || factor < 1.0 {
                return err(format!(
                    "resilience: {name} must be finite and >= 1 \
                     (1 = no effect), got {factor}"
                ));
            }
        }
        Ok(())
    }

    /// Number of straggler nodes on an `n`-node cluster (rounded).
    pub fn straggler_count(&self, n_nodes: usize) -> usize {
        ((self.straggler_frac * n_nodes as f64).round() as usize).min(n_nodes)
    }

    /// Number of nodes with degraded links on an `n`-node cluster.
    pub fn degraded_count(&self, n_nodes: usize) -> usize {
        ((self.link_degrade_frac * n_nodes as f64).round() as usize)
            .min(n_nodes)
    }

    /// Cluster-level MTBF in seconds: `n` nodes failing independently
    /// divide the per-node MTBF by `n`.
    pub fn mtbf_cluster_s(&self, n_nodes: usize) -> f64 {
        if !self.mtbf_node_hours.is_finite() {
            return f64::INFINITY;
        }
        self.mtbf_node_hours * SECONDS_PER_HOUR / n_nodes.max(1) as f64
    }

    /// Sample the wall-clock seconds until the next cluster failure
    /// (exponential with mean [`FaultModel::mtbf_cluster_s`]). Returns
    /// infinity when failures are disabled.
    pub fn time_to_failure(&self, rng: &mut Rng, n_nodes: usize) -> f64 {
        let m = self.mtbf_cluster_s(n_nodes);
        if !m.is_finite() {
            return f64::INFINITY;
        }
        // Inverse-CDF sampling; 1 - u is in (0, 1] so ln is finite.
        -(1.0 - rng.f64()).ln() * m
    }
}

/// Effective checkpoint bandwidth: state is read out of the tier it
/// lives in (expanded memory at `bw_em` when attached, local HBM at
/// `bw_lm` otherwise) and streamed over the inter-pod network at
/// `bw_inter`; the slower leg bounds the write. A strategy that leans
/// on memory expansion therefore checkpoints its larger footprint at a
/// rate the EM tier can cap, so the memory-expansion story also changes
/// checkpoint time.
pub fn checkpoint_bandwidth(bw_inter: f64, bw_lm: f64, bw_em: f64) -> f64 {
    let read = if bw_em > 0.0 { bw_em } else { bw_lm };
    bw_inter.min(read)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_disabled_and_valid() {
        let f = FaultModel::none();
        assert!(!f.enabled());
        f.validate().unwrap();
        assert_eq!(f, FaultModel::default());
        assert!(f.mtbf_cluster_s(1024).is_infinite());
        assert_eq!(f.straggler_count(1024), 0);
        assert_eq!(f.degraded_count(1024), 0);
    }

    #[test]
    fn default_faults_are_enabled_and_valid() {
        let f = FaultModel::default_faults();
        assert!(f.enabled());
        f.validate().unwrap();
        // 500 h over 1024 nodes ~ 1758 s cluster MTBF.
        let m = f.mtbf_cluster_s(1024);
        assert!((m - 500.0 * 3600.0 / 1024.0).abs() < 1e-9, "{m}");
        assert_eq!(f.straggler_count(1024), 10);
    }

    #[test]
    fn validate_rejects_bad_ranges() {
        let cases: &[(&str, FaultModel)] = &[
            ("mtbf", FaultModel { mtbf_node_hours: 0.0, ..FaultModel::none() }),
            (
                "mtbf-nan",
                FaultModel { mtbf_node_hours: f64::NAN, ..FaultModel::none() },
            ),
            ("restart", FaultModel { restart_s: -1.0, ..FaultModel::none() }),
            (
                "frac",
                FaultModel { straggler_frac: 1.5, ..FaultModel::none() },
            ),
            (
                "slowdown",
                FaultModel { straggler_slowdown: 0.5, ..FaultModel::none() },
            ),
            (
                "degrade",
                FaultModel {
                    link_degrade_factor: f64::NAN,
                    ..FaultModel::none()
                },
            ),
        ];
        for (tag, f) in cases {
            assert!(f.validate().is_err(), "{tag} should be rejected");
        }
    }

    #[test]
    fn failure_sampling_is_seed_deterministic() {
        let f = FaultModel { mtbf_node_hours: 100.0, ..FaultModel::none() };
        let sample = |seed| {
            let mut rng = Rng::new(seed);
            (0..32).map(|_| f.time_to_failure(&mut rng, 256)).collect()
        };
        let a: Vec<f64> = sample(7);
        let b: Vec<f64> = sample(7);
        let c: Vec<f64> = sample(8);
        assert_eq!(a, b);
        assert_ne!(a, c);
        let m = f.mtbf_cluster_s(256);
        for t in &a {
            assert!(t.is_finite() && *t >= 0.0);
        }
        // The empirical mean of many samples should be near the MTBF.
        let mut rng = Rng::new(1);
        let n = 20_000;
        let mean: f64 =
            (0..n).map(|_| f.time_to_failure(&mut rng, 256)).sum::<f64>()
                / n as f64;
        assert!((mean - m).abs() / m < 0.05, "mean {mean} vs mtbf {m}");
    }

    #[test]
    fn checkpoint_bandwidth_takes_the_slower_leg() {
        // No EM: HBM read, network-bound.
        assert_eq!(checkpoint_bandwidth(31.25e9, 2e12, 0.0), 31.25e9);
        // Fast EM: still network-bound.
        assert_eq!(checkpoint_bandwidth(31.25e9, 2e12, 2.039e12), 31.25e9);
        // Slow EM tier caps the read-out below the network.
        assert_eq!(checkpoint_bandwidth(31.25e9, 2e12, 10e9), 10e9);
    }
}
