//! COMET command-line leader: design-space sweeps, figure regeneration,
//! workload/config inspection, and cross-backend validation.
//!
//! ```text
//! comet scenario <run FILE-or-NAME.. | list | show NAME | export NAME>
//!       [--backend native|des|artifact|auto] [--out-dir DIR] [--out FILE]
//!       [--json] [--verbose]
//!       (run accepts several targets; they share one coordinator, so
//!        the derive cache carries across the studies)
//! comet optimize [SCENARIO] [--workload W] [--cluster PRESET] [--backend B]
//!       [--min-mp N] [--max-mp N] [--max-pp N] [--microbatches M]
//!       [--schedule gpipe|1f1b] [--em-bandwidths GB/s,..]
//!       [--em-capacities GB,..] [--collectives ring,hierarchical]
//!       [--zero-stages 0,2,..] [--top-k N] [--threads N]
//!       [--objective time|goodput] [--infinite-memory] [--json]
//!       [--deadline SECS] [--checkpoint FILE] [--checkpoint-every SECS]
//!       [--resume FILE] [--cross-check des]
//!       (SCENARIO = an optimize/pipeline builtin name or TOML path,
//!        e.g. `comet optimize pipeline-transformer`; --threads N sets
//!        the search's evaluation lanes — the result is bit-identical
//!        at every N; --objective goodput ranks by fault-adjusted
//!        effective time under the spec's [resilience] model;
//!        --deadline stops the search at a safe boundary when the
//!        budget expires and reports the partial best-so-far table;
//!        SIGINT does the same; either flushes --checkpoint when set,
//!        and --resume continues from it to a final result that is
//!        bit-identical to an uninterrupted run at any thread count;
//!        --cross-check des re-simulates every top-k candidate on the
//!        DES engine and reports the analytical/DES divergence)
//! comet serve [--addr HOST:PORT] [--max-queue N] [--max-concurrency N]
//!       [--request-deadline SECS] [--backend B] [--threads N]
//!       (the co-design service: POST /run takes a ScenarioSpec JSON
//!        body on one shared coordinator — warm caches across requests;
//!        GET /stats and GET /healthz report counters and liveness;
//!        a full admission queue sheds load with 503 + Retry-After;
//!        SIGINT/SIGTERM drains gracefully and exits 0 — see
//!        docs/SERVE.md)
//! comet figure <fig6|fig8a|fig8b|fig9|fig10|fig11|fig12|fig13a|fig13b|fig15|all>
//!       [--backend native|des|artifact] [--out-dir DIR] [--csv]
//! comet sweep   [--cluster PRESET] [--backend B] [--infinite-memory]
//! comet eval    --strategy MP8_DP128 [--cluster PRESET] [--backend B]
//! comet footprint [--zero 0|1|2|3]
//! comet config  <list|show NAME>
//! comet workload --model MODEL [--mp N] [--dp N] [--nodes N]
//! comet compare [--backend B]
//! comet validate
//! ```
//!
//! Exit codes: `0` = success (including a `comet serve` graceful drain
//! on SIGINT/SIGTERM); `2` = partial result (deadline expired or run
//! cancelled — best-so-far printed, checkpoint flushed when
//! configured); `3` = configuration / input error; `4` = internal error
//! (worker panic, backend failure).

use std::path::Path;
use std::process::ExitCode;

use comet::config::presets;
use comet::coordinator::{sweep, Coordinator};
use comet::error::{Error, Result};
use comet::model::inputs::{derive_inputs, EvalOptions};
use comet::optimizer::Objective;
use comet::parallel::{footprint_per_node, Strategy, ZeroStage};
use comet::report::FigureData;
use comet::scenario::{
    self, registry, BackendSpec, OptionsSpec, OutputFormat, OutputSpec,
    ScenarioSpec, StrategyAxis, Study, WorkloadSpec,
};
use comet::serve::{ServeConfig, Server};
use comet::util::units::{fmt_bytes, fmt_secs};
use comet::workload::dlrm::Dlrm;
use comet::workload::transformer::Transformer;
use comet::workload::{trace, Workload};

/// Minimal argument cursor: positionals + --flag [value] pairs.
struct Args {
    positional: Vec<String>,
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    fn parse(raw: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < raw.len() {
            if let Some(name) = raw[i].strip_prefix("--") {
                let value = raw
                    .get(i + 1)
                    .filter(|v| !v.starts_with("--"))
                    .cloned();
                if value.is_some() {
                    i += 1;
                }
                flags.push((name.to_string(), value));
            } else {
                positional.push(raw[i].clone());
            }
            i += 1;
        }
        Args { positional, flags }
    }

    fn flag(&self, name: &str) -> Option<&str> {
        self.flags
            .iter()
            .find(|(n, _)| n == name)
            .and_then(|(_, v)| v.as_deref())
    }

    fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|(n, _)| n == name)
    }
}

fn coordinator_for(args: &Args) -> Result<Coordinator> {
    match args.flag("backend").unwrap_or("native") {
        "native" => Ok(Coordinator::native()),
        "des" => Ok(Coordinator::des()),
        "artifact" => Coordinator::artifact(),
        "auto" => Ok(Coordinator::auto()),
        other => Err(Error::Config(format!(
            "unknown backend '{other}' (native|des|artifact|auto)"
        ))),
    }
}

fn cluster_for(args: &Args) -> Result<comet::ClusterConfig> {
    let name = args.flag("cluster").unwrap_or("baseline");
    if let Some(c) = presets::by_name(name) {
        return Ok(c);
    }
    // Fall back to a config file path.
    let p = Path::new(name);
    if p.exists() {
        return comet::ClusterConfig::load(p);
    }
    Err(Error::Config(format!(
        "unknown cluster '{name}'; presets: {:?}",
        presets::preset_names()
    )))
}

fn workload_for(args: &Args) -> Result<Workload> {
    let model = args.flag("model").unwrap_or("transformer-1t");
    let nodes: usize = args
        .flag("nodes")
        .map(|v| v.parse().unwrap_or(64))
        .unwrap_or(64);
    match model {
        "transformer-1t" | "transformer-100m" => {
            let t = if model == "transformer-1t" {
                Transformer::t1()
            } else {
                Transformer::t100m()
            };
            let strategy = match args.flag("strategy") {
                Some(s) => Strategy::parse(s)?,
                None => Strategy::new(
                    args.flag("mp").map(|v| v.parse().unwrap_or(8)).unwrap_or(8),
                    args.flag("dp")
                        .map(|v| v.parse().unwrap_or(128))
                        .unwrap_or(128),
                )?,
            };
            t.build(&strategy)
        }
        "dlrm-1.2t" => Dlrm::dlrm_1_2t().build(nodes),
        "dlrm-small" => Dlrm::small().build(nodes),
        other => Err(Error::Config(format!("unknown model '{other}'"))),
    }
}

fn emit_figure(f: &FigureData, args: &Args) -> Result<()> {
    if args.has("json") {
        // Machine-readable stdout (CI byte-diffs thread counts on it);
        // wins over the table and --csv prints, not over --out-dir.
        println!("{}", f.to_json().to_string_pretty());
    } else {
        println!("{}", f.to_table());
    }
    if let Some(dir) = args.flag("out-dir") {
        std::fs::create_dir_all(dir)?;
        let path = Path::new(dir).join(format!("{}.csv", f.id));
        std::fs::write(&path, f.to_csv())?;
        if !args.has("json") {
            println!("  wrote {}", path.display());
        }
    } else if args.has("csv") && !args.has("json") {
        println!("{}", f.to_csv());
    }
    Ok(())
}

fn cmd_figure(args: &Args) -> Result<()> {
    let coord = coordinator_for(args)?;
    let which = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("all");
    let figs: Vec<FigureData> = match which {
        "all" => sweep::all_figures(&coord)?,
        "fig6" => vec![sweep::fig6()],
        "fig8a" => vec![sweep::fig8a(&coord)?],
        "fig8b" => vec![sweep::fig8b(&coord)?],
        "fig9" => vec![sweep::fig9(&coord)?],
        "fig10" => vec![sweep::fig10(&coord)?],
        "fig11" => vec![sweep::fig11(&coord)?],
        "fig12" => vec![sweep::fig12(&coord)?],
        "fig13a" => vec![sweep::fig13a(&coord)?],
        "fig13b" => vec![sweep::fig13b(&coord)?],
        "fig15" => vec![sweep::fig15(&coord)?],
        "ablation-collectives" => vec![sweep::ablation_collectives(&coord)?],
        "ablation-zero" => vec![sweep::ablation_zero(&coord)?],
        "ablations" => vec![
            sweep::ablation_collectives(&coord)?,
            sweep::ablation_zero(&coord)?,
        ],
        other => {
            return Err(Error::Config(format!("unknown figure '{other}'")))
        }
    };
    for f in &figs {
        emit_figure(f, args)?;
    }
    let (hits, misses) = coord.cache_stats();
    eprintln!(
        "[comet] backend={:?} cache {hits} hits / {misses} misses",
        coord.backend()
    );
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let coord = coordinator_for(args)?;
    let cluster = cluster_for(args)?;
    let opts = EvalOptions {
        ignore_capacity: args.has("infinite-memory"),
        ..Default::default()
    };
    println!(
        "{:>14} {:>12} {:>12} {:>12} {:>12}",
        "config", "compute", "exposed", "total", "footprint"
    );
    for s in
        Strategy::sweep_bounded(cluster.n_nodes, 1, 128.min(cluster.n_nodes))?
    {
        let w = match Transformer::t1().build(&s) {
            Ok(w) => w,
            Err(_) => continue,
        };
        let fp = footprint_per_node(&w, &s, opts.zero_stage).total();
        let inputs = derive_inputs(&w, &cluster, &opts)?;
        let b = coord.evaluate_inputs(std::slice::from_ref(&inputs))?[0];
        println!(
            "{:>14} {:>12} {:>12} {:>12} {:>12}",
            s.label(),
            fmt_secs(b.compute()),
            fmt_secs(b.exposed_comm()),
            fmt_secs(b.total()),
            fmt_bytes(fp),
        );
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let coord = coordinator_for(args)?;
    let cluster = cluster_for(args)?;
    let w = workload_for(args)?;
    let b = coord.evaluate(&w, &cluster)?;
    println!("workload : {}", w.name);
    println!("cluster  : {}", cluster.name);
    println!("backend  : {:?}", coord.backend());
    println!(
        "FP  compute {:>12}  exposed {:>12}",
        fmt_secs(b.fp_compute),
        fmt_secs(b.fp_exposed_comm)
    );
    println!(
        "IG  compute {:>12}  exposed {:>12}",
        fmt_secs(b.ig_compute),
        fmt_secs(b.ig_exposed_comm)
    );
    println!(
        "WG  compute {:>12}  exposed {:>12}",
        fmt_secs(b.wg_compute),
        fmt_secs(b.wg_exposed_comm)
    );
    println!("total iteration time: {}", fmt_secs(b.total()));
    Ok(())
}

fn cmd_footprint(args: &Args) -> Result<()> {
    let stage = match args.flag("zero").unwrap_or("2") {
        "0" => ZeroStage::Baseline,
        "1" => ZeroStage::Os,
        "2" => ZeroStage::OsG,
        "3" => ZeroStage::OsGP,
        other => {
            return Err(Error::Config(format!("unknown ZeRO stage '{other}'")))
        }
    };
    let f = sweep::fig6();
    println!("{}", f.to_table());
    println!("selected stage: {}", stage.label());
    Ok(())
}

fn cmd_config(args: &Args) -> Result<()> {
    match args.positional.get(1).map(String::as_str) {
        Some("list") | None => {
            for n in presets::preset_names() {
                let c = presets::by_name(n).unwrap();
                println!(
                    "{:<12} {:>5} nodes  {:>10} peak  {:>9} local  {:>9} expanded",
                    n,
                    c.n_nodes,
                    format!("{:.0}T", c.node.perf_peak / 1e12),
                    fmt_bytes(c.node.local.capacity),
                    fmt_bytes(c.node.expanded.capacity),
                );
            }
            Ok(())
        }
        Some("show") => {
            let name = args
                .positional
                .get(2)
                .ok_or_else(|| Error::Config("config show NAME".into()))?;
            let c = presets::by_name(name).ok_or_else(|| {
                Error::Config(format!("unknown preset '{name}'"))
            })?;
            println!("{}", c.to_json().to_string_pretty());
            Ok(())
        }
        Some(other) => Err(Error::Config(format!("unknown config cmd '{other}'"))),
    }
}

fn cmd_workload(args: &Args) -> Result<()> {
    let w = workload_for(args)?;
    print!("{}", trace::emit(&w));
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let coord = coordinator_for(args)?;
    emit_figure(&sweep::fig15(&coord)?, args)
}

fn cmd_validate(_args: &Args) -> Result<()> {
    // Cross-backend validation: native vs DES vs artifact on a spread of
    // configurations; prints max relative difference per pair.
    let native = Coordinator::native();
    let des = Coordinator::des();
    let artifact = Coordinator::artifact().ok();
    let cluster = presets::dgx_a100_1024();
    let opts = EvalOptions {
        ignore_capacity: true,
        ..Default::default()
    };
    let mut max_nd: f64 = 0.0;
    let mut max_na: f64 = 0.0;
    for s in Strategy::sweep_bounded(1024, 1, 128)? {
        let w = Transformer::t1().build(&s)?;
        let inputs = derive_inputs(&w, &cluster, &opts)?;
        let n = native.evaluate_inputs(std::slice::from_ref(&inputs))?[0];
        let d = des.evaluate_inputs(std::slice::from_ref(&inputs))?[0];
        let nd = (n.total() - d.total()).abs() / n.total();
        max_nd = max_nd.max(nd);
        if let Some(a) = &artifact {
            let ab = a.evaluate_inputs(std::slice::from_ref(&inputs))?[0];
            let na = (n.total() - ab.total()).abs() / n.total();
            max_na = max_na.max(na);
        }
        println!(
            "{:>14}: native {:>10}  des {:>10}  delta {:.3}%",
            s.label(),
            fmt_secs(n.total()),
            fmt_secs(d.total()),
            nd * 100.0
        );
    }
    println!("max native-vs-DES delta      : {:.3}%", max_nd * 100.0);
    if artifact.is_some() {
        println!("max native-vs-artifact delta : {:.4}%", max_na * 100.0);
    } else {
        println!("artifact backend unavailable (run `make artifacts`)");
    }
    if max_nd > 0.05 || max_na > 0.001 {
        return Err(Error::Runtime("cross-backend validation failed".into()));
    }
    println!("validation OK");
    Ok(())
}

/// Parse a comma-separated list of numbers ("250,500,2039").
fn csv_f64(s: &str, flag: &str) -> Result<Vec<f64>> {
    s.split(',')
        .filter(|p| !p.trim().is_empty())
        .map(|p| {
            p.trim().parse::<f64>().map_err(|_| {
                Error::Config(format!("--{flag}: bad number '{p}'"))
            })
        })
        .collect()
}

/// Parse a `--flag SECS` non-negative seconds value.
fn secs_flag(args: &Args, name: &str) -> Result<Option<f64>> {
    match args.flag(name) {
        None => Ok(None),
        Some(v) => match v.parse::<f64>() {
            Ok(d) if d >= 0.0 && d.is_finite() => Ok(Some(d)),
            _ => Err(Error::Config(format!(
                "--{name}: bad value '{v}' (seconds >= 0)"
            ))),
        },
    }
}

/// `comet optimize`: construct an optimize scenario from flags and run
/// the branch-and-bound search. The same engine as
/// `comet scenario run optimize-*`, parameterized from the command line.
///
/// With a positional target (`comet optimize pipeline-transformer` or a
/// TOML path), the spec's own lattice is searched instead — the target
/// must be an `optimize` or `pipeline` study.
///
/// Returns the process exit code: success exits 0, a partial result
/// (deadline expired or SIGINT) prints the best-so-far table, flushes
/// the checkpoint when one is configured, and exits 2.
fn cmd_optimize(args: &Args) -> Result<ExitCode> {
    // --threads N: evaluation lanes for the search (and the pool width
    // backing them). The outcome is bit-identical at every N — CI diffs
    // the --threads 1 and --threads 4 JSON byte-for-byte.
    let threads = match args.flag("threads") {
        None => None,
        Some(v) => match v.parse::<usize>() {
            Ok(n) if n >= 1 => Some(n),
            _ => {
                return Err(Error::Config(format!(
                    "--threads: bad value '{v}' (integer >= 1)"
                )))
            }
        },
    };
    // --objective time|goodput: ranking objective for the search. The
    // goodput objective needs a fault model; the spec's [resilience]
    // table supplies it (or the documented defaults when absent).
    let objective = match args.flag("objective") {
        None => None,
        Some(v) => Some(Objective::parse(v)?),
    };
    // --cross-check des: after the search, re-simulate every top-k
    // candidate on the DES engine and report the analytical/DES
    // divergence. Validated up front so a typo fails before the search.
    match args.flag("cross-check") {
        None | Some("des") => {}
        Some(other) => {
            return Err(Error::Config(format!(
                "--cross-check: unknown mode '{other}' (supported: des)"
            )))
        }
    }
    // Execution-robustness flags: a wall-clock budget, a checkpoint to
    // flush resumable search state to, and a checkpoint to resume from.
    // SIGINT cancels cooperatively at the next safe boundary — the
    // search still returns its partial result and flushes the
    // checkpoint before the process exits.
    let exec = scenario::ExecOverrides {
        token: Some(comet::util::cancel::install_signal_token()),
        resume: args.flag("resume").map(String::from),
        deadline_s: secs_flag(args, "deadline")?,
        checkpoint: args.flag("checkpoint").map(String::from),
        checkpoint_every_s: secs_flag(args, "checkpoint-every")?,
    };
    let mut coord = coordinator_for(args)?;
    if let Some(n) = threads {
        coord = coord.with_threads(n);
    }
    if let Some(target) = args.positional.get(1) {
        let mut spec = scenario_spec_for(target)?;
        if !matches!(
            spec.study,
            Study::Optimize { .. } | Study::Pipeline { .. }
        ) {
            return Err(Error::Config(format!(
                "comet optimize needs an optimize or pipeline study; '{}' \
                 is a {} study",
                spec.name,
                spec.study.kind()
            )));
        }
        // The flags outrank the spec's own study options.
        if let (Some(n), Study::Optimize { threads: t, .. }) =
            (threads, &mut spec.study)
        {
            *t = Some(n);
        }
        match (objective, &mut spec.study) {
            (Some(o), Study::Optimize { objective: obj, .. }) => *obj = o,
            (Some(_), _) => {
                return Err(Error::Config(format!(
                    "--objective applies to optimize studies; '{}' is a {} \
                     study",
                    spec.name,
                    spec.study.kind()
                )))
            }
            (None, _) => {}
        }
        let (fig, out) = scenario::run_optimize_exec(&spec, &coord, &exec)?;
        return finish_optimize(args, &coord, &spec, &fig, &out);
    }
    let cluster = cluster_for(args)?;
    let workload = match args.flag("workload").unwrap_or("transformer-1t") {
        "transformer-1t" => WorkloadSpec::Transformer(Transformer::t1()),
        "transformer-100m" => WorkloadSpec::Transformer(Transformer::t100m()),
        "dlrm-1.2t" => WorkloadSpec::Dlrm(Dlrm::dlrm_1_2t()),
        "dlrm-small" => WorkloadSpec::Dlrm(Dlrm::small()),
        other => {
            return Err(Error::Config(format!(
                "unknown workload '{other}' (transformer-1t|transformer-100m|\
                 dlrm-1.2t|dlrm-small)"
            )))
        }
    };
    let num_flag = |name: &str, default: usize| -> Result<usize> {
        match args.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                Error::Config(format!("--{name}: bad integer '{v}'"))
            }),
        }
    };
    // Reuse the scenario-file parsers so the CLI and TOML surfaces accept
    // exactly the same values (scenario::collective_of / zero_stage_of
    // reject unknown names and non-integer stages alike).
    let collectives = match args.flag("collectives") {
        None => Vec::new(),
        Some(s) => s
            .split(',')
            .filter(|p| !p.trim().is_empty())
            .map(|p| scenario::collective_of(p.trim()))
            .collect::<Result<Vec<_>>>()?,
    };
    let zero_stages = match args.flag("zero-stages") {
        None => Vec::new(),
        Some(s) => csv_f64(s, "zero-stages")?
            .into_iter()
            .map(scenario::zero_stage_of)
            .collect::<Result<Vec<_>>>()?,
    };
    // DLRM workloads have no strategy axis: leave it at the spec default
    // unless the user explicitly bounded it (optimizer_for then rejects
    // the combination loudly).
    let strategies = if matches!(workload, WorkloadSpec::Dlrm(_))
        && args.flag("min-mp").is_none()
        && args.flag("max-mp").is_none()
        && args.flag("max-pp").is_none()
    {
        StrategyAxis::Pow2 {
            min_mp: 1,
            max_mp: None,
            max_pp: 1,
        }
    } else {
        StrategyAxis::Pow2 {
            min_mp: num_flag("min-mp", 1)?,
            max_mp: Some(num_flag("max-mp", 128.min(cluster.n_nodes))?),
            max_pp: match num_flag("max-pp", 1)? {
                0 => {
                    return Err(Error::Config(
                        "--max-pp must be >= 1".into(),
                    ))
                }
                p => p,
            },
        }
    };
    let study = Study::Optimize {
        strategies,
        em_bandwidths_gbps: match args.flag("em-bandwidths") {
            Some(s) => csv_f64(s, "em-bandwidths")?,
            None => Vec::new(),
        },
        em_capacities_gb: match args.flag("em-capacities") {
            Some(s) => csv_f64(s, "em-capacities")?,
            None => Vec::new(),
        },
        collectives,
        zero_stages,
        top_k: match num_flag("top-k", 5)? {
            0 => {
                return Err(Error::Config(
                    "--top-k must be >= 1".into(),
                ))
            }
            k => k,
        },
        threads,
        objective: objective.unwrap_or_default(),
        // The execution knobs travel via `ExecOverrides` (built from the
        // flags above), not the ad-hoc spec.
        deadline_s: None,
        checkpoint: None,
        checkpoint_every_s: None,
    };
    let spec = ScenarioSpec {
        name: "optimize".into(),
        title: format!(
            "Optimize {} on {} ({} nodes)",
            workload.name(),
            cluster.name,
            cluster.n_nodes
        ),
        workload,
        cluster,
        study,
        options: OptionsSpec {
            infinite_memory: args.has("infinite-memory"),
            microbatches: match num_flag("microbatches", 8)? {
                0 => {
                    return Err(Error::Config(
                        "--microbatches must be >= 1".into(),
                    ))
                }
                n => n,
            },
            schedule: match args.flag("schedule") {
                Some(s) => comet::parallel::PipeSchedule::parse(s)?,
                None => comet::parallel::PipeSchedule::OneFOneB,
            },
            ..Default::default()
        },
        output: OutputSpec::default(),
    };
    let (fig, out) = scenario::run_optimize_exec(&spec, &coord, &exec)?;
    finish_optimize(args, &coord, &spec, &fig, &out)
}

/// Emit the optimize result and map its completeness to an exit code:
/// 0 for a finished search, 2 for a partial (deadline/cancel) one.
fn finish_optimize(
    args: &Args,
    coord: &Coordinator,
    spec: &ScenarioSpec,
    fig: &FigureData,
    out: &comet::optimizer::Outcome,
) -> Result<ExitCode> {
    emit_figure(fig, args)?;
    report_optimize_stats(coord, out);
    if args.flag("cross-check") == Some("des") {
        let rows = scenario::cross_check_des(spec, coord, out)?;
        let mut worst = 0.0f64;
        for r in &rows {
            eprintln!(
                "[comet] cross-check des: {} analytical={:.6e}s \
                 des={:.6e}s rel_diff={:.4}",
                r.label, r.analytical_s, r.des_s, r.rel_diff
            );
            worst = worst.max(r.rel_diff);
        }
        if worst > 0.05 {
            eprintln!(
                "[comet] cross-check des: WARNING — worst analytical/DES \
                 divergence {worst:.4} exceeds 0.05; the analytical \
                 ranking may be unreliable for this lattice"
            );
        } else {
            eprintln!(
                "[comet] cross-check des: {} candidates re-simulated, \
                 worst rel_diff {worst:.4}",
                rows.len()
            );
        }
    }
    if let Some(stop) = &out.stop {
        eprintln!(
            "[comet] PARTIAL ({}): {} of {} lattice points unexplored; \
             best-so-far reported — resume from the checkpoint to finish",
            stop.label(),
            out.remaining,
            out.total_points
        );
        return Ok(ExitCode::from(2));
    }
    Ok(ExitCode::SUCCESS)
}

/// Shared stderr report for `comet optimize` (flag and spec-target modes).
fn report_optimize_stats(coord: &Coordinator, out: &comet::optimizer::Outcome) {
    let (hits, misses) = coord.cache_stats();
    let (dh, dm) = coord.derive_cache_stats();
    eprintln!(
        "[comet] optimizer backend={:?}: evaluated {}/{} points, {} pruned \
         by bound, {} infeasible; eval cache {hits}/{misses} hit/miss, \
         {dm} decompositions ({dh} reused)",
        coord.backend(),
        out.evaluated,
        out.total_points,
        out.pruned,
        out.infeasible,
    );
}

/// `comet serve`: bind the co-design service on `--addr` and serve
/// `POST /run` / `GET /stats` / `GET /healthz` on one shared
/// coordinator until SIGINT or SIGTERM, then drain gracefully — stop
/// accepting, finish every admitted request — and exit 0. The
/// robustness contract (bounded admission with 503 load-shedding,
/// per-request deadlines and disconnect cancellation, per-request
/// panic isolation) is documented in docs/SERVE.md.
fn cmd_serve(args: &Args) -> Result<ExitCode> {
    let mut coord = coordinator_for(args)?;
    if let Some(v) = args.flag("threads") {
        match v.parse::<usize>() {
            Ok(n) if n >= 1 => coord = coord.with_threads(n),
            _ => {
                return Err(Error::Config(format!(
                    "--threads: bad value '{v}' (integer >= 1)"
                )))
            }
        }
    }
    let usize_flag = |name: &str, default: usize| -> Result<usize> {
        match args.flag(name) {
            None => Ok(default),
            Some(v) => v.parse::<usize>().map_err(|_| {
                Error::Config(format!("--{name}: bad integer '{v}'"))
            }),
        }
    };
    let defaults = ServeConfig::default();
    let cfg = ServeConfig {
        addr: args
            .flag("addr")
            .unwrap_or(defaults.addr.as_str())
            .to_string(),
        max_queue: usize_flag("max-queue", defaults.max_queue)?,
        max_concurrency: usize_flag(
            "max-concurrency",
            defaults.max_concurrency,
        )?,
        request_deadline_s: secs_flag(args, "request-deadline")?,
    };
    let server = Server::bind(cfg, coord)?;
    let addr = server.local_addr()?;
    println!("comet serve: listening on http://{addr}");
    // The CI smoke test and the socket tests parse the port from that
    // line; a piped stdout is block-buffered, so flush explicitly.
    use std::io::Write as _;
    std::io::stdout()
        .flush()
        .map_err(|e| Error::Io(format!("serve: flush stdout: {e}")))?;
    let shutdown = comet::util::cancel::install_signal_token();
    server.run(&shutdown)?;
    eprintln!("[comet] serve: drained; exiting");
    Ok(ExitCode::SUCCESS)
}

/// Resolve a `scenario run|show|export` target: a file if one exists at
/// that path, otherwise a built-in registry name (so a stray directory
/// named like a built-in cannot shadow it).
fn scenario_spec_for(target: &str) -> Result<ScenarioSpec> {
    let p = Path::new(target);
    if p.is_file() {
        ScenarioSpec::load(p)
    } else {
        registry::get(target)
    }
}

fn cmd_scenario(args: &Args) -> Result<()> {
    match args.positional.get(1).map(String::as_str) {
        Some("run") => {
            let targets = &args.positional[2..];
            if targets.is_empty() {
                return Err(Error::Config(
                    "scenario run <FILE|NAME>..".into(),
                ));
            }
            // All targets of one invocation share coordinators (one per
            // distinct backend, built lazily): the derive cache — and
            // its decompositions — carries across the studies, so a
            // multi-study run decomposes each distinct workload once.
            // --backend overrides every spec's choice.
            let flag_coord = if args.flag("backend").is_some() {
                Some(coordinator_for(args)?)
            } else {
                None
            };
            let mut coords: Vec<(BackendSpec, Coordinator)> = Vec::new();
            for target in targets {
                let spec = scenario_spec_for(target)?;
                let coord: &Coordinator = match &flag_coord {
                    Some(c) => c,
                    None => {
                        let bs = spec.options.backend;
                        if !coords.iter().any(|(b, _)| *b == bs) {
                            coords.push((bs, bs.coordinator()?));
                        }
                        &coords.iter().find(|(b, _)| *b == bs).unwrap().1
                    }
                };
                // Optimize studies keep their search report so --verbose
                // can surface evaluated/pruned counts without re-running.
                let (fig, search) =
                    if matches!(spec.study, Study::Optimize { .. }) {
                        let (fig, out) =
                            scenario::run_optimize(&spec, coord)?;
                        (fig, Some(out))
                    } else {
                        (scenario::run(&spec, coord)?, None)
                    };
                // --json overrides the spec's declared output format.
                let format = if args.has("json") {
                    OutputFormat::Json
                } else {
                    spec.output.format
                };
                match format {
                    OutputFormat::Table => println!("{}", fig.to_table()),
                    OutputFormat::Csv => println!("{}", fig.to_csv()),
                    OutputFormat::Json => {
                        println!("{}", fig.to_json().to_string_pretty())
                    }
                }
                if let Some(dir) = args.flag("out-dir") {
                    std::fs::create_dir_all(dir)?;
                    // Persist in the effective format (table output is
                    // persisted as plot-ready CSV, like `comet figure`).
                    let (ext, payload) = match format {
                        OutputFormat::Table | OutputFormat::Csv => {
                            ("csv", fig.to_csv())
                        }
                        OutputFormat::Json => {
                            ("json", fig.to_json().to_string_pretty())
                        }
                    };
                    let path =
                        Path::new(dir).join(format!("{}.{ext}", fig.id));
                    std::fs::write(&path, payload)?;
                    if !args.has("json") {
                        // Keep --json stdout pure (byte-diffable) JSON.
                        println!("  wrote {}", path.display());
                    }
                }
                // Reprinted from the structured snapshot (the same one
                // `GET /stats` serves) — the strings stay byte-identical
                // to the pre-snapshot wording.
                let st = coord.stats();
                eprintln!(
                    "[comet] scenario '{}' backend={:?} cache {hits} hits / \
                     {misses} misses",
                    spec.name,
                    coord.backend(),
                    hits = st.eval_hits,
                    misses = st.eval_misses,
                );
                if args.has("verbose") {
                    eprintln!(
                        "[comet] derive cache {dh} hits / {dm} misses \
                         ({dm} workload decompositions; cumulative across \
                         this run's studies)",
                        dh = st.derive_hits,
                        dm = st.derive_misses,
                    );
                    if let Some(out) = &search {
                        eprintln!(
                            "[comet] optimizer: evaluated {}/{} points, {} \
                             pruned by bound, {} infeasible, frontier {}",
                            out.evaluated,
                            out.total_points,
                            out.pruned,
                            out.infeasible,
                            out.frontier.len()
                        );
                    }
                }
            }
            Ok(())
        }
        Some("list") | None => {
            for name in registry::names() {
                let spec = registry::get(name)?;
                println!(
                    "{name:<22} [{:<17}] {}",
                    spec.study.kind(),
                    spec.title
                );
            }
            println!("\nrun one with: comet scenario run <NAME>");
            println!("or from a file: comet scenario run scenarios/<NAME>.toml");
            Ok(())
        }
        Some("show") => {
            let target = args.positional.get(2).ok_or_else(|| {
                Error::Config("scenario show <FILE|NAME>".into())
            })?;
            let spec = scenario_spec_for(target)?;
            println!("{}", spec.to_json().to_string_pretty());
            Ok(())
        }
        Some("export") => {
            let target = args.positional.get(2).ok_or_else(|| {
                Error::Config("scenario export <FILE|NAME> [--out FILE]".into())
            })?;
            let spec = scenario_spec_for(target)?;
            let toml = spec.to_toml()?;
            match args.flag("out") {
                Some(path) => {
                    std::fs::write(path, &toml)?;
                    println!("wrote {path}");
                }
                None => print!("{toml}"),
            }
            Ok(())
        }
        Some(other) => Err(Error::Config(format!(
            "unknown scenario cmd '{other}' (run|list|show|export)"
        ))),
    }
}

const USAGE: &str = "usage: comet <scenario|optimize|serve|figure|sweep|eval|footprint|config|workload|compare|validate> [options]
see README.md for per-command options";

fn run() -> Result<ExitCode> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw);
    let done = |r: Result<()>| r.map(|()| ExitCode::SUCCESS);
    match args.positional.first().map(String::as_str) {
        Some("scenario") => done(cmd_scenario(&args)),
        Some("optimize") => cmd_optimize(&args),
        Some("serve") => cmd_serve(&args),
        Some("figure") => done(cmd_figure(&args)),
        Some("sweep") => done(cmd_sweep(&args)),
        Some("eval") => done(cmd_eval(&args)),
        Some("footprint") => done(cmd_footprint(&args)),
        Some("config") => done(cmd_config(&args)),
        Some("workload") => done(cmd_workload(&args)),
        Some("compare") => done(cmd_compare(&args)),
        Some("validate") => done(cmd_validate(&args)),
        _ => {
            eprintln!("{USAGE}");
            Err(Error::Config("no command given".into()))
        }
    }
}

/// Map an error to its documented exit code: `2` = stopped by a
/// deadline or cancel, `3` = configuration / input problem, `4` =
/// internal failure (worker panic, backend/runtime error).
fn exit_code_for(e: &Error) -> ExitCode {
    match e {
        Error::Cancelled(_) | Error::Deadline(_) => ExitCode::from(2),
        Error::Config(_)
        | Error::Parse(_)
        | Error::Json(_)
        | Error::Io(_)
        | Error::Artifact(_) => ExitCode::from(3),
        _ => ExitCode::from(4),
    }
}

fn main() -> ExitCode {
    // Last-resort boundary for panics that escape the library — e.g. the
    // worker pool re-raising a job panic with its index. The quiet hook
    // suppresses the raw backtrace print (the payload message survives
    // into the error), so the user sees one actionable line and a
    // nonzero exit instead of a panic dump. The pool itself already
    // contains worker panics; this converts the re-raise at the top.
    std::panic::set_hook(Box::new(|_| {}));
    let result =
        std::panic::catch_unwind(run).unwrap_or_else(|p| Err(Error::from_panic(p)));
    match result {
        Ok(code) => code,
        Err(e) => {
            eprintln!("comet: {e}");
            exit_code_for(&e)
        }
    }
}
