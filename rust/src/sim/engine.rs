//! The training-iteration discrete-event simulator (ASTRA-SIM-style
//! workload + system + network layering, condensed to the per-node view of
//! a symmetric SPMD job).
//!
//! Simulates one training iteration event-by-event:
//!
//! * **FP**: layers in forward order; each layer-instance's compute event
//!   is followed by its blocking collective's transfer phases — the next
//!   layer cannot start until they complete (critical-path exposure).
//! * **Backward**: layers in reverse order. Each instance runs its IG
//!   compute, its *blocking* IG collective, then its WG compute; the WG
//!   data-parallel collective is *non-blocking* — its transfer phases are
//!   enqueued on the link FIFOs as soon as that instance's gradient is
//!   ready and drain concurrently with the remaining backward compute
//!   (exactly how gradient reduction overlaps backprop in real stacks).
//!   The iteration ends when both compute and links are idle; exposed WG
//!   communication is whatever outlives the compute stream.
//!
//! This executes the exact same per-layer quantities and collective
//! schedules as the closed-form backend (crate::analytical); on symmetric
//! topologies the two agree within a few percent (ASTRA-SIM's own
//! validation band vs real systems is ~5%), with the DES additionally
//! capturing link contention between IG collectives and in-flight WG
//! reductions that the closed form ignores.

use crate::analytical::TrainingBreakdown;
use crate::compute::{em_fraction, gemm_traffic, hybrid_bandwidth};
use crate::model::inputs::ModelInputs;
use crate::network::chunking::{concurrent_phases, schedule_into, LinkClass, TransferPhase};
use crate::network::CollectiveImpl;
use crate::workload::Collective;

use super::event::EventQueue;
use super::link::Links;

/// DES statistics beyond the breakdown.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimStats {
    /// Events processed.
    pub events: u64,
    /// Link utilization (busy / makespan) for intra-pod links.
    pub util_intra: f64,
    /// Link utilization for inter-pod links.
    pub util_inter: f64,
}

/// DES result: breakdown + stats.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimResult {
    /// Per-phase training-time breakdown (same shape as the analytical
    /// backend's).
    pub breakdown: TrainingBreakdown,
    /// Simulation statistics (event count, link utilization).
    pub stats: SimStats,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Ev {
    /// A non-blocking WG transfer phase completed.
    WgPhaseDone,
}

struct Engine<'a> {
    links: Links,
    impl_: CollectiveImpl,
    events: u64,
    inputs: &'a ModelInputs,
    bw_eff: f64,
}

impl<'a> Engine<'a> {
    fn delay(&self, q: &crate::workload::PhaseQuantities) -> f64 {
        let p = &self.inputs.params;
        let traffic = gemm_traffic(q.u, q.v, q.w, p.sram);
        crate::compute::compute_delay(q.flops, traffic, p.perf_peak, self.bw_eff)
    }

    /// Execute a blocking collective starting at `t`; returns completion.
    fn blocking(&mut self, collective: Collective, phases: &[TransferPhase], t: f64) -> f64 {
        if phases.is_empty() {
            return t;
        }
        let mut end = t;
        if concurrent_phases(collective) {
            for ph in phases {
                let e = self.links.transfer(ph.link, t, ph.bytes, ph.hops);
                end = end.max(e);
                self.events += 1;
            }
        } else {
            let mut ready = t;
            for ph in phases {
                ready = self.links.transfer(ph.link, ready, ph.bytes, ph.hops);
                self.events += 1;
            }
            end = ready;
        }
        end
    }

    /// Enqueue a non-blocking collective ready at `t`; returns completion
    /// and schedules its phase-done events.
    fn nonblocking(
        &mut self,
        collective: Collective,
        phases: &[TransferPhase],
        t: f64,
        queue: &mut EventQueue<Ev>,
    ) -> f64 {
        if phases.is_empty() {
            return t;
        }
        let mut end = t;
        if concurrent_phases(collective) {
            for ph in phases {
                let e = self.links.transfer(ph.link, t, ph.bytes, ph.hops);
                queue.schedule(e.max(queue.now()), Ev::WgPhaseDone);
                end = end.max(e);
                self.events += 1;
            }
        } else {
            let mut ready = t;
            for ph in phases {
                ready = self.links.transfer(ph.link, ready, ph.bytes, ph.hops);
                queue.schedule(ready.max(queue.now()), Ev::WgPhaseDone);
                self.events += 1;
            }
            end = ready;
        }
        end
    }
}

/// Run the discrete-event simulation of one training iteration.
pub fn simulate(inputs: &ModelInputs) -> SimResult {
    let p = &inputs.params;
    let frac_em = p
        .em_frac_override
        .unwrap_or_else(|| em_fraction(p.footprint, p.cap_lm));
    let bw_eff = hybrid_bandwidth(p.bw_lm, p.bw_em, frac_em);

    let mut eng = Engine {
        links: Links::new(p.bw_intra, p.bw_inter, p.link_latency),
        impl_: p.collective_impl,
        events: 0,
        inputs,
        bw_eff,
    };

    let mut t = 0.0f64;
    let mut fp_compute = 0.0;
    let mut fp_exposed = 0.0;

    // Scratch schedule buffers reused across all layers of the evaluation
    // (collective schedules are at most a handful of phases; reallocating
    // them per layer-instance dominated small-sweep profiles).
    let mut phases: Vec<TransferPhase> = Vec::new();

    // ---- FP: forward order, blocking collectives -------------------------
    for layer in &inputs.layers {
        let reps = layer.repeat.max(0.0);
        if reps == 0.0 {
            continue;
        }
        let d = eng.delay(&layer.q[0]);
        let spec = &layer.comm[0];
        schedule_into(spec, eng.impl_, &mut phases);
        if phases.is_empty() {
            t += d * reps;
            fp_compute += d * reps;
            eng.events += 1;
            continue;
        }
        let whole = reps.floor() as u64;
        // Identical-repeat folding (SPerf): simulate up to two instances;
        // if the second reproduces the first's deltas exactly (periodic
        // steady state — always true for blocking chains, since the links
        // drain before the next compute), fold the remainder analytically.
        // Bitwise-exact with the unfolded loop.
        let mut done = 0u64;
        let mut prev: Option<(f64, [(f64, f64); 2], f64, f64)> = None;
        while done < whole {
            let snap_t = t;
            let snap_links = eng.links.snapshot();
            let snap_exp = fp_exposed;
            t += d;
            fp_compute += d;
            eng.events += 1;
            let end = eng.blocking(spec.collective, &phases, t);
            fp_exposed += end - t;
            t = end;
            done += 1;
            let now_links = eng.links.snapshot();
            let delta = (
                t - snap_t,
                [
                    (
                        now_links[0].0 - snap_links[0].0,
                        now_links[0].1 - snap_links[0].1,
                    ),
                    (
                        now_links[1].0 - snap_links[1].0,
                        now_links[1].1 - snap_links[1].1,
                    ),
                ],
                fp_exposed - snap_exp,
                d,
            );
            if let Some(p) = prev {
                if p == delta {
                    let k = (whole - done) as f64;
                    t += delta.0 * k;
                    fp_compute += d * k;
                    fp_exposed += delta.2 * k;
                    eng.links.fold(delta.1, k);
                    eng.events += (whole - done) * (1 + phases.len() as u64);
                    break;
                }
            }
            prev = Some(delta);
        }
        let frac = reps - whole as f64;
        if frac > 0.0 {
            // Fractional tail (sequence-sharded microbatch): closed form.
            let mut cost = 0.0;
            for ph in &phases {
                cost += eng.links.duration(ph.link, ph.bytes, ph.hops);
            }
            t += (d + cost) * frac;
            fp_compute += d * frac;
            fp_exposed += cost * frac;
            eng.events += 1;
        }
    }

    // ---- Backward: reverse order, IG blocking + WG non-blocking ----------
    let mut ig_compute = 0.0;
    let mut ig_exposed = 0.0;
    let mut wg_compute = 0.0;
    let mut wg_comm_total = 0.0;
    let mut queue: EventQueue<Ev> = EventQueue::new();
    let mut last_wg_end = t;
    let mut ig_phases: Vec<TransferPhase> = Vec::new();
    let mut wg_phases: Vec<TransferPhase> = Vec::new();
    let mut scaled: Vec<TransferPhase> = Vec::new();

    for layer in inputs.layers.iter().rev() {
        let reps = layer.repeat.max(0.0);
        if reps == 0.0 {
            continue;
        }
        let d_ig = eng.delay(&layer.q[1]);
        let d_wg = eng.delay(&layer.q[2]);
        let ig_spec = &layer.comm[1];
        let wg_spec = &layer.comm[2];
        schedule_into(ig_spec, eng.impl_, &mut ig_phases);
        schedule_into(wg_spec, eng.impl_, &mut wg_phases);
        for ph in &wg_phases {
            wg_comm_total +=
                reps * eng.links.duration(ph.link, ph.bytes, ph.hops);
        }

        if ig_phases.is_empty() && wg_phases.is_empty() {
            t += (d_ig + d_wg) * reps;
            ig_compute += d_ig * reps;
            wg_compute += d_wg * reps;
            eng.events += 1;
            continue;
        }

        let whole = reps.floor() as u64;
        // Identical-repeat folding, backward-pass variant: the in-flight
        // WG transfers make the first repeats transient (link backlog can
        // build up), so folding engages only once two consecutive repeats
        // produce identical deltas across compute time, both link cursors,
        // exposure, and the WG completion frontier. Bitwise-exact.
        let mut done = 0u64;
        let mut prev: Option<(f64, [(f64, f64); 2], f64, f64)> = None;
        while done < whole {
            let snap_t = t;
            let snap_links = eng.links.snapshot();
            let snap_exp = ig_exposed;
            let snap_wg_end = last_wg_end;
            // IG compute + blocking collective.
            t += d_ig;
            ig_compute += d_ig;
            eng.events += 1;
            let end = eng.blocking(ig_spec.collective, &ig_phases, t);
            ig_exposed += end - t;
            t = end;
            // WG compute, then fire the gradient reduction non-blocking.
            t += d_wg;
            wg_compute += d_wg;
            eng.events += 1;
            let e = eng.nonblocking(wg_spec.collective, &wg_phases, t, &mut queue);
            last_wg_end = last_wg_end.max(e);
            done += 1;
            let now_links = eng.links.snapshot();
            let delta = (
                t - snap_t,
                [
                    (
                        now_links[0].0 - snap_links[0].0,
                        now_links[0].1 - snap_links[0].1,
                    ),
                    (
                        now_links[1].0 - snap_links[1].0,
                        now_links[1].1 - snap_links[1].1,
                    ),
                ],
                ig_exposed - snap_exp,
                last_wg_end - snap_wg_end,
            );
            if let Some(p) = prev {
                if p == delta {
                    let k = (whole - done) as f64;
                    t += delta.0 * k;
                    ig_compute += d_ig * k;
                    wg_compute += d_wg * k;
                    ig_exposed += delta.2 * k;
                    last_wg_end += delta.3 * k;
                    eng.links.fold(delta.1, k);
                    eng.events += (whole - done)
                        * (2 + ig_phases.len() as u64 + wg_phases.len() as u64);
                    break;
                }
            }
            prev = Some(delta);
        }
        let frac = reps - whole as f64;
        if frac > 0.0 {
            let mut ig_cost = 0.0;
            for ph in &ig_phases {
                ig_cost += eng.links.duration(ph.link, ph.bytes, ph.hops);
            }
            t += (d_ig + ig_cost + d_wg) * frac;
            ig_compute += d_ig * frac;
            ig_exposed += ig_cost * frac;
            wg_compute += d_wg * frac;
            eng.events += 1;
            if !wg_phases.is_empty() {
                scaled.clear();
                scaled.extend(wg_phases.iter().map(|ph| TransferPhase {
                    bytes: ph.bytes * frac,
                    ..*ph
                }));
                let e =
                    eng.nonblocking(wg_spec.collective, &scaled, t, &mut queue);
                last_wg_end = last_wg_end.max(e);
            }
        }
    }

    // Drain outstanding WG transfer completions.
    while let Some(_ev) = queue.pop() {
        eng.events += 1;
    }

    let compute_end = t;
    let iteration_end = compute_end.max(last_wg_end);
    let wg_exposed = if p.overlap_wg {
        iteration_end - compute_end
    } else {
        wg_comm_total
    };

    let makespan = iteration_end.max(1e-30);
    let breakdown = TrainingBreakdown {
        fp_compute,
        fp_exposed_comm: fp_exposed,
        ig_compute,
        ig_exposed_comm: ig_exposed,
        wg_compute,
        wg_exposed_comm: wg_exposed,
    };
    SimResult {
        breakdown,
        stats: SimStats {
            events: eng.events,
            util_intra: eng.links.busy(LinkClass::IntraPod) / makespan,
            util_inter: eng.links.busy(LinkClass::InterPod) / makespan,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytical::evaluate;
    use crate::config::presets;
    use crate::model::inputs::{derive_inputs, EvalOptions};
    use crate::parallel::Strategy;
    use crate::util::stats::rel_diff;
    use crate::workload::dlrm::Dlrm;
    use crate::workload::transformer::Transformer;

    fn inputs(mp: usize, dp: usize) -> crate::model::inputs::ModelInputs {
        derive_inputs(
            &Transformer::t1().build(&Strategy::new(mp, dp)).unwrap(),
            &presets::dgx_a100_1024(),
            &EvalOptions {
                ignore_capacity: true,
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn des_matches_analytical_within_5pct() {
        // The ASTRA-SIM validation band: DES total vs closed form.
        for (mp, dp) in [(64, 16), (8, 128), (2, 512), (128, 8)] {
            let inp = inputs(mp, dp);
            let a = evaluate(&inp).total();
            let d = simulate(&inp).breakdown.total();
            assert!(
                rel_diff(a, d) < 0.05,
                "MP{mp}_DP{dp}: analytical {a:.3} vs DES {d:.3}"
            );
        }
    }

    #[test]
    fn des_blocking_compute_matches_exactly() {
        // FP/IG compute is serial in both backends: equal to fp rounding.
        for (mp, dp) in [(64, 16), (8, 128)] {
            let inp = inputs(mp, dp);
            let a = evaluate(&inp);
            let d = simulate(&inp).breakdown;
            assert!(rel_diff(a.fp_compute, d.fp_compute) < 1e-9);
            assert!(rel_diff(a.ig_compute, d.ig_compute) < 1e-9);
            assert!(rel_diff(a.wg_compute, d.wg_compute) < 1e-9);
        }
    }

    #[test]
    fn des_fp_exposure_close_to_analytical() {
        // FP has no competing non-blocking traffic; exposure should agree
        // closely (identical schedules, FIFO links idle in between).
        let inp = inputs(64, 16);
        let a = evaluate(&inp);
        let d = simulate(&inp).breakdown;
        assert!(
            rel_diff(a.fp_exposed_comm, d.fp_exposed_comm) < 1e-6,
            "{} vs {}",
            a.fp_exposed_comm,
            d.fp_exposed_comm
        );
    }

    #[test]
    fn des_wg_overlap_leaves_little_exposed() {
        // Paper claim, via the event-level mechanism rather than the
        // closed-form max(): WG comm hides under the backward compute.
        let inp = inputs(8, 128);
        let d = simulate(&inp).breakdown;
        assert!(
            d.wg_exposed_comm < 0.15 * d.wg_compute,
            "exposed {} vs compute {}",
            d.wg_exposed_comm,
            d.wg_compute
        );
    }

    #[test]
    fn des_dlrm_runs() {
        let inp = derive_inputs(
            &Dlrm::dlrm_1_2t().build(64).unwrap(),
            &presets::dgx_a100_64(),
            &EvalOptions::default(),
        )
        .unwrap();
        let r = simulate(&inp);
        assert!(r.breakdown.total() > 0.0);
        assert!(r.stats.events > 0);
        let a = evaluate(&inp).total();
        assert!(rel_diff(a, r.breakdown.total()) < 0.05);
    }

    #[test]
    fn utilization_bounded() {
        let r = simulate(&inputs(64, 16));
        assert!((0.0..=1.0).contains(&r.stats.util_intra));
        assert!((0.0..=1.0).contains(&r.stats.util_inter));
        // MP64 is comm-bound: inter-pod links should be busy.
        assert!(r.stats.util_inter > 0.5, "{}", r.stats.util_inter);
    }

    #[test]
    fn deterministic() {
        let inp = inputs(8, 128);
        let a = simulate(&inp);
        let b = simulate(&inp);
        assert_eq!(a, b);
    }

    #[test]
    fn no_overlap_mode_counts_all_wg_comm() {
        let w = Transformer::t1().build(&Strategy::new(8, 128)).unwrap();
        let inp = derive_inputs(
            &w,
            &presets::dgx_a100_1024(),
            &EvalOptions {
                ignore_capacity: true,
                overlap_wg: false,
                ..Default::default()
            },
        )
        .unwrap();
        let d = simulate(&inp).breakdown;
        assert!(d.wg_exposed_comm > 0.0);
        let a = evaluate(&inp);
        assert!(
            rel_diff(d.wg_exposed_comm, a.wg_exposed_comm) < 1e-6,
            "{} vs {}",
            d.wg_exposed_comm,
            a.wg_exposed_comm
        );
    }
}
