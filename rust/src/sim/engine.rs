//! The training-iteration discrete-event simulator (ASTRA-SIM-style
//! workload + system + network layering, condensed to the per-node view of
//! a symmetric SPMD job).
//!
//! Simulates one training iteration event-by-event:
//!
//! * **FP**: layers in forward order; each layer-instance's compute event
//!   is followed by its blocking collective's transfer phases — the next
//!   layer cannot start until they complete (critical-path exposure).
//! * **Backward**: layers in reverse order. Each instance runs its IG
//!   compute, its *blocking* IG collective, then its WG compute; the WG
//!   data-parallel collective is *non-blocking* — its transfer phases are
//!   enqueued on the link FIFOs as soon as that instance's gradient is
//!   ready and drain concurrently with the remaining backward compute
//!   (exactly how gradient reduction overlaps backprop in real stacks).
//!   The iteration ends when both compute and links are idle; exposed WG
//!   communication is whatever outlives the compute stream.
//!
//! This executes the exact same per-layer quantities and collective
//! schedules as the closed-form backend (crate::analytical); on symmetric
//! topologies the two agree within a few percent (ASTRA-SIM's own
//! validation band vs real systems is ~5%), with the DES additionally
//! capturing link contention between IG collectives and in-flight WG
//! reductions that the closed form ignores.
//!
//! ## Raw-speed structure
//!
//! The steady-state loop allocates nothing: all per-run buffers live in a
//! reusable [`SimScratch`] (thread-local for the plain entry points,
//! caller-carried via [`simulate_with`]), events are scheduled on a
//! calendar queue ([`super::event::CalendarQueue`]) whose payloads are
//! `u32` indices into a [`Slab`] of in-flight records, and the drain loop
//! dispatches all events sharing a timestamp in one batch. The engine
//! core is generic over [`Scheduler`], so the retained binary-heap oracle
//! ([`simulate_oracle`], [`simulate_goodput_oracle`]) runs the *same*
//! code path — bit-identity between the two schedulers is structural and
//! pinned by randomized property tests plus a CI byte-diff of goodput
//! traces. Tier-annotated inputs run natively on N per-tier link FIFOs
//! ([`super::link::NodeLinks`]) instead of being projected onto two
//! classes.

use crate::analytical::TrainingBreakdown;
use crate::compute::{em_fraction, gemm_traffic, hybrid_bandwidth};
use crate::config::MAX_TIERS;
use crate::model::inputs::{LayerRecord, ModelInputs, NodeParams};
use crate::network::chunking::{
    concurrent_phases, schedule_classes_into, TierPhase, TransferPhase,
};
use crate::workload::Collective;

use super::event::{CalendarQueue, Event, EventQueue, Scheduler, Slab};
use super::link::NodeLinks;

/// DES statistics beyond the breakdown.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimStats {
    /// Events processed.
    pub events: u64,
    /// Peak pending-event count of the scheduler — the high-water mark
    /// of concurrently in-flight non-blocking transfers. 0 on the
    /// pipeline path (`pp > 1`), which precomputes its event order and
    /// never queues.
    pub peak_events: u64,
    /// Link utilization (busy / makespan) for intra-pod links (class 0
    /// — the innermost tier under tiered addressing).
    pub util_intra: f64,
    /// Link utilization for inter-pod links (the outermost active
    /// class).
    pub util_inter: f64,
}

/// DES result: breakdown + stats.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimResult {
    /// Per-phase training-time breakdown (same shape as the analytical
    /// backend's).
    pub breakdown: TrainingBreakdown,
    /// Simulation statistics (event count, link utilization).
    pub stats: SimStats,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Ev {
    /// A non-blocking WG transfer phase completed; the payload is the
    /// slab index of its in-flight record.
    WgPhaseDone(u32),
}

/// Which scheduler drives the run: the calendar queue (production) or
/// the retained heap queue (oracle). Both produce bit-identical pops.
#[derive(Debug, Clone, Copy, PartialEq)]
enum QueueKind {
    Calendar,
    Heap,
}

/// Reusable simulation state: phase-schedule buffers, both schedulers,
/// the in-flight slab, the batch-dispatch buffer, and the pipeline
/// path's per-stage vectors. After the first run on a given shape the
/// steady-state loop performs zero allocations. Obtain one with
/// [`SimScratch::new`] and thread it through [`simulate_with`] when
/// running many simulations back to back (sweeps, cross-checks,
/// goodput renewal loops); the plain [`simulate`] entry uses a
/// thread-local instance.
#[derive(Debug, Default)]
pub struct SimScratch {
    calendar: CalendarQueue<Ev>,
    heap: EventQueue<Ev>,
    flights: Slab<f64>,
    batch: Vec<Event<Ev>>,
    fp: Vec<TierPhase>,
    ig: Vec<TierPhase>,
    wg: Vec<TierPhase>,
    scaled: Vec<TierPhase>,
    legacy: Vec<TransferPhase>,
    plans: Vec<StagePlan>,
    pipe: PipeScratch,
}

impl SimScratch {
    /// Empty scratch; buffers grow on first use and are retained.
    pub fn new() -> Self {
        SimScratch::default()
    }
}

std::thread_local! {
    static SCRATCH: std::cell::RefCell<SimScratch> =
        std::cell::RefCell::new(SimScratch::new());
}

/// Run `f` with this thread's scratch. Simulations never nest (the
/// goodput renewal loop calls the `_parts` internals directly), so the
/// borrow cannot conflict.
fn with_scratch<R>(f: impl FnOnce(&mut SimScratch) -> R) -> R {
    SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

struct Engine<'a> {
    links: NodeLinks,
    events: u64,
    p: &'a NodeParams,
    bw_eff: f64,
}

/// The engine's link set under the inputs' addressing: per-tier FIFOs
/// for tier-annotated params, the legacy two-class layout otherwise.
fn node_links(p: &NodeParams) -> NodeLinks {
    if p.n_tiers > 0 {
        NodeLinks::tiered(&p.tier_bw, &p.tier_lat, p.n_tiers)
    } else {
        NodeLinks::two_level(p.bw_intra, p.bw_inter, p.link_latency)
    }
}

/// Per-period (free_at, busy) link deltas for identical-repeat folding.
fn links_delta(
    now: &[(f64, f64); MAX_TIERS],
    snap: &[(f64, f64); MAX_TIERS],
) -> [(f64, f64); MAX_TIERS] {
    let mut d = [(0.0, 0.0); MAX_TIERS];
    for ((d, n), s) in d.iter_mut().zip(now.iter()).zip(snap.iter()) {
        *d = (n.0 - s.0, n.1 - s.1);
    }
    d
}

impl<'a> Engine<'a> {
    fn delay(&self, q: &crate::workload::PhaseQuantities) -> f64 {
        let traffic = gemm_traffic(q.u, q.v, q.w, self.p.sram);
        crate::compute::compute_delay(
            q.flops,
            traffic,
            self.p.perf_peak,
            self.bw_eff,
        )
    }

    /// Execute a blocking collective starting at `t`; returns completion.
    fn blocking(
        &mut self,
        collective: Collective,
        phases: &[TierPhase],
        t: f64,
    ) -> f64 {
        if phases.is_empty() {
            return t;
        }
        let mut end = t;
        if concurrent_phases(collective) {
            for ph in phases {
                let e = self.links.transfer(ph.tier, t, ph.bytes, ph.hops);
                end = end.max(e);
                self.events += 1;
            }
        } else {
            let mut ready = t;
            for ph in phases {
                ready = self.links.transfer(ph.tier, ready, ph.bytes, ph.hops);
                self.events += 1;
            }
            end = ready;
        }
        end
    }

    /// Enqueue a non-blocking collective ready at `t`; returns completion
    /// and schedules its phase-done events (slab-indexed payloads).
    fn nonblocking<Q: Scheduler<Ev>>(
        &mut self,
        collective: Collective,
        phases: &[TierPhase],
        t: f64,
        queue: &mut Q,
        flights: &mut Slab<f64>,
    ) -> f64 {
        if phases.is_empty() {
            return t;
        }
        let mut end = t;
        if concurrent_phases(collective) {
            for ph in phases {
                let e = self.links.transfer(ph.tier, t, ph.bytes, ph.hops);
                let idx = flights.insert(e);
                queue
                    .schedule(e.max(queue.now()), Ev::WgPhaseDone(idx))
                    .expect("WG completion is clamped to the queue's now");
                end = end.max(e);
                self.events += 1;
            }
        } else {
            let mut ready = t;
            for ph in phases {
                ready = self.links.transfer(ph.tier, ready, ph.bytes, ph.hops);
                let idx = flights.insert(ready);
                queue
                    .schedule(ready.max(queue.now()), Ev::WgPhaseDone(idx))
                    .expect("WG completion is clamped to the queue's now");
                self.events += 1;
            }
            end = ready;
        }
        end
    }
}

/// Run the discrete-event simulation of one training iteration.
///
/// Pipeline-parallel inputs (`pp > 1`) are simulated as a software
/// pipeline (`simulate_pipeline`): per-microbatch stage services on
/// serial stage resources, send/recv events on FIFO stage-boundary
/// links, and WG collectives still overlapping backward *within* each
/// stage on that stage's own link FIFOs.
///
/// Uses a thread-local [`SimScratch`] and the calendar-queue scheduler;
/// see [`simulate_with`] for an explicit scratch and
/// [`simulate_oracle`] for the retained heap-queue oracle.
pub fn simulate(inputs: &ModelInputs) -> SimResult {
    with_scratch(|s| {
        simulate_parts(&inputs.layers, &inputs.params, s, QueueKind::Calendar)
    })
}

/// [`simulate`] with a caller-carried [`SimScratch`] — for hot paths
/// running many simulations back to back (optimizer cross-checks,
/// benches, sweeps) that want buffer reuse without the thread-local.
pub fn simulate_with(inputs: &ModelInputs, scratch: &mut SimScratch) -> SimResult {
    simulate_parts(&inputs.layers, &inputs.params, scratch, QueueKind::Calendar)
}

/// [`simulate`] on the retained binary-heap event queue — the in-tree
/// oracle the calendar-queue scheduler is pinned bit-identical against.
/// The pipeline path (`pp > 1`) precomputes its event order and is
/// scheduler-independent by construction.
pub fn simulate_oracle(inputs: &ModelInputs) -> SimResult {
    let mut scratch = SimScratch::new();
    simulate_parts(&inputs.layers, &inputs.params, &mut scratch, QueueKind::Heap)
}

fn simulate_parts(
    layers: &[LayerRecord],
    p: &NodeParams,
    s: &mut SimScratch,
    kind: QueueKind,
) -> SimResult {
    if p.pp > 1 {
        return simulate_pipeline(layers, p, s);
    }
    // Destructure so the queue and the buffers borrow disjointly.
    let SimScratch {
        calendar,
        heap,
        flights,
        batch,
        fp,
        ig,
        wg,
        scaled,
        legacy,
        ..
    } = s;
    flights.clear();
    match kind {
        QueueKind::Calendar => {
            calendar.reset();
            sim_2d(layers, p, calendar, flights, batch, fp, ig, wg, scaled, legacy)
        }
        QueueKind::Heap => {
            heap.reset();
            sim_2d(layers, p, heap, flights, batch, fp, ig, wg, scaled, legacy)
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn sim_2d<Q: Scheduler<Ev>>(
    layers: &[LayerRecord],
    p: &NodeParams,
    queue: &mut Q,
    flights: &mut Slab<f64>,
    batch: &mut Vec<Event<Ev>>,
    fp_phases: &mut Vec<TierPhase>,
    ig_phases: &mut Vec<TierPhase>,
    wg_phases: &mut Vec<TierPhase>,
    scaled: &mut Vec<TierPhase>,
    legacy: &mut Vec<TransferPhase>,
) -> SimResult {
    let frac_em = p
        .em_frac_override
        .unwrap_or_else(|| em_fraction(p.footprint, p.cap_lm));
    let bw_eff = hybrid_bandwidth(p.bw_lm, p.bw_em, frac_em);

    let mut eng = Engine {
        links: node_links(p),
        events: 0,
        p,
        bw_eff,
    };

    let mut t = 0.0f64;
    let mut fp_compute = 0.0;
    let mut fp_exposed = 0.0;

    // ---- FP: forward order, blocking collectives -------------------------
    for layer in layers {
        let reps = layer.repeat.max(0.0);
        if reps == 0.0 {
            continue;
        }
        let d = eng.delay(&layer.q[0]);
        let spec = &layer.comm[0];
        schedule_classes_into(spec, p.collective_impl, fp_phases, legacy);
        if fp_phases.is_empty() {
            t += d * reps;
            fp_compute += d * reps;
            eng.events += 1;
            continue;
        }
        let whole = reps.floor() as u64;
        // Identical-repeat folding (SPerf): simulate up to two instances;
        // if the second reproduces the first's deltas exactly (periodic
        // steady state — always true for blocking chains, since the links
        // drain before the next compute), fold the remainder analytically.
        // Bitwise-exact with the unfolded loop.
        let mut done = 0u64;
        let mut prev: Option<(f64, [(f64, f64); MAX_TIERS], f64, f64)> = None;
        while done < whole {
            let snap_t = t;
            let snap_links = eng.links.snapshot();
            let snap_exp = fp_exposed;
            t += d;
            fp_compute += d;
            eng.events += 1;
            let end = eng.blocking(spec.collective, fp_phases, t);
            fp_exposed += end - t;
            t = end;
            done += 1;
            let now_links = eng.links.snapshot();
            let delta = (
                t - snap_t,
                links_delta(&now_links, &snap_links),
                fp_exposed - snap_exp,
                d,
            );
            if let Some(p) = prev {
                if p == delta {
                    let k = (whole - done) as f64;
                    t += delta.0 * k;
                    fp_compute += d * k;
                    fp_exposed += delta.2 * k;
                    eng.links.fold(delta.1, k);
                    eng.events += (whole - done) * (1 + fp_phases.len() as u64);
                    break;
                }
            }
            prev = Some(delta);
        }
        let frac = reps - whole as f64;
        if frac > 0.0 {
            // Fractional tail (sequence-sharded microbatch): closed form.
            let mut cost = 0.0;
            for ph in fp_phases.iter() {
                cost += eng.links.duration(ph.tier, ph.bytes, ph.hops);
            }
            t += (d + cost) * frac;
            fp_compute += d * frac;
            fp_exposed += cost * frac;
            eng.events += 1;
        }
    }

    // ---- Backward: reverse order, IG blocking + WG non-blocking ----------
    let mut ig_compute = 0.0;
    let mut ig_exposed = 0.0;
    let mut wg_compute = 0.0;
    let mut wg_comm_total = 0.0;
    let mut last_wg_end = t;

    for layer in layers.iter().rev() {
        let reps = layer.repeat.max(0.0);
        if reps == 0.0 {
            continue;
        }
        let d_ig = eng.delay(&layer.q[1]);
        let d_wg = eng.delay(&layer.q[2]);
        let ig_spec = &layer.comm[1];
        let wg_spec = &layer.comm[2];
        schedule_classes_into(ig_spec, p.collective_impl, ig_phases, legacy);
        schedule_classes_into(wg_spec, p.collective_impl, wg_phases, legacy);
        for ph in wg_phases.iter() {
            wg_comm_total +=
                reps * eng.links.duration(ph.tier, ph.bytes, ph.hops);
        }

        if ig_phases.is_empty() && wg_phases.is_empty() {
            t += (d_ig + d_wg) * reps;
            ig_compute += d_ig * reps;
            wg_compute += d_wg * reps;
            eng.events += 1;
            continue;
        }

        let whole = reps.floor() as u64;
        // Identical-repeat folding, backward-pass variant: the in-flight
        // WG transfers make the first repeats transient (link backlog can
        // build up), so folding engages only once two consecutive repeats
        // produce identical deltas across compute time, all link cursors,
        // exposure, and the WG completion frontier. Bitwise-exact.
        let mut done = 0u64;
        let mut prev: Option<(f64, [(f64, f64); MAX_TIERS], f64, f64)> = None;
        while done < whole {
            let snap_t = t;
            let snap_links = eng.links.snapshot();
            let snap_exp = ig_exposed;
            let snap_wg_end = last_wg_end;
            // IG compute + blocking collective.
            t += d_ig;
            ig_compute += d_ig;
            eng.events += 1;
            let end = eng.blocking(ig_spec.collective, ig_phases, t);
            ig_exposed += end - t;
            t = end;
            // WG compute, then fire the gradient reduction non-blocking.
            t += d_wg;
            wg_compute += d_wg;
            eng.events += 1;
            let e = eng.nonblocking(
                wg_spec.collective,
                wg_phases,
                t,
                queue,
                flights,
            );
            last_wg_end = last_wg_end.max(e);
            done += 1;
            let now_links = eng.links.snapshot();
            let delta = (
                t - snap_t,
                links_delta(&now_links, &snap_links),
                ig_exposed - snap_exp,
                last_wg_end - snap_wg_end,
            );
            if let Some(p) = prev {
                if p == delta {
                    let k = (whole - done) as f64;
                    t += delta.0 * k;
                    ig_compute += d_ig * k;
                    wg_compute += d_wg * k;
                    ig_exposed += delta.2 * k;
                    last_wg_end += delta.3 * k;
                    eng.links.fold(delta.1, k);
                    eng.events += (whole - done)
                        * (2 + ig_phases.len() as u64 + wg_phases.len() as u64);
                    break;
                }
            }
            prev = Some(delta);
        }
        let frac = reps - whole as f64;
        if frac > 0.0 {
            let mut ig_cost = 0.0;
            for ph in ig_phases.iter() {
                ig_cost += eng.links.duration(ph.tier, ph.bytes, ph.hops);
            }
            t += (d_ig + ig_cost + d_wg) * frac;
            ig_compute += d_ig * frac;
            ig_exposed += ig_cost * frac;
            wg_compute += d_wg * frac;
            eng.events += 1;
            if !wg_phases.is_empty() {
                scaled.clear();
                scaled.extend(wg_phases.iter().map(|ph| TierPhase {
                    bytes: ph.bytes * frac,
                    ..*ph
                }));
                let e = eng.nonblocking(
                    wg_spec.collective,
                    scaled,
                    t,
                    queue,
                    flights,
                );
                last_wg_end = last_wg_end.max(e);
            }
        }
    }

    // Drain outstanding WG transfer completions, a whole timestamp per
    // batch, recycling each event's slab record.
    loop {
        let n = queue.pop_batch(batch);
        if n == 0 {
            break;
        }
        eng.events += n as u64;
        for ev in batch.iter() {
            let Ev::WgPhaseDone(idx) = ev.payload;
            let _end = flights.remove(idx);
            debug_assert_eq!(
                _end.to_bits(),
                ev.time.to_bits(),
                "slab flight record out of sync with its event"
            );
        }
    }
    debug_assert!(flights.is_empty(), "undrained in-flight records");

    let compute_end = t;
    let iteration_end = compute_end.max(last_wg_end);
    let wg_exposed = if p.overlap_wg {
        iteration_end - compute_end
    } else {
        wg_comm_total
    };

    let makespan = iteration_end.max(1e-30);
    let breakdown = TrainingBreakdown {
        fp_compute,
        fp_exposed_comm: fp_exposed,
        ig_compute,
        ig_exposed_comm: ig_exposed,
        wg_compute,
        wg_exposed_comm: wg_exposed,
        bubble: 0.0,
        pp_exposed_comm: 0.0,
    };
    let top = eng.links.classes() - 1;
    SimResult {
        breakdown,
        stats: SimStats {
            events: eng.events,
            peak_events: queue.peak() as u64,
            util_intra: eng.links.busy(0) / makespan,
            util_inter: eng.links.busy(top) / makespan,
        },
    }
}

/// One serialized link occupation of a per-microbatch collective chain.
#[derive(Debug, Clone, Copy)]
struct Seg {
    class: usize,
    dur: f64,
}

/// One layer-instance collective: a `[start, start + len)` slice of the
/// plan's shared segment arena (structure-of-arrays — no per-chain Vec).
#[derive(Debug, Clone, Copy)]
struct ChainRef {
    start: u32,
    len: u32,
    /// All-to-all phases proceed concurrently on their link classes.
    concurrent: bool,
}

/// Per-stage precomputed plan: full-batch compute per phase, blocking
/// FP/IG chains, non-blocking WG chains (as ranges into `segs`), and
/// closed-form per-phase collective totals (bottleneck selection +
/// no-overlap accounting). Reused across runs via [`SimScratch`].
#[derive(Debug, Default)]
struct StagePlan {
    d: [f64; 3],
    comm: [f64; 3],
    segs: Vec<Seg>,
    /// FP / IG / WG chain lists.
    chains: [Vec<ChainRef>; 3],
}

impl StagePlan {
    fn reset(&mut self) {
        self.d = [0.0; 3];
        self.comm = [0.0; 3];
        self.segs.clear();
        for c in &mut self.chains {
            c.clear();
        }
    }
}

/// Per-stage FIFO link frontiers (the stage's own NICs), one per class.
#[derive(Debug, Clone, Copy, Default)]
struct StageLinks {
    free: [f64; MAX_TIERS],
    busy: [f64; MAX_TIERS],
}

impl StageLinks {
    /// Serialize a segment starting no earlier than `ready`.
    fn occupy(&mut self, class: usize, ready: f64, dur: f64) -> f64 {
        let start = ready.max(self.free[class]);
        self.free[class] = start + dur;
        self.busy[class] += dur;
        self.free[class]
    }
}

/// Reusable per-stage vectors for the pipeline path.
#[derive(Debug, Default)]
struct PipeScratch {
    stage_t: Vec<f64>,
    links: Vec<StageLinks>,
    bfree: Vec<f64>,
    fp_compute: Vec<f64>,
    fp_exposed: Vec<f64>,
    ig_compute: Vec<f64>,
    ig_exposed: Vec<f64>,
    wg_compute: Vec<f64>,
    last_wg: Vec<f64>,
}

impl PipeScratch {
    fn reset(&mut self, pp: usize) {
        for v in [
            &mut self.stage_t,
            &mut self.bfree,
            &mut self.fp_compute,
            &mut self.fp_exposed,
            &mut self.ig_compute,
            &mut self.ig_exposed,
            &mut self.wg_compute,
            &mut self.last_wg,
        ] {
            v.clear();
        }
        self.stage_t.resize(pp, 0.0);
        self.bfree.resize(pp - 1, 0.0);
        self.fp_compute.resize(pp, 0.0);
        self.fp_exposed.resize(pp, 0.0);
        self.ig_compute.resize(pp, 0.0);
        self.ig_exposed.resize(pp, 0.0);
        self.wg_compute.resize(pp, 0.0);
        self.last_wg.resize(pp, 0.0);
        self.links.clear();
        self.links.resize(pp, StageLinks::default());
    }
}

/// Execute one phase's chain list starting at `t`; returns completion.
fn run_chains(
    links: &mut StageLinks,
    plan: &StagePlan,
    phase: usize,
    t: f64,
    events: &mut u64,
) -> f64 {
    let mut ready = t;
    for c in &plan.chains[phase] {
        let segs = &plan.segs[c.start as usize..(c.start + c.len) as usize];
        if c.concurrent {
            let mut end = ready;
            for seg in segs {
                end = end.max(links.occupy(seg.class, ready, seg.dur));
                *events += 1;
            }
            ready = end;
        } else {
            for seg in segs {
                ready = links.occupy(seg.class, ready, seg.dur);
                *events += 1;
            }
        }
    }
    ready
}

/// Software-pipeline DES for `pp > 1` inputs: GPipe-style fill–drain over
/// `m` microbatches. Stage compute is a serial resource, stage-boundary
/// activation/gradient transfers are send/recv events on per-boundary
/// FIFO links (at the boundary's link class — its tier, under tiered
/// addressing), blocking FP/IG collectives occupy the stage's own link
/// FIFOs, and WG collectives are enqueued non-blocking per microbatch so
/// they overlap the remaining backward compute within the stage — the
/// same overlap mechanism as the 2D engine. The per-node view is the
/// bottleneck stage's; everything the schedule adds on top lands in
/// `bubble` / `pp_exposed_comm`, mirroring the analytical composition so
/// the two backends can be cross-asserted in the bubble- and
/// communication-dominated corners. Event order here is precomputed
/// (no queue), so the path is scheduler-independent by construction.
fn simulate_pipeline(
    layers: &[LayerRecord],
    p: &NodeParams,
    s: &mut SimScratch,
) -> SimResult {
    let SimScratch {
        plans,
        pipe,
        fp: phases,
        legacy,
        ..
    } = s;
    let frac_em = p
        .em_frac_override
        .unwrap_or_else(|| em_fraction(p.footprint, p.cap_lm));
    let bw_eff = hybrid_bandwidth(p.bw_lm, p.bw_em, frac_em);
    let pp = p.pp;
    let m = p.microbatches.max(1);
    let mf = m as f64;
    let mut events: u64 = 0;

    // Reference link set for closed-form durations (never occupied).
    let ref_links = node_links(p);
    let delay = |q: &crate::workload::PhaseQuantities| {
        let traffic = gemm_traffic(q.u, q.v, q.w, p.sram);
        crate::compute::compute_delay(q.flops, traffic, p.perf_peak, bw_eff)
    };

    // ---- precompute per-stage plans --------------------------------------
    plans.resize_with(pp, StagePlan::default);
    plans.truncate(pp);
    for plan in plans.iter_mut() {
        plan.reset();
    }
    for layer in layers {
        let stage = layer.stage.min(pp - 1);
        let plan = &mut plans[stage];
        let reps = layer.repeat.max(0.0);
        for phase in 0..3 {
            plan.d[phase] += reps * delay(&layer.q[phase]);
            let spec = &layer.comm[phase];
            if matches!(spec.collective, Collective::None) {
                continue;
            }
            schedule_classes_into(spec, p.collective_impl, phases, legacy);
            if phases.is_empty() {
                continue;
            }
            // Per-microbatch segment durations: the layer's full chain
            // cost (repeat x closed-form phase time) spread evenly over
            // the m microbatches — the fluid split the analytical
            // composition uses.
            let start = plan.segs.len();
            plan.segs.extend(phases.iter().map(|ph| Seg {
                class: ph.tier,
                dur: reps * ref_links.duration(ph.tier, ph.bytes, ph.hops)
                    / mf,
            }));
            plan.comm[phase] += plan.segs[start..]
                .iter()
                .map(|seg| seg.dur)
                .sum::<f64>()
                * mf;
            plan.chains[phase].push(ChainRef {
                start: start as u32,
                len: (plan.segs.len() - start) as u32,
                concurrent: concurrent_phases(spec.collective),
            });
        }
    }

    // Stage-boundary per-microbatch transfer time (one hop), on the
    // boundary's link class under the inputs' addressing.
    let (bw_b, lat_b) = crate::analytical::pp_boundary_link(p);
    let bclass = if p.n_tiers > 0 {
        p.pp_tier.min(p.n_tiers.saturating_sub(1))
    } else if p.pp_inter {
        1
    } else {
        0
    };
    let x = (p.pp_boundary_bytes / mf) / bw_b.max(1.0) + lat_b;

    // ---- run the fill–drain schedule -------------------------------------
    pipe.reset(pp);
    let mut bbusy = 0.0f64;

    // Forward: every microbatch through every stage in order.
    for _ in 0..m {
        let mut carry = 0.0f64;
        for s in 0..pp {
            let arrive = if s == 0 {
                0.0
            } else {
                let t = carry.max(pipe.bfree[s - 1]) + x;
                pipe.bfree[s - 1] = t;
                bbusy += x;
                events += 1;
                t
            };
            let start = arrive.max(pipe.stage_t[s]);
            let d = plans[s].d[0] / mf;
            let t_c = start + d;
            pipe.fp_compute[s] += d;
            events += 1;
            let end =
                run_chains(&mut pipe.links[s], &plans[s], 0, t_c, &mut events);
            pipe.fp_exposed[s] += end - t_c;
            pipe.stage_t[s] = end;
            carry = end;
        }
    }
    // Backward: reverse microbatch train through the stages in reverse.
    for _ in 0..m {
        let mut carry = 0.0f64;
        for s in (0..pp).rev() {
            let arrive = if s == pp - 1 {
                0.0
            } else {
                let t = carry.max(pipe.bfree[s]) + x;
                pipe.bfree[s] = t;
                bbusy += x;
                events += 1;
                t
            };
            let start = arrive.max(pipe.stage_t[s]);
            let d_ig = plans[s].d[1] / mf;
            let t_c = start + d_ig;
            pipe.ig_compute[s] += d_ig;
            events += 1;
            let end =
                run_chains(&mut pipe.links[s], &plans[s], 1, t_c, &mut events);
            pipe.ig_exposed[s] += end - t_c;
            let d_wg = plans[s].d[2] / mf;
            let t_w = end + d_wg;
            pipe.wg_compute[s] += d_wg;
            events += 1;
            let e =
                run_chains(&mut pipe.links[s], &plans[s], 2, t_w, &mut events);
            pipe.last_wg[s] = pipe.last_wg[s].max(e);
            pipe.stage_t[s] = t_w;
            carry = t_w;
        }
    }

    // ---- compose the result ----------------------------------------------
    // Bottleneck stage: largest per-microbatch service time (ties ->
    // lowest index), matching the analytical backend's selection.
    let svc = |s: usize| {
        (plans[s].d[0] + plans[s].comm[0]) / mf
            + (plans[s].d[1] + plans[s].comm[1] + plans[s].d[2]) / mf
    };
    let mut btl = 0usize;
    for s in 1..pp {
        if svc(s) > svc(btl) {
            btl = s;
        }
    }
    let compute_end = pipe.stage_t.iter().copied().fold(0.0, f64::max);
    let wg_end = pipe.last_wg.iter().copied().fold(0.0, f64::max);
    let wg_exp_btl = if p.overlap_wg {
        (pipe.last_wg[btl] - pipe.stage_t[btl]).max(0.0)
    } else {
        plans[btl].comm[2]
    };
    // No-overlap accounting mirrors the 2D engine and the analytical
    // pipeline path: WG communication is charged in full on top of the
    // compute makespan, NOT via the (already overlapped) link drain —
    // using `wg_end` there would double-count it.
    let total = if p.overlap_wg {
        compute_end.max(wg_end)
    } else {
        compute_end + plans[btl].comm[2]
    };
    let busy = pipe.fp_compute[btl]
        + pipe.fp_exposed[btl]
        + pipe.ig_compute[btl]
        + pipe.ig_exposed[btl]
        + pipe.wg_compute[btl]
        + wg_exp_btl;
    let slack = (total - busy).max(0.0);
    let pp_exposed = slack.min(2.0 * (pp as f64 - 1.0) * x);
    let bubble = slack - pp_exposed;

    let makespan = total.max(1e-30);
    let mut busy_by = [0.0f64; MAX_TIERS];
    for l in &pipe.links {
        for (acc, b) in busy_by.iter_mut().zip(l.busy.iter()) {
            *acc += b;
        }
    }
    busy_by[bclass] += bbusy;
    let nclasses = if p.n_tiers > 0 {
        p.n_tiers.clamp(1, MAX_TIERS)
    } else {
        2
    };
    SimResult {
        breakdown: TrainingBreakdown {
            fp_compute: pipe.fp_compute[btl],
            fp_exposed_comm: pipe.fp_exposed[btl],
            ig_compute: pipe.ig_compute[btl],
            ig_exposed_comm: pipe.ig_exposed[btl],
            wg_compute: pipe.wg_compute[btl],
            wg_exposed_comm: wg_exp_btl,
            bubble,
            pp_exposed_comm: pp_exposed,
        },
        stats: SimStats {
            events,
            peak_events: 0,
            // Per-stage NIC utilization averaged over the pp stages;
            // boundary-FIFO traffic is folded into its link class and the
            // ratio clamped (boundary links are extra resources).
            util_intra: (busy_by[0] / (pp as f64 * makespan)).min(1.0),
            util_inter: (busy_by[nclasses - 1] / (pp as f64 * makespan))
                .min(1.0),
        },
    }
}

// ---- fault injection ------------------------------------------------------

/// What happened on the fault timeline of a goodput simulation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultEventKind {
    /// A node failed; all uncommitted work since the last checkpoint is
    /// lost.
    Failure {
        /// The failed node's index (sampled deterministically).
        node: usize,
    },
    /// The job finished restarting from the last checkpoint.
    Restart,
    /// A checkpoint write completed; work up to here is committed.
    Checkpoint,
}

/// One entry of the deterministic fault-event trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Wall-clock time of the event, seconds.
    pub at_s: f64,
    /// The event.
    pub kind: FaultEventKind,
}

/// Result of a checkpoint–restart goodput simulation
/// ([`simulate_goodput`]).
#[derive(Debug, Clone, PartialEq)]
pub struct GoodputSim {
    /// Fault-free per-step time from the plain DES, seconds.
    pub ideal_step_s: f64,
    /// Straggler/link-degraded per-step time ([`simulate_faulty`]).
    pub step_s: f64,
    /// Useful (committed) work over total wall-clock, relative to the
    /// fault-free rate — the DES counterpart of
    /// [`crate::analytical::goodput::Goodput::efficiency`].
    pub efficiency: f64,
    /// Wall-clock seconds simulated.
    pub wall_s: f64,
    /// Failures injected.
    pub failures: usize,
    /// Checkpoints committed.
    pub checkpoints: usize,
    /// The full event trace (failure/restart/checkpoint), in time order;
    /// identical across runs for the same seed.
    pub trace: Vec<FaultEvent>,
    /// `true` when the renewal loop hit [`MAX_FAULT_EVENTS`] before
    /// committing the full horizon: the model predicts essentially no
    /// forward progress (MTBF below the restart + checkpoint cycle), and
    /// `efficiency`/`wall_s` describe only the simulated prefix. Callers
    /// rendering results must surface this instead of presenting the
    /// truncated numbers as a completed horizon.
    pub truncated: bool,
}

/// The params with straggler and link-degradation service rates
/// injected: a plain `Copy` + in-place patch of [`NodeParams`] — no
/// `ModelInputs` clone (the layer records are shared by reference), so
/// fault injection adds nothing to the steady-state allocation profile.
/// Deflates exactly the fields the historical clone path deflated.
fn faulty_params(
    inputs: &ModelInputs,
    fault: &crate::resilience::FaultModel,
    n_nodes: usize,
) -> NodeParams {
    let mut p = inputs.params;
    if fault.straggler_count(n_nodes) > 0 {
        let s = fault.straggler_slowdown;
        p.perf_peak /= s;
        p.bw_lm /= s;
        if p.bw_em > 0.0 {
            p.bw_em /= s;
        }
    }
    if fault.degraded_count(n_nodes) > 0 {
        let f = fault.link_degrade_factor;
        p.bw_intra /= f;
        p.bw_inter /= f;
    }
    p
}

/// Run the DES with straggler and link-degradation service rates
/// injected: stragglers gate every barrier (collectives, pipeline
/// stages), so any straggler slows the whole job's compute and memory
/// streams by its slowdown factor, and degraded links divide the
/// network bandwidths. The disabled fault model returns exactly
/// [`simulate`]'s result.
pub fn simulate_faulty(
    inputs: &ModelInputs,
    fault: &crate::resilience::FaultModel,
    n_nodes: usize,
) -> SimResult {
    with_scratch(|s| {
        simulate_faulty_parts(inputs, fault, n_nodes, s, QueueKind::Calendar)
    })
}

fn simulate_faulty_parts(
    inputs: &ModelInputs,
    fault: &crate::resilience::FaultModel,
    n_nodes: usize,
    s: &mut SimScratch,
    kind: QueueKind,
) -> SimResult {
    let p = faulty_params(inputs, fault, n_nodes);
    simulate_parts(&inputs.layers, &p, s, kind)
}

/// Hard cap on simulated fault events — bounds the renewal loop when
/// the model predicts essentially no forward progress (MTBF below the
/// restart + checkpoint cycle).
const MAX_FAULT_EVENTS: usize = 100_000;

/// Checkpoint–restart renewal simulation over `horizon_steps` training
/// steps: work proceeds at the straggler/link-degraded step rate,
/// checkpoints are written every Young/Daly interval (costing the
/// footprint over the effective checkpoint bandwidth), and failures
/// arrive as a Poisson process at the cluster MTBF, each losing the
/// uncommitted work since the last checkpoint and charging the restart
/// time. Failure times and failed-node indices come from the
/// deterministic PRNG seeded by `fault.seed` — the trace and totals are
/// bit-identical across runs.
pub fn simulate_goodput(
    inputs: &ModelInputs,
    fault: &crate::resilience::FaultModel,
    n_nodes: usize,
    horizon_steps: usize,
) -> GoodputSim {
    simulate_goodput_controlled(
        inputs,
        fault,
        n_nodes,
        horizon_steps,
        &crate::util::cancel::RunControl::unbounded(),
    )
    .expect("unbounded goodput simulation cannot be stopped")
}

/// [`simulate_goodput`] with a cooperative stop source polled every
/// renewal-loop event (failures arrive thousands-per-horizon under
/// pessimistic fault models, so the loop is a long-running path in its
/// own right). A stop surfaces as [`crate::error::Error::Cancelled`] /
/// [`crate::error::Error::Deadline`] — the renewal trace has no useful
/// partial interpretation.
pub fn simulate_goodput_controlled(
    inputs: &ModelInputs,
    fault: &crate::resilience::FaultModel,
    n_nodes: usize,
    horizon_steps: usize,
    control: &crate::util::cancel::RunControl,
) -> crate::error::Result<GoodputSim> {
    with_scratch(|s| {
        goodput_core(
            inputs,
            fault,
            n_nodes,
            horizon_steps,
            control,
            s,
            QueueKind::Calendar,
        )
    })
}

/// [`simulate_goodput`] on the retained heap-queue oracle — drives the
/// CI byte-diff of goodput traces old-queue vs new-queue
/// (`examples/des_trace.rs`).
pub fn simulate_goodput_oracle(
    inputs: &ModelInputs,
    fault: &crate::resilience::FaultModel,
    n_nodes: usize,
    horizon_steps: usize,
) -> GoodputSim {
    let mut s = SimScratch::new();
    goodput_core(
        inputs,
        fault,
        n_nodes,
        horizon_steps,
        &crate::util::cancel::RunControl::unbounded(),
        &mut s,
        QueueKind::Heap,
    )
    .expect("unbounded goodput simulation cannot be stopped")
}

#[allow(clippy::too_many_arguments)]
fn goodput_core(
    inputs: &ModelInputs,
    fault: &crate::resilience::FaultModel,
    n_nodes: usize,
    horizon_steps: usize,
    control: &crate::util::cancel::RunControl,
    scratch: &mut SimScratch,
    kind: QueueKind,
) -> crate::error::Result<GoodputSim> {
    use crate::analytical::goodput;
    use crate::resilience::checkpoint_bandwidth;
    use crate::util::prng::Rng;

    let ideal = simulate_parts(&inputs.layers, &inputs.params, scratch, kind);
    let faulty = simulate_faulty_parts(inputs, fault, n_nodes, scratch, kind);
    let ideal_step_s = ideal.breakdown.total();
    let step_s = faulty.breakdown.total();

    // Shared checkpoint geometry with the analytical model: same
    // footprint, same bandwidth rule, same Young/Daly interval.
    let p = &inputs.params;
    let ckpt_bw = checkpoint_bandwidth(p.bw_inter, p.bw_lm, p.bw_em);
    let g = goodput::analyze(
        fault,
        n_nodes,
        p.footprint,
        ckpt_bw,
        &faulty.breakdown,
    );
    let (tau, delta) = (g.ckpt_interval_s, g.ckpt_write_s);

    let horizon_s = horizon_steps as f64 * step_s;
    let mut rng = Rng::new(fault.seed);
    let mut trace: Vec<FaultEvent> = Vec::new();
    let mut wall = 0.0f64;
    let mut committed = 0.0f64; // checkpoint-protected useful seconds
    let mut failures = 0usize;
    let mut checkpoints = 0usize;
    let mut next_fail = fault.time_to_failure(&mut rng, n_nodes);
    // delta == 0 with a finite MTBF is the free-continuous-checkpoint
    // limit (tau -> 0): a failure then loses no work, only restart time.
    let continuous = delta == 0.0 && !tau.is_finite();

    // Work segments always start at a committed boundary: run until the
    // next checkpoint is due (paying the write) or the horizon is done.
    // A failure striking before that milestone — including mid-write —
    // loses the whole uncommitted segment and charges the restart.
    while committed < horizon_s && trace.len() < MAX_FAULT_EVENTS {
        control.check("goodput renewal simulation")?;
        let to_ckpt = if tau.is_finite() { tau } else { f64::INFINITY };
        let to_done = horizon_s - committed;
        let work = to_ckpt.min(to_done);
        let write = if to_ckpt <= to_done { delta } else { 0.0 };
        if next_fail <= wall + work + write {
            let node = rng.below(n_nodes.max(1));
            trace.push(FaultEvent {
                at_s: next_fail,
                kind: FaultEventKind::Failure { node },
            });
            failures += 1;
            if continuous {
                committed += (next_fail - wall).min(to_done);
            }
            wall = next_fail + fault.restart_s;
            trace.push(FaultEvent {
                at_s: wall,
                kind: FaultEventKind::Restart,
            });
            next_fail = wall + fault.time_to_failure(&mut rng, n_nodes);
            continue;
        }
        if to_done < to_ckpt {
            wall += to_done;
            committed += to_done;
            break;
        }
        wall += to_ckpt + delta;
        committed += to_ckpt;
        checkpoints += 1;
        trace.push(FaultEvent {
            at_s: wall,
            kind: FaultEventKind::Checkpoint,
        });
    }

    // Efficiency relative to the fault-free rate: committed useful work
    // happened at the degraded step rate, so fold the straggler/link
    // inflation in alongside the checkpoint–restart wall-clock waste.
    let rate = if step_s > 0.0 { ideal_step_s / step_s } else { 1.0 };
    let efficiency = if wall > 0.0 {
        (committed / wall) * rate
    } else {
        1.0
    };
    // An event-budget exhaustion is a modeling signal, not a rounding
    // artifact: surface it explicitly so downstream consumers (scenario
    // tables, goodput scoring) never mistake a truncated prefix for the
    // full horizon.
    let truncated = committed < horizon_s && trace.len() >= MAX_FAULT_EVENTS;
    Ok(GoodputSim {
        ideal_step_s,
        step_s,
        efficiency,
        wall_s: wall,
        failures,
        checkpoints,
        trace,
        truncated,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytical::evaluate;
    use crate::config::presets;
    use crate::model::inputs::{derive_inputs, EvalOptions};
    use crate::parallel::Strategy;
    use crate::util::stats::rel_diff;
    use crate::workload::dlrm::Dlrm;
    use crate::workload::transformer::Transformer;

    fn inputs(mp: usize, dp: usize) -> crate::model::inputs::ModelInputs {
        derive_inputs(
            &Transformer::t1().build(&Strategy::new(mp, dp).unwrap()).unwrap(),
            &presets::dgx_a100_1024(),
            &EvalOptions {
                ignore_capacity: true,
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn des_matches_analytical_within_5pct() {
        // The ASTRA-SIM validation band: DES total vs closed form.
        for (mp, dp) in [(64, 16), (8, 128), (2, 512), (128, 8)] {
            let inp = inputs(mp, dp);
            let a = evaluate(&inp).total();
            let d = simulate(&inp).breakdown.total();
            assert!(
                rel_diff(a, d) < 0.05,
                "MP{mp}_DP{dp}: analytical {a:.3} vs DES {d:.3}"
            );
        }
    }

    #[test]
    fn des_blocking_compute_matches_exactly() {
        // FP/IG compute is serial in both backends: equal to fp rounding.
        for (mp, dp) in [(64, 16), (8, 128)] {
            let inp = inputs(mp, dp);
            let a = evaluate(&inp);
            let d = simulate(&inp).breakdown;
            assert!(rel_diff(a.fp_compute, d.fp_compute) < 1e-9);
            assert!(rel_diff(a.ig_compute, d.ig_compute) < 1e-9);
            assert!(rel_diff(a.wg_compute, d.wg_compute) < 1e-9);
        }
    }

    #[test]
    fn des_fp_exposure_close_to_analytical() {
        // FP has no competing non-blocking traffic; exposure should agree
        // closely (identical schedules, FIFO links idle in between).
        let inp = inputs(64, 16);
        let a = evaluate(&inp);
        let d = simulate(&inp).breakdown;
        assert!(
            rel_diff(a.fp_exposed_comm, d.fp_exposed_comm) < 1e-6,
            "{} vs {}",
            a.fp_exposed_comm,
            d.fp_exposed_comm
        );
    }

    #[test]
    fn des_wg_overlap_leaves_little_exposed() {
        // Paper claim, via the event-level mechanism rather than the
        // closed-form max(): WG comm hides under the backward compute.
        let inp = inputs(8, 128);
        let d = simulate(&inp).breakdown;
        assert!(
            d.wg_exposed_comm < 0.15 * d.wg_compute,
            "exposed {} vs compute {}",
            d.wg_exposed_comm,
            d.wg_compute
        );
    }

    #[test]
    fn des_dlrm_runs() {
        let inp = derive_inputs(
            &Dlrm::dlrm_1_2t().build(64).unwrap(),
            &presets::dgx_a100_64(),
            &EvalOptions::default(),
        )
        .unwrap();
        let r = simulate(&inp);
        assert!(r.breakdown.total() > 0.0);
        assert!(r.stats.events > 0);
        let a = evaluate(&inp).total();
        assert!(rel_diff(a, r.breakdown.total()) < 0.05);
    }

    #[test]
    fn utilization_bounded() {
        let r = simulate(&inputs(64, 16));
        assert!((0.0..=1.0).contains(&r.stats.util_intra));
        assert!((0.0..=1.0).contains(&r.stats.util_inter));
        // MP64 is comm-bound: inter-pod links should be busy.
        assert!(r.stats.util_inter > 0.5, "{}", r.stats.util_inter);
    }

    #[test]
    fn deterministic() {
        let inp = inputs(8, 128);
        let a = simulate(&inp);
        let b = simulate(&inp);
        assert_eq!(a, b);
    }

    // The calendar queue must reproduce the retained heap oracle's
    // results bit-for-bit: same event order, same link arithmetic, same
    // stats (including the peak pending count — both track len the same
    // way over the same schedule/pop sequence).
    #[test]
    fn calendar_matches_heap_oracle_bitwise() {
        for (mp, dp) in [(64, 16), (8, 128), (2, 512)] {
            let inp = inputs(mp, dp);
            assert_eq!(simulate(&inp), simulate_oracle(&inp), "MP{mp}_DP{dp}");
        }
        // DP-heavy DLRM exercises the all-to-all concurrent phases.
        let inp = derive_inputs(
            &Dlrm::dlrm_1_2t().build(64).unwrap(),
            &presets::dgx_a100_64(),
            &EvalOptions::default(),
        )
        .unwrap();
        assert_eq!(simulate(&inp), simulate_oracle(&inp));
    }

    #[test]
    fn peak_events_tracks_in_flight_wg_transfers() {
        // DP-dominated 2D config: WG reductions pile up non-blocking.
        let r = simulate(&inputs(8, 128));
        assert!(r.stats.peak_events > 0, "{:?}", r.stats);
        assert!(r.stats.peak_events <= r.stats.events);
    }

    // An explicit scratch must behave exactly like the thread-local one,
    // including when reused across different shapes back to back.
    #[test]
    fn scratch_reuse_is_bitwise_stable() {
        let mut scratch = SimScratch::new();
        let a = inputs(8, 128);
        let b = inputs(64, 16);
        let pipe = pipeline_inputs(4, 8);
        let ra1 = simulate_with(&a, &mut scratch);
        let rp = simulate_with(&pipe, &mut scratch);
        let rb = simulate_with(&b, &mut scratch);
        let ra2 = simulate_with(&a, &mut scratch);
        assert_eq!(ra1, ra2);
        assert_eq!(ra1, simulate(&a));
        assert_eq!(rb, simulate(&b));
        assert_eq!(rp, simulate(&pipe));
    }

    fn pipeline_inputs(
        pp: usize,
        m: usize,
    ) -> crate::model::inputs::ModelInputs {
        derive_inputs(
            &Transformer::t1()
                .build(&Strategy::new_3d(8, 128 / pp, pp).unwrap())
                .unwrap(),
            &presets::dgx_a100_1024(),
            &EvalOptions {
                ignore_capacity: true,
                microbatches: m,
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn des_matches_analytical_in_bubble_dominated_corner() {
        // pp = 8, m = 2: the fill/drain bubble is (pp-1)/m = 3.5x the
        // steady-state work — both backends must agree on it.
        let inp = pipeline_inputs(8, 2);
        let a = evaluate(&inp);
        let d = simulate(&inp).breakdown;
        assert!(a.bubble > a.compute(), "not bubble-dominated: {a:?}");
        assert!(
            rel_diff(a.total(), d.total()) < 0.05,
            "analytical {} vs DES {}",
            a.total(),
            d.total()
        );
        assert!(rel_diff(a.bubble, d.bubble) < 0.10, "{} vs {}", a.bubble, d.bubble);
    }

    #[test]
    fn des_matches_analytical_in_comm_dominated_corner() {
        // Synthetic 4-stage pipeline whose stage spans a full pod, so the
        // boundary activations ride the slow inter-pod fabric (31.25 GB/s
        // vs 2 TB/s memory) and dwarf the compute. Both backends reduce
        // to the same boundary-FIFO recurrence, so agreement is tight.
        use crate::workload::{Layer, LayerOp, PhaseQuantities, Workload};
        let act = PhaseQuantities {
            flops: 1e6,
            u: 0.0,
            v: 0.0,
            w: 4e11, // activation_elems = 1e11 -> 2e11 boundary bytes
        };
        let tiny = PhaseQuantities {
            flops: 1e6,
            u: 0.0,
            v: 0.0,
            w: 1e3,
        };
        let w = Workload {
            name: "pipe-comm".into(),
            layers: vec![Layer::new(
                "blob",
                LayerOp::Raw([act, tiny, tiny]),
                16.0,
            )],
            mp: 8, // a stage fills the 8-GPU pod: inter-pod boundary
            dp: 1,
            pp: 4,
            nodes: 32,
            total_params: 1e6,
        };
        let inp = derive_inputs(
            &w,
            &presets::dgx_a100_64(),
            &EvalOptions {
                footprint_override: Some(1e9),
                microbatches: 4,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(inp.params.pp_inter);
        let a = evaluate(&inp);
        let d = simulate(&inp).breakdown;
        assert!(
            a.pp_exposed_comm > a.compute(),
            "not comm-dominated: {a:?}"
        );
        assert!(
            rel_diff(a.total(), d.total()) < 1e-6,
            "analytical {} vs DES {}",
            a.total(),
            d.total()
        );
    }

    #[test]
    fn des_pipeline_deterministic_and_counts_events() {
        let inp = pipeline_inputs(4, 8);
        let a = simulate(&inp);
        let b = simulate(&inp);
        assert_eq!(a, b);
        assert!(a.stats.events > 0);
        // The pipeline path precomputes its event order: no queue.
        assert_eq!(a.stats.peak_events, 0);
        assert!((0.0..=1.0).contains(&a.stats.util_intra));
        assert!((0.0..=1.0).contains(&a.stats.util_inter));
    }

    #[test]
    fn des_pipeline_wg_still_overlaps_within_stages() {
        let inp = pipeline_inputs(4, 8);
        let d = simulate(&inp).breakdown;
        assert!(
            d.wg_exposed_comm < 0.25 * d.wg_compute,
            "exposed {} vs compute {}",
            d.wg_exposed_comm,
            d.wg_compute
        );
    }

    #[test]
    fn no_overlap_mode_counts_all_wg_comm() {
        let w = Transformer::t1()
            .build(&Strategy::new(8, 128).unwrap())
            .unwrap();
        let inp = derive_inputs(
            &w,
            &presets::dgx_a100_1024(),
            &EvalOptions {
                ignore_capacity: true,
                overlap_wg: false,
                ..Default::default()
            },
        )
        .unwrap();
        let d = simulate(&inp).breakdown;
        assert!(d.wg_exposed_comm > 0.0);
        let a = evaluate(&inp);
        assert!(
            rel_diff(d.wg_exposed_comm, a.wg_exposed_comm) < 1e-6,
            "{} vs {}",
            d.wg_exposed_comm,
            a.wg_exposed_comm
        );
    }

    #[test]
    fn faulty_with_disabled_model_matches_plain_des_bitwise() {
        let inp = inputs(8, 128);
        let fault = crate::resilience::FaultModel::none();
        assert_eq!(simulate_faulty(&inp, &fault, 1024), simulate(&inp));
    }

    // The in-place param patch must be bit-identical to the historical
    // full-`ModelInputs`-clone injection path.
    #[test]
    fn faulty_no_clone_matches_clone_path_bitwise() {
        let inp = inputs(8, 128);
        let mut fault = crate::resilience::FaultModel::none();
        fault.straggler_frac = 0.02;
        fault.straggler_slowdown = 1.5;
        fault.link_degrade_frac = 0.05;
        fault.link_degrade_factor = 2.0;
        // The clone path, spelled out: clone the inputs, deflate the
        // same fields in the same order, simulate the clone.
        let mut inj = inp.clone();
        let s = fault.straggler_slowdown;
        inj.params.perf_peak /= s;
        inj.params.bw_lm /= s;
        if inj.params.bw_em > 0.0 {
            inj.params.bw_em /= s;
        }
        let f = fault.link_degrade_factor;
        inj.params.bw_intra /= f;
        inj.params.bw_inter /= f;
        assert_eq!(simulate_faulty(&inp, &fault, 1024), simulate(&inj));
    }

    #[test]
    fn faulty_stragglers_and_degraded_links_slow_the_job() {
        let inp = inputs(8, 128);
        let mut fault = crate::resilience::FaultModel::none();
        fault.straggler_frac = 0.02;
        fault.straggler_slowdown = 1.5;
        let base = simulate(&inp).breakdown.total();
        let slow = simulate_faulty(&inp, &fault, 1024).breakdown.total();
        assert!(slow > base, "straggler {slow} vs base {base}");
        fault.link_degrade_frac = 0.05;
        fault.link_degrade_factor = 2.0;
        let slower = simulate_faulty(&inp, &fault, 1024).breakdown.total();
        assert!(slower > slow, "degraded {slower} vs straggler {slow}");
    }

    #[test]
    fn goodput_sim_disabled_faults_are_free() {
        let inp = inputs(8, 128);
        let fault = crate::resilience::FaultModel::none();
        let des = simulate_goodput(&inp, &fault, 1024, 50);
        assert_eq!(des.efficiency, 1.0);
        assert_eq!(des.failures, 0);
        assert_eq!(des.checkpoints, 0);
        assert!(des.trace.is_empty());
        assert_eq!(des.step_s.to_bits(), des.ideal_step_s.to_bits());
    }

    #[test]
    fn goodput_sim_is_seed_deterministic() {
        let inp = inputs(8, 128);
        let mut fault = crate::resilience::FaultModel::default_faults();
        fault.mtbf_node_hours = 50.0;
        // Size the horizon to ~10 cluster MTBFs so failures certainly
        // land, regardless of the absolute step time.
        let step = simulate(&inp).breakdown.total();
        let steps =
            ((10.0 * fault.mtbf_cluster_s(1024)) / step).ceil() as usize;
        let a = simulate_goodput(&inp, &fault, 1024, steps);
        let b = simulate_goodput(&inp, &fault, 1024, steps);
        assert_eq!(a, b);
        let inp2 = inp.clone();
        let c = std::thread::spawn(move || {
            simulate_goodput(&inp2, &fault, 1024, steps)
        })
        .join()
        .unwrap();
        assert_eq!(a, c);
        assert!(a.failures >= 1, "expected failures, got {:?}", a);
        let mut other = fault;
        other.seed = 7;
        let d = simulate_goodput(&inp, &other, 1024, steps);
        assert_ne!(a.trace, d.trace);
    }

    // Goodput traces must be bit-identical old-queue vs new-queue — the
    // same pin CI byte-diffs via examples/des_trace.rs.
    #[test]
    fn goodput_oracle_matches_calendar_bitwise() {
        let inp = inputs(8, 128);
        let mut fault = crate::resilience::FaultModel::default_faults();
        fault.mtbf_node_hours = 50.0;
        let a = simulate_goodput(&inp, &fault, 1024, 200);
        let b = simulate_goodput_oracle(&inp, &fault, 1024, 200);
        assert_eq!(a, b);
    }

    #[test]
    fn goodput_sim_truncation_is_surfaced_not_silent() {
        let inp = inputs(8, 128);
        // MTBF orders of magnitude below the restart cycle: the model
        // predicts essentially no forward progress, so the renewal loop
        // must exhaust its event budget — and say so.
        let mut fault = crate::resilience::FaultModel::default_faults();
        fault.mtbf_node_hours = 1e-9;
        fault.restart_s = 10.0;
        let des = simulate_goodput(&inp, &fault, 1024, 50);
        assert!(des.truncated, "expected event-budget truncation");
        assert!(
            des.trace.len() >= MAX_FAULT_EVENTS - 1,
            "trace should be at the budget, got {}",
            des.trace.len()
        );
        // A healthy model completes its horizon untruncated.
        let ok = simulate_goodput(
            &inp,
            &crate::resilience::FaultModel::none(),
            1024,
            10,
        );
        assert!(!ok.truncated);
    }

    #[test]
    fn goodput_sim_stops_cooperatively_mid_renewal_loop() {
        use crate::util::cancel::RunControl;
        let inp = inputs(8, 128);
        let mut fault = crate::resilience::FaultModel::default_faults();
        fault.mtbf_node_hours = 1e-9;
        fault.restart_s = 10.0;
        let control = RunControl::unbounded().cancel_after_polls(10);
        let err =
            simulate_goodput_controlled(&inp, &fault, 1024, 50, &control)
                .unwrap_err();
        assert!(
            matches!(err, crate::error::Error::Cancelled(_)),
            "{err}"
        );
    }

    #[test]
    fn goodput_sim_matches_analytical_in_failure_dominated_corner() {
        use crate::analytical::goodput;
        use crate::resilience::{checkpoint_bandwidth, FaultModel};
        let inp = inputs(8, 128);
        let step = simulate(&inp).breakdown.total();
        let n = 1024;
        // Engineer the renewal geometry in units of the step time so the
        // statistics converge: MTBF = 200 steps, checkpoint write =
        // 2 steps, restart = 5 steps, horizon = 20k steps (~120
        // failures, ~700 checkpoints). `ignore_capacity` pins em_frac,
        // so overriding the footprint changes only checkpoint size.
        let mut fault = FaultModel::none();
        fault.mtbf_node_hours = 200.0 * step * n as f64 / 3600.0;
        fault.restart_s = 5.0 * step;
        let ckpt_bw = checkpoint_bandwidth(
            inp.params.bw_inter,
            inp.params.bw_lm,
            inp.params.bw_em,
        );
        let mut inp2 = inp.clone();
        inp2.params.footprint = 2.0 * step * ckpt_bw;
        let des = simulate_goodput(&inp2, &fault, n, 20_000);
        let g = goodput::analyze(
            &fault,
            n,
            inp2.params.footprint,
            ckpt_bw,
            &simulate(&inp2).breakdown,
        );
        assert!(des.failures > 30, "{}", des.failures);
        assert!(des.checkpoints > 100, "{}", des.checkpoints);
        assert!((0.3..1.0).contains(&des.efficiency), "{}", des.efficiency);
        assert!(
            (des.efficiency - g.efficiency).abs() < 0.06,
            "DES {} vs analytical {}",
            des.efficiency,
            g.efficiency
        );
    }

    #[test]
    fn goodput_sim_matches_analytical_in_straggler_dominated_corner() {
        use crate::analytical::goodput;
        use crate::resilience::{checkpoint_bandwidth, FaultModel};
        let inp = inputs(2, 512);
        let mut fault = FaultModel::none();
        fault.straggler_frac = 0.02;
        fault.straggler_slowdown = 1.5;
        let des = simulate_goodput(&inp, &fault, 1024, 100);
        assert_eq!(des.failures, 0);
        assert!(des.trace.is_empty());
        assert!(des.step_s > des.ideal_step_s);
        let ckpt_bw = checkpoint_bandwidth(
            inp.params.bw_inter,
            inp.params.bw_lm,
            inp.params.bw_em,
        );
        let g = goodput::analyze(
            &fault,
            1024,
            inp.params.footprint,
            ckpt_bw,
            &simulate_faulty(&inp, &fault, 1024).breakdown,
        );
        // The analytical model charges the full 1/slowdown; the DES only
        // slows compute/memory streams, not the network, so agreement is
        // loose — but both must land in the same regime.
        assert!(
            rel_diff(des.efficiency, g.efficiency) < 0.25,
            "DES {} vs analytical {}",
            des.efficiency,
            g.efficiency
        );
        assert!(des.efficiency < 1.0, "{}", des.efficiency);
    }

    // DES vs analytical on tier-annotated inputs: the engine now runs
    // the per-tier schedule natively, so blocking chains integrate the
    // tiered closed form on idle links — agreement stays in the same
    // validation band as the legacy path.
    #[test]
    fn des_matches_analytical_on_tiered_inputs() {
        let inp = derive_inputs(
            &Transformer::t1().build(&Strategy::new(8, 8).unwrap()).unwrap(),
            &presets::tiered_het_64(),
            &EvalOptions {
                ignore_capacity: true,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(inp.params.n_tiers > 0, "preset should resolve tiered");
        let a = evaluate(&inp).total();
        let r = simulate(&inp);
        assert!(
            rel_diff(a, r.breakdown.total()) < 0.05,
            "analytical {a} vs DES {}",
            r.breakdown.total()
        );
        assert_eq!(simulate(&inp), simulate_oracle(&inp));
        assert!((0.0..=1.0).contains(&r.stats.util_intra));
        assert!((0.0..=1.0).contains(&r.stats.util_inter));
    }
}
