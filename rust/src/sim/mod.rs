//! Discrete-event simulation backend — the from-scratch ASTRA-SIM-like
//! substrate (workload scheduling + collective execution on link FIFOs).

pub mod engine;
pub mod event;
pub mod link;

pub use engine::{
    simulate, simulate_faulty, simulate_goodput,
    simulate_goodput_controlled, FaultEvent, FaultEventKind, GoodputSim,
    SimResult, SimStats,
};
pub use link::TierLinks;
