//! Discrete-event simulation backend — the from-scratch ASTRA-SIM-like
//! substrate (workload scheduling + collective execution on link FIFOs).

pub mod engine;
pub mod event;
pub mod link;

pub use engine::{
    simulate, simulate_faulty, simulate_goodput,
    simulate_goodput_controlled, simulate_goodput_oracle, simulate_oracle,
    simulate_with, FaultEvent, FaultEventKind, GoodputSim, SimResult,
    SimScratch, SimStats,
};
pub use event::{CalendarQueue, Event, EventQueue, Scheduler, Slab};
pub use link::TierLinks;
