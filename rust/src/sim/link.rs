//! Link resources for the discrete-event backend.
//!
//! Each link class (intra-pod, inter-pod) is a FIFO serialization resource:
//! one transfer occupies the node's NIC for `bytes / bw + hops x lat`.
//! This models the per-node injection bandwidth that bounds symmetric
//! collectives on switched fabrics (the same abstraction ASTRA-SIM's
//! analytical network backend uses).

use super::event::SimTime;
use crate::network::chunking::LinkClass;

/// One link class's FIFO state.
#[derive(Debug, Clone, Copy)]
struct LinkState {
    /// Bandwidth, bytes/s.
    bw: f64,
    /// Per-hop latency, seconds.
    lat: f64,
    /// Time the link becomes free.
    free_at: SimTime,
    /// Total busy seconds (utilization accounting).
    busy: f64,
}

/// The node's two link classes.
#[derive(Debug, Clone)]
pub struct Links {
    intra: LinkState,
    inter: LinkState,
}

impl Links {
    /// New link set.
    pub fn new(bw_intra: f64, bw_inter: f64, lat: f64) -> Links {
        let mk = |bw: f64| LinkState {
            bw: bw.max(1.0),
            lat,
            free_at: 0.0,
            busy: 0.0,
        };
        Links {
            intra: mk(bw_intra),
            inter: mk(bw_inter),
        }
    }

    fn state(&mut self, class: LinkClass) -> &mut LinkState {
        match class {
            LinkClass::IntraPod => &mut self.intra,
            LinkClass::InterPod => &mut self.inter,
        }
    }

    /// Duration a transfer occupies the link.
    pub fn duration(&self, class: LinkClass, bytes: f64, hops: usize) -> f64 {
        let s = match class {
            LinkClass::IntraPod => &self.intra,
            LinkClass::InterPod => &self.inter,
        };
        bytes / s.bw + hops as f64 * s.lat
    }

    /// Enqueue a transfer that may not start before `ready`; returns its
    /// completion time.
    pub fn transfer(
        &mut self,
        class: LinkClass,
        ready: SimTime,
        bytes: f64,
        hops: usize,
    ) -> SimTime {
        let d = self.duration(class, bytes, hops);
        let s = self.state(class);
        let start = ready.max(s.free_at);
        s.free_at = start + d;
        s.busy += d;
        s.free_at
    }

    /// Time the class becomes free.
    pub fn free_at(&self, class: LinkClass) -> SimTime {
        match class {
            LinkClass::IntraPod => self.intra.free_at,
            LinkClass::InterPod => self.inter.free_at,
        }
    }

    /// Total busy time of a class (utilization numerator).
    pub fn busy(&self, class: LinkClass) -> f64 {
        match class {
            LinkClass::IntraPod => self.intra.busy,
            LinkClass::InterPod => self.inter.busy,
        }
    }

    /// Snapshot (free_at, busy) of both classes — used by the engine's
    /// identical-repeat folding to verify periodic steady state.
    pub fn snapshot(&self) -> [(f64, f64); 2] {
        [
            (self.intra.free_at, self.intra.busy),
            (self.inter.free_at, self.inter.busy),
        ]
    }

    /// Advance both classes by per-period deltas for `k` folded periods
    /// (exact when the per-period pattern is verified constant).
    pub fn fold(&mut self, deltas: [(f64, f64); 2], k: f64) {
        self.intra.free_at += deltas[0].0 * k;
        self.intra.busy += deltas[0].1 * k;
        self.inter.free_at += deltas[1].0 * k;
        self.inter.busy += deltas[1].1 * k;
    }
}

/// A generalization of [`Links`] to N link classes — one FIFO resource
/// per topology tier. The engine itself still runs on the two-class
/// [`Links`] (tiered inputs project onto it); `TierLinks` exists so the
/// tiered collective closed forms can be cross-checked against an
/// event-driven per-tier ring simulation (`tests/properties.rs`).
#[derive(Debug, Clone)]
pub struct TierLinks {
    tiers: Vec<LinkState>,
}

impl TierLinks {
    /// New link set, one `(bandwidth, latency)` pair per tier,
    /// innermost first.
    pub fn new(tiers: &[(f64, f64)]) -> TierLinks {
        TierLinks {
            tiers: tiers
                .iter()
                .map(|&(bw, lat)| LinkState {
                    bw: bw.max(1.0),
                    lat,
                    free_at: 0.0,
                    busy: 0.0,
                })
                .collect(),
        }
    }

    /// Number of tiers.
    pub fn n_tiers(&self) -> usize {
        self.tiers.len()
    }

    /// Duration a transfer occupies tier `t`'s link.
    pub fn duration(&self, t: usize, bytes: f64, hops: usize) -> f64 {
        let s = &self.tiers[t];
        bytes / s.bw + hops as f64 * s.lat
    }

    /// Enqueue a transfer on tier `t` that may not start before `ready`;
    /// returns its completion time (same FIFO discipline as [`Links`]).
    pub fn transfer(
        &mut self,
        t: usize,
        ready: SimTime,
        bytes: f64,
        hops: usize,
    ) -> SimTime {
        let d = self.duration(t, bytes, hops);
        let s = &mut self.tiers[t];
        let start = ready.max(s.free_at);
        s.free_at = start + d;
        s.busy += d;
        s.free_at
    }

    /// Time tier `t`'s link becomes free.
    pub fn free_at(&self, t: usize) -> SimTime {
        self.tiers[t].free_at
    }

    /// Total busy time of tier `t` (utilization numerator).
    pub fn busy(&self, t: usize) -> f64 {
        self.tiers[t].busy
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_serialization() {
        let mut l = Links::new(100.0, 10.0, 0.0);
        let t1 = l.transfer(LinkClass::IntraPod, 0.0, 100.0, 0); // 1 s
        assert_eq!(t1, 1.0);
        // Ready at 0 but link busy until 1.0.
        let t2 = l.transfer(LinkClass::IntraPod, 0.0, 200.0, 0);
        assert_eq!(t2, 3.0);
    }

    #[test]
    fn classes_are_independent() {
        let mut l = Links::new(100.0, 10.0, 0.0);
        l.transfer(LinkClass::IntraPod, 0.0, 1000.0, 0);
        let t = l.transfer(LinkClass::InterPod, 0.0, 10.0, 0);
        assert_eq!(t, 1.0);
    }

    #[test]
    fn latency_hops_add() {
        let l = Links::new(100.0, 10.0, 0.5);
        assert_eq!(l.duration(LinkClass::IntraPod, 100.0, 4), 1.0 + 2.0);
    }

    #[test]
    fn ready_gates_start() {
        let mut l = Links::new(100.0, 10.0, 0.0);
        let t = l.transfer(LinkClass::IntraPod, 5.0, 100.0, 0);
        assert_eq!(t, 6.0);
    }

    #[test]
    fn tier_links_fifo_per_tier() {
        let mut l = TierLinks::new(&[(100.0, 0.0), (10.0, 0.5)]);
        assert_eq!(l.n_tiers(), 2);
        let t1 = l.transfer(0, 0.0, 100.0, 0); // 1 s on tier 0
        assert_eq!(t1, 1.0);
        // Tier 1 is an independent resource: starts at 0, 1 s wire +
        // one hop of latency.
        let t2 = l.transfer(1, 0.0, 10.0, 1);
        assert_eq!(t2, 1.5);
        // Tier 0 serializes behind the first transfer.
        let t3 = l.transfer(0, 0.0, 200.0, 0);
        assert_eq!(t3, 3.0);
        assert_eq!(l.busy(0), 3.0);
        assert_eq!(l.free_at(1), 1.5);
    }

    #[test]
    fn busy_accounts_utilization() {
        let mut l = Links::new(100.0, 10.0, 0.0);
        l.transfer(LinkClass::IntraPod, 0.0, 100.0, 0);
        l.transfer(LinkClass::IntraPod, 10.0, 100.0, 0);
        assert_eq!(l.busy(LinkClass::IntraPod), 2.0);
    }
}
