//! Link resources for the discrete-event backend.
//!
//! Each link class (intra-pod, inter-pod) is a FIFO serialization resource:
//! one transfer occupies the node's NIC for `bytes / bw + hops x lat`.
//! This models the per-node injection bandwidth that bounds symmetric
//! collectives on switched fabrics (the same abstraction ASTRA-SIM's
//! analytical network backend uses).

use super::event::SimTime;
use crate::config::MAX_TIERS;
use crate::network::chunking::LinkClass;

/// One link class's FIFO state.
#[derive(Debug, Clone, Copy)]
struct LinkState {
    /// Bandwidth, bytes/s.
    bw: f64,
    /// Per-hop latency, seconds.
    lat: f64,
    /// Time the link becomes free.
    free_at: SimTime,
    /// Total busy seconds (utilization accounting).
    busy: f64,
}

/// The node's two link classes.
#[derive(Debug, Clone)]
pub struct Links {
    intra: LinkState,
    inter: LinkState,
}

impl Links {
    /// New link set.
    pub fn new(bw_intra: f64, bw_inter: f64, lat: f64) -> Links {
        let mk = |bw: f64| LinkState {
            bw: bw.max(1.0),
            lat,
            free_at: 0.0,
            busy: 0.0,
        };
        Links {
            intra: mk(bw_intra),
            inter: mk(bw_inter),
        }
    }

    fn state(&mut self, class: LinkClass) -> &mut LinkState {
        match class {
            LinkClass::IntraPod => &mut self.intra,
            LinkClass::InterPod => &mut self.inter,
        }
    }

    /// Duration a transfer occupies the link.
    pub fn duration(&self, class: LinkClass, bytes: f64, hops: usize) -> f64 {
        let s = match class {
            LinkClass::IntraPod => &self.intra,
            LinkClass::InterPod => &self.inter,
        };
        bytes / s.bw + hops as f64 * s.lat
    }

    /// Enqueue a transfer that may not start before `ready`; returns its
    /// completion time.
    pub fn transfer(
        &mut self,
        class: LinkClass,
        ready: SimTime,
        bytes: f64,
        hops: usize,
    ) -> SimTime {
        let d = self.duration(class, bytes, hops);
        let s = self.state(class);
        let start = ready.max(s.free_at);
        s.free_at = start + d;
        s.busy += d;
        s.free_at
    }

    /// Time the class becomes free.
    pub fn free_at(&self, class: LinkClass) -> SimTime {
        match class {
            LinkClass::IntraPod => self.intra.free_at,
            LinkClass::InterPod => self.inter.free_at,
        }
    }

    /// Total busy time of a class (utilization numerator).
    pub fn busy(&self, class: LinkClass) -> f64 {
        match class {
            LinkClass::IntraPod => self.intra.busy,
            LinkClass::InterPod => self.inter.busy,
        }
    }

    /// Snapshot (free_at, busy) of both classes — used by the engine's
    /// identical-repeat folding to verify periodic steady state.
    pub fn snapshot(&self) -> [(f64, f64); 2] {
        [
            (self.intra.free_at, self.intra.busy),
            (self.inter.free_at, self.inter.busy),
        ]
    }

    /// Advance both classes by per-period deltas for `k` folded periods
    /// (exact when the per-period pattern is verified constant).
    pub fn fold(&mut self, deltas: [(f64, f64); 2], k: f64) {
        self.intra.free_at += deltas[0].0 * k;
        self.intra.busy += deltas[0].1 * k;
        self.inter.free_at += deltas[1].0 * k;
        self.inter.busy += deltas[1].1 * k;
    }
}

/// The engine's link set: N FIFO classes in a fixed-size array —
/// class indices are topology tiers (innermost first) for tiered
/// inputs, `{0 = intra-pod, 1 = inter-pod}` for legacy two-level
/// inputs. Same per-class arithmetic as [`Links`], textually, so the
/// legacy path stays bit-identical; the fixed `MAX_TIERS` array keeps
/// construction allocation-free and makes snapshot/fold tuples `Copy`.
#[derive(Debug, Clone, Copy)]
pub(crate) struct NodeLinks {
    tiers: [LinkState; MAX_TIERS],
    n: usize,
}

impl NodeLinks {
    fn mk(bw: f64, lat: f64) -> LinkState {
        LinkState {
            bw: bw.max(1.0),
            lat,
            free_at: 0.0,
            busy: 0.0,
        }
    }

    /// Two classes (intra = 0, inter = 1), shared per-hop latency —
    /// the [`Links`]-equivalent layout for legacy inputs.
    pub(crate) fn two_level(bw_intra: f64, bw_inter: f64, lat: f64) -> NodeLinks {
        let mut tiers = [Self::mk(1.0, 0.0); MAX_TIERS];
        tiers[0] = Self::mk(bw_intra, lat);
        tiers[1] = Self::mk(bw_inter, lat);
        NodeLinks { tiers, n: 2 }
    }

    /// One class per topology tier, innermost first.
    pub(crate) fn tiered(
        tier_bw: &[f64; MAX_TIERS],
        tier_lat: &[f64; MAX_TIERS],
        n_tiers: usize,
    ) -> NodeLinks {
        let n = n_tiers.clamp(1, MAX_TIERS);
        let mut tiers = [Self::mk(1.0, 0.0); MAX_TIERS];
        for (t, (&bw, &lat)) in tiers
            .iter_mut()
            .zip(tier_bw.iter().zip(tier_lat.iter()))
            .take(n)
        {
            *t = Self::mk(bw, lat);
        }
        NodeLinks { tiers, n }
    }

    /// Number of active link classes.
    pub(crate) fn classes(&self) -> usize {
        self.n
    }

    /// Duration a transfer occupies class `c`'s link.
    pub(crate) fn duration(&self, c: usize, bytes: f64, hops: usize) -> f64 {
        let s = &self.tiers[c];
        bytes / s.bw + hops as f64 * s.lat
    }

    /// Enqueue a transfer on class `c` that may not start before
    /// `ready`; returns its completion time.
    pub(crate) fn transfer(
        &mut self,
        c: usize,
        ready: SimTime,
        bytes: f64,
        hops: usize,
    ) -> SimTime {
        let d = self.duration(c, bytes, hops);
        let s = &mut self.tiers[c];
        let start = ready.max(s.free_at);
        s.free_at = start + d;
        s.busy += d;
        s.free_at
    }

    /// Time class `c` becomes free.
    #[cfg(test)]
    pub(crate) fn free_at(&self, c: usize) -> SimTime {
        self.tiers[c].free_at
    }

    /// Total busy time of class `c` (utilization numerator).
    pub(crate) fn busy(&self, c: usize) -> f64 {
        self.tiers[c].busy
    }

    /// Snapshot (free_at, busy) of every class — the engine's
    /// identical-repeat folding compares these deltas bit-exactly.
    /// Inactive classes contribute constant zeros, so the widened
    /// array preserves the legacy two-class comparison verbatim.
    pub(crate) fn snapshot(&self) -> [(f64, f64); MAX_TIERS] {
        let mut s = [(0.0, 0.0); MAX_TIERS];
        for (out, t) in s.iter_mut().zip(self.tiers.iter()) {
            *out = (t.free_at, t.busy);
        }
        s
    }

    /// Advance every class by per-period deltas for `k` folded periods
    /// (exact when the per-period pattern is verified constant).
    pub(crate) fn fold(&mut self, deltas: [(f64, f64); MAX_TIERS], k: f64) {
        for (t, d) in self.tiers.iter_mut().zip(deltas.iter()) {
            t.free_at += d.0 * k;
            t.busy += d.1 * k;
        }
    }
}

/// A growable N-class generalization of [`Links`] kept as a *test
/// oracle*: the tiered collective closed forms are cross-checked
/// against an event-driven per-tier ring simulation built on it
/// (`tests/properties.rs`). The engine itself runs the fixed-size
/// [`NodeLinks`] natively.
#[derive(Debug, Clone)]
pub struct TierLinks {
    tiers: Vec<LinkState>,
}

impl TierLinks {
    /// New link set, one `(bandwidth, latency)` pair per tier,
    /// innermost first.
    pub fn new(tiers: &[(f64, f64)]) -> TierLinks {
        TierLinks {
            tiers: tiers
                .iter()
                .map(|&(bw, lat)| LinkState {
                    bw: bw.max(1.0),
                    lat,
                    free_at: 0.0,
                    busy: 0.0,
                })
                .collect(),
        }
    }

    /// Number of tiers.
    pub fn n_tiers(&self) -> usize {
        self.tiers.len()
    }

    /// Duration a transfer occupies tier `t`'s link.
    pub fn duration(&self, t: usize, bytes: f64, hops: usize) -> f64 {
        let s = &self.tiers[t];
        bytes / s.bw + hops as f64 * s.lat
    }

    /// Enqueue a transfer on tier `t` that may not start before `ready`;
    /// returns its completion time (same FIFO discipline as [`Links`]).
    pub fn transfer(
        &mut self,
        t: usize,
        ready: SimTime,
        bytes: f64,
        hops: usize,
    ) -> SimTime {
        let d = self.duration(t, bytes, hops);
        let s = &mut self.tiers[t];
        let start = ready.max(s.free_at);
        s.free_at = start + d;
        s.busy += d;
        s.free_at
    }

    /// Time tier `t`'s link becomes free.
    pub fn free_at(&self, t: usize) -> SimTime {
        self.tiers[t].free_at
    }

    /// Total busy time of tier `t` (utilization numerator).
    pub fn busy(&self, t: usize) -> f64 {
        self.tiers[t].busy
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_serialization() {
        let mut l = Links::new(100.0, 10.0, 0.0);
        let t1 = l.transfer(LinkClass::IntraPod, 0.0, 100.0, 0); // 1 s
        assert_eq!(t1, 1.0);
        // Ready at 0 but link busy until 1.0.
        let t2 = l.transfer(LinkClass::IntraPod, 0.0, 200.0, 0);
        assert_eq!(t2, 3.0);
    }

    #[test]
    fn classes_are_independent() {
        let mut l = Links::new(100.0, 10.0, 0.0);
        l.transfer(LinkClass::IntraPod, 0.0, 1000.0, 0);
        let t = l.transfer(LinkClass::InterPod, 0.0, 10.0, 0);
        assert_eq!(t, 1.0);
    }

    #[test]
    fn latency_hops_add() {
        let l = Links::new(100.0, 10.0, 0.5);
        assert_eq!(l.duration(LinkClass::IntraPod, 100.0, 4), 1.0 + 2.0);
    }

    #[test]
    fn ready_gates_start() {
        let mut l = Links::new(100.0, 10.0, 0.0);
        let t = l.transfer(LinkClass::IntraPod, 5.0, 100.0, 0);
        assert_eq!(t, 6.0);
    }

    #[test]
    fn tier_links_fifo_per_tier() {
        let mut l = TierLinks::new(&[(100.0, 0.0), (10.0, 0.5)]);
        assert_eq!(l.n_tiers(), 2);
        let t1 = l.transfer(0, 0.0, 100.0, 0); // 1 s on tier 0
        assert_eq!(t1, 1.0);
        // Tier 1 is an independent resource: starts at 0, 1 s wire +
        // one hop of latency.
        let t2 = l.transfer(1, 0.0, 10.0, 1);
        assert_eq!(t2, 1.5);
        // Tier 0 serializes behind the first transfer.
        let t3 = l.transfer(0, 0.0, 200.0, 0);
        assert_eq!(t3, 3.0);
        assert_eq!(l.busy(0), 3.0);
        assert_eq!(l.free_at(1), 1.5);
    }

    // The engine's NodeLinks must reproduce the legacy two-class
    // Links arithmetic bit-for-bit (same formulas, same op order).
    #[test]
    fn node_links_two_level_matches_links_bitwise() {
        let mut a = Links::new(95.0, 0.6, 0.25); // 0.6 exercises bw.max(1.0)
        let mut b = NodeLinks::two_level(95.0, 0.6, 0.25);
        let xfers = [
            (LinkClass::IntraPod, 0usize, 0.0, 103.0, 2usize),
            (LinkClass::InterPod, 1, 0.3, 7.5, 5),
            (LinkClass::IntraPod, 0, 0.1, 11.0, 0),
            (LinkClass::InterPod, 1, 2.0, 1e9, 3),
        ];
        for &(class, c, ready, bytes, hops) in &xfers {
            let ta = a.transfer(class, ready, bytes, hops);
            let tb = b.transfer(c, ready, bytes, hops);
            assert_eq!(ta.to_bits(), tb.to_bits());
        }
        let sa = a.snapshot();
        let sb = b.snapshot();
        for c in 0..2 {
            assert_eq!(sa[c].0.to_bits(), sb[c].0.to_bits());
            assert_eq!(sa[c].1.to_bits(), sb[c].1.to_bits());
        }
        // Unused classes snapshot as constant zeros, so folding them
        // is a no-op and the widened delta compare stays exact.
        assert_eq!(sb[2], (0.0, 0.0));
        assert_eq!(sb[3], (0.0, 0.0));
    }

    #[test]
    fn node_links_tiered_matches_tier_links() {
        let spec = [(100.0, 0.0), (10.0, 0.5), (2.0, 1.0)];
        let mut a = TierLinks::new(&spec);
        let mut b = NodeLinks::tiered(
            &[100.0, 10.0, 2.0, 0.0],
            &[0.0, 0.5, 1.0, 0.0],
            3,
        );
        assert_eq!(b.classes(), 3);
        for &(t, ready, bytes, hops) in
            &[(0usize, 0.0, 100.0, 0usize), (1, 0.0, 10.0, 1), (2, 0.5, 4.0, 2), (0, 0.0, 200.0, 0)]
        {
            let ta = a.transfer(t, ready, bytes, hops);
            let tb = b.transfer(t, ready, bytes, hops);
            assert_eq!(ta.to_bits(), tb.to_bits());
        }
        for t in 0..3 {
            assert_eq!(a.busy(t).to_bits(), b.busy(t).to_bits());
            assert_eq!(a.free_at(t).to_bits(), b.free_at(t).to_bits());
        }
    }

    #[test]
    fn busy_accounts_utilization() {
        let mut l = Links::new(100.0, 10.0, 0.0);
        l.transfer(LinkClass::IntraPod, 0.0, 100.0, 0);
        l.transfer(LinkClass::IntraPod, 10.0, 100.0, 0);
        assert_eq!(l.busy(LinkClass::IntraPod), 2.0);
    }
}
