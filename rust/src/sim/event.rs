//! Event scheduling for the discrete-event backend.
//!
//! Two schedulers share one `(time, seq)` FIFO total order behind the
//! [`Scheduler`] trait:
//!
//! * [`EventQueue`] — the original time-ordered binary min-heap,
//!   retained as the in-tree oracle (O(log n) per op).
//! * [`CalendarQueue`] — a bucketed calendar scheduler (Brown 1988)
//!   with O(1) amortized schedule/pop: a circular window of time
//!   buckets over `[cur, cur + nbuckets) x width`, plus a fallback
//!   heap for far-future events that drains into the window as the
//!   cursor advances.
//!
//! Determinism argument: bucket index `floor(t / width)` is a
//! weakly-monotone function of `t` (IEEE division by a positive
//! constant and `as u64` truncation both preserve order), so bucket
//! order never contradicts time order and bitwise-equal times always
//! land in the same bucket, where the linear min-scan breaks ties by
//! `seq`. Both schedulers therefore pop the exact same event sequence
//! — pinned by a randomized property test in `tests/properties.rs`.
//!
//! [`Slab`] is the free-list arena the engine stores event payload
//! records in, so events carry a `u32` index instead of an owned
//! allocation (zero-allocation steady state).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::error::{Error, Result};

/// Simulation time, seconds.
pub type SimTime = f64;

/// A scheduled event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event<T> {
    /// Fire time, seconds.
    pub time: SimTime,
    /// Monotonic sequence number — FIFO among equal-time events.
    pub seq: u64,
    /// The event payload.
    pub payload: T,
}

impl<T: PartialEq> Eq for Event<T> {}

impl<T: PartialEq> PartialOrd for Event<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T: PartialEq> Ord for Event<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

fn past_event(time: SimTime, now: SimTime) -> Error {
    Error::Config(format!(
        "event scheduled in the past: t = {time:e} s < now = {now:e} s"
    ))
}

/// The scheduling discipline shared by [`EventQueue`] and
/// [`CalendarQueue`]: a deterministic `(time, seq)` FIFO total order.
///
/// The engine core is generic over this trait so the calendar queue
/// and the retained heap oracle run the *same* code path — bit-identity
/// of simulation results is structural, not re-derived.
pub trait Scheduler<T: PartialEq> {
    /// Clear all state back to t = 0, retaining allocated capacity.
    fn reset(&mut self);

    /// Current simulation time (time of the last popped event).
    fn now(&self) -> SimTime;

    /// Schedule `payload` at absolute time `time`. Scheduling in the
    /// past (`time < now`) is a structured configuration error in all
    /// build profiles, not a `debug_assert`.
    fn schedule(&mut self, time: SimTime, payload: T) -> Result<()>;

    /// Pop the earliest event, advancing simulation time.
    fn pop(&mut self) -> Option<Event<T>>;

    /// Pop every event sharing the earliest pending (bitwise-equal)
    /// time into `out` (cleared first), in `seq` order; returns the
    /// batch size (0 when the queue is empty). Dispatching a whole
    /// timestamp at once lets the engine coalesce state updates.
    fn pop_batch(&mut self, out: &mut Vec<Event<T>>) -> usize;

    /// Pending event count.
    fn len(&self) -> usize;

    /// Whether any events remain.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Peak pending-event count observed since the last reset.
    fn peak(&self) -> usize;
}

/// Deterministic discrete-event queue over a binary min-heap.
///
/// This is the original scheduler, retained as the in-tree oracle the
/// calendar queue is pinned against (property tests, the
/// `examples/des_trace.rs` byte-diff, and `*_oracle` engine entries).
#[derive(Debug)]
pub struct EventQueue<T: PartialEq> {
    heap: BinaryHeap<Event<T>>,
    next_seq: u64,
    now: SimTime,
    peak: usize,
}

impl<T: PartialEq> EventQueue<T> {
    /// Empty queue at t = 0.
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            next_seq: 0,
            now: 0.0,
            peak: 0,
        }
    }
}

impl<T: PartialEq> Scheduler<T> for EventQueue<T> {
    fn reset(&mut self) {
        self.heap.clear();
        self.next_seq = 0;
        self.now = 0.0;
        self.peak = 0;
    }

    fn now(&self) -> SimTime {
        self.now
    }

    fn schedule(&mut self, time: SimTime, payload: T) -> Result<()> {
        if time < self.now {
            return Err(past_event(time, self.now));
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Event { time, seq, payload });
        self.peak = self.peak.max(self.heap.len());
        Ok(())
    }

    fn pop(&mut self) -> Option<Event<T>> {
        let e = self.heap.pop()?;
        self.now = e.time;
        Some(e)
    }

    fn pop_batch(&mut self, out: &mut Vec<Event<T>>) -> usize {
        out.clear();
        let Some(first) = self.pop() else {
            return 0;
        };
        let t = first.time;
        out.push(first);
        // Heap order is (time asc, seq asc), so equal-time events peel
        // off the top in FIFO order.
        while matches!(self.heap.peek(), Some(e) if e.time == t) {
            out.push(self.heap.pop().expect("peeked non-empty"));
        }
        out.len()
    }

    fn len(&self) -> usize {
        self.heap.len()
    }

    fn peak(&self) -> usize {
        self.peak
    }
}

impl<T: PartialEq> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// Default physical bucket count for [`CalendarQueue::new`].
const DEFAULT_BUCKETS: usize = 64;

/// Floor on the bucket width so degenerate time scales can't divide
/// by ~0 when mapping times to virtual buckets.
const MIN_WIDTH: f64 = 1e-12;

/// Calendar-queue scheduler: O(1) amortized schedule/pop.
///
/// Times map to *virtual* buckets `vb(t) = floor(t / width)`; the
/// physical array holds the active window `[cur_vb, cur_vb + nbuckets)`
/// at slots `vb % nbuckets`. Events past the window land in a fallback
/// min-heap (`overflow`) and drain into the window as the cursor
/// advances. Three invariants carry correctness:
///
/// 1. *Monotone bucketing* — `vb` is weakly monotone in `t`, so every
///    event outside the cursor bucket fires no earlier than every
///    event inside it, and bitwise-equal times share a bucket (exact
///    FIFO tie order comes from the in-bucket `(time, seq)` min-scan).
/// 2. *Cursor pinning* — the cursor only advances past empty buckets,
///    so a pending in-window event pins it; combined with (1), all
///    pending events for one timestamp are co-located when popped,
///    which is what makes [`Scheduler::pop_batch`] complete.
/// 3. *Past-window clamp* — an event with `t >= now` whose virtual
///    bucket already passed (possible after the cursor jumps across
///    empty regions) is clamped into the cursor bucket; by (1) it
///    can only be earlier than the rest of the window, and the
///    min-scan orders it correctly.
#[derive(Debug)]
pub struct CalendarQueue<T: PartialEq> {
    buckets: Vec<Vec<Event<T>>>,
    overflow: BinaryHeap<Event<T>>,
    /// Bucket width, seconds; 0.0 = not yet inferred (auto geometry).
    width: f64,
    /// Auto geometry: re-infer the width on first schedule after reset.
    auto_width: bool,
    /// Virtual index of the cursor bucket.
    cur_vb: u64,
    /// Events currently stored in the bucket window.
    in_window: usize,
    next_seq: u64,
    now: SimTime,
    peak: usize,
}

impl<T: PartialEq> CalendarQueue<T> {
    /// Empty queue at t = 0 with automatic geometry: the bucket width
    /// is inferred from the first scheduled event's horizon so the
    /// window roughly spans the active event range.
    pub fn new() -> Self {
        let mut q = Self::with_geometry(1.0, DEFAULT_BUCKETS);
        q.width = 0.0;
        q.auto_width = true;
        q
    }

    /// Empty queue with explicit geometry — used by the randomized
    /// property tests to exercise many widths/rotations. `width` is
    /// clamped to a positive floor, `nbuckets` to at least 1.
    pub fn with_geometry(width: f64, nbuckets: usize) -> Self {
        let nbuckets = nbuckets.max(1);
        CalendarQueue {
            buckets: (0..nbuckets).map(|_| Vec::new()).collect(),
            overflow: BinaryHeap::new(),
            width: width.max(MIN_WIDTH),
            auto_width: false,
            cur_vb: 0,
            in_window: 0,
            next_seq: 0,
            now: 0.0,
            peak: 0,
        }
    }

    /// Virtual bucket of time `t` (saturating: huge ratios collapse
    /// into the last virtual bucket, which is correct — they are
    /// "far future" either way).
    fn vb(&self, t: SimTime) -> u64 {
        let r = t / self.width;
        if r >= u64::MAX as f64 {
            u64::MAX
        } else {
            r as u64
        }
    }

    /// Drain overflow events whose virtual bucket entered the window.
    fn refill(&mut self) {
        let nb = self.buckets.len() as u64;
        let horizon = self.cur_vb.saturating_add(nb);
        while let Some(e) = self.overflow.peek() {
            let vb = self.vb(e.time);
            if vb >= horizon {
                break;
            }
            let e = self.overflow.pop().expect("peeked non-empty");
            let slot = (vb.max(self.cur_vb) % nb) as usize;
            self.buckets[slot].push(e);
            self.in_window += 1;
        }
    }
}

impl<T: PartialEq> Scheduler<T> for CalendarQueue<T> {
    fn reset(&mut self) {
        for b in &mut self.buckets {
            b.clear();
        }
        self.overflow.clear();
        if self.auto_width {
            self.width = 0.0;
        }
        self.cur_vb = 0;
        self.in_window = 0;
        self.next_seq = 0;
        self.now = 0.0;
        self.peak = 0;
    }

    fn now(&self) -> SimTime {
        self.now
    }

    fn schedule(&mut self, time: SimTime, payload: T) -> Result<()> {
        if time < self.now {
            return Err(past_event(time, self.now));
        }
        if self.width == 0.0 {
            // Auto geometry: let the window span [0, first event time]
            // — engine event horizons sit near the iteration makespan,
            // so subsequent events land in-window or one rotation out.
            self.width = (time / self.buckets.len() as f64).max(MIN_WIDTH);
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        let e = Event { time, seq, payload };
        let nb = self.buckets.len() as u64;
        let vb = self.vb(time);
        if vb >= self.cur_vb.saturating_add(nb) {
            self.overflow.push(e);
        } else {
            // vb < cur_vb (a passed bucket, time still >= now) clamps
            // into the cursor bucket — invariant 3.
            let slot = (vb.max(self.cur_vb) % nb) as usize;
            self.buckets[slot].push(e);
            self.in_window += 1;
        }
        self.peak = self.peak.max(self.len());
        Ok(())
    }

    fn pop(&mut self) -> Option<Event<T>> {
        if self.in_window == 0 && self.overflow.is_empty() {
            return None;
        }
        let nb = self.buckets.len() as u64;
        loop {
            if self.in_window == 0 {
                // Window empty: jump straight to the earliest overflow
                // event's bucket instead of stepping across the gap.
                let t = self.overflow.peek().expect("overflow non-empty").time;
                self.cur_vb = self.vb(t);
                self.refill();
                continue;
            }
            let slot = (self.cur_vb % nb) as usize;
            if self.buckets[slot].is_empty() {
                self.cur_vb = self.cur_vb.saturating_add(1);
                self.refill();
                continue;
            }
            // Linear min-scan by (time, seq): the cursor bucket is
            // small by construction, and `swap_remove` keeps it dense.
            let b = &mut self.buckets[slot];
            let mut mi = 0;
            for (i, e) in b.iter().enumerate().skip(1) {
                if (e.time, e.seq) < (b[mi].time, b[mi].seq) {
                    mi = i;
                }
            }
            let e = b.swap_remove(mi);
            self.in_window -= 1;
            self.now = e.time;
            return Some(e);
        }
    }

    fn pop_batch(&mut self, out: &mut Vec<Event<T>>) -> usize {
        out.clear();
        let Some(first) = self.pop() else {
            return 0;
        };
        let t = first.time;
        out.push(first);
        // Invariants 1 + 2: every remaining event at time `t` lives in
        // the bucket the cursor now points at. Repeated min-seq
        // extraction yields FIFO order among the ties.
        let nb = self.buckets.len() as u64;
        loop {
            let slot = (self.cur_vb % nb) as usize;
            let b = &mut self.buckets[slot];
            let mut mi = None;
            for (i, e) in b.iter().enumerate() {
                let better = match mi {
                    None => true,
                    Some(m) => e.seq < b[m].seq,
                };
                if e.time == t && better {
                    mi = Some(i);
                }
            }
            match mi {
                Some(i) => {
                    out.push(b.swap_remove(i));
                    self.in_window -= 1;
                }
                None => break,
            }
        }
        out.len()
    }

    fn len(&self) -> usize {
        self.in_window + self.overflow.len()
    }

    fn peak(&self) -> usize {
        self.peak
    }
}

impl<T: PartialEq> Default for CalendarQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// Sentinel for "no free slot" in [`Slab`]'s free list.
const SLAB_NONE: u32 = u32::MAX;

#[derive(Debug)]
enum SlabEntry<T> {
    Free { next: u32 },
    Full(T),
}

/// A free-list arena for in-flight event records: `insert` returns a
/// `u32` index the event payload carries, `remove` recycles the slot.
/// After warmup the engine's event loop allocates nothing — slots churn
/// in place.
#[derive(Debug)]
pub struct Slab<T> {
    entries: Vec<SlabEntry<T>>,
    free: u32,
    len: usize,
}

impl<T> Slab<T> {
    /// Empty slab.
    pub fn new() -> Self {
        Slab {
            entries: Vec::new(),
            free: SLAB_NONE,
            len: 0,
        }
    }

    /// Store `v`, returning its slot index.
    pub fn insert(&mut self, v: T) -> u32 {
        self.len += 1;
        if self.free != SLAB_NONE {
            let i = self.free;
            match std::mem::replace(
                &mut self.entries[i as usize],
                SlabEntry::Full(v),
            ) {
                SlabEntry::Free { next } => self.free = next,
                SlabEntry::Full(_) => unreachable!("free list points at a full slot"),
            }
            i
        } else {
            let i = self.entries.len() as u32;
            self.entries.push(SlabEntry::Full(v));
            i
        }
    }

    /// Take the value at `i` out, freeing the slot.
    ///
    /// # Panics
    /// If `i` is out of bounds or already free (an engine logic error).
    pub fn remove(&mut self, i: u32) -> T {
        match std::mem::replace(
            &mut self.entries[i as usize],
            SlabEntry::Free { next: self.free },
        ) {
            SlabEntry::Full(v) => {
                self.free = i;
                self.len -= 1;
                v
            }
            SlabEntry::Free { .. } => panic!("slab: remove of free slot {i}"),
        }
    }

    /// Borrow the value at `i`, if occupied.
    pub fn get(&self, i: u32) -> Option<&T> {
        match self.entries.get(i as usize) {
            Some(SlabEntry::Full(v)) => Some(v),
            _ => None,
        }
    }

    /// Live entry count.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether no entries are live.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Drop all entries and the free list (keeps the backing capacity).
    pub fn clear(&mut self) {
        self.entries.clear();
        self.free = SLAB_NONE;
        self.len = 0;
    }
}

impl<T> Default for Slab<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn queues() -> Vec<Box<dyn Scheduler<i32>>> {
        vec![
            Box::new(EventQueue::new()),
            Box::new(CalendarQueue::new()),
            Box::new(CalendarQueue::with_geometry(0.25, 4)),
            Box::new(CalendarQueue::with_geometry(100.0, 2)),
        ]
    }

    #[test]
    fn pops_in_time_order() {
        for mut q in queues() {
            q.schedule(3.0, 30).unwrap();
            q.schedule(1.0, 10).unwrap();
            q.schedule(2.0, 20).unwrap();
            assert_eq!(q.pop().unwrap().payload, 10);
            assert_eq!(q.pop().unwrap().payload, 20);
            assert_eq!(q.pop().unwrap().payload, 30);
            assert!(q.pop().is_none());
        }
    }

    #[test]
    fn fifo_among_equal_times() {
        for mut q in queues() {
            for i in 0..10 {
                q.schedule(1.0, i).unwrap();
            }
            for i in 0..10 {
                assert_eq!(q.pop().unwrap().payload, i);
            }
        }
    }

    #[test]
    fn now_tracks_pops() {
        for mut q in queues() {
            q.schedule(5.0, 0).unwrap();
            q.schedule(7.5, 0).unwrap();
            assert_eq!(q.now(), 0.0);
            q.pop();
            assert_eq!(q.now(), 5.0);
            q.pop();
            assert_eq!(q.now(), 7.5);
        }
    }

    #[test]
    fn interleaved_scheduling() {
        for mut q in queues() {
            q.schedule(1.0, 1).unwrap();
            let e = q.pop().unwrap();
            assert_eq!(e.payload, 1);
            q.schedule(q.now() + 0.5, 2).unwrap();
            q.schedule(q.now() + 0.25, 3).unwrap();
            assert_eq!(q.pop().unwrap().payload, 3);
            assert_eq!(q.pop().unwrap().payload, 2);
        }
    }

    #[test]
    fn len_empty_and_peak() {
        for mut q in queues() {
            assert!(q.is_empty());
            q.schedule(1.0, 0).unwrap();
            q.schedule(2.0, 0).unwrap();
            assert_eq!(q.len(), 2);
            q.pop();
            q.pop();
            assert!(q.is_empty());
            assert_eq!(q.peak(), 2);
            q.reset();
            assert_eq!(q.peak(), 0);
            assert_eq!(q.now(), 0.0);
        }
    }

    // Regression: scheduling in the past must surface a structured
    // Error::Config in release builds, not a debug-only assert.
    #[test]
    fn past_schedule_is_config_error() {
        for mut q in queues() {
            q.schedule(2.0, 1).unwrap();
            q.pop();
            let err = q.schedule(1.0, 2).unwrap_err();
            assert!(matches!(err, Error::Config(_)), "got {err:?}");
            // The queue stays usable after the rejected schedule.
            q.schedule(2.0, 3).unwrap();
            assert_eq!(q.pop().unwrap().payload, 3);
        }
    }

    #[test]
    fn pop_batch_extracts_whole_timestamp_fifo() {
        for mut q in queues() {
            q.schedule(2.0, 4).unwrap();
            q.schedule(1.0, 1).unwrap();
            q.schedule(1.0, 2).unwrap();
            q.schedule(1.0, 3).unwrap();
            let mut out = Vec::new();
            assert_eq!(q.pop_batch(&mut out), 3);
            assert_eq!(
                out.iter().map(|e| e.payload).collect::<Vec<_>>(),
                vec![1, 2, 3]
            );
            assert_eq!(q.now(), 1.0);
            assert_eq!(q.pop_batch(&mut out), 1);
            assert_eq!(out[0].payload, 4);
            assert_eq!(q.pop_batch(&mut out), 0);
        }
    }

    // The calendar window is 4 x 0.25 = 1.0 s here, so events 10 s out
    // exercise the overflow heap, the refill path, and the
    // empty-window jump.
    #[test]
    fn calendar_overflow_and_jump() {
        let mut q = CalendarQueue::with_geometry(0.25, 4);
        q.schedule(10.0, 1).unwrap();
        q.schedule(0.1, 0).unwrap();
        q.schedule(20.0, 2).unwrap();
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop().unwrap().payload, 0);
        assert_eq!(q.pop().unwrap().payload, 1);
        // Scheduling "behind" the jumped cursor but >= now clamps into
        // the cursor bucket and still pops in time order.
        q.schedule(10.5, 3).unwrap();
        assert_eq!(q.pop().unwrap().payload, 3);
        assert_eq!(q.pop().unwrap().payload, 2);
        assert!(q.pop().is_none());
    }

    #[test]
    fn slab_reuses_slots() {
        let mut s = Slab::new();
        let a = s.insert("a");
        let b = s.insert("b");
        assert_eq!(s.len(), 2);
        assert_eq!(s.remove(a), "a");
        // Freed slot is recycled LIFO.
        let c = s.insert("c");
        assert_eq!(c, a);
        assert_eq!(s.get(c), Some(&"c"));
        assert_eq!(s.remove(b), "b");
        assert_eq!(s.remove(c), "c");
        assert!(s.is_empty());
        s.clear();
        assert_eq!(s.insert("d"), 0);
    }

    #[test]
    #[should_panic(expected = "remove of free slot")]
    fn slab_double_remove_panics() {
        let mut s = Slab::new();
        let a = s.insert(1);
        s.remove(a);
        s.remove(a);
    }
}
