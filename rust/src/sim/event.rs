//! Event queue for the discrete-event backend: a time-ordered min-heap
//! with stable FIFO tie-breaking (deterministic replay).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulation time, seconds.
pub type SimTime = f64;

/// A scheduled event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event<T> {
    /// Fire time, seconds.
    pub time: SimTime,
    /// Monotonic sequence number — FIFO among equal-time events.
    pub seq: u64,
    /// The event payload.
    pub payload: T,
}

impl<T: PartialEq> Eq for Event<T> {}

impl<T: PartialEq> PartialOrd for Event<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T: PartialEq> Ord for Event<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic discrete-event queue.
#[derive(Debug)]
pub struct EventQueue<T: PartialEq> {
    heap: BinaryHeap<Event<T>>,
    next_seq: u64,
    now: SimTime,
}

impl<T: PartialEq> EventQueue<T> {
    /// Empty queue at t = 0.
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            next_seq: 0,
            now: 0.0,
        }
    }

    /// Current simulation time (time of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `payload` at absolute time `time` (>= now).
    pub fn schedule(&mut self, time: SimTime, payload: T) {
        debug_assert!(time >= self.now, "event scheduled in the past");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Event { time, seq, payload });
    }

    /// Pop the earliest event, advancing simulation time.
    pub fn pop(&mut self) -> Option<Event<T>> {
        let e = self.heap.pop()?;
        self.now = e.time;
        Some(e)
    }

    /// Whether any events remain.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Pending event count.
    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

impl<T: PartialEq> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, "c");
        q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        assert_eq!(q.pop().unwrap().payload, "a");
        assert_eq!(q.pop().unwrap().payload, "b");
        assert_eq!(q.pop().unwrap().payload, "c");
        assert!(q.pop().is_none());
    }

    #[test]
    fn fifo_among_equal_times() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.schedule(1.0, i);
        }
        for i in 0..10 {
            assert_eq!(q.pop().unwrap().payload, i);
        }
    }

    #[test]
    fn now_tracks_pops() {
        let mut q = EventQueue::new();
        q.schedule(5.0, ());
        q.schedule(7.5, ());
        assert_eq!(q.now(), 0.0);
        q.pop();
        assert_eq!(q.now(), 5.0);
        q.pop();
        assert_eq!(q.now(), 7.5);
    }

    #[test]
    fn interleaved_scheduling() {
        let mut q = EventQueue::new();
        q.schedule(1.0, 1);
        let e = q.pop().unwrap();
        assert_eq!(e.payload, 1);
        q.schedule(q.now() + 0.5, 2);
        q.schedule(q.now() + 0.25, 3);
        assert_eq!(q.pop().unwrap().payload, 3);
        assert_eq!(q.pop().unwrap().payload, 2);
    }

    #[test]
    fn len_and_empty() {
        let mut q: EventQueue<()> = EventQueue::new();
        assert!(q.is_empty());
        q.schedule(1.0, ());
        assert_eq!(q.len(), 1);
        q.pop();
        assert!(q.is_empty());
    }
}
