//! Built-in scenarios: every figure of the paper's evaluation plus the
//! case studies, embedded from the checked-in `scenarios/*.toml` files.
//!
//! The registry parses the *same bytes* that live in the repository
//! (`include_str!`), so a file edit is a registry edit — the two cannot
//! drift. `tests/scenario_roundtrip.rs` further pins the registry to the
//! legacy hand-written drivers in [`crate::coordinator::sweep`] by
//! comparing full [`crate::report::FigureData`] output cell-for-cell.

use crate::error::{Error, Result};

use super::spec::ScenarioSpec;

/// `(name, embedded TOML)` for every built-in scenario, in presentation
/// order (quickstart first, then paper order, then case studies).
const BUILTINS: &[(&str, &str)] = &[
    ("quickstart", include_str!("../../../scenarios/quickstart.toml")),
    ("fig6", include_str!("../../../scenarios/fig6.toml")),
    ("fig8a", include_str!("../../../scenarios/fig8a.toml")),
    ("fig8b", include_str!("../../../scenarios/fig8b.toml")),
    ("fig9", include_str!("../../../scenarios/fig9.toml")),
    ("fig10", include_str!("../../../scenarios/fig10.toml")),
    ("fig11", include_str!("../../../scenarios/fig11.toml")),
    ("fig12", include_str!("../../../scenarios/fig12.toml")),
    ("fig13a", include_str!("../../../scenarios/fig13a.toml")),
    ("fig13b", include_str!("../../../scenarios/fig13b.toml")),
    ("fig15", include_str!("../../../scenarios/fig15.toml")),
    (
        "ablation-collectives",
        include_str!("../../../scenarios/ablation_collectives.toml"),
    ),
    (
        "ablation-zero",
        include_str!("../../../scenarios/ablation_zero.toml"),
    ),
    (
        "memory-expansion",
        include_str!("../../../scenarios/memory_expansion.toml"),
    ),
    (
        "optimize-transformer",
        include_str!("../../../scenarios/optimize_transformer.toml"),
    ),
    (
        "optimize-dlrm",
        include_str!("../../../scenarios/optimize_dlrm.toml"),
    ),
    (
        "optimize-tiered",
        include_str!("../../../scenarios/optimize_tiered.toml"),
    ),
    (
        "pipeline-transformer",
        include_str!("../../../scenarios/pipeline_transformer.toml"),
    ),
    (
        "tier-mapping",
        include_str!("../../../scenarios/tier_mapping.toml"),
    ),
    (
        "resilience-transformer",
        include_str!("../../../scenarios/resilience_transformer.toml"),
    ),
    (
        "cluster-compare",
        include_str!("../../../scenarios/cluster_compare.toml"),
    ),
    (
        "gemm-roofline",
        include_str!("../../../scenarios/gemm_roofline.toml"),
    ),
];

/// Names of all built-in scenarios, in presentation order.
pub fn names() -> Vec<&'static str> {
    BUILTINS.iter().map(|(n, _)| *n).collect()
}

/// The embedded TOML source of a built-in scenario.
pub fn source(name: &str) -> Option<&'static str> {
    BUILTINS
        .iter()
        .find(|(n, _)| *n == name)
        .map(|(_, text)| *text)
}

/// Parse a built-in scenario by name.
pub fn get(name: &str) -> Result<ScenarioSpec> {
    let text = source(name).ok_or_else(|| {
        Error::Config(format!(
            "unknown scenario '{name}'; built-ins: {}",
            names().join(", ")
        ))
    })?;
    ScenarioSpec::parse_str(text)
        .map_err(|e| Error::Config(format!("builtin scenario '{name}': {e}")))
}

/// Parse every built-in scenario.
pub fn all() -> Result<Vec<ScenarioSpec>> {
    names().iter().map(|n| get(n)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_builtin_parses_and_is_self_named() {
        for name in names() {
            let spec = get(name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(spec.name, name, "spec name must match registry key");
        }
        assert_eq!(all().unwrap().len(), names().len());
    }

    #[test]
    fn unknown_name_lists_builtins() {
        let e = get("fig99").unwrap_err();
        assert!(e.to_string().contains("fig8a"), "{e}");
    }

    #[test]
    fn figure_ids_cover_the_paper() {
        for id in [
            "fig6", "fig8a", "fig8b", "fig9", "fig10", "fig11", "fig12",
            "fig13a", "fig13b", "fig15", "ablation-collectives",
            "ablation-zero",
        ] {
            assert!(names().contains(&id), "missing builtin {id}");
        }
    }
}
