//! Self-contained scenario-file reader: a minimal TOML subset plus JSON,
//! both lowered to [`crate::util::json::Value`] so the spec layer parses
//! one tree shape regardless of the on-disk syntax.
//!
//! The offline build vendors no `toml`/`serde` crates, so this module
//! implements the slice of TOML that scenario files need:
//!
//! * `[table]` and `[nested.table]` headers,
//! * `key = value` pairs with bare (`[A-Za-z0-9_-]+`) or basic-quoted keys,
//! * basic strings with the common escapes, booleans, integers and floats
//!   (with `_` separators and exponents), arrays (nestable, trailing comma
//!   allowed, may span lines), and inline tables `{ k = v, ... }`,
//! * `#` comments.
//!
//! Unsupported on purpose (a parse error, never a silent misread):
//! array-of-tables `[[x]]`, dotted keys in assignments, literal/multiline
//! strings, and dates. [`to_toml`] is the inverse used by
//! `comet scenario export`; [`parse_document`] auto-detects JSON input by
//! its leading `{`.

use crate::error::{Error, Result};
use crate::util::json::{self, Value};
use std::collections::BTreeMap;

/// Parse a scenario document, auto-detecting the syntax: a document whose
/// first non-whitespace byte is `{` is JSON, anything else is TOML.
pub fn parse_document(text: &str) -> Result<Value> {
    match text.trim_start().as_bytes().first() {
        Some(b'{') => json::parse(text),
        _ => parse_toml(text),
    }
}

/// Parse the TOML subset into a JSON value tree (objects all the way
/// down; TOML integers become `Value::Num`).
pub fn parse_toml(text: &str) -> Result<Value> {
    let mut p = Toml {
        bytes: text.as_bytes(),
        pos: 0,
    };
    let mut root = BTreeMap::new();
    let mut path: Vec<String> = Vec::new();
    let mut seen_headers: std::collections::HashSet<Vec<String>> =
        std::collections::HashSet::new();
    loop {
        p.skip_trivia();
        match p.peek() {
            None => break,
            Some(b'[') => {
                p.pos += 1;
                if p.peek() == Some(b'[') {
                    return Err(p.err("array-of-tables [[..]] is not supported"));
                }
                path = p.dotted_key()?;
                p.skip_inline_ws();
                p.expect(b']')?;
                p.end_line()?;
                if !seen_headers.insert(path.clone()) {
                    return Err(p.err(&format!(
                        "duplicate table header [{}]",
                        path.join(".")
                    )));
                }
                // Materialize the (possibly empty) table.
                table_at(&mut root, &path, &p)?;
            }
            _ => {
                let key = p.key()?;
                p.skip_inline_ws();
                p.expect(b'=')?;
                p.skip_inline_ws();
                let v = p.value()?;
                p.end_line()?;
                let t = table_at(&mut root, &path, &p)?;
                if t.insert(key.clone(), v).is_some() {
                    return Err(p.err(&format!("duplicate key '{key}'")));
                }
            }
        }
    }
    Ok(Value::Obj(root))
}

/// Navigate (creating as needed) to the object at `path`.
fn table_at<'a>(
    root: &'a mut BTreeMap<String, Value>,
    path: &[String],
    p: &Toml<'_>,
) -> Result<&'a mut BTreeMap<String, Value>> {
    let mut cur = root;
    for seg in path {
        let entry = cur
            .entry(seg.clone())
            .or_insert_with(|| Value::Obj(BTreeMap::new()));
        match entry {
            Value::Obj(m) => cur = m,
            _ => {
                return Err(p.err(&format!("'{seg}' is not a table")));
            }
        }
    }
    Ok(cur)
}

struct Toml<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Toml<'a> {
    fn err(&self, msg: &str) -> Error {
        // 1-based line number for human-friendly diagnostics. Every
        // malformed-input path in the reader funnels through here, so a
        // bad scenario file always reports what and where as a typed
        // [`Error::Parse`] (whose Display adds the "toml parse error:"
        // prefix) instead of panicking somewhere downstream.
        let line = 1 + self.bytes[..self.pos.min(self.bytes.len())]
            .iter()
            .filter(|&&b| b == b'\n')
            .count();
        Error::Parse(format!("{msg} (line {line})"))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    /// Skip spaces and tabs (not newlines).
    fn skip_inline_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t')) {
            self.pos += 1;
        }
    }

    /// Require nothing but optional whitespace/comment before the next
    /// newline (or EOF) — TOML allows one statement per line.
    fn end_line(&mut self) -> Result<()> {
        self.skip_inline_ws();
        if self.peek() == Some(b'#') {
            while !matches!(self.peek(), None | Some(b'\n')) {
                self.pos += 1;
            }
        }
        match self.peek() {
            None | Some(b'\n') | Some(b'\r') => Ok(()),
            _ => Err(self.err("expected end of line")),
        }
    }

    /// Skip whitespace (including newlines) and `#` comments.
    fn skip_trivia(&mut self) {
        loop {
            match self.peek() {
                Some(b' ' | b'\t' | b'\n' | b'\r') => self.pos += 1,
                Some(b'#') => {
                    while !matches!(self.peek(), None | Some(b'\n')) {
                        self.pos += 1;
                    }
                }
                _ => return,
            }
        }
    }

    fn bare_key(&mut self) -> Result<String> {
        let start = self.pos;
        while matches!(self.peek(),
            Some(c) if c.is_ascii_alphanumeric() || c == b'_' || c == b'-')
        {
            self.pos += 1;
        }
        if self.pos == start {
            return Err(self.err("expected a key"));
        }
        Ok(String::from_utf8_lossy(&self.bytes[start..self.pos]).into_owned())
    }

    fn key(&mut self) -> Result<String> {
        if self.peek() == Some(b'"') {
            self.basic_string()
        } else {
            self.bare_key()
        }
    }

    fn dotted_key(&mut self) -> Result<Vec<String>> {
        let mut segs = Vec::new();
        loop {
            self.skip_inline_ws();
            segs.push(self.key()?);
            self.skip_inline_ws();
            if self.peek() == Some(b'.') {
                self.pos += 1;
            } else {
                return Ok(segs);
            }
        }
    }

    fn basic_string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None | Some(b'\n') => {
                    return Err(self.err("unterminated string"))
                }
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'u') => {
                            self.pos += 1;
                            let mut cp = 0u32;
                            for _ in 0..4 {
                                let c = self
                                    .peek()
                                    .ok_or_else(|| self.err("truncated \\u"))?;
                                let d = (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex digit"))?;
                                cp = cp * 16 + d;
                                self.pos += 1;
                            }
                            self.pos -= 1; // re-consumed below
                            s.push(
                                char::from_u32(cp).ok_or_else(|| {
                                    self.err("bad unicode escape")
                                })?,
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(b) if b < 0x80 => {
                    s.push(b as char);
                    self.pos += 1;
                }
                Some(b) => {
                    // Multi-byte UTF-8: copy the encoded char through.
                    let len = if b >= 0xF0 {
                        4
                    } else if b >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let chunk = self
                        .bytes
                        .get(self.pos..self.pos + len)
                        .ok_or_else(|| self.err("truncated utf-8"))?;
                    s.push_str(
                        std::str::from_utf8(chunk)
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                    self.pos += len;
                }
            }
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'"') => Ok(Value::Str(self.basic_string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.inline_table(),
            Some(b't') | Some(b'f') => {
                let k = self.bare_key()?;
                match k.as_str() {
                    "true" => Ok(Value::Bool(true)),
                    "false" => Ok(Value::Bool(false)),
                    other => Err(self.err(&format!("bad value '{other}'"))),
                }
            }
            Some(c) if c == b'-' || c == b'+' || c.is_ascii_digit() => {
                self.number()
            }
            _ => Err(self.err("expected a value")),
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        loop {
            self.skip_trivia();
            match self.peek() {
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(a));
                }
                None => return Err(self.err("unterminated array")),
                _ => {}
            }
            a.push(self.value()?);
            self.skip_trivia();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {}
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn inline_table(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_inline_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_inline_ws();
            let k = self.key()?;
            self.skip_inline_ws();
            self.expect(b'=')?;
            self.skip_inline_ws();
            let v = self.value()?;
            if m.insert(k.clone(), v).is_some() {
                return Err(self.err(&format!("duplicate key '{k}'")));
            }
            self.skip_inline_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        while matches!(self.peek(),
            Some(c) if c.is_ascii_digit()
                || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E' | b'_'))
        {
            self.pos += 1;
        }
        let raw: String =
            String::from_utf8_lossy(&self.bytes[start..self.pos])
                .replace('_', "");
        raw.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err(&format!("bad number '{raw}'")))
    }
}

// ---- writer ---------------------------------------------------------------

/// Serialize a JSON value tree (the shape `ScenarioSpec::to_json`
/// produces) as TOML. Sub-objects become `[dotted.sections]`; arrays may
/// contain scalars or nested scalar arrays, not objects.
pub fn to_toml(root: &Value) -> Result<String> {
    let Value::Obj(m) = root else {
        return Err(Error::Config(
            "toml export requires a top-level object".into(),
        ));
    };
    let mut out = String::new();
    write_table(&mut out, m, &mut Vec::new())?;
    Ok(out)
}

fn write_table(
    out: &mut String,
    m: &BTreeMap<String, Value>,
    path: &mut Vec<String>,
) -> Result<()> {
    // Scalar/array keys first — anything after a [section] header would
    // otherwise be parsed as belonging to that section.
    for (k, v) in m {
        if !matches!(v, Value::Obj(_)) {
            out.push_str(&toml_key(k));
            out.push_str(" = ");
            write_scalar(out, v)?;
            out.push('\n');
        }
    }
    for (k, v) in m {
        if let Value::Obj(sub) = v {
            path.push(k.clone());
            out.push_str(&format!(
                "\n[{}]\n",
                path.iter()
                    .map(|s| toml_key(s))
                    .collect::<Vec<_>>()
                    .join(".")
            ));
            write_table(out, sub, path)?;
            path.pop();
        }
    }
    Ok(())
}

fn toml_key(k: &str) -> String {
    let bare = !k.is_empty()
        && k.bytes()
            .all(|b| b.is_ascii_alphanumeric() || b == b'_' || b == b'-');
    if bare {
        k.to_string()
    } else {
        Value::Str(k.to_string()).to_string_compact()
    }
}

fn write_scalar(out: &mut String, v: &Value) -> Result<()> {
    match v {
        Value::Obj(_) => Err(Error::Config(
            "toml export: objects inside arrays are not supported".into(),
        )),
        Value::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push_str(", ");
                }
                write_scalar(out, x)?;
            }
            out.push(']');
            Ok(())
        }
        Value::Null => Err(Error::Config(
            "toml export: null has no TOML form".into(),
        )),
        scalar => {
            out.push_str(&scalar.to_string_compact());
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_tables_and_scalars() {
        let v = parse_toml(
            "name = \"fig8a\"\ncount = 3\nratio = 2.5\nflag = true\n\
             [study]\nkind = \"grid\"\nmin_mp = 1\n\
             [study.sub]\nx = -4\n",
        )
        .unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("fig8a"));
        assert_eq!(v.get("count").unwrap().as_f64(), Some(3.0));
        assert_eq!(v.get("ratio").unwrap().as_f64(), Some(2.5));
        assert_eq!(v.get("flag"), Some(&Value::Bool(true)));
        let study = v.get("study").unwrap();
        assert_eq!(study.get("kind").unwrap().as_str(), Some("grid"));
        assert_eq!(study.get("sub").unwrap().get("x").unwrap().as_f64(), Some(-4.0));
    }

    #[test]
    fn parses_arrays_and_inline_tables() {
        let v = parse_toml(
            "xs = [250, 500, 2039]\nnames = [\"a\", \"b\",]\n\
             multi = [\n  1, # comment\n  2,\n]\n\
             inline = { a = 1, b = \"x\" }\nnested = [[1, 2], [3]]\n",
        )
        .unwrap();
        assert_eq!(
            v.get("xs").unwrap().as_arr().unwrap()[2].as_f64(),
            Some(2039.0)
        );
        assert_eq!(v.get("names").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("multi").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("inline").unwrap().get("b").unwrap().as_str(), Some("x"));
        assert_eq!(
            v.get("nested").unwrap().as_arr().unwrap()[0]
                .as_arr()
                .unwrap()
                .len(),
            2
        );
    }

    #[test]
    fn parses_numbers_with_separators_and_exponents() {
        let v = parse_toml("a = 65_536\nb = 1.2e12\nc = -3e-2\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(65536.0));
        assert_eq!(v.get("b").unwrap().as_f64(), Some(1.2e12));
        assert_eq!(v.get("c").unwrap().as_f64(), Some(-0.03));
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let v = parse_toml(
            "# leading comment\n\na = 1 # trailing\n\n# only comment\nb = 2\n",
        )
        .unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("b").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn rejects_unsupported_and_malformed() {
        assert!(parse_toml("[[points]]\nx = 1\n").is_err());
        assert!(parse_toml("a = \n").is_err());
        assert!(parse_toml("a = \"unterminated\n").is_err());
        assert!(parse_toml("a = [1, 2\n").is_err());
        assert!(parse_toml("a = 1\na = 2\n").is_err());
        assert!(parse_toml("a = tru\n").is_err());
        assert!(parse_toml("[x]\nk = 1\n[x.k.y]\nz = 2\n").is_err());
        // One statement per line: a second key=value on the same line is
        // invalid TOML and must not be silently accepted.
        assert!(parse_toml("min_mp = 2 max_mp = 8\n").is_err());
        assert!(parse_toml("[study] kind = \"grid\"\n").is_err());
        // Redefining a table header merges silently in lenient parsers;
        // here it is an error.
        assert!(parse_toml("[study]\na = 1\n[study]\nb = 2\n").is_err());
    }

    #[test]
    fn error_reports_line_number() {
        let e = parse_toml("a = 1\nb = ?\n").unwrap_err();
        assert!(e.to_string().contains("line 2"), "{e}");
    }

    #[test]
    fn document_autodetects_json() {
        let v = parse_document("  {\"a\": [1, 2]}").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        let v = parse_document("a = 1\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn writer_roundtrips() {
        let src = "flag = false\nname = \"x, with commas\"\nxs = [1, 2.5, \"s\"]\n\
                   [outer]\nk = 3\n[outer.inner]\nv = [true]\n";
        let v = parse_toml(src).unwrap();
        let emitted = to_toml(&v).unwrap();
        assert_eq!(parse_toml(&emitted).unwrap(), v);
    }

    #[test]
    fn writer_rejects_objects_in_arrays() {
        let v = parse_toml("xs = [{ a = 1 }]\n").unwrap();
        assert!(to_toml(&v).is_err());
    }
}
