//! The declarative scenario engine: COMET studies as data, not code.
//!
//! A scenario file (TOML or JSON) names a workload, a cluster, a study
//! shape (the swept axes), evaluation options, and output presentation;
//! the engine lowers it onto the same batched, cached, pooled evaluation
//! hot path the figure drivers use. Every paper figure ships as a
//! checked-in spec under `scenarios/` — the [`registry`] embeds those
//! files, so `comet scenario run fig8a` and `comet scenario run
//! scenarios/fig8a.toml` are the same study by construction — and new
//! cluster-design studies are a new `.toml` file, not new Rust.
//!
//! * [`spec`] — the [`ScenarioSpec`] data model and its strict JSON
//!   mapping (unknown keys are errors).
//! * [`parse`] — the self-contained TOML-subset reader/writer.
//! * [`run()`] — lowering onto [`crate::coordinator::Coordinator`].
//! * [`registry`] — the built-in specs (paper figures + case studies).
//!
//! ```no_run
//! use comet::coordinator::Coordinator;
//! use comet::scenario::{registry, run};
//!
//! let spec = registry::get("fig8a").unwrap();
//! let fig = run(&spec, &Coordinator::native()).unwrap();
//! println!("{}", fig.to_table());
//! ```

pub mod parse;
pub mod registry;
mod run;
pub mod spec;

pub use run::{
    cross_check_des, optimizer_for, run, run_controlled, run_optimize,
    run_optimize_exec, DesCrossCheck, ExecOverrides,
};
pub use spec::{
    collective_name, collective_of, zero_stage_of, BackendSpec, Content,
    Normalize, OptionsSpec, OutputFormat, OutputSpec, ScenarioSpec,
    StrategyAxis, Study, WorkloadSpec,
};
